"""L2: the JAX compute graphs AOT-lowered to HLO artifacts.

Two graph families:

* ``lbm_step`` — one (or ``steps`` fused) D3Q19 stream-collide update:
  the Pallas collision kernel (L1) + periodic streaming as lattice rolls.
  This is the analogue of an lbmpy-generated compute kernel: authored and
  optimized outside the framework, loaded by the rust framework at run
  time via PJRT.
* ``rve_cg`` — fixed-iteration matrix-free CG on the structured two-phase
  RVE operator: the accelerator path for FE2TI's micro solves.

Python only runs at build time (``make artifacts``); the rust coordinator
executes the lowered HLO through the PJRT CPU client.
"""

import jax
import jax.numpy as jnp

from .kernels import lattice
from .kernels import ref
from .kernels.lbm_pallas import collide_pallas


def stream(f):
    """Periodic streaming as lattice shifts; XLA fuses these to copies."""
    out = []
    for q in range(lattice.Q):
        cx, cy, cz = (int(v) for v in lattice.C[q])
        out.append(jnp.roll(f[q], shift=(cx, cy, cz), axis=(0, 1, 2)))
    return jnp.stack(out, axis=0)


def lbm_step(f, operator="srt", tau=0.6, steps=1, tile_z=8):
    """``steps`` fused stream-collide updates on a periodic box."""
    for _ in range(steps):
        f = stream(collide_pallas(f, operator=operator, tau=tau, tile_z=tile_z))
    return (f,)


def lbm_step_ref_variant(f, operator="srt", tau=0.6, steps=1):
    """Same update lowered from pure jnp (no pallas_call): XLA:CPU fuses
    this variant into far fewer kernels — the preferred artifact for CPU
    execution (§Perf L2); the Pallas variant remains the TPU-structured
    path. Numerics are identical (same oracle)."""
    for _ in range(steps):
        f = ref.lbm_step_ref(f, tau, operator)
    return (f,)


def rve_cg(b, kappa, iters=32):
    """Fixed-iteration CG; returns (x, rel_residual)."""
    x, rel = ref.rve_cg_ref(b, kappa, iters)
    return (x, rel)


def lbm_macroscopic(f):
    """Density/velocity output graph (dashboard verification panels)."""
    rho, u = ref.macroscopic(f)
    return (rho, u)
