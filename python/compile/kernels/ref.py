"""Pure-jnp oracles for the L1 Pallas kernels.

These are the ground truth the Pallas implementations are tested against
(pytest + hypothesis) and the reference the paper-level MLUP/s roofline is
computed from. Everything is plain ``jnp`` — no pallas, no custom calls.
"""

import jax.numpy as jnp
import numpy as np

from . import lattice


def macroscopic(f):
    """Density and velocity moments of a (Q, X, Y, Z) PDF field."""
    c = jnp.asarray(lattice.C, dtype=f.dtype)  # (Q, 3)
    rho = jnp.sum(f, axis=0)
    mom = jnp.einsum("qxyz,qi->ixyz", f, c)
    u = mom / rho[None]
    return rho, u


def equilibrium(rho, u):
    """D3Q19 second-order equilibrium, eq. (4) of the paper."""
    c = jnp.asarray(lattice.C, dtype=u.dtype)  # (Q, 3)
    w = jnp.asarray(lattice.W, dtype=u.dtype)  # (Q,)
    cu = jnp.einsum("qi,ixyz->qxyz", c, u)  # c_q . u
    uu = jnp.sum(u * u, axis=0)  # |u|^2
    return (
        w[:, None, None, None]
        * rho[None]
        * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * uu[None])
    )


def collide_srt_ref(f, tau):
    """Single-relaxation-time (BGK) collision, eq. (3)."""
    rho, u = macroscopic(f)
    feq = equilibrium(rho, u)
    omega = 1.0 / tau
    return f - omega * (f - feq)


def collide_trt_ref(f, tau_plus):
    """Two-relaxation-time collision with magic parameter 3/16."""
    opp = jnp.asarray(lattice.OPPOSITE)
    tau_minus = lattice.trt_tau_minus(tau_plus)
    rho, u = macroscopic(f)
    feq = equilibrium(rho, u)
    f_opp = f[opp]
    feq_opp = feq[opp]
    f_plus = 0.5 * (f + f_opp)
    f_minus = 0.5 * (f - f_opp)
    feq_plus = 0.5 * (feq + feq_opp)
    feq_minus = 0.5 * (feq - feq_opp)
    return (
        f
        - (1.0 / tau_plus) * (f_plus - feq_plus)
        - (1.0 / tau_minus) * (f_minus - feq_minus)
    )


def stream_ref(f):
    """Periodic streaming, eq. (2): push f*_q along c_q."""
    out = []
    for q in range(lattice.Q):
        cx, cy, cz = (int(v) for v in lattice.C[q])
        out.append(jnp.roll(f[q], shift=(cx, cy, cz), axis=(0, 1, 2)))
    return jnp.stack(out, axis=0)


def lbm_step_ref(f, tau, operator="srt"):
    """One full stream-collide update (periodic box)."""
    if operator == "srt":
        f_star = collide_srt_ref(f, tau)
    elif operator == "trt":
        f_star = collide_trt_ref(f, tau)
    else:
        raise ValueError(f"unknown operator {operator}")
    return stream_ref(f_star)


def init_equilibrium(shape, rho0=1.0, u0=(0.0, 0.0, 0.0), dtype=jnp.float32):
    """PDF field at equilibrium for constant density/velocity."""
    x, y, z = shape
    rho = jnp.full((x, y, z), rho0, dtype=dtype)
    u = jnp.stack(
        [jnp.full((x, y, z), u0[i], dtype=dtype) for i in range(3)], axis=0
    )
    return equilibrium(rho, u)


# ---------------------------------------------------------------------------
# RVE structured-grid operator + CG (oracle for the rve_cg artifact)
# ---------------------------------------------------------------------------

def _axis_flux_term(u, kappa, axis):
    """Flux-form contribution of one axis: symmetric, Dirichlet walls."""
    uu = jnp.moveaxis(u, axis, 0)
    ku = jnp.moveaxis(kappa, axis, 0)
    # interior faces: arithmetic-mean coefficient, flux from i to i+1
    kf = 0.5 * (ku[1:] + ku[:-1])
    flux = kf * (uu[:-1] - uu[1:])
    out = jnp.zeros_like(uu)
    out = out.at[:-1].add(flux)
    out = out.at[1:].add(-flux)
    # Dirichlet walls: face to zero-valued ghost with the cell coefficient
    out = out.at[0].add(ku[0] * uu[0])
    out = out.at[-1].add(ku[-1] * uu[-1])
    return jnp.moveaxis(out, 0, axis)


def rve_apply_ref(u, kappa):
    """7-point variable-coefficient Laplacian with Dirichlet walls.

    ``u`` is (N, N, N); ``kappa`` is the per-cell stiffness (two-phase
    microstructure: martensite inclusion in ferrite matrix). Written in
    flux form with face-averaged coefficients so the operator is SPD —
    the structured stand-in for the RVE tangent operator.
    """
    return (
        _axis_flux_term(u, kappa, 0)
        + _axis_flux_term(u, kappa, 1)
        + _axis_flux_term(u, kappa, 2)
    )


def rve_cg_ref(b, kappa, iters):
    """Fixed-iteration CG on the RVE operator. Returns (x, rel_res)."""
    x = jnp.zeros_like(b)
    r = b - rve_apply_ref(x, kappa)
    p = r
    rs = jnp.sum(r * r)
    b_norm = jnp.sqrt(jnp.sum(b * b))
    tiny = jnp.asarray(1e-30, dtype=b.dtype)
    for _ in range(iters):
        ap = rve_apply_ref(p, kappa)
        pap = jnp.sum(p * ap)
        # guard against exact convergence (0/0) under fixed iteration count
        alpha = jnp.where(pap > tiny, rs / jnp.maximum(pap, tiny), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.sum(r * r)
        beta = jnp.where(rs > tiny, rs_new / jnp.maximum(rs, tiny), 0.0)
        p = r + beta * p
        rs = rs_new
    return x, jnp.sqrt(rs) / b_norm


def two_phase_kappa(n, radius_frac=0.3, k_matrix=1.0, k_inclusion=10.0):
    """Spherical martensite inclusion in a ferrite matrix (paper §2.1.3)."""
    axis = np.arange(n) - (n - 1) / 2.0
    xx, yy, zz = np.meshgrid(axis, axis, axis, indexing="ij")
    r2 = xx**2 + yy**2 + zz**2
    inside = r2 <= (radius_frac * n) ** 2
    kappa = np.where(inside, k_inclusion, k_matrix)
    return jnp.asarray(kappa, dtype=jnp.float32)
