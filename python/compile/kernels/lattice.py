"""D3Q19 lattice constants shared by the Pallas kernel and the jnp oracle.

Velocity set ordering follows the common lbmpy/waLBerla convention:
index 0 is the rest velocity, 1..6 the axis-aligned directions, 7..18 the
diagonal (two-axis) directions. ``OPPOSITE[q]`` gives the index of ``-c_q``
(needed by TRT and by bounce-back boundaries).
"""

import numpy as np

# fmt: off
C = np.array([
    [ 0,  0,  0],
    [ 1,  0,  0], [-1,  0,  0],
    [ 0,  1,  0], [ 0, -1,  0],
    [ 0,  0,  1], [ 0,  0, -1],
    [ 1,  1,  0], [-1, -1,  0], [ 1, -1,  0], [-1,  1,  0],
    [ 1,  0,  1], [-1,  0, -1], [ 1,  0, -1], [-1,  0,  1],
    [ 0,  1,  1], [ 0, -1, -1], [ 0,  1, -1], [ 0, -1,  1],
], dtype=np.int32)
# fmt: on

Q = C.shape[0]  # 19

W = np.array(
    [1.0 / 3.0]
    + [1.0 / 18.0] * 6
    + [1.0 / 36.0] * 12,
    dtype=np.float64,
)

CS2 = 1.0 / 3.0  # speed of sound squared (lattice units)

# OPPOSITE[q] = index of -C[q]
OPPOSITE = np.array(
    [int(np.where((C == -C[q]).all(axis=1))[0][0]) for q in range(Q)],
    dtype=np.int32,
)

# TRT magic parameter Lambda = (tau_plus - 1/2)(tau_minus - 1/2)
TRT_MAGIC = 3.0 / 16.0


def trt_tau_minus(tau_plus: float) -> float:
    """Second relaxation time from the magic-parameter relation."""
    return TRT_MAGIC / (tau_plus - 0.5) + 0.5


def checks() -> None:
    assert Q == 19
    assert abs(W.sum() - 1.0) < 1e-14
    # lattice isotropy: sum_q w_q c_q c_q = cs^2 * I
    m2 = np.einsum("q,qi,qj->ij", W, C.astype(np.float64), C.astype(np.float64))
    assert np.allclose(m2, CS2 * np.eye(3), atol=1e-14)
    assert (C[OPPOSITE] == -C).all()


checks()
