"""L1: Pallas D3Q19 collision kernels (SRT and TRT).

Hardware adaptation (DESIGN.md §3): the paper's waLBerla/lbmpy kernels are
CPU/GPU sweeps where collision is the FLOP-dense part and streaming is pure
data movement. On a TPU-like memory hierarchy we tile the (Q, X, Y, Z) PDF
field along Z with a ``BlockSpec`` so one block — all 19 PDFs of an
(X, Y, TZ) slab — fits VMEM; the collision is a fused register computation
per block (moments -> equilibrium -> relaxation), reading each PDF once and
writing it once. Streaming stays in the surrounding L2 graph as lattice
shifts (XLA lowers them to copies), exactly how lbmpy separates "collide"
and "stream" pattern-wise.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the rust
runtime loads. Real-TPU lowering is a compile-only target.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import lattice

# VMEM budget check happens in aot.py; default tile covers full XY plane.
DEFAULT_TILE_Z = 8


def _moments(f_block):
    """rho, u from a (Q, X, Y, TZ) block; returns (rho, ux, uy, uz)."""
    rho = f_block[0]
    for q in range(1, lattice.Q):
        rho = rho + f_block[q]
    zeros = jnp.zeros_like(rho)
    ux, uy, uz = zeros, zeros, zeros
    for q in range(lattice.Q):
        cx, cy, cz = (float(v) for v in lattice.C[q])
        if cx:
            ux = ux + cx * f_block[q]
        if cy:
            uy = uy + cy * f_block[q]
        if cz:
            uz = uz + cz * f_block[q]
    inv_rho = 1.0 / rho
    return rho, ux * inv_rho, uy * inv_rho, uz * inv_rho


def _equilibrium_q(q, rho, ux, uy, uz, uu):
    cx, cy, cz = (float(v) for v in lattice.C[q])
    w = float(lattice.W[q])
    cu = cx * ux + cy * uy + cz * uz
    return w * rho * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * uu)


def _srt_kernel(tau, f_ref, out_ref):
    f = f_ref[...]
    rho, ux, uy, uz = _moments(f)
    uu = ux * ux + uy * uy + uz * uz
    omega = 1.0 / tau
    out = []
    for q in range(lattice.Q):
        feq = _equilibrium_q(q, rho, ux, uy, uz, uu)
        out.append(f[q] - omega * (f[q] - feq))
    out_ref[...] = jnp.stack(out, axis=0)


def _trt_kernel(tau_plus, f_ref, out_ref):
    tau_minus = lattice.trt_tau_minus(tau_plus)
    om_p = 1.0 / tau_plus
    om_m = 1.0 / tau_minus
    f = f_ref[...]
    rho, ux, uy, uz = _moments(f)
    uu = ux * ux + uy * uy + uz * uz
    feq = [
        _equilibrium_q(q, rho, ux, uy, uz, uu) for q in range(lattice.Q)
    ]
    out = []
    for q in range(lattice.Q):
        qb = int(lattice.OPPOSITE[q])
        f_p = 0.5 * (f[q] + f[qb])
        f_m = 0.5 * (f[q] - f[qb])
        feq_p = 0.5 * (feq[q] + feq[qb])
        feq_m = 0.5 * (feq[q] - feq[qb])
        out.append(f[q] - om_p * (f_p - feq_p) - om_m * (f_m - feq_m))
    out_ref[...] = jnp.stack(out, axis=0)


@functools.partial(jax.jit, static_argnames=("operator", "tau", "tile_z"))
def collide_pallas(f, operator="srt", tau=0.6, tile_z=DEFAULT_TILE_Z):
    """Collision over a (Q, X, Y, Z) field, z-tiled through 'VMEM'."""
    q, x, y, z = f.shape
    assert q == lattice.Q, f"expected {lattice.Q} PDFs, got {q}"
    tz = min(tile_z, z)
    assert z % tz == 0, f"Z={z} not divisible by tile {tz}"
    kernel = {
        "srt": functools.partial(_srt_kernel, float(tau)),
        "trt": functools.partial(_trt_kernel, float(tau)),
    }[operator]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        grid=(z // tz,),
        in_specs=[pl.BlockSpec((q, x, y, tz), lambda i: (0, 0, 0, i))],
        out_specs=pl.BlockSpec((q, x, y, tz), lambda i: (0, 0, 0, i)),
        interpret=True,
    )(f)


def vmem_bytes_per_block(x, y, tile_z, dtype_bytes=4):
    """VMEM footprint estimate: in + out block (2x) of Q PDFs."""
    return 2 * lattice.Q * x * y * tile_z * dtype_bytes


def flops_per_cell(operator="srt"):
    """Exact FLOP count of the collision per lattice cell.

    Counted from the kernel structure: moments (rho: Q-1 adds; momentum:
    ~30 mul-adds; 3 divides), uu (5), per-q equilibrium (~12 each) and
    relaxation (3 each for SRT / 10 for TRT including the +/- splits).
    Used by the likwid-like counters and the roofline projection.
    """
    base = (lattice.Q - 1) + 30 + 3 + 5 + lattice.Q * 12
    relax = lattice.Q * (3 if operator == "srt" else 10)
    return float(base + relax)
