"""AOT lowering: JAX graphs -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
Writes one ``<name>.hlo.txt`` per variant plus ``manifest.json`` describing
shapes/dtypes/constants so the rust side can build input literals without
re-deriving them.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.lbm_pallas import flops_per_cell, vmem_bytes_per_block


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lbm_variants():
    """(name, fn, example-arg shapes, metadata) for each LBM artifact.

    Three lowering families per operator (§Perf L2):
    * ``lbm_d3q19_<op>_<n>`` — the Pallas kernel (interpret=True), the
      TPU-structured reference path;
    * ``lbm_d3q19_<op>_ref_<n>`` — the same math lowered from pure jnp,
      which XLA:CPU fuses better (preferred CPU execution variant);
    * ``lbm_d3q19_srt_x4_<n>`` — four steps fused in one executable to
      amortize PJRT dispatch.
    """
    out = []
    for operator in ("srt", "trt"):
        for size in (8, 16, 32):
            tile_z = min(8, size)
            name = f"lbm_d3q19_{operator}_{size}"
            fn = functools.partial(
                model.lbm_step, operator=operator, tau=0.6, steps=1, tile_z=tile_z
            )
            spec = jax.ShapeDtypeStruct((19, size, size, size), jnp.float32)
            meta = {
                "kind": "lbm_step",
                "operator": operator,
                "shape": [19, size, size, size],
                "dtype": "f32",
                "tau": 0.6,
                "tile_z": tile_z,
                "flops_per_cell": flops_per_cell(operator),
                "vmem_bytes_per_block": vmem_bytes_per_block(size, size, tile_z),
                "cells": size**3,
            }
            out.append((name, fn, (spec,), meta))
    # pure-jnp lowering (CPU-preferred) and fused-steps variants
    for size in (16, 32):
        spec = jax.ShapeDtypeStruct((19, size, size, size), jnp.float32)
        base_meta = {
            "kind": "lbm_step",
            "operator": "srt",
            "shape": [19, size, size, size],
            "dtype": "f32",
            "tau": 0.6,
            "flops_per_cell": flops_per_cell("srt"),
            "cells": size**3,
        }
        out.append(
            (
                f"lbm_d3q19_srt_ref_{size}",
                functools.partial(model.lbm_step_ref_variant, operator="srt", tau=0.6),
                (spec,),
                dict(base_meta, lowering="jnp"),
            )
        )
        out.append(
            (
                f"lbm_d3q19_srt_x4_{size}",
                functools.partial(
                    model.lbm_step_ref_variant, operator="srt", tau=0.6, steps=4
                ),
                (spec,),
                dict(base_meta, lowering="jnp", steps=4),
            )
        )
    return out


def rve_variants():
    out = []
    for n, iters in ((8, 24), (12, 32), (16, 48)):
        name = f"rve_cg_{n}_{iters}"
        fn = functools.partial(model.rve_cg, iters=iters)
        b = jax.ShapeDtypeStruct((n, n, n), jnp.float32)
        kappa = jax.ShapeDtypeStruct((n, n, n), jnp.float32)
        meta = {
            "kind": "rve_cg",
            "shape": [n, n, n],
            "dtype": "f32",
            "iters": iters,
            "dofs": n**3,
        }
        out.append((name, fn, (b, kappa), meta))
    return out


def macroscopic_variants():
    out = []
    for size in (16,):
        name = f"lbm_macroscopic_{size}"
        spec = jax.ShapeDtypeStruct((19, size, size, size), jnp.float32)
        meta = {
            "kind": "lbm_macroscopic",
            "shape": [19, size, size, size],
            "dtype": "f32",
        }
        out.append((name, model.lbm_macroscopic, (spec,), meta))
    return out


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    p.add_argument("--only", default=None, help="substring filter on names")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # merge into an existing manifest so `--only` doesn't clobber entries
    man_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {}
    if os.path.exists(man_path):
        with open(man_path) as f:
            manifest = json.load(f)
    variants = lbm_variants() + rve_variants() + macroscopic_variants()
    for name, fn, specs, meta in variants:
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = f"{name}.hlo.txt"
        meta["hlo_chars"] = len(text)
        manifest[name] = meta
        print(f"wrote {path} ({len(text)} chars)")

    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {man_path} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
