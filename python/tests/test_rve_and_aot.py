"""L2 correctness: RVE CG graph, model shapes, and AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.aot import lbm_variants, macroscopic_variants, rve_variants, to_hlo_text
from compile.kernels import ref


def test_rve_operator_is_spd_like():
    n = 6
    kappa = ref.two_phase_kappa(n)
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(n, n, n)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, n, n)), jnp.float32)
    au = ref.rve_apply_ref(u, kappa)
    av = ref.rve_apply_ref(v, kappa)
    # symmetry: <Au, v> == <u, Av>
    assert float(jnp.sum(au * v)) == pytest.approx(float(jnp.sum(u * av)), rel=1e-4)
    # positive definiteness on a random vector
    assert float(jnp.sum(au * u)) > 0.0


def test_rve_cg_converges():
    n = 8
    kappa = ref.two_phase_kappa(n)
    b = jnp.ones((n, n, n), jnp.float32)
    x, rel = ref.rve_cg_ref(b, kappa, iters=60)
    assert float(rel) < 1e-4
    r = b - ref.rve_apply_ref(x, kappa)
    assert float(jnp.max(jnp.abs(r))) < 1e-3


def test_rve_two_phase_kappa_geometry():
    n = 16
    kappa = np.asarray(ref.two_phase_kappa(n, radius_frac=0.3))
    assert kappa[n // 2, n // 2, n // 2] == 10.0  # inclusion center
    assert kappa[0, 0, 0] == 1.0  # matrix corner
    frac = (kappa == 10.0).mean()
    # sphere of r=0.3n in unit cube: 4/3 pi 0.027 ≈ 0.113
    assert 0.05 < frac < 0.2


@settings(max_examples=8, deadline=None)
@given(
    n=st.sampled_from([4, 6, 8]),
    k_inc=st.floats(min_value=1.0, max_value=100.0),
)
def test_rve_cg_reduces_residual_hypothesis(n, k_inc):
    kappa = ref.two_phase_kappa(n, k_inclusion=k_inc)
    b = jnp.ones((n, n, n), jnp.float32)
    _, rel8 = ref.rve_cg_ref(b, kappa, iters=8)
    _, rel32 = ref.rve_cg_ref(b, kappa, iters=32)
    assert float(rel32) <= float(rel8) + 1e-6
    assert np.isfinite(float(rel32))


def test_model_lbm_step_shapes_and_physics():
    f = ref.init_equilibrium((8, 8, 8), u0=(0.03, 0.0, 0.0))
    (out,) = model.lbm_step(f, operator="srt", tau=0.6, steps=2, tile_z=4)
    assert out.shape == f.shape
    # advecting uniform equilibrium stays equilibrium
    np.testing.assert_allclose(np.asarray(out), np.asarray(f), atol=1e-5)


def test_model_macroscopic():
    f = ref.init_equilibrium((4, 4, 4), rho0=1.1, u0=(0.01, 0.02, 0.03))
    rho, u = model.lbm_macroscopic(f)
    np.testing.assert_allclose(np.asarray(rho), 1.1, atol=1e-5)
    np.testing.assert_allclose(np.asarray(u[0]), 0.01, atol=1e-5)


@pytest.mark.parametrize(
    "variant",
    [lbm_variants()[0], rve_variants()[0], macroscopic_variants()[0]],
    ids=lambda v: v[0],
)
def test_aot_lowering_produces_hlo_text(variant):
    name, fn, specs, meta = variant
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule"), f"{name}: not HLO text"
    assert "ENTRY" in text
    assert len(text) > 500


def test_variant_registry_complete():
    names = [v[0] for v in lbm_variants() + rve_variants() + macroscopic_variants()]
    assert len(names) == len(set(names))
    assert any("srt" in n for n in names)
    assert any("trt" in n for n in names)
    assert any("rve_cg" in n for n in names)
    for name, _, _, meta in lbm_variants():
        assert meta["flops_per_cell"] > 0
        if "vmem_bytes_per_block" in meta:  # pallas-lowered variants only
            assert meta["vmem_bytes_per_block"] < 16 * 2**20, "block must fit VMEM"
        else:
            assert meta.get("lowering") == "jnp", name


def test_ref_variant_matches_pallas_lowering():
    """The CPU-preferred jnp lowering and the Pallas lowering are the same
    update (§Perf L2): one step on a perturbed field must agree."""
    import numpy as np
    f = ref.init_equilibrium((8, 8, 8), u0=(0.02, -0.01, 0.0))
    noise = np.random.default_rng(1).normal(0, 1e-3, f.shape)
    f = f + jnp.asarray(noise, jnp.float32)
    (a,) = model.lbm_step(f, operator="srt", tau=0.6, steps=1, tile_z=4)
    (b,) = model.lbm_step_ref_variant(f, operator="srt", tau=0.6, steps=1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-7)


def test_fused_steps_equal_sequential_steps():
    import numpy as np
    f = ref.init_equilibrium((8, 8, 8), u0=(0.01, 0.02, 0.0))
    (a,) = model.lbm_step_ref_variant(f, operator="srt", tau=0.7, steps=4)
    b = f
    for _ in range(4):
        (b,) = model.lbm_step_ref_variant(b, operator="srt", tau=0.7, steps=1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
