"""L1 correctness: Pallas collision kernels vs the pure-jnp oracle.

This is the core build-time correctness signal (DESIGN.md §4): the HLO
artifacts the rust runtime executes embed exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lattice, ref
from compile.kernels.lbm_pallas import (
    collide_pallas,
    flops_per_cell,
    vmem_bytes_per_block,
)


def perturbed_field(shape, seed=0, amp=1e-3, u0=(0.02, -0.01, 0.005)):
    f = ref.init_equilibrium(shape, rho0=1.0, u0=u0)
    noise = np.random.default_rng(seed).normal(0.0, amp, f.shape)
    return f + jnp.asarray(noise, dtype=jnp.float32)


@pytest.mark.parametrize("operator", ["srt", "trt"])
@pytest.mark.parametrize("shape", [(8, 8, 8), (16, 8, 8), (8, 4, 16)])
def test_collide_matches_ref(operator, shape):
    f = perturbed_field(shape)
    got = collide_pallas(f, operator=operator, tau=0.6, tile_z=4)
    want = (
        ref.collide_srt_ref(f, 0.6)
        if operator == "srt"
        else ref.collide_trt_ref(f, 0.6)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-7)


@pytest.mark.parametrize("operator", ["srt", "trt"])
def test_collision_conserves_mass_and_momentum(operator):
    f = perturbed_field((8, 8, 8), seed=3)
    out = collide_pallas(f, operator=operator, tau=0.8)
    rho0, u0 = ref.macroscopic(f)
    rho1, u1 = ref.macroscopic(out)
    np.testing.assert_allclose(np.asarray(rho1), np.asarray(rho0), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(u1 * rho1[None]), np.asarray(u0 * rho0[None]), atol=1e-5
    )


def test_equilibrium_is_fixed_point():
    f = ref.init_equilibrium((8, 8, 8), rho0=1.2, u0=(0.05, 0.0, -0.02))
    out = collide_pallas(f, operator="srt", tau=0.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(f), atol=1e-5)


def test_tiling_is_transparent():
    f = perturbed_field((8, 8, 16), seed=5)
    full = collide_pallas(f, operator="srt", tau=0.6, tile_z=16)
    tiled = collide_pallas(f, operator="srt", tau=0.6, tile_z=2)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), atol=1e-7)


def test_indivisible_tile_rejected():
    f = perturbed_field((4, 4, 6))
    with pytest.raises(AssertionError):
        collide_pallas(f, operator="srt", tau=0.6, tile_z=4)


@settings(max_examples=15, deadline=None)
@given(
    nx=st.sampled_from([4, 8]),
    nz=st.sampled_from([4, 8, 12]),
    tau=st.floats(min_value=0.52, max_value=1.8),
    operator=st.sampled_from(["srt", "trt"]),
)
def test_collide_hypothesis_sweep(nx, nz, tau, operator):
    """Property sweep over shapes and relaxation times."""
    f = perturbed_field((nx, 4, nz), seed=nx * 100 + nz)
    got = collide_pallas(f, operator=operator, tau=tau, tile_z=4)
    want = (
        ref.collide_srt_ref(f, tau)
        if operator == "srt"
        else ref.collide_trt_ref(f, tau)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert bool(jnp.all(jnp.isfinite(got)))


def test_trt_equals_srt_when_taus_match():
    """With tau_minus == tau_plus TRT degenerates to SRT; our magic-
    parameter TRT must NOT equal SRT for generic tau (sanity that the two
    operators genuinely differ)."""
    f = perturbed_field((8, 8, 8), seed=9)
    srt = collide_pallas(f, operator="srt", tau=0.6)
    trt = collide_pallas(f, operator="trt", tau=0.6)
    assert np.max(np.abs(np.asarray(srt) - np.asarray(trt))) > 1e-9


def test_stream_is_permutation():
    f = perturbed_field((6, 6, 6), seed=2)
    g = ref.stream_ref(f)
    # streaming only moves values around: sorted multiset is preserved
    np.testing.assert_allclose(
        np.sort(np.asarray(f).ravel()), np.sort(np.asarray(g).ravel()), atol=0
    )


def test_full_step_conserves_mass():
    f = perturbed_field((8, 8, 8), seed=4)
    g = ref.lbm_step_ref(f, 0.6, "srt")
    assert abs(float(jnp.sum(g) - jnp.sum(f))) < 1e-3


def test_lattice_constants():
    assert lattice.Q == 19
    assert abs(lattice.W.sum() - 1.0) < 1e-14
    assert (lattice.C[lattice.OPPOSITE] == -lattice.C).all()
    assert lattice.trt_tau_minus(1.0) == pytest.approx(3.0 / 16.0 / 0.5 + 0.5)


def test_flops_and_vmem_models():
    assert flops_per_cell("trt") > flops_per_cell("srt") > 200
    # 32x32 XY plane, tile_z=8, f32: 2 * 19 * 32*32*8 * 4 B ≈ 1.24 MB << 16 MiB VMEM
    assert vmem_bytes_per_block(32, 32, 8) < 16 * 2**20
