//! Property-based tests over randomized inputs (seeded `util::rng` —
//! the vendored crate set has no proptest, so generation is explicit and
//! every case is reproducible from its seed).

use cbench::apps::walberla::collision::{collide_cell, CollisionOp};
use cbench::apps::walberla::fslbm::FsBlock;
use cbench::apps::walberla::lattice::{d3q19, d3q27};
use cbench::ci::{substitute_vars, CiJob};
use cbench::cluster::nodes::catalogue;
use cbench::coordinator::campaign::{
    run_campaign_with, CampaignConfig, CampaignProject, ProjectKind,
};
use cbench::coordinator::{CbSystem, PreparedJob};
use cbench::regress::detector::evaluate_policy_run_scoped;
use cbench::regress::Detector;
use cbench::sched::{JobOutcome, SimScheduler, SubmitSpec};
use cbench::sparse::{cg, gmres, Csr, Ilu0, SparseLu, Work};
use cbench::tsdb::{Db, Point, Query};
use cbench::util::json::Json;
use cbench::util::rng::Rng;
use std::collections::BTreeMap;

/// Random SPD matrix: diagonally-dominant with random symmetric pattern.
fn random_spd(rng: &mut Rng, n: usize, extra: usize) -> Csr {
    let mut t = Vec::new();
    let mut diag = vec![1.0f64; n];
    for _ in 0..extra {
        let i = rng.below(n);
        let j = rng.below(n);
        if i == j {
            continue;
        }
        let v = rng.range(-1.0, 1.0);
        t.push((i, j, v));
        t.push((j, i, v));
        diag[i] += v.abs();
        diag[j] += v.abs();
    }
    for (i, d) in diag.iter().enumerate() {
        t.push((i, i, d + 0.5));
    }
    Csr::from_triplets(n, &t)
}

#[test]
fn prop_direct_and_iterative_solvers_agree() {
    // 20 random SPD systems: LU, GMRES+ILU and CG must produce the same x
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let n = 20 + rng.below(60);
        let a = random_spd(&mut rng, n, 3 * n);
        let b: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();

        let lu = SparseLu::factor(&a).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let mut w = Work::default();
        let x_lu = lu.solve(&b, &mut w);
        let ilu = Ilu0::factor(&a).unwrap();
        let x_gm = gmres(&a, &b, Some(&ilu), 1e-12, 30, 5000);
        let x_cg = cg(&a, &b, 1e-12, 5000);
        assert!(x_gm.converged && x_cg.converged, "seed {seed}");
        for i in 0..n {
            assert!(
                (x_lu[i] - x_gm.x[i]).abs() < 1e-6,
                "seed {seed} lu vs gmres at {i}: {} vs {}",
                x_lu[i],
                x_gm.x[i]
            );
            assert!(
                (x_lu[i] - x_cg.x[i]).abs() < 1e-6,
                "seed {seed} lu vs cg at {i}"
            );
        }
        assert!(a.residual_norm(&x_lu, &b) < 1e-8, "seed {seed}");
    }
}

#[test]
fn prop_permutation_preserves_lu_solution() {
    for seed in 100..110u64 {
        let mut rng = Rng::new(seed);
        let n = 30 + rng.below(30);
        let a = random_spd(&mut rng, n, 2 * n);
        let b: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let x = SparseLu::factor(&a).unwrap().solve(&b, &mut Work::default());

        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let ap = a.permute(&perm);
        let bp: Vec<f64> = perm.iter().map(|&o| b[o]).collect();
        let xp = SparseLu::factor(&ap).unwrap().solve(&bp, &mut Work::default());
        for (new, &old) in perm.iter().enumerate() {
            assert!((xp[new] - x[old]).abs() < 1e-8, "seed {seed}");
        }
    }
}

#[test]
fn prop_collision_invariants_random_states() {
    // random positive PDF states: mass/momentum conserved, result finite
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let lat = if seed % 2 == 0 { d3q19() } else { d3q27() };
        let op = CollisionOp::all()[rng.below(4)];
        let tau = rng.range(0.51, 2.0);
        let mut f: Vec<f64> = (0..lat.q).map(|q| lat.w[q] * rng.range(0.5, 1.5)).collect();
        let (rho0, u0) = lat.moments(&f);
        let mut scratch = vec![0.0; lat.q];
        collide_cell(op, &lat, tau, &mut f, &mut scratch);
        let (rho1, u1) = lat.moments(&f);
        assert!((rho0 - rho1).abs() < 1e-10, "seed {seed} {op:?} rho");
        for i in 0..3 {
            assert!(
                (rho0 * u0[i] - rho1 * u1[i]).abs() < 1e-10,
                "seed {seed} {op:?} mom"
            );
        }
        assert!(f.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn prop_fslbm_mass_conserved_random_waves() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed);
        let mut b = FsBlock::new(8 + rng.below(6), 8 + rng.below(6), 4);
        b.gravity = rng.range(1e-6, 3e-4);
        b.init_gravity_wave(rng.range(0.05, 0.2));
        let m0 = b.total_mass();
        for _ in 0..10 {
            b.step(CollisionOp::Srt);
        }
        let m1 = b.total_mass();
        assert!(
            (m1 - m0).abs() / m0 < 0.03,
            "seed {seed}: mass {m0} -> {m1}"
        );
        let (g, i, l) = b.state_counts();
        assert!(g > 0 && i > 0 && l > 0, "seed {seed}: {g}/{i}/{l}");
    }
}

#[test]
fn prop_tsdb_query_partitions_points() {
    // group-by over any tag partitions exactly the matching points
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let mut db = Db::new();
        let nodes = ["a", "b", "c"];
        let total = 50 + rng.below(100);
        for i in 0..total {
            db.insert(
                Point::new("m", i as i64)
                    .tag("node", nodes[rng.below(3)])
                    .tag("op", if rng.uniform() < 0.5 { "x" } else { "y" })
                    .field("v", rng.range(0.0, 10.0)),
            );
        }
        let series = Query::new("m", "v").group_by(&["node", "op"]).run(&db);
        let sum: usize = series.iter().map(|s| s.points.len()).sum();
        assert_eq!(sum, total, "seed {seed}");
        // every series is time-ordered
        for s in &series {
            assert!(s.points.windows(2).all(|w| w[0].0 <= w[1].0));
        }
    }
}

#[test]
fn prop_line_protocol_roundtrip_random_points() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed);
        let weird = ["plain", "with space", "co,mma", "eq=uals", "back\\slash"];
        let mut p = Point::new(weird[rng.below(weird.len())], rng.next_u64() as i64 / 2);
        for _ in 0..1 + rng.below(4) {
            let k = format!("t{}", rng.below(5));
            p.tags.insert(k, weird[rng.below(weird.len())].to_string());
        }
        for _ in 0..1 + rng.below(4) {
            let k = format!("f{}", rng.below(5));
            p.fields.insert(k, rng.gauss(0.0, 100.0));
        }
        let q = Point::parse_line(&p.to_line()).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(p, q, "seed {seed}");
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.gauss(0.0, 1000.0) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(100))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..rng.below(4) {
                    m.insert(format!("k{}", rng.below(10)), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed);
        let doc = random_json(&mut rng, 3);
        for text in [doc.to_string_compact(), doc.to_string_pretty()] {
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
            assert_eq!(back, doc, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------
// sched:: invariants — randomized rosters over the real Testcluster
// node set, with maintenance windows and conservative backfill
// ---------------------------------------------------------------------

#[derive(Clone)]
struct RosterJob {
    host: String,
    dur: f64,
    tl_min: f64,
    prio: i64,
    owner: String,
}

fn testcluster_hosts() -> Vec<String> {
    catalogue()
        .into_iter()
        .filter(|n| n.testcluster)
        .map(|n| n.host.to_string())
        .collect()
}

/// Random roster: every job is submitted at t=0 (durations are
/// start-time-independent, so replays are exact). `distinct_prio` makes
/// the dispatch order independent of the fair-share usage ledger — the
/// precondition of the no-delay property (a).
fn random_roster(rng: &mut Rng, hosts: &[String], n: usize, distinct_prio: bool) -> Vec<RosterJob> {
    let mut prios: Vec<i64> = (0..n as i64).collect();
    rng.shuffle(&mut prios);
    (0..n)
        .map(|i| {
            let dur = 1.0 + rng.range(0.0, 120.0);
            // mostly generous limits, sometimes tight (exercises Timeout)
            let tl_secs = if rng.uniform() < 0.15 {
                dur * rng.range(0.3, 0.9)
            } else {
                dur * rng.range(1.1, 4.0) + rng.range(0.0, 200.0)
            };
            RosterJob {
                host: hosts[rng.below(hosts.len())].clone(),
                dur,
                tl_min: tl_secs / 60.0,
                prio: if distinct_prio { prios[i] } else { rng.below(4) as i64 },
                owner: format!("repo-{}", rng.below(3)),
            }
        })
        .collect()
}

/// Random *closed*, non-overlapping maintenance windows per node.
fn random_windows(rng: &mut Rng, hosts: &[String]) -> Vec<(String, f64, f64)> {
    let mut out = Vec::new();
    for h in hosts {
        let mut t = 0.0;
        for _ in 0..rng.below(3) {
            let from = t + rng.range(10.0, 150.0);
            let to = from + rng.range(20.0, 300.0);
            out.push((h.clone(), from, to));
            t = to;
        }
    }
    out
}

fn build_and_run(
    roster: &[RosterJob],
    windows: &[(String, f64, f64)],
    backfill: bool,
    slots: usize,
) -> SimScheduler {
    let nodes: Vec<_> = catalogue().into_iter().filter(|n| n.testcluster).collect();
    let mut s = SimScheduler::with_slots(nodes, slots);
    s.set_backfill(backfill);
    for (h, a, b) in windows {
        s.maintenance(h, *a, *b).unwrap();
    }
    for (i, j) in roster.iter().enumerate() {
        let dur = j.dur;
        s.submit(
            SubmitSpec::new(&format!("j{i}"), &j.host)
                .timelimit(j.tl_min)
                .priority(j.prio)
                .owner(&j.owner),
            Box::new(move |_n, _t| JobOutcome {
                duration: dur,
                stdout: String::new(),
                exit_code: 0,
            }),
        )
        .unwrap();
    }
    s.run_until_idle();
    s
}

#[test]
fn prop_backfill_never_delays_any_start_under_distinct_priorities() {
    // (a) with a usage-independent dispatch order (distinct priorities,
    // everything submitted at t=0), conservative backfill may only move
    // starts EARLIER: the shadow job starts exactly when it would have
    // with backfill off, and no job starts later
    for seed in 0..25u64 {
        let mut rng = Rng::new(1000 + seed);
        let hosts = testcluster_hosts();
        let n = 30 + rng.below(30);
        let roster = random_roster(&mut rng, &hosts, n, true);
        let windows = random_windows(&mut rng, &hosts);
        let on = build_and_run(&roster, &windows, true, 1);
        let off = build_and_run(&roster, &windows, false, 1);
        for (a, b) in on.jobs().zip(off.jobs()) {
            assert_eq!(a.spec.name, b.spec.name, "seed {seed}: same submission order");
            let (sa, sb) = (a.start_time.unwrap(), b.start_time.unwrap());
            assert!(
                sa <= sb + 1e-9,
                "seed {seed}: backfill delayed `{}` from {sb} to {sa}",
                a.spec.name
            );
        }
        assert!(
            on.now() <= off.now() + 1e-9,
            "seed {seed}: backfill-on makespan {} vs off {}",
            on.now(),
            off.now()
        );
    }
}

#[test]
fn prop_no_job_starts_inside_a_drain_window() {
    // (b) for any roster — fair-share ties and all — no start lands
    // inside a window, and no started job's [start, end) interval
    // touches one (conservative limit rule + timeout cap)
    for seed in 0..25u64 {
        let mut rng = Rng::new(2000 + seed);
        let hosts = testcluster_hosts();
        let n = 25 + rng.below(35);
        let roster = random_roster(&mut rng, &hosts, n, false);
        let windows = random_windows(&mut rng, &hosts);
        let s = build_and_run(&roster, &windows, true, 1);
        for j in s.jobs() {
            let (Some(start), Some(end)) = (j.start_time, j.end_time) else {
                panic!("seed {seed}: `{}` never ran (finite windows)", j.spec.name);
            };
            for (h, from, to) in &windows {
                if *h != j.spec.nodelist {
                    continue;
                }
                assert!(
                    !(start >= *from && start < *to),
                    "seed {seed}: `{}` started at {start} inside [{from}, {to})",
                    j.spec.name
                );
                assert!(
                    end <= *from + 1e-9 || start >= *to - 1e-9,
                    "seed {seed}: `{}` ran [{start}, {end}) across [{from}, {to})",
                    j.spec.name
                );
            }
        }
    }
}

#[test]
fn prop_per_node_concurrency_never_exceeds_slots() {
    // (c) at every timeline instant the number of running jobs per node
    // is at most the slot count — for 1 and 2 slots per node
    for seed in 0..20u64 {
        for slots in [1usize, 2] {
            let mut rng = Rng::new(3000 + seed);
            let hosts = testcluster_hosts();
            let n = 30 + rng.below(30);
            let roster = random_roster(&mut rng, &hosts, n, false);
            let windows = random_windows(&mut rng, &hosts);
            let s = build_and_run(&roster, &windows, true, slots);
            let mut per_node: BTreeMap<&str, Vec<(f64, f64)>> = BTreeMap::new();
            for j in s.jobs() {
                if let (Some(a), Some(b)) = (j.start_time, j.end_time) {
                    per_node.entry(j.spec.nodelist.as_str()).or_default().push((a, b));
                }
            }
            for (host, mut spans) in per_node {
                // sweep: +1 at start, -1 at end; ends sort before starts
                // at the same instant (a slot frees before the next start)
                let mut events: Vec<(f64, i32)> = Vec::new();
                spans.sort_by(|x, y| x.0.total_cmp(&y.0));
                for (a, b) in &spans {
                    events.push((*a, 1));
                    events.push((*b, -1));
                }
                events.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
                let mut running = 0i32;
                for (t, d) in events {
                    running += d;
                    assert!(
                        running <= slots as i32,
                        "seed {seed}: {running} concurrent jobs on {host} at t={t} (slots={slots})"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_drained_backfilled_rosters_replay_byte_identical() {
    // (d) determinism: identical submissions + identical windows replay
    // to byte-identical timelines with backfill enabled
    for seed in 0..15u64 {
        let build = |seed: u64| {
            let mut rng = Rng::new(4000 + seed);
            let hosts = testcluster_hosts();
            let roster = random_roster(&mut rng, &hosts, 40, false);
            let windows = random_windows(&mut rng, &hosts);
            let s = build_and_run(&roster, &windows, true, 1);
            s.timeline()
        };
        let t1 = build(seed);
        let t2 = build(seed);
        assert!(!t1.is_empty());
        assert_eq!(t1, t2, "seed {seed}: timeline must replay byte-identically");
    }
}

#[test]
fn backfill_strictly_improves_the_gap_heavy_roster() {
    // the acceptance number: a constructed gap-heavy roster (hour-limit
    // head jobs blocked by a window, minute-limit jobs that fit the gap)
    // must have a strictly lower makespan with backfill on
    let build = |backfill: bool| {
        let nodes: Vec<_> = catalogue().into_iter().filter(|n| n.testcluster).collect();
        let mut s = SimScheduler::new(nodes);
        s.set_backfill(backfill);
        s.maintenance("icx36", 100.0, 1000.0).unwrap();
        s.maintenance("rome1", 150.0, 900.0).unwrap();
        let job = |dur: f64| -> cbench::sched::Payload {
            Box::new(move |_n, _t| JobOutcome {
                duration: dur,
                stdout: String::new(),
                exit_code: 0,
            })
        };
        // heads: hour-scale limits, cross the windows
        s.submit(SubmitSpec::new("h1", "icx36").timelimit(60.0).priority(9), job(200.0))
            .unwrap();
        s.submit(SubmitSpec::new("h2", "rome1").timelimit(60.0).priority(9), job(150.0))
            .unwrap();
        // gap fillers: minute-scale limits
        for (i, host) in [(0, "icx36"), (1, "icx36"), (2, "rome1")] {
            s.submit(
                SubmitSpec::new(&format!("s{i}"), host).timelimit(0.5).priority(1),
                job(20.0),
            )
            .unwrap();
        }
        s.run_until_idle();
        (s.now(), s.jobs().filter(|j| j.backfilled).count())
    };
    let (on, backfilled) = build(true);
    let (off, none) = build(false);
    assert_eq!(none, 0);
    assert!(backfilled >= 3, "all gap fillers backfill: {backfilled}");
    assert!(
        on < off,
        "gap-heavy roster: backfill-on makespan {on} must be strictly below {off}"
    );
    // exact numbers: icx36 off = 1000+200+2x20 = 1240, on = 1200;
    // rome1 off = 900+150+20 = 1070, on = 1050
    assert_eq!(off, 1240.0);
    assert_eq!(on, 1200.0);
}

// ---------------------------------------------------------------------
// tsdb:: tail(n) pushdown — equivalence with full-history scans
// ---------------------------------------------------------------------

#[test]
fn prop_tail_pushdown_matches_full_history_on_interleaved_tenants() {
    // multi-repo fixtures with interleaved trigger timestamps: as long as
    // a repo's history fits the policy's lookback window, the bounded
    // tail(n) pushdown must judge exactly like a full-history scan —
    // same findings, same evaluated-series fingerprints, same numbers
    let stock = Detector::with_default_policies();
    let policy = stock
        .policies
        .iter()
        .find(|p| p.name == "lbm-mlups")
        .unwrap()
        .clone();
    let lookback = policy.baseline_window + policy.recent_window;
    for seed in 0..15u64 {
        let mut rng = Rng::new(5000 + seed);
        let repos = 2 + rng.below(3); // 2..=4 tenants
        let pushes = 2 + rng.below(lookback - 1); // 2..=lookback
        let mut db = Db::new();
        let mut ts = 0i64;
        for push in 0..pushes {
            for r in 0..repos {
                ts += 1_000_000_000; // interleaved per-repo trigger times
                for node in ["icx36", "rome1"] {
                    let base = 1000.0 + 50.0 * r as f64;
                    // repo-0 regresses on icx36 at the last push
                    let v = if push + 1 == pushes && r == 0 && node == "icx36" {
                        base * 0.7
                    } else {
                        base * (1.0 + rng.range(-0.004, 0.004))
                    };
                    db.insert(
                        Point::new("lbm", ts)
                            .tag("repo", &format!("repo-{r}"))
                            .tag("node", node)
                            .tag("case", "uniformgridcpu")
                            .tag("collision_op", "srt")
                            .field("mlups", v),
                    );
                }
            }
        }
        for r in 0..repos {
            let repo = format!("repo-{r}");
            let scope = [("repo", repo.as_str())];
            let (f_tail, mut e_tail) = evaluate_policy_run_scoped(&policy, &db, &scope);
            let mut full = policy.clone();
            full.scan_full_history = true;
            let (f_full, mut e_full) = evaluate_policy_run_scoped(&full, &db, &scope);
            e_tail.sort();
            e_full.sort();
            assert_eq!(e_tail, e_full, "seed {seed} repo {r}: evaluated sets differ");
            assert_eq!(f_tail.len(), f_full.len(), "seed {seed} repo {r}");
            for (a, b) in f_tail.iter().zip(f_full.iter()) {
                assert_eq!(a.series, b.series, "seed {seed}");
                assert_eq!(a.current, b.current, "seed {seed}");
                assert_eq!(a.rel_change, b.rel_change, "seed {seed}");
                assert_eq!(a.confidence, b.confidence, "seed {seed}");
            }
            if r == 0 {
                assert!(
                    f_tail.iter().any(|f| f.series.contains("node=icx36")),
                    "seed {seed}: the planted repo-0 drop must be found"
                );
            }
        }
    }
}

#[test]
fn tail_scan_cap_boundary_is_inclusive_at_n_times_32() {
    // the filtered tail(n) walk visits at most n x 32 distinct global
    // timestamps (TAIL_SCAN_SLACK): a tenant whose last upload sits
    // exactly at the cap is still found; one step beyond is stale
    let build = |foreign: i64| {
        let mut db = Db::new();
        db.insert(Point::new("m", 0).tag("repo", "old").field("v", 1.0));
        for ts in 1..=foreign {
            db.insert(Point::new("m", ts).tag("repo", "live").field("v", ts as f64));
        }
        Query::new("m", "v")
            .where_tag("repo", "old")
            .group_by(&["repo"])
            .tail(1)
            .run(&db)
    };
    // 31 foreign triggers + the matching one = 32 distinct timestamps:
    // exactly the n=1 cap — still visible
    let series = build(31);
    assert_eq!(series.len(), 1);
    assert_eq!(series[0].points, vec![(0, 1.0)]);
    // 32 foreign triggers push the match to the 33rd timestamp: stale
    assert!(build(32).is_empty());
}

#[test]
fn prop_range_pushdown_matches_linear_filter() {
    // points_in_range (binary search) must select exactly the points a
    // linear timestamp filter would, for arbitrary interleaved inserts
    for seed in 0..20u64 {
        let mut rng = Rng::new(6000 + seed);
        let mut db = Db::new();
        let n = 50 + rng.below(150);
        for _ in 0..n {
            db.insert(
                Point::new("m", rng.below(300) as i64)
                    .tag("s", if rng.uniform() < 0.5 { "a" } else { "b" })
                    .field("v", rng.range(0.0, 10.0)),
            );
        }
        let (a, b) = {
            let x = rng.below(300) as i64;
            let y = rng.below(300) as i64;
            (x.min(y), x.max(y))
        };
        let fast: Vec<(i64, f64)> = Query::new("m", "v")
            .range(a, b)
            .run(&db)
            .first()
            .map(|s| s.points.clone())
            .unwrap_or_default();
        let slow: Vec<(i64, f64)> = db
            .points_iter("m")
            .filter(|p| p.ts >= a && p.ts <= b)
            .map(|p| (p.ts, p.fields["v"]))
            .collect();
        assert_eq!(fast, slow, "seed {seed}: range [{a}, {b}]");
    }
}

#[test]
fn prop_ci_substitution_never_panics_and_is_idempotent_without_vars() {
    let empty: BTreeMap<String, String> = BTreeMap::new();
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let tokens = ["${A}", "$", "{", "}", "x", "€", "${", "}${B", "\n"];
        let s: String = (0..rng.below(20))
            .map(|_| tokens[rng.below(tokens.len())])
            .collect();
        // without variables the text must come back unchanged
        assert_eq!(substitute_vars(&s, &empty), s, "seed {seed}: {s:?}");
    }
}

#[test]
fn prop_sharded_queries_match_single_shard_linear_scan() {
    // shard-boundary equivalence: the same random inserts land in a
    // many-shard store (tiny span) and an effectively unsharded one
    // (huge span); points_in_range, tail(n) and grouped Query runs must
    // agree exactly, including ranges that hit shard edges dead on
    for seed in 0..15u64 {
        let mut rng = Rng::new(7000 + seed);
        let span = 8 + rng.below(24) as i64; // 8..31 ticks per shard
        let mut sharded = Db::with_shard_span(span);
        let mut single = Db::with_shard_span(i64::MAX / 4);
        let n = 80 + rng.below(200);
        for _ in 0..n {
            let p = Point::new("m", rng.below(400) as i64 - 50)
                .tag("s", if rng.uniform() < 0.5 { "a" } else { "b" })
                .field("v", rng.range(0.0, 10.0));
            sharded.insert(p.clone());
            single.insert(p);
        }
        assert!(sharded.shards("m").len() > 1, "seed {seed}: span {span} must shard");
        assert_eq!(sharded.len(), single.len());
        // full iteration order identical
        let all_a: Vec<String> = sharded.points_iter("m").map(|p| p.to_line()).collect();
        let all_b: Vec<String> = single.points_iter("m").map(|p| p.to_line()).collect();
        assert_eq!(all_a, all_b, "seed {seed}");
        // ranges: random plus exact shard-boundary multiples of the span
        let mut ranges: Vec<(i64, i64)> = (0..6)
            .map(|_| {
                let x = rng.below(400) as i64 - 50;
                let y = rng.below(400) as i64 - 50;
                (x.min(y), x.max(y))
            })
            .collect();
        ranges.push((0, span - 1));
        ranges.push((span, 2 * span));
        ranges.push((span - 1, span));
        for (a, b) in ranges {
            let fast: Vec<i64> = sharded
                .points_in_range("m", Some(a), Some(b))
                .map(|p| p.ts)
                .collect();
            let slow: Vec<i64> = single
                .points_in_range("m", Some(a), Some(b))
                .map(|p| p.ts)
                .collect();
            assert_eq!(fast, slow, "seed {seed}: range [{a}, {b}]");
        }
        // tail(n) pushdown and grouped runs agree across the layouts
        for n in [1usize, 3, 10] {
            assert_eq!(
                sharded.tail_start_ts("m", n),
                single.tail_start_ts("m", n),
                "seed {seed}: tail bound n={n}"
            );
            let qa = Query::new("m", "v").group_by(&["s"]).tail(n).run(&sharded);
            let qb = Query::new("m", "v").group_by(&["s"]).tail(n).run(&single);
            assert_eq!(qa, qb, "seed {seed}: tail({n}) query");
            let fa = Query::new("m", "v").where_tag("s", "a").tail(n).run(&sharded);
            let fb = Query::new("m", "v").where_tag("s", "a").tail(n).run(&single);
            assert_eq!(fa, fb, "seed {seed}: filtered tail({n}) query");
        }
    }
}

// ---------------------------------------------------------------------
// regress::state — incremental detection ≡ full tail re-query
// ---------------------------------------------------------------------

fn dump_findings(f: &[cbench::regress::Finding]) -> Vec<String> {
    f.iter()
        .map(|f| {
            format!(
                "{}|{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{}|{:?}|{}",
                f.policy,
                f.series,
                f.baseline.mean,
                f.baseline.sd,
                f.current,
                f.rel_change,
                f.p_welch,
                f.p_mann_whitney,
                f.p_z,
                f.change_ts,
                f.suspect_commit,
                f.confidence
            )
        })
        .collect()
}

#[test]
fn prop_incremental_detector_state_matches_full_requery_across_campaigns() {
    // randomized multi-repo "campaigns": repositories upload at
    // interleaved trigger timestamps, some skip rounds (staleness paths),
    // one round collects under tuned regress.* config (state
    // invalidation + rebuild), one round plants a real drop, fieldless
    // points advance the global distinct-timestamp walk, and the state is
    // saved/reloaded mid-sequence. After EVERY collect the incremental
    // path must equal the full tail re-query byte for byte — findings,
    // evaluated-series fingerprints, and the alert books each feeds.
    use cbench::coordinator::{detector_with_config, BenchConfig};
    use cbench::regress::{AlertBook, DetectorState};
    let stock = Detector::with_default_policies();
    for seed in 0..12u64 {
        let mut rng = Rng::new(9000 + seed);
        let repos = 2 + rng.below(3); // 2..=4 tenants
        let rounds = 4 + rng.below(10);
        let drop_round = 2 + rng.below(rounds - 2);
        let cfg_round = 1 + rng.below(rounds);
        let mut db = Db::new();
        let mut st = DetectorState::new();
        let mut book_inc = AlertBook::new();
        let mut book_req = AlertBook::new();
        let mut ts = 0i64;
        for round in 0..rounds {
            for r in 0..repos {
                // co-tenants sometimes skip a push; the regressing repo
                // never does (the planted drop must stay observable)
                if r > 0 && rng.uniform() < 0.2 {
                    continue;
                }
                ts += 1_000_000_000;
                let repo = format!("repo-{r}");
                for node in ["icx36", "rome1"] {
                    let base = 1000.0 + 40.0 * r as f64;
                    let v = if round >= drop_round && r == 0 && node == "icx36" {
                        base * 0.75
                    } else {
                        base * (1.0 + rng.range(-0.003, 0.003))
                    };
                    db.insert(
                        Point::new("lbm", ts)
                            .tag("repo", &repo)
                            .tag("node", node)
                            .tag("case", "uniformgridcpu")
                            .tag("collision_op", "srt")
                            .tag("commit", &format!("c{r}x{round}"))
                            .field("mlups", v),
                    );
                }
                if rng.uniform() < 0.3 {
                    // a point without the watched field: part of the
                    // measurement's distinct-timestamp walk, invisible to
                    // the policies
                    db.insert(Point::new("lbm", ts).tag("repo", &repo).field("other", 1.0));
                }
                // this collect's detector: one round runs under tuned
                // regress.* overrides (state must invalidate + rebuild,
                // twice: into the override and back out of it)
                let det = if round + 1 == cfg_round {
                    detector_with_config(
                        &stock,
                        &BenchConfig::parse(
                            "regress.lbm-mlups.baseline_window = 4\n\
                             regress.lbm-mlups.min_rel_change = 0.2\n",
                        ),
                    )
                } else {
                    stock.clone()
                };
                let scope = [("repo", repo.as_str())];
                st.sync(&det, &db);
                let (f_inc, e_inc) = st.detect_measurement_scoped(&det, &db, "lbm", &scope);
                let (f_req, e_req) = det.detect_measurement_scoped(&db, "lbm", &scope);
                assert_eq!(e_inc, e_req, "seed {seed} round {round} repo {r}: evaluated sets");
                assert_eq!(
                    dump_findings(&f_inc),
                    dump_findings(&f_req),
                    "seed {seed} round {round} repo {r}: findings"
                );
                let s_inc = book_inc.ingest(&f_inc, &e_inc, ts);
                let s_req = book_req.ingest(&f_req, &e_req, ts);
                assert_eq!(s_inc, s_req, "seed {seed} round {round} repo {r}: ingest");
            }
            if round == rounds / 2 {
                // mid-campaign restart: persisted state must resume
                // incrementally with no behavioural difference
                let p = std::env::temp_dir().join(format!("cbench_state_prop_{seed}.json"));
                st.save(&p).unwrap();
                st = DetectorState::load(&p).unwrap();
                std::fs::remove_file(&p).ok();
            }
        }
        assert_eq!(
            book_inc.to_json().to_string_pretty(),
            book_req.to_json().to_string_pretty(),
            "seed {seed}: alert books must be byte-identical"
        );
        assert!(
            !book_req.alerts.is_empty(),
            "seed {seed}: the planted drop must have opened an alert"
        );
    }
}

#[test]
fn prop_compaction_keeps_retained_raw_queries_unchanged() {
    // compaction round-trip: queries whose window lies entirely inside
    // the retained raw range return exactly what they returned before
    // the pass; older shards collapse to per-series rollups
    for seed in 0..10u64 {
        let mut rng = Rng::new(8000 + seed);
        let span = 10i64;
        let mut db = Db::with_shard_span(span);
        let horizon = 120 + rng.below(80) as i64;
        for ts in 0..horizon {
            // series `a` reports at every tick (so every shard's max-ts
            // index sits at its last tick and the compaction watermark
            // falls exactly where the arithmetic below assumes); b and c
            // are spotty like real co-tenants
            for s in ["a", "b", "c"] {
                if s == "a" || rng.uniform() < 0.9 {
                    db.insert(
                        Point::new("m", ts)
                            .tag("s", s)
                            .field("v", rng.range(1.0, 2.0)),
                    );
                }
            }
        }
        let retain = 35i64;
        let watermark = horizon - 1 - retain;
        // any shard whose points all predate the watermark gets compacted;
        // the raw region provably starts at the first kept shard boundary
        let raw_from = (watermark.div_euclid(span)) * span;
        let before: Vec<String> = db
            .points_in_range("m", Some(raw_from), None)
            .map(|p| p.to_line())
            .collect();
        let before_tail = Query::new("m", "v").group_by(&["s"]).tail(8).run(&db);
        let rep = db.compact(retain);
        assert!(rep.shards_compacted > 0, "seed {seed}: old shards must compact");
        assert!(rep.points_after < rep.points_before, "seed {seed}");
        let after: Vec<String> = db
            .points_in_range("m", Some(raw_from), None)
            .map(|p| p.to_line())
            .collect();
        assert_eq!(before, after, "seed {seed}: retained raw range changed");
        // detector-style trailing-window queries see raw points only
        let after_tail = Query::new("m", "v").group_by(&["s"]).tail(8).run(&db);
        assert_eq!(before_tail, after_tail, "seed {seed}: tail window changed");
        // compacted shards carry exactly one rollup per live series
        let first = &db.shards("m")[0];
        assert!(first.is_compacted(), "seed {seed}");
        assert!(first.len() <= 3, "seed {seed}: at most one rollup per series");
        for p in first.points() {
            assert_eq!(p.tags["rollup"], "mean", "seed {seed}");
            assert!(p.fields["rollup_n"] >= 1.0, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// par:: hot-path parallelism: a campaign run must produce byte-identical
// artifacts for any worker count. Both tests below mutate the process-global
// par:: thread knob, so they serialize on PAR_LOCK (cargo runs the tests in
// one binary concurrently).
// ---------------------------------------------------------------------------

static PAR_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Toy job matrix in the campaign harness shape: fixed durations keep the
/// simulated schedule deterministic; the metric steps down after round 2 so
/// the per-series detector fan-out has non-constant history to evaluate.
fn par_toy_jobs(tag: &str, round: usize, spec: &[(&str, f64, usize)]) -> Vec<PreparedJob> {
    let mut jobs = Vec::new();
    for (host, dur, count) in spec {
        for i in 0..*count {
            let dur = *dur;
            let mlups = if round >= 3 { dur * 0.5 } else { dur } + i as f64 * 0.01;
            jobs.push(PreparedJob {
                ci: CiJob::new(&format!("{tag}-{host}-{i}"), "benchmark").var("HOST", host),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: dur,
                    stdout: format!("TAG case=toy\nTAG collision_op=srt\nMETRIC mlups={mlups}\n"),
                    exit_code: 0,
                }),
            });
        }
    }
    jobs
}

/// Runs one randomized two-repo campaign under `threads` workers and returns
/// every artifact the CLI can persist: the simulated timeline, a full TSDB
/// dump, the alert book and trace JSON, and the byte content of a saved
/// manifest-layout store. The config is a pure function of `seed`, so the
/// serial and parallel runs see identical inputs.
fn campaign_artifacts(
    threads: usize,
    seed: u64,
) -> (String, String, String, String, Vec<(String, String)>) {
    cbench::par::set_threads(threads);
    let mut rng = Rng::new(seed);
    let cfg = CampaignConfig {
        pushes: 3 + rng.below(2),
        inject_at: 0,
        penalty: 0.0,
        seed,
        backfill: rng.below(2) == 0,
        drains: if rng.below(2) == 0 {
            vec![("icx36".to_string(), 50.0, 400.0)]
        } else {
            Vec::new()
        },
        streaming: rng.below(2) == 0,
        incremental: rng.below(2) == 0,
        select: Default::default(),
    };
    let mut cb = CbSystem::new();
    let mut projects = vec![
        CampaignProject::new("alpha", ProjectKind::Walberla),
        CampaignProject::new("beta", ProjectKind::Walberla).priority(1),
    ];
    let mut rounds: BTreeMap<String, usize> = BTreeMap::new();
    run_campaign_with(&mut cb, &mut projects, &cfg, |p, _commit| {
        let r = rounds.entry(p.name.clone()).or_insert(0);
        *r += 1;
        if p.name == "alpha" {
            par_toy_jobs("a", *r, &[("icx36", 10.0, 3), ("rome1", 5.0, 2)])
        } else {
            par_toy_jobs("b", *r, &[("rome1", 20.0, 2), ("skylakesp2", 8.0, 2)])
        }
    })
    .unwrap();

    let timeline = cb.scheduler.timeline();
    let mut dump = String::new();
    let measurements: Vec<String> = cb.db.measurements().cloned().collect();
    for m in &measurements {
        for p in cb.db.points_iter(m) {
            dump.push_str(&p.to_line());
            dump.push('\n');
        }
    }
    let alerts = cb.alerts.to_json().to_string_pretty();
    let trace = cb.trace.to_json().to_string_pretty();

    // persist under the manifest layout (parallel per-shard writes) and read
    // every file back for byte comparison
    let dir = std::env::temp_dir().join(format!(
        "cbench_par_prop_{}_{}_{}",
        std::process::id(),
        seed,
        threads
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    cb.db.save_report(&dir).unwrap();
    let mut files: Vec<(String, String)> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (
                e.file_name().to_string_lossy().into_owned(),
                std::fs::read_to_string(e.path()).unwrap(),
            )
        })
        .collect();
    files.sort();
    let _ = std::fs::remove_dir_all(&dir);
    (timeline, dump, alerts, trace, files)
}

#[test]
fn prop_parallel_equals_serial() {
    // ISSUE 7 acceptance: timelines, TSDB contents, saved manifest stores,
    // alert books and traces are byte-identical for --threads 1 vs 4 across
    // randomized drained / streaming / incremental two-repo campaigns.
    let _g = PAR_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for seed in 0..4u64 {
        let serial = campaign_artifacts(1, seed);
        let parallel = campaign_artifacts(4, seed);
        assert!(!serial.0.is_empty() && !serial.1.is_empty(), "seed {seed}");
        assert!(serial.4.len() >= 2, "seed {seed}: store must have manifest + shards");
        assert_eq!(serial.0, parallel.0, "seed {seed}: timeline diverged");
        assert_eq!(serial.1, parallel.1, "seed {seed}: TSDB dump diverged");
        assert_eq!(serial.2, parallel.2, "seed {seed}: alert book diverged");
        assert_eq!(serial.3, parallel.3, "seed {seed}: trace diverged");
        assert_eq!(serial.4, parallel.4, "seed {seed}: saved store diverged");
    }
    cbench::par::set_threads(0);
}

#[test]
fn prop_lp_batch_parse_matches_serial_and_roundtrip() {
    // The zero-copy batched parser must agree with the per-line parser on
    // the PR 1 escape / negative-timestamp / extreme-value fixtures and on
    // randomized round-tripped points, serial and parallel alike.
    let _g = PAR_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let fixtures = [
        "weird\\ name,t1=co\\,mma,t2=eq\\=uals v=1 42",
        "m,host=a v=0.5,w=-3e-7 -1234567890",
        "m value=1.7976931348623157e308 1",
        "m value=5e-324 2",
        "m value=-1234567890.123456 3",
        "m value=0 4",
        "back\\\\slash,k=v\\ w x=9 -5",
    ];
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed);
        let weird = ["plain", "with space", "co,mma", "eq=uals", "back\\slash"];
        let mut originals: Vec<Point> = Vec::new();
        for _ in 0..600 {
            // 600 lines > lp::PAR_MIN_LINES, so the chunked path engages
            let ts = rng.next_u64() as i64 / 2 - i64::MAX / 4;
            let mut p = Point::new(weird[rng.below(weird.len())], ts);
            for _ in 0..1 + rng.below(3) {
                let k = format!("t{}", rng.below(5));
                p.tags.insert(k, weird[rng.below(weird.len())].to_string());
            }
            for _ in 0..1 + rng.below(3) {
                p.fields.insert(format!("f{}", rng.below(5)), rng.gauss(0.0, 100.0));
            }
            originals.push(p);
        }
        let mut text = String::new();
        for f in &fixtures {
            text.push_str(f);
            text.push('\n');
        }
        text.push_str("# comment line\n\n");
        for p in &originals {
            text.push_str(&p.to_line());
            text.push('\n');
        }

        let mut expect: Vec<Point> =
            fixtures.iter().map(|l| Point::parse_line(l).unwrap()).collect();
        expect.extend(originals.iter().cloned());

        cbench::par::set_threads(1);
        let serial = cbench::tsdb::lp::parse_lines(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        cbench::par::set_threads(4);
        let parallel = cbench::tsdb::lp::parse_lines(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        cbench::par::set_threads(0);
        assert_eq!(serial, expect, "seed {seed}: serial batch != per-line parse");
        assert_eq!(parallel, expect, "seed {seed}: parallel batch != per-line parse");

        // a malformed line anywhere in the batch rejects the whole batch with
        // the first (input-order) error, same text as the per-line parser
        let bad = format!("{text}m value=nope 9\n");
        cbench::par::set_threads(4);
        let err = cbench::tsdb::lp::parse_lines(&bad).unwrap_err();
        cbench::par::set_threads(0);
        assert!(err.contains("bad field value `nope`"), "seed {seed}: {err}");
    }
}
