//! Property-based tests over randomized inputs (seeded `util::rng` —
//! the vendored crate set has no proptest, so generation is explicit and
//! every case is reproducible from its seed).

use cbench::apps::walberla::collision::{collide_cell, CollisionOp};
use cbench::apps::walberla::fslbm::FsBlock;
use cbench::apps::walberla::lattice::{d3q19, d3q27};
use cbench::ci::substitute_vars;
use cbench::sparse::{cg, gmres, Csr, Ilu0, SparseLu, Work};
use cbench::tsdb::{Db, Point, Query};
use cbench::util::json::Json;
use cbench::util::rng::Rng;
use std::collections::BTreeMap;

/// Random SPD matrix: diagonally-dominant with random symmetric pattern.
fn random_spd(rng: &mut Rng, n: usize, extra: usize) -> Csr {
    let mut t = Vec::new();
    let mut diag = vec![1.0f64; n];
    for _ in 0..extra {
        let i = rng.below(n);
        let j = rng.below(n);
        if i == j {
            continue;
        }
        let v = rng.range(-1.0, 1.0);
        t.push((i, j, v));
        t.push((j, i, v));
        diag[i] += v.abs();
        diag[j] += v.abs();
    }
    for (i, d) in diag.iter().enumerate() {
        t.push((i, i, d + 0.5));
    }
    Csr::from_triplets(n, &t)
}

#[test]
fn prop_direct_and_iterative_solvers_agree() {
    // 20 random SPD systems: LU, GMRES+ILU and CG must produce the same x
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let n = 20 + rng.below(60);
        let a = random_spd(&mut rng, n, 3 * n);
        let b: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();

        let lu = SparseLu::factor(&a).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let mut w = Work::default();
        let x_lu = lu.solve(&b, &mut w);
        let ilu = Ilu0::factor(&a).unwrap();
        let x_gm = gmres(&a, &b, Some(&ilu), 1e-12, 30, 5000);
        let x_cg = cg(&a, &b, 1e-12, 5000);
        assert!(x_gm.converged && x_cg.converged, "seed {seed}");
        for i in 0..n {
            assert!(
                (x_lu[i] - x_gm.x[i]).abs() < 1e-6,
                "seed {seed} lu vs gmres at {i}: {} vs {}",
                x_lu[i],
                x_gm.x[i]
            );
            assert!(
                (x_lu[i] - x_cg.x[i]).abs() < 1e-6,
                "seed {seed} lu vs cg at {i}"
            );
        }
        assert!(a.residual_norm(&x_lu, &b) < 1e-8, "seed {seed}");
    }
}

#[test]
fn prop_permutation_preserves_lu_solution() {
    for seed in 100..110u64 {
        let mut rng = Rng::new(seed);
        let n = 30 + rng.below(30);
        let a = random_spd(&mut rng, n, 2 * n);
        let b: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let x = SparseLu::factor(&a).unwrap().solve(&b, &mut Work::default());

        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let ap = a.permute(&perm);
        let bp: Vec<f64> = perm.iter().map(|&o| b[o]).collect();
        let xp = SparseLu::factor(&ap).unwrap().solve(&bp, &mut Work::default());
        for (new, &old) in perm.iter().enumerate() {
            assert!((xp[new] - x[old]).abs() < 1e-8, "seed {seed}");
        }
    }
}

#[test]
fn prop_collision_invariants_random_states() {
    // random positive PDF states: mass/momentum conserved, result finite
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let lat = if seed % 2 == 0 { d3q19() } else { d3q27() };
        let op = CollisionOp::all()[rng.below(4)];
        let tau = rng.range(0.51, 2.0);
        let mut f: Vec<f64> = (0..lat.q).map(|q| lat.w[q] * rng.range(0.5, 1.5)).collect();
        let (rho0, u0) = lat.moments(&f);
        let mut scratch = vec![0.0; lat.q];
        collide_cell(op, &lat, tau, &mut f, &mut scratch);
        let (rho1, u1) = lat.moments(&f);
        assert!((rho0 - rho1).abs() < 1e-10, "seed {seed} {op:?} rho");
        for i in 0..3 {
            assert!(
                (rho0 * u0[i] - rho1 * u1[i]).abs() < 1e-10,
                "seed {seed} {op:?} mom"
            );
        }
        assert!(f.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn prop_fslbm_mass_conserved_random_waves() {
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed);
        let mut b = FsBlock::new(8 + rng.below(6), 8 + rng.below(6), 4);
        b.gravity = rng.range(1e-6, 3e-4);
        b.init_gravity_wave(rng.range(0.05, 0.2));
        let m0 = b.total_mass();
        for _ in 0..10 {
            b.step(CollisionOp::Srt);
        }
        let m1 = b.total_mass();
        assert!(
            (m1 - m0).abs() / m0 < 0.03,
            "seed {seed}: mass {m0} -> {m1}"
        );
        let (g, i, l) = b.state_counts();
        assert!(g > 0 && i > 0 && l > 0, "seed {seed}: {g}/{i}/{l}");
    }
}

#[test]
fn prop_tsdb_query_partitions_points() {
    // group-by over any tag partitions exactly the matching points
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed);
        let mut db = Db::new();
        let nodes = ["a", "b", "c"];
        let total = 50 + rng.below(100);
        for i in 0..total {
            db.insert(
                Point::new("m", i as i64)
                    .tag("node", nodes[rng.below(3)])
                    .tag("op", if rng.uniform() < 0.5 { "x" } else { "y" })
                    .field("v", rng.range(0.0, 10.0)),
            );
        }
        let series = Query::new("m", "v").group_by(&["node", "op"]).run(&db);
        let sum: usize = series.iter().map(|s| s.points.len()).sum();
        assert_eq!(sum, total, "seed {seed}");
        // every series is time-ordered
        for s in &series {
            assert!(s.points.windows(2).all(|w| w[0].0 <= w[1].0));
        }
    }
}

#[test]
fn prop_line_protocol_roundtrip_random_points() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed);
        let weird = ["plain", "with space", "co,mma", "eq=uals", "back\\slash"];
        let mut p = Point::new(weird[rng.below(weird.len())], rng.next_u64() as i64 / 2);
        for _ in 0..1 + rng.below(4) {
            let k = format!("t{}", rng.below(5));
            p.tags.insert(k, weird[rng.below(weird.len())].to_string());
        }
        for _ in 0..1 + rng.below(4) {
            let k = format!("f{}", rng.below(5));
            p.fields.insert(k, rng.gauss(0.0, 100.0));
        }
        let q = Point::parse_line(&p.to_line()).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(p, q, "seed {seed}");
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.gauss(0.0, 1000.0) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}\"\\\n{}", rng.below(100), rng.below(100))),
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..rng.below(4) {
                    m.insert(format!("k{}", rng.below(10)), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed);
        let doc = random_json(&mut rng, 3);
        for text in [doc.to_string_compact(), doc.to_string_pretty()] {
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
            assert_eq!(back, doc, "seed {seed}");
        }
    }
}

#[test]
fn prop_ci_substitution_never_panics_and_is_idempotent_without_vars() {
    let empty: BTreeMap<String, String> = BTreeMap::new();
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let tokens = ["${A}", "$", "{", "}", "x", "€", "${", "}${B", "\n"];
        let s: String = (0..rng.below(20))
            .map(|_| tokens[rng.below(tokens.len())])
            .collect();
        // without variables the text must come back unchanged
        assert_eq!(substitute_vars(&s, &empty), s, "seed {seed}: {s:?}");
    }
}
