//! End-to-end tests of the serve:: benchmark-as-a-service facade over
//! real TCP connections: concurrent multi-tenant correctness (no lost
//! points, no cross-project leakage), the served-vs-serial on-disk
//! determinism property, restart/reload persistence, the HTTP error
//! mapping contract and per-project threshold overrides.

use cbench::serve::loadgen::{http_request, lp_batch};
use cbench::serve::{start, ServeConfig, ServerHandle};
use cbench::util::json::Json;
use std::path::PathBuf;

fn spawn(data_dir: Option<PathBuf>, max_body: usize) -> ServerHandle {
    start(ServeConfig {
        addr: "127.0.0.1:0".to_string(), // ephemeral port per test
        data_dir,
        max_body,
        ..ServeConfig::default()
    })
    .expect("server starts")
}

/// Fresh per-test scratch dir (tests run in one process; names are
/// distinct per call site).
fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cbench_serve_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn get_json(addr: &str, path: &str) -> (u16, Json) {
    let (status, body) = http_request(addr, "GET", path, b"").expect("request");
    let json = Json::parse(&String::from_utf8_lossy(&body)).unwrap_or(Json::Null);
    (status, json)
}

/// Total points across every grouped series of a query response.
fn response_points(json: &Json) -> usize {
    json.as_arr()
        .map(|series| {
            series
                .iter()
                .filter_map(|s| s.get("points").and_then(|p| p.as_arr().map(|a| a.len())))
                .sum()
        })
        .unwrap_or(0)
}

#[test]
fn concurrent_writers_and_readers_no_lost_points_no_leakage() {
    let handle = spawn(None, 8 * 1024 * 1024);
    let addr = handle.addr.to_string();
    const WRITERS: usize = 4;
    const BATCHES: usize = 6;
    const BATCH_POINTS: usize = 20;

    // seed batch 0 for every project up front so the concurrent readers
    // below can never race project creation (a 404 would be legal but
    // would muddy the zero-errors assertion at the end)
    for w in 0..WRITERS {
        let project = format!("w{w}");
        let (body, _) = lp_batch(&project, 0, BATCH_POINTS, false);
        let (status, _) = http_request(
            &addr,
            "POST",
            &format!("/v0/projects/{project}/ingest"),
            body.as_bytes(),
        )
        .expect("seed ingest");
        assert_eq!(status, 200);
    }
    let writers: Vec<_> = (0..WRITERS)
        .map(|w| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let project = format!("w{w}");
                for b in 1..BATCHES {
                    let (body, _) = lp_batch(&project, b, BATCH_POINTS, false);
                    let (status, _) = http_request(
                        &addr,
                        "POST",
                        &format!("/v0/projects/{project}/ingest"),
                        body.as_bytes(),
                    )
                    .expect("ingest request");
                    assert_eq!(status, 200, "writer {w} batch {b}");
                }
            })
        })
        .collect();
    // readers run against the same projects while the writers write:
    // every response must be a clean 200 — never a 5xx, never a hang
    let readers: Vec<_> = (0..3)
        .map(|r| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                for i in 0..30 {
                    let project = format!("w{}", (r + i) % WRITERS);
                    let (status, _) = get_json(
                        &addr,
                        &format!("/v0/projects/{project}/query?measurement=lbm&field=mlups&tail=8"),
                    );
                    assert_eq!(status, 200, "reader saw status {status}");
                }
            })
        })
        .collect();
    for t in writers {
        t.join().unwrap();
    }
    for t in readers {
        t.join().unwrap();
    }

    for w in 0..WRITERS {
        let project = format!("w{w}");
        // no lost points: everything each writer sent is queryable
        let (status, json) = get_json(
            &addr,
            &format!("/v0/projects/{project}/query?measurement=lbm&field=mlups"),
        );
        assert_eq!(status, 200);
        assert_eq!(
            response_points(&json),
            BATCHES * BATCH_POINTS,
            "project {project} lost points"
        );
        // no leakage: grouping by repo shows exactly this writer's tag
        let (_, grouped) = get_json(
            &addr,
            &format!("/v0/projects/{project}/query?measurement=lbm&field=mlups&group_by=repo"),
        );
        let groups = grouped.as_arr().expect("array");
        assert_eq!(groups.len(), 1, "project {project} sees foreign series");
        let repo = groups[0]
            .get("group")
            .and_then(|g| g.get("repo"))
            .and_then(|r| r.as_str().map(|s| s.to_string()));
        assert_eq!(repo.as_deref(), Some(project.as_str()));
        // filtering by another tenant's repo tag inside this project
        // finds nothing
        let other = format!("w{}", (w + 1) % WRITERS);
        let (_, leaked) = get_json(
            &addr,
            &format!(
                "/v0/projects/{project}/query?measurement=lbm&field=mlups&tag.repo={other}"
            ),
        );
        assert_eq!(response_points(&leaked), 0, "cross-project leakage");
    }
    let report = handle.stop();
    assert_eq!(report.errors, 0, "clean run must log zero request errors");
}

/// THE determinism property of the service layer: driving the same
/// per-project request streams concurrently or strictly serially must
/// leave byte-identical stores on disk — manifest, shard files, alert
/// book, detector state.
#[test]
fn served_concurrent_matches_serial_on_disk_byte_for_byte() {
    const PROJECTS: usize = 3;
    const BATCHES: usize = 4;
    const BATCH_POINTS: usize = 25;
    let dir_con = fresh_dir("concurrent");
    let dir_ser = fresh_dir("serial");

    // concurrent: one writer thread per project
    let handle = spawn(Some(dir_con.clone()), 8 * 1024 * 1024);
    let addr = handle.addr.to_string();
    let writers: Vec<_> = (0..PROJECTS)
        .map(|p| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let project = format!("p{p}");
                for b in 0..BATCHES {
                    let (body, _) = lp_batch(&project, b, BATCH_POINTS, false);
                    let (status, _) = http_request(
                        &addr,
                        "POST",
                        &format!("/v0/projects/{project}/ingest"),
                        body.as_bytes(),
                    )
                    .unwrap();
                    assert_eq!(status, 200);
                }
            })
        })
        .collect();
    for t in writers {
        t.join().unwrap();
    }
    let report = handle.stop();
    assert_eq!(report.projects_saved, PROJECTS);
    assert_eq!(report.dirty_after_save, 0, "drain save must leave nothing dirty");

    // serial: identical per-project request streams, one after another
    let handle = spawn(Some(dir_ser.clone()), 8 * 1024 * 1024);
    let addr = handle.addr.to_string();
    for p in 0..PROJECTS {
        let project = format!("p{p}");
        for b in 0..BATCHES {
            let (body, _) = lp_batch(&project, b, BATCH_POINTS, false);
            let (status, _) = http_request(
                &addr,
                "POST",
                &format!("/v0/projects/{project}/ingest"),
                body.as_bytes(),
            )
            .unwrap();
            assert_eq!(status, 200);
        }
    }
    let report = handle.stop();
    assert_eq!(report.dirty_after_save, 0);

    assert_eq!(
        dir_snapshot(&dir_con),
        dir_snapshot(&dir_ser),
        "concurrent and serial stores must be byte-identical"
    );
    let _ = std::fs::remove_dir_all(&dir_con);
    let _ = std::fs::remove_dir_all(&dir_ser);
}

/// Sorted (relative-path, contents) pairs of every file under `root`.
fn dir_snapshot(root: &std::path::Path) -> Vec<(String, Vec<u8>)> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                stack.push(path);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                out.push((rel, std::fs::read(&path).unwrap()));
            }
        }
    }
    out.sort();
    out
}

#[test]
fn restart_reloads_persisted_projects() {
    let dir = fresh_dir("restart");
    let handle = spawn(Some(dir.clone()), 8 * 1024 * 1024);
    let addr = handle.addr.to_string();
    let (body, n) = lp_batch("persist", 0, 30, false);
    let (status, _) = http_request(&addr, "POST", "/v0/projects/persist/ingest", body.as_bytes())
        .unwrap();
    assert_eq!(status, 200);
    handle.stop();

    // a fresh process-equivalent: new server, same data dir, no ingest
    let handle = spawn(Some(dir.clone()), 8 * 1024 * 1024);
    let addr = handle.addr.to_string();
    let (status, json) = get_json(
        &addr,
        "/v0/projects/persist/query?measurement=lbm&field=mlups",
    );
    assert_eq!(status, 200, "persisted project must load on demand");
    assert_eq!(response_points(&json), n);
    handle.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn http_error_mapping_contract() {
    let handle = spawn(None, 1024); // 1 KiB body cap to exercise 413
    let addr = handle.addr.to_string();

    // 404: read endpoints never create projects
    let (status, _) = get_json(&addr, "/v0/projects/ghost/query?measurement=lbm&field=mlups");
    assert_eq!(status, 404);
    let (status, _) = get_json(&addr, "/v0/projects/ghost/alerts");
    assert_eq!(status, 404);

    // 400: malformed line protocol fails the whole batch
    let (status, _) =
        http_request(&addr, "POST", "/v0/projects/bad/ingest", b"this is not lp\n").unwrap();
    assert_eq!(status, 400);
    // ...atomically: the project exists but holds zero points
    let (status, json) = get_json(&addr, "/v0/projects/bad/query?measurement=lbm&field=mlups");
    assert_eq!(status, 200);
    assert_eq!(response_points(&json), 0);

    // 400: invalid project names (path traversal shapes) are rejected
    let (status, _) = get_json(&addr, "/v0/projects/a%2Eb%2Fc/query?measurement=lbm&field=mlups");
    assert_eq!(status, 400);

    // 413: body over the configured cap
    let big = vec![b'x'; 4096];
    let (status, _) = http_request(&addr, "POST", "/v0/projects/big/ingest", &big).unwrap();
    assert_eq!(status, 413);

    // 404 + 400 on the alert resolve path
    let (status, _) = http_request(&addr, "POST", "/v0/projects/bad/alerts/99/resolve", b"").unwrap();
    assert_eq!(status, 404);
    let (status, _) =
        http_request(&addr, "POST", "/v0/projects/bad/alerts/zzz/resolve", b"").unwrap();
    assert_eq!(status, 400);

    handle.stop();
}

#[test]
fn alert_lifecycle_over_http_and_double_resolve_conflict() {
    let handle = spawn(None, 8 * 1024 * 1024);
    let addr = handle.addr.to_string();
    // healthy baseline, then single-point regressed batches (the recent
    // window is 1 — a whole regressed batch would shift the baseline)
    let (body, _) = lp_batch("alerts", 0, 20, false);
    let (status, _) =
        http_request(&addr, "POST", "/v0/projects/alerts/ingest", body.as_bytes()).unwrap();
    assert_eq!(status, 200);
    let mut opened = 0usize;
    for k in 0..3 {
        let i = 20 + k;
        let line = format!(
            "lbm,case=uniform,node=icx36,collision_op=srt,gpu=false,repo=alerts mlups={} {}\n",
            520.0 + (i % 5) as f64,
            (i as i64 + 1) * 1_000_000_000
        );
        let (status, body) =
            http_request(&addr, "POST", "/v0/projects/alerts/ingest", line.as_bytes()).unwrap();
        assert_eq!(status, 200);
        let json = Json::parse(&String::from_utf8_lossy(&body)).unwrap();
        opened += json.get("alerts_opened").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
    }
    assert!(opened >= 1, "a 35% drop must open an alert over HTTP");

    let (status, alerts) = get_json(&addr, "/v0/projects/alerts/alerts");
    assert_eq!(status, 200);
    let id = alerts
        .as_arr()
        .and_then(|a| a.first().cloned())
        .and_then(|a| a.get("id").cloned())
        .and_then(|v| v.as_f64())
        .expect("open alert with id") as u64;

    let path = format!("/v0/projects/alerts/alerts/{id}/resolve");
    let (status, _) = http_request(&addr, "POST", &path, b"").unwrap();
    assert_eq!(status, 200);
    let (status, _) = http_request(&addr, "POST", &path, b"").unwrap();
    assert_eq!(status, 409, "double resolve must conflict");
    // resolved alerts drop out of the default listing, stay under state=all
    let (_, open) = get_json(&addr, "/v0/projects/alerts/alerts");
    assert_eq!(open.as_arr().map(|a| a.len()), Some(0));
    let (_, all) = get_json(&addr, "/v0/projects/alerts/alerts?state=all");
    assert!(all.as_arr().map(|a| !a.is_empty()).unwrap_or(false));
    handle.stop();
}

#[test]
fn thresholds_put_rebuilds_detector_per_project() {
    let handle = spawn(None, 8 * 1024 * 1024);
    let addr = handle.addr.to_string();

    // project "tuned" requires a 90% drop before alerting
    let cfg = "regress.lbm-mlups.min_rel_change = 0.9\n";
    let (status, body) =
        http_request(&addr, "PUT", "/v0/projects/tuned/thresholds", cfg.as_bytes()).unwrap();
    assert_eq!(status, 200);
    let fp1 = Json::parse(&String::from_utf8_lossy(&body))
        .ok()
        .and_then(|j| j.get("fingerprint").and_then(|f| f.as_str().map(|s| s.to_string())))
        .expect("fingerprint");
    let (_, body) = http_request(
        &addr,
        "PUT",
        "/v0/projects/tuned/thresholds",
        b"regress.lbm-mlups.min_rel_change = 0.9\nregress.lbm-mlups.alpha = 0.01\n",
    )
    .unwrap();
    let fp2 = Json::parse(&String::from_utf8_lossy(&body))
        .ok()
        .and_then(|j| j.get("fingerprint").and_then(|f| f.as_str().map(|s| s.to_string())))
        .expect("fingerprint");
    assert_ne!(fp1, fp2, "changed knobs must change the detector fingerprint");

    // same traffic, different outcomes: "stock" alerts on a 35% drop,
    // "tuned" (90% required) does not — per-project isolation of the
    // override, not just of the data
    let drive = |project: &str| -> usize {
        let (body, _) = lp_batch(project, 0, 20, false);
        http_request(
            &addr,
            "POST",
            &format!("/v0/projects/{project}/ingest"),
            body.as_bytes(),
        )
        .unwrap();
        let mut opened = 0usize;
        for k in 0..3 {
            let i = 20 + k;
            let line = format!(
                "lbm,case=uniform,node=icx36,collision_op=srt,gpu=false,repo={project} mlups={} {}\n",
                520.0 + (i % 5) as f64,
                (i as i64 + 1) * 1_000_000_000
            );
            let (status, body) = http_request(
                &addr,
                "POST",
                &format!("/v0/projects/{project}/ingest"),
                line.as_bytes(),
            )
            .unwrap();
            assert_eq!(status, 200);
            let json = Json::parse(&String::from_utf8_lossy(&body)).unwrap();
            opened +=
                json.get("alerts_opened").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
        }
        opened
    };
    assert!(drive("stock") >= 1, "default thresholds must alert");
    assert_eq!(drive("tuned"), 0, "tuned project must stay quiet");
    handle.stop();
}
