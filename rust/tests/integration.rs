//! Integration tests: cross-module flows over the real public API.

use cbench::coordinator::{
    detect_regressions, fe2ti_pipeline::fe2ti_pipeline_jobs,
    walberla_pipeline::walberla_pipeline_jobs, BenchConfig, CbSystem,
};
use cbench::dashboard::{fe2ti_dashboard, walberla_dashboard};
use cbench::tsdb::{Aggregate, Db, Query};
use cbench::vcs::{ProxyRepo, Repository};

/// Full FE2TI pipeline over the scheduler with a reduced matrix: points
/// land in the TSDB with the right tags, records + links in the store.
#[test]
fn fe2ti_pipeline_end_to_end_reduced() {
    let mut repo = Repository::new("fe2ti");
    let ev = repo.commit_change("master", "a", "init", 0.0, "benchmark.cfg", "# defaults\n");
    let mut cb = CbSystem::new();
    let jobs: Vec<_> = fe2ti_pipeline_jobs(&repo, &ev.commit_id)
        .into_iter()
        .filter(|j| j.ci.name.contains("icx36") && j.ci.name.contains("mpi"))
        .collect();
    assert!(jobs.len() >= 8, "matrix slice too small: {}", jobs.len());
    let r = cb.execute_pipeline(&ev, false, jobs, "fe2ti").unwrap();
    assert_eq!(r.jobs_failed, 0);
    assert_eq!(r.points_uploaded, r.jobs_total);

    // solver ordering visible through the TSDB (the paper's Fig. 9 read)
    let tts = |solver: &str, compiler: &str| -> f64 {
        let series = Query::new("fe2ti", "tts")
            .where_tag("solver", solver)
            .where_tag("compiler", compiler)
            .where_tag("case", "fe2ti216")
            .where_tag("parallelization", "mpi")
            .run(&cb.db);
        series[0].aggregate(Aggregate::Last)
    };
    assert!(tts("ilu1e-4", "intel") < tts("ilu1e-8", "intel"));
    assert!(tts("ilu1e-8", "intel") < tts("pardiso", "intel"));
    assert!(tts("pardiso", "intel") < tts("umfpack", "gcc"));

    // records: 3 per job, linked
    assert_eq!(cb.store.n_records(), 3 * r.jobs_total);
    assert_eq!(cb.store.n_links(), 2 * r.jobs_total);
}

/// waLBerla proxy-repo flow: untrusted users cannot trigger branches; the
/// regression planted in a commit is detected and cleared.
#[test]
fn walberla_proxy_regression_cycle() {
    let mut upstream = Repository::new("walberla");
    let mut proxy = ProxyRepo::new("walberla", "proxy", &["trusted"]);
    let mut cb = CbSystem::new();

    let run = |cb: &mut CbSystem, proxy: &mut ProxyRepo, upstream: &Repository, cid: &str| {
        let ev = proxy.trigger(upstream, cid, "master", "trusted").unwrap();
        let jobs: Vec<_> = walberla_pipeline_jobs(&proxy.proxy, &ev.commit_id)
            .into_iter()
            .filter(|j| j.ci.get("HOST") == Some("icx36"))
            .collect();
        cb.execute_pipeline(&ev, true, jobs, "lbm").unwrap();
    };

    let c1 = upstream.commit_change("master", "d", "base", 0.0, "benchmark.cfg", "");
    run(&mut cb, &mut proxy, &upstream, &c1.commit_id);
    let c2 = upstream.commit_change(
        "master",
        "d",
        "bad",
        1.0,
        "benchmark.cfg",
        "lbm_efficiency_penalty = 0.2\n",
    );
    run(&mut cb, &mut proxy, &upstream, &c2.commit_id);
    let regs = detect_regressions(&cb.db, "lbm", "mlups", &["collision_op"], 0.1, true);
    assert_eq!(regs.len(), 4, "all four operators degraded");
    // untrusted trigger on a fork branch is denied
    let c3 = upstream.commit_change("fork/x", "d", "wip", 2.0, "benchmark.cfg", "");
    assert!(proxy
        .trigger(&upstream, &c3.commit_id, "fork/x", "mallory")
        .is_err());
}

/// TSDB persistence across "sessions": the dashboard renders identically
/// from a saved+reloaded database.
#[test]
fn tsdb_roundtrip_preserves_dashboard() {
    let mut repo = Repository::new("walberla");
    let ev = repo.commit_change("master", "d", "c", 0.0, "benchmark.cfg", "");
    let mut cb = CbSystem::new();
    let jobs: Vec<_> = walberla_pipeline_jobs(&repo, &ev.commit_id)
        .into_iter()
        .filter(|j| j.ci.get("HOST") == Some("rome1"))
        .collect();
    cb.execute_pipeline(&ev, true, jobs, "lbm").unwrap();

    let path = std::env::temp_dir().join("cbench_integration_tsdb.lp");
    let _ = std::fs::remove_dir_all(&path);
    cb.db.save(&path).unwrap();
    // the store persists as a manifest directory; the reload is lazy
    // (the dashboard render below materializes what it queries)
    assert!(path.join("manifest.json").is_file());
    let reloaded = Db::load(&path).unwrap();

    let dash = walberla_dashboard();
    assert_eq!(dash.render_text(&cb.db), dash.render_text(&reloaded));
    assert_eq!(cb.db.len(), reloaded.len());
    std::fs::remove_dir_all(&path).ok();
}

/// The BLAS-fix story through the full stack: two commits, queryable drop.
#[test]
fn blas_fix_detected_across_commits() {
    let mut repo = Repository::new("fe2ti");
    let mut cb = CbSystem::new();
    for cfg in ["# defaults\n", "umfpack_blas = blis\n"] {
        let ev = repo.commit_change("master", "a", "c", 0.0, "benchmark.cfg", cfg);
        let jobs: Vec<_> = fe2ti_pipeline_jobs(&repo, &ev.commit_id)
            .into_iter()
            .filter(|j| j.ci.name.contains("umfpack-gcc-mpi-skylakesp2"))
            .collect();
        assert_eq!(jobs.len(), 1); // fe2ti216 only (fe2ti1728 has no pure-MPI mode)
        cb.execute_pipeline(&ev, false, jobs, "fe2ti").unwrap();
    }
    let improved = detect_regressions(&cb.db, "fe2ti", "tts", &["case"], 0.1, false);
    assert!(improved.is_empty(), "a fix is not a regression");
    let series = Query::new("fe2ti", "tts")
        .where_tag("case", "fe2ti216")
        .run(&cb.db);
    let pts = &series[0].points;
    assert!(pts[1].1 < 0.5 * pts[0].1, "BLAS fix halves TTS at least");
}

/// Config parsing from the commit tree drives the job payloads.
#[test]
fn bench_config_flows_from_tree_to_jobs() {
    let mut repo = Repository::new("fe2ti");
    let ev = repo.commit_change(
        "master",
        "a",
        "cfg",
        0.0,
        "benchmark.cfg",
        "umfpack_blas = blis\nsome_other = 1\n",
    );
    let cfg = BenchConfig::from_commit(&repo, &ev.commit_id);
    assert_eq!(cfg.get("umfpack_blas"), Some("blis"));
    // absent file -> defaults
    let ev2 = repo.commit_change("clean", "a", "c", 0.0, "src.c", "x");
    assert!(BenchConfig::from_commit(&repo, &ev2.commit_id).entries.is_empty());
}

/// Dashboards render every panel against a populated DB without panicking
/// and respect combined filters.
#[test]
fn dashboards_render_with_combined_filters() {
    let mut repo = Repository::new("fe2ti");
    let ev = repo.commit_change("master", "a", "c", 0.0, "benchmark.cfg", "");
    let mut cb = CbSystem::new();
    let jobs: Vec<_> = fe2ti_pipeline_jobs(&repo, &ev.commit_id)
        .into_iter()
        .filter(|j| j.ci.name.contains("rome1"))
        .collect();
    cb.execute_pipeline(&ev, false, jobs, "fe2ti").unwrap();
    let mut d = fe2ti_dashboard();
    d.select("solver", &["ilu1e-4", "pardiso"]);
    d.select("parallelization", &["hybrid"]);
    let txt = d.render_text(&cb.db);
    assert!(txt.contains("solver=ilu1e-4") || txt.contains("solver=pardiso"));
    assert!(!txt.contains("solver=umfpack,"));
}
