//! Failure-injection tests: the CB pipeline must stay coherent when jobs
//! time out, crash, or produce garbage output — exactly the situations a
//! production CI system on shared HPC resources hits routinely.

use cbench::ci::CiJob;
use cbench::coordinator::{CbSystem, PreparedJob};
use cbench::slurm::{JobOutcome, JobState};
use cbench::tsdb::Point;
use cbench::vcs::PushEvent;

fn event() -> PushEvent {
    PushEvent {
        repo: "fe2ti".into(),
        branch: "master".into(),
        commit_id: "feedfacecafebeef".into(),
        changed: vec![],
    }
}

fn job(name: &str, timelimit: &str, payload: impl FnOnce(&cbench::cluster::nodes::NodeModel, f64) -> JobOutcome + Send + 'static) -> PreparedJob {
    PreparedJob {
        ci: CiJob::new(name, "benchmark")
            .var("HOST", "icx36")
            .var("SLURM_TIMELIMIT", timelimit),
        payload: Box::new(payload),
    }
}

#[test]
fn timeout_job_is_archived_but_not_completed() {
    let mut cb = CbSystem::new();
    let jobs = vec![
        job("slow", "1", |_n, _t| JobOutcome {
            duration: 3600.0, // >> 1 min limit
            stdout: "METRIC tts=3600\n".into(),
            exit_code: 0,
        }),
        job("ok", "120", |_n, _t| JobOutcome {
            duration: 5.0,
            stdout: "METRIC tts=5\n".into(),
            exit_code: 0,
        }),
    ];
    let r = cb.execute_pipeline(&event(), false, jobs, "m").unwrap();
    assert_eq!(r.jobs_completed, 1);
    assert_eq!(r.jobs_failed, 1);
    // the timeout job still leaves records (log + perf + machinestate)
    assert_eq!(r.records_created, 6);
    let log = cb
        .store
        .record_by_identifier("p1-job-slow")
        .unwrap()
        .files
        .get("slurm.log")
        .unwrap()
        .clone();
    assert!(log.contains("CANCELLED DUE TO TIME LIMIT"));
    // scheduler agrees
    let slow = cb.scheduler.squeue(JobState::Timeout);
    assert_eq!(slow.len(), 1);
}

#[test]
fn crashing_job_does_not_poison_the_pipeline() {
    let mut cb = CbSystem::new();
    let jobs = vec![
        job("segfault", "10", |_n, _t| JobOutcome {
            duration: 0.5,
            stdout: "Segmentation fault (core dumped)\n".into(),
            exit_code: 139,
        }),
        job("fine", "10", |_n, _t| JobOutcome {
            duration: 1.0,
            stdout: "METRIC tts=1\nTAG solver=ilu\n".into(),
            exit_code: 0,
        }),
    ];
    let r = cb.execute_pipeline(&event(), false, jobs, "m").unwrap();
    assert_eq!(r.jobs_failed, 1);
    assert_eq!(r.jobs_completed, 1);
    // only the good job uploads a point; the crash log has no METRIC lines
    assert_eq!(r.points_uploaded, 1);
    assert_eq!(cb.db.n_points("m"), 1);
}

#[test]
fn garbage_output_yields_no_points_but_keeps_raw_log() {
    let mut cb = CbSystem::new();
    let jobs = vec![job("garbage", "10", |_n, _t| JobOutcome {
        duration: 1.0,
        stdout: "METRIC =\nMETRIC x=notanumber\nTAG =v\nMETRICtts=1\n∆∆∆\n".into(),
        exit_code: 0,
    })];
    let r = cb.execute_pipeline(&event(), false, jobs, "m").unwrap();
    assert_eq!(r.points_uploaded, 0);
    assert!(cb.db.is_empty());
    // raw output still archived for forensics (FAIR principle)
    let rec = cb.store.record_by_identifier("p1-perf-garbage").unwrap();
    assert!(rec.files["perfctr.txt"].contains("∆∆∆"));
}

#[test]
fn malformed_tsdb_ingest_rejected_atomically_per_line() {
    let mut db = cbench::tsdb::Db::new();
    let text = "good v=1 1\nbad line without fields\n";
    // the second line errors; the caller decides what to do — nothing
    // before the error is lost
    let err = db.ingest_lines(text);
    assert!(err.is_err());
    assert_eq!(db.n_points("good"), 1);
}

#[test]
fn scheduler_rejects_unknown_host_before_running_anything() {
    let mut cb = CbSystem::new();
    let jobs = vec![PreparedJob {
        ci: CiJob::new("bad-host", "benchmark").var("HOST", "cray-1"),
        payload: Box::new(|_n, _t| JobOutcome {
            duration: 1.0,
            stdout: String::new(),
            exit_code: 0,
        }),
    }];
    assert!(cb.execute_pipeline(&event(), false, jobs, "m").is_err());
    assert!(cb.db.is_empty());
}

#[test]
fn duplicate_job_names_in_two_pipelines_do_not_collide_in_store() {
    // record identifiers embed the pipeline id: the same job name across
    // pipelines must create distinct records
    let mut cb = CbSystem::new();
    for _ in 0..2 {
        let jobs = vec![job("same-name", "10", |_n, _t| JobOutcome {
            duration: 1.0,
            stdout: "METRIC tts=1\n".into(),
            exit_code: 0,
        })];
        cb.execute_pipeline(&event(), false, jobs, "m").unwrap();
    }
    assert!(cb.store.record_by_identifier("p1-job-same-name").is_some());
    assert!(cb.store.record_by_identifier("p2-job-same-name").is_some());
    assert_eq!(cb.db.n_points("m"), 2);
}

#[test]
fn regression_detector_ignores_short_series_and_zero_baselines() {
    let mut db = cbench::tsdb::Db::new();
    db.insert(Point::new("m", 1).tag("s", "single").field("v", 5.0));
    db.insert(Point::new("m", 1).tag("s", "zero").field("v", 0.0));
    db.insert(Point::new("m", 2).tag("s", "zero").field("v", 1.0));
    let regs =
        cbench::coordinator::detect_regressions(&db, "m", "v", &["s"], 0.1, true);
    assert!(regs.is_empty());
}
