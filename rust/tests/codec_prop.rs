//! Property suites for the PR-10 byte-compatibility contracts:
//!
//! 1. `tsdb::codec` formats/parses **byte-identically** to the stdlib
//!    (`format!("{}")` / `str::parse`) — fuzzed over random bit
//!    patterns, structured values, and adversarial decimal strings.
//! 2. The columnar ingest path produces the same on-disk shards and
//!    the same `export_lp` bytes as the legacy per-point path.
//! 3. Overlapped campaign collects are byte-identical to serial for
//!    any worker-thread count (the ISSUE 10 acceptance sweep, 1..8).
//!
//! Own integration binary: the equivalence tests set the global
//! `par::set_threads` count, which must not race the library's unit
//! tests (integration binaries are separate processes). Within this
//! binary the thread-touching tests serialize on a local lock.

use cbench::tsdb::codec::{fmt_f64, fmt_i64, parse_f64, parse_i64};
use cbench::util::rng::Rng;
use std::sync::Mutex;

/// Guards the global worker-thread count against sibling tests in this
/// binary (cargo runs `#[test]`s on parallel threads).
static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn fmt(v: f64) -> String {
    let mut s = String::new();
    fmt_f64(v, &mut s);
    s
}

// --- layer 1: codec vs stdlib -------------------------------------

#[test]
fn fmt_f64_matches_display_on_random_bit_patterns() {
    // raw bit patterns cover every regime at once: normals across the
    // full exponent range, subnormals, both zeros, infinities, and NaN
    // payloads (Display renders every NaN as "NaN")
    let mut rng = Rng::new(0xC0DE_C0DE);
    for i in 0..200_000u64 {
        let v = f64::from_bits(rng.next_u64());
        assert_eq!(fmt(v), format!("{v}"), "iteration {i}, bits {:#x}", v.to_bits());
    }
}

#[test]
fn fmt_f64_matches_display_on_structured_values() {
    let mut rng = Rng::new(0xF0F0_0001);
    for _ in 0..100_000 {
        // integral doubles around and across the 2^53 fast-path bound,
        // scaled by powers of ten into fractional territory
        let mant = rng.next_u64() % (1u64 << 54); // deliberately crosses 2^53
        let exp = (rng.below(13) as i32) - 6; // 10^-6 .. 10^6
        let mut v = mant as f64 * 10f64.powi(exp);
        if rng.below(2) == 0 {
            v = -v;
        }
        assert_eq!(fmt(v), format!("{v}"), "mant {mant} exp {exp}");
    }
    for v in [0.0, -0.0, f64::MIN_POSITIVE, f64::EPSILON, f64::MAX, f64::MIN] {
        assert_eq!(fmt(v), format!("{v}"));
    }
}

#[test]
fn fmt_i64_matches_display_on_random_values() {
    let mut rng = Rng::new(0x1111_2222);
    for _ in 0..100_000 {
        let v = rng.next_u64() as i64;
        let mut s = String::new();
        fmt_i64(v, &mut s);
        assert_eq!(s, v.to_string());
    }
}

#[test]
fn parse_f64_round_trips_every_rendered_double() {
    // format -> parse must return the exact same bits (shortest-digits
    // rendering is defined to round-trip); this exercises the parser on
    // precisely the strings the formatter writes into shard files
    let mut rng = Rng::new(0x0A0B_0C0D);
    for _ in 0..100_000 {
        let v = f64::from_bits(rng.next_u64());
        if v.is_nan() {
            continue; // NaN never compares equal; rejected at ingest anyway
        }
        let s = fmt(v);
        let back = parse_f64(&s).unwrap_or_else(|e| panic!("{s:?}: {e}"));
        assert_eq!(back.to_bits(), v.to_bits(), "via {s:?}");
    }
}

/// A decimal-ish string built to straddle every fast-path boundary:
/// digit counts around the 19-digit delegation cutoff, exponents around
/// the Clinger |exp10| <= 22 window, and occasional malformed bytes.
fn fuzz_decimal(rng: &mut Rng) -> String {
    let mut s = String::new();
    match rng.below(8) {
        0 => s.push('-'),
        1 => s.push('+'), // always delegates; acceptance must still match
        _ => {}
    }
    let int_digits = rng.below(22);
    for _ in 0..int_digits {
        s.push((b'0' + rng.below(10) as u8) as char);
    }
    if rng.below(2) == 0 {
        s.push('.');
        for _ in 0..rng.below(22) {
            s.push((b'0' + rng.below(10) as u8) as char);
        }
    }
    if rng.below(4) == 0 {
        s.push(if rng.below(2) == 0 { 'e' } else { 'E' });
        if rng.below(2) == 0 {
            s.push('-');
        }
        for _ in 0..1 + rng.below(3) {
            s.push((b'0' + rng.below(10) as u8) as char);
        }
    }
    if rng.below(16) == 0 {
        // stray byte somewhere: both parsers must reject
        let pos = rng.below(s.len() + 1);
        s.insert(pos, ['x', ' ', '.', '-', '_'][rng.below(5)]);
    }
    s
}

#[test]
fn parse_f64_matches_stdlib_on_fuzzed_decimal_strings() {
    let mut rng = Rng::new(0xDEAD_10CC);
    for i in 0..200_000 {
        let s = fuzz_decimal(&mut rng);
        match (parse_f64(&s), s.parse::<f64>()) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.to_bits(), b.to_bits(), "iteration {i}, input {s:?}")
            }
            (Err(_), Err(_)) => {}
            (a, b) => panic!("iteration {i}, input {s:?}: fast {a:?} vs stdlib {b:?}"),
        }
    }
}

#[test]
fn parse_i64_matches_stdlib_on_fuzzed_digit_strings() {
    let mut rng = Rng::new(0x5151_5151);
    for i in 0..200_000 {
        let mut s = String::new();
        match rng.below(6) {
            0 => s.push('-'),
            1 => s.push('+'),
            _ => {}
        }
        // 0..22 digits: crosses both the 18-digit fast path and i64::MAX
        for _ in 0..rng.below(23) {
            s.push((b'0' + rng.below(10) as u8) as char);
        }
        if rng.below(16) == 0 {
            let pos = rng.below(s.len() + 1);
            s.insert(pos, ['x', ' ', '.', '-'][rng.below(4)]);
        }
        match (parse_i64(&s), s.parse::<i64>()) {
            (Ok(a), Ok(b)) => assert_eq!(a, b, "iteration {i}, input {s:?}"),
            (Err(_), Err(_)) => {}
            (a, b) => panic!("iteration {i}, input {s:?}: fast {a:?} vs stdlib {b:?}"),
        }
    }
}

// --- layer 2: columnar vs per-point persistence --------------------

/// Line-protocol batch whose field values sweep the codec regimes:
/// integral, fractional, negative, extreme-magnitude, and "-0".
fn mixed_lp(lines: usize) -> String {
    let awkward = [
        0.1,
        -0.30000000000000004,
        1e15,
        5e-324,
        123456.0,
        -0.0,
        2.5,
        1.7976931348623157e308,
        9_007_199_254_740_991.0, // 2^53 - 1: last integral fast-path value
        9_007_199_254_740_994.0, // 2^53 + 2: Display fallback territory
        -42.0,
        0.000244140625, // exact binary fraction
    ];
    let mut out = String::new();
    for i in 0..lines {
        let v = awkward[i % awkward.len()];
        out.push_str(&format!(
            "lbm,case=c{},node=node{:02},repo=r{} mlups={v} {}\n",
            i % 3,
            i % 7,
            i % 2,
            i as i64 * 7_000_000_000 // ~7 s apart: many shards at a 64 s span
        ));
    }
    out
}

/// Recursively collect `(relative path, bytes)` sorted by path.
fn dir_contents(root: &std::path::Path) -> Vec<(String, Vec<u8>)> {
    fn walk(dir: &std::path::Path, root: &std::path::Path, out: &mut Vec<(String, Vec<u8>)>) {
        for e in std::fs::read_dir(dir).unwrap() {
            let p = e.unwrap().path();
            if p.is_dir() {
                walk(&p, root, out);
            } else {
                let rel = p.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                out.push((rel, std::fs::read(&p).unwrap()));
            }
        }
    }
    let mut out = Vec::new();
    walk(root, root, &mut out);
    out.sort();
    out
}

#[test]
fn columnar_ingest_is_byte_identical_to_per_point_on_disk_and_export() {
    use cbench::tsdb::{Db, Point};
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());

    let span = 64_000_000_000; // 64 s shards over a ~6 h batch
    let text = mixed_lp(3000); // > PAR_MIN_LINES: the chunked path fires
    let tmp = std::env::temp_dir().join(format!("cbench_codec_prop_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();

    let export = |db: &Db, name: &str| -> String {
        let p = tmp.join(name);
        db.export_lp(&p).unwrap();
        std::fs::read_to_string(&p).unwrap()
    };

    // reference: the legacy owned-Point path, one insert per line
    let mut legacy = Db::with_shard_span(span);
    for line in text.lines() {
        legacy.insert(Point::parse_line(line).unwrap());
    }
    let legacy_export = export(&legacy, "legacy.lp");
    let legacy_dir = tmp.join("legacy");
    legacy.save(&legacy_dir).unwrap();

    // columnar path at 1 and 3 worker threads: same bytes either way
    for threads in [1usize, 3] {
        cbench::par::set_threads(threads);
        let mut col = Db::with_shard_span(span);
        assert_eq!(col.ingest_lines(&text).unwrap(), 3000);
        assert_eq!(
            export(&col, &format!("col{threads}.lp")),
            legacy_export,
            "export_lp diverged at {threads} ingest threads"
        );
        let col_dir = tmp.join(format!("col{threads}"));
        col.save(&col_dir).unwrap();
        assert_eq!(
            dir_contents(&col_dir),
            dir_contents(&legacy_dir),
            "on-disk store diverged at {threads} ingest threads"
        );
        // and the store round-trips back to the same export
        let back = Db::load_with_shard_span(&col_dir, span).unwrap();
        assert_eq!(back.len(), legacy.len());
        assert_eq!(export(&back, "back.lp"), legacy_export);
    }

    cbench::par::set_threads(0);
    let _ = std::fs::remove_dir_all(&tmp);
}

// --- layer 3: overlapped vs serial campaign collects ----------------

#[test]
fn overlapped_collects_are_byte_identical_to_serial_for_threads_1_to_8() {
    use cbench::coordinator::campaign::{default_projects, run_campaign, CampaignConfig};
    use cbench::coordinator::CbSystem;
    let _g = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());

    // 2 repos x 2 pushes with an injected regression: exercises
    // submission, collection, detection, and alert opening
    let run = |threads: usize| {
        cbench::par::set_threads(threads);
        let mut cb = CbSystem::new();
        let mut projects = default_projects(2);
        let out = run_campaign(
            &mut cb,
            &mut projects,
            &CampaignConfig {
                pushes: 2,
                inject_at: 2,
                penalty: 25.0,
                seed: 1,
                ..CampaignConfig::default()
            },
        )
        .unwrap();
        let lp = std::env::temp_dir().join(format!(
            "cbench_codec_prop_campaign_{threads}_{}.lp",
            std::process::id()
        ));
        cb.db.export_lp(&lp).unwrap();
        let export = std::fs::read_to_string(&lp).unwrap();
        let _ = std::fs::remove_file(&lp);
        (
            cb.scheduler.timeline(),
            export,
            cb.alerts.to_json().to_string_pretty(),
            out.reports.iter().map(|r| r.pipeline_id).collect::<Vec<_>>(),
            out.makespan,
        )
    };

    // threads=1 is the serial collect path (overlap gates off); every
    // other count takes the gather/background-parse/FIFO-commit path
    let serial = run(1);
    assert!(!serial.1.is_empty(), "campaign produced no points");
    for threads in 2..=8 {
        let overlapped = run(threads);
        assert_eq!(
            overlapped, serial,
            "overlapped campaign diverged from serial at {threads} threads"
        );
    }
    cbench::par::set_threads(0);
}
