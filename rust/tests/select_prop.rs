//! Property-tested safety contract of change-aware benchmark selection
//! (`select::`): for any campaign, skipping the jobs a push cannot
//! affect and carrying their last measured points forward must be
//! *observationally equivalent* to running the full matrix — identical
//! measured values on every affected series, an identical alert book
//! (modulo the cluster-latency stamps selection exists to shrink),
//! byte-stable artifacts across thread counts and save/load, planted
//! regressions in touched components always caught, and regressions
//! hidden behind a mis-declared dependency deferred but never lost.

mod common;

use cbench::ci::CiJob;
use cbench::coordinator::campaign::{default_projects, run_campaign_with, CampaignConfig};
use cbench::coordinator::{BenchConfig, CbSystem, PipelineReport, PreparedJob};
use cbench::sched::JobOutcome;
use cbench::select::{SelectMode, COMPONENTS_VAR};
use cbench::tsdb::TAIL_SCAN_SLACK;
use cbench::vcs::Repository;

/// One campaign over the stock two-project roster (walberla + fe2ti,
/// one-host slices of the real matrices) in the given selection mode.
fn run_campaign_in(
    select: SelectMode,
    seed: u64,
    pushes: usize,
    inject_at: usize,
) -> (cbench::coordinator::campaign::CampaignOutcome, CbSystem) {
    let mut cb = CbSystem::new();
    let mut projects = default_projects(2);
    let out = run_campaign_with(
        &mut cb,
        &mut projects,
        &CampaignConfig {
            pushes,
            inject_at,
            penalty: 0.15,
            seed,
            select,
            ..CampaignConfig::default()
        },
        common::one_host_slice,
    )
    .unwrap();
    (out, cb)
}

#[test]
fn change_aware_equals_full_across_random_campaigns() {
    let mut rng = common::Rng::new(0x5E1E_C701);
    for case in 0..3 {
        let seed = rng.below(1_000);
        let pushes = 3 + rng.below(2) as usize; // 3..=4
        let inject_at = 3 + rng.below(pushes as u64 - 2) as usize; // 3..=pushes
        let (full, cb_full) = run_campaign_in(SelectMode::Full, seed, pushes, inject_at);
        let (ca, cb_ca) = run_campaign_in(SelectMode::ChangeAware, seed, pushes, inject_at);

        // every affected series measured the same values: once the
        // carried markers are stripped, the benchmark stores agree line
        // for line (carried points equal the values a full run measures,
        // because job payloads are pure functions of the benchmark config)
        for m in ["lbm", "fe2ti"] {
            assert_eq!(
                common::sorted_lines_sans_carried(&cb_full, m),
                common::sorted_lines_sans_carried(&cb_ca, m),
                "case {case} seed {seed}: measurement `{m}` diverged"
            );
        }

        // identical alert book: verdicts, fingerprints, states,
        // trigger-clock timestamps, archive ids — byte for byte. Only
        // the sla_* latency stamps may differ (they shrink with the
        // saved cluster time, which is the point of selection).
        assert_eq!(
            common::alert_book_sans_sla(&cb_full),
            common::alert_book_sans_sla(&cb_ca),
            "case {case} seed {seed}: alert books diverged"
        );
        assert!(full.alerts_opened() > 0, "case {case}: plant must fire");
        assert_eq!(full.alerts_opened(), ca.alerts_opened());

        // selection really skipped work and banked the savings
        assert_eq!(full.jobs_skipped(), 0);
        assert!(ca.jobs_skipped() > 0, "case {case} seed {seed}");
        assert!(ca.cluster_hours_saved() > 0.0);
        assert_eq!(full.total_jobs(), ca.total_jobs());

        // both modes stamp an SLA on the opened alert (the stamps
        // themselves are schedule-dependent — see alert_book_sans_sla)
        assert_eq!(
            full.worst_alert_sla().is_some(),
            ca.worst_alert_sla().is_some(),
            "case {case} seed {seed}"
        );
    }
}

#[test]
fn planted_regression_in_touched_component_is_always_caught() {
    // the inject round ships its penalty through benchmark.cfg — config
    // surface, affects-everything — so change-aware selection must
    // measure it on the very round it lands, every time
    for seed in [1u64, 2, 3] {
        let (full, _) = run_campaign_in(SelectMode::Full, seed, 4, 3);
        let (ca, _) = run_campaign_in(SelectMode::ChangeAware, seed, 4, 3);
        assert!(full.alerts_opened() > 0, "seed {seed}");
        assert_eq!(full.alerts_opened(), ca.alerts_opened(), "seed {seed}");
        assert!(ca.jobs_skipped() > 0, "seed {seed}: selection must engage");
    }
}

#[test]
fn carried_artifacts_are_byte_stable_across_threads_and_reload() {
    let run = |threads: usize| {
        cbench::par::set_threads(threads);
        let mut cb = CbSystem::new();
        let mut projects = default_projects(2);
        run_campaign_with(
            &mut cb,
            &mut projects,
            &CampaignConfig {
                pushes: 4,
                inject_at: 3,
                penalty: 0.15,
                seed: 13,
                select: SelectMode::ChangeAware,
                ..CampaignConfig::default()
            },
            common::one_host_slice,
        )
        .unwrap();
        (
            common::db_dump(&cb),
            common::alert_book(&cb),
            common::detector_state(&cb),
            cb,
        )
    };
    let (db1, book1, st1, _) = run(1);
    let (db4, book4, st4, mut cb) = run(4);
    assert!(db1.contains("carried=1"), "change-aware run must carry points");
    assert_eq!(db1, db4, "TSDB must be byte-identical for any thread count");
    assert_eq!(book1, book4, "alert book must be byte-identical for any thread count");
    assert_eq!(st1, st4, "detector state must be byte-identical for any thread count");

    // save → load: carried points, alert book and detector state survive
    // persistence byte for byte (lines compared sorted: shard iteration
    // order is not part of the contract, line contents are)
    let dir = std::env::temp_dir().join("cbench_select_prop_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store = dir.join("tsdb");
    let alerts_path = dir.join("alerts.json");
    let state_path = dir.join("state.json");
    cb.db.save(&store).unwrap();
    cb.alerts.save(&alerts_path).unwrap();
    cb.det_state.save(&state_path).unwrap();

    let mut back = CbSystem::new();
    back.adopt_db(cbench::tsdb::Db::load(&store).unwrap());
    back.alerts = cbench::regress::AlertBook::load(&alerts_path).unwrap();
    back.det_state = cbench::regress::DetectorState::load(&state_path).unwrap();
    let sorted = |s: &str| {
        let mut v: Vec<&str> = s.lines().collect();
        v.sort_unstable();
        v
    };
    assert_eq!(sorted(&common::db_dump(&back)), sorted(&db4));
    assert_eq!(common::alert_book(&back), book4);
    assert_eq!(common::detector_state(&back), st4);
    std::fs::remove_dir_all(&dir).ok();
}

/// One benchmark job that *really* reads `src/lbm/cpu/**` but declares
/// `lbm/gpu` — the mis-declared dependency every selection scheme has to
/// survive. Value is a pure function of the commit's tree.
fn misdeclared_job(repo: &Repository, commit: &str) -> Vec<PreparedJob> {
    let slow = repo
        .get(commit)
        .map(|c| {
            c.tree
                .get("src/lbm/cpu/kernel.c")
                .map(|t| t.contains("slow"))
                .unwrap_or(false)
        })
        .unwrap_or(false);
    let mlups = if slow { 820.0 } else { 1000.0 };
    vec![PreparedJob {
        ci: CiJob::new("uniform-srt-icx36", "benchmark")
            .var("HOST", "icx36")
            .var(COMPONENTS_VAR, "lbm/gpu"),
        payload: Box::new(move |_n, _t| JobOutcome {
            duration: 10.0,
            stdout: format!(
                "TAG case=uniformgridcpu\nTAG collision_op=srt\nMETRIC mlups={mlups}\n"
            ),
            exit_code: 0,
        }),
    }]
}

/// Commit `content` to `path` and run the pipeline for it.
fn push_and_run(
    cb: &mut CbSystem,
    repo: &mut Repository,
    path: &str,
    content: &str,
    jobs_for: impl Fn(&Repository, &str) -> Vec<PreparedJob>,
) -> PipelineReport {
    let ev = repo.commit_change("master", "dev", &format!("edit {path}: {content}"), 0.0, path, content);
    let jobs = jobs_for(repo, &ev.commit_id);
    cb.execute_pipeline(&ev, false, jobs, "lbm").unwrap()
}

#[test]
fn misdeclared_dependency_defers_but_never_loses_the_regression() {
    let mut repo = Repository::new("walberla");
    let mut cb = CbSystem::new();
    cb.set_select_mode(SelectMode::ChangeAware);

    // warm-up: four rounds touching the declared component — job runs
    for i in 0..4 {
        let r = push_and_run(&mut cb, &mut repo, "src/lbm/gpu/tune.cu", &format!("rev {i}\n"), misdeclared_job);
        assert_eq!(r.jobs_skipped, 0);
        assert_eq!(r.regressions.opened, 0);
    }

    // the regression lands in src/lbm/cpu/** — which the job really
    // reads but does NOT declare. Selection skips the job: the round is
    // carried, nothing fires yet (deferred)…
    let r = push_and_run(&mut cb, &mut repo, "src/lbm/cpu/kernel.c", "slow kernel\n", misdeclared_job);
    assert_eq!(r.jobs_skipped, 1);
    assert_eq!(r.points_carried, 1);
    assert_eq!(r.regressions.opened, 0, "the skipped round cannot see the plant");
    assert!(cb.alerts.active().is_empty());

    // …and the next commit touching the *declared* component re-measures
    // and catches it: one commit late, never lost
    let r = push_and_run(&mut cb, &mut repo, "src/lbm/gpu/tune.cu", "rev 4\n", misdeclared_job);
    assert_eq!(r.jobs_skipped, 0);
    assert_eq!(r.regressions.opened, 1, "deferred regression must surface");
    assert_eq!(cb.alerts.active().len(), 1);
}

/// Two-job fixture for the boundary tests: a cpu job reading the cpu
/// kernel and a gpu job with an independent healthy value, as distinct
/// series of the stock `lbm` policy.
fn cpu_gpu_jobs(repo: &Repository, commit: &str) -> Vec<PreparedJob> {
    let slow = repo
        .get(commit)
        .map(|c| {
            c.tree
                .get("src/lbm/cpu/kernel.c")
                .map(|t| t.contains("slow"))
                .unwrap_or(false)
        })
        .unwrap_or(false);
    let cpu_mlups = if slow { 820.0 } else { 1000.0 };
    vec![
        PreparedJob {
            ci: CiJob::new("cpu-icx36", "benchmark")
                .var("HOST", "icx36")
                .var(COMPONENTS_VAR, "lbm/cpu"),
            payload: Box::new(move |_n, _t| JobOutcome {
                duration: 10.0,
                stdout: format!(
                    "TAG case=uniformgridcpu\nTAG collision_op=srt\nMETRIC mlups={cpu_mlups}\n"
                ),
                exit_code: 0,
            }),
        },
        PreparedJob {
            ci: CiJob::new("gpu-rome1", "benchmark")
                .var("HOST", "rome1")
                .var(COMPONENTS_VAR, "lbm/gpu"),
            payload: Box::new(|_n, _t| JobOutcome {
                duration: 20.0,
                stdout: "TAG case=uniformgridgpu\nTAG collision_op=srt\nMETRIC mlups=4000\n"
                    .into(),
                exit_code: 0,
            }),
        },
    ]
}

#[test]
fn carried_series_survives_the_stale_tenant_boundary() {
    // after the cpu regression opens its alert, a long gpu-only stretch
    // pushes the cpu series' last MEASURED point far beyond the capped
    // reverse tail walk (lookback × TAIL_SCAN_SLACK distinct trigger
    // timestamps) — without carried points the series would flip to
    // stale-tenant exclusion and the open alert would rot. With them it
    // stays fresh, keeps updating, and never auto-resolves; the book
    // stays byte-identical to the full run's (modulo latency stamps).
    let lookback = 8 + 1; // stock lbm policy: windows(8, 1)
    let rounds = lookback * TAIL_SCAN_SLACK + 16;
    let run = |select: SelectMode| {
        let mut repo = Repository::new("walberla");
        let mut cb = CbSystem::new();
        cb.set_select_mode(select);
        // warm-up touches shared lbm source: both series measured
        for i in 0..4 {
            push_and_run(&mut cb, &mut repo, "src/lbm/lattice.h", &format!("rev {i}\n"), cpu_gpu_jobs);
        }
        // the cpu kernel regresses — its component is touched, so the
        // cpu job runs (in both modes) and the alert opens
        let r = push_and_run(&mut cb, &mut repo, "src/lbm/cpu/kernel.c", "slow kernel\n", cpu_gpu_jobs);
        assert_eq!(r.regressions.opened, 1, "{select:?}");
        // gpu-only stretch past the stale-tenant cap
        for i in 0..rounds {
            push_and_run(&mut cb, &mut repo, "src/lbm/gpu/tune.cu", &format!("tune {i}\n"), cpu_gpu_jobs);
        }
        cb
    };
    let cb_full = run(SelectMode::Full);
    let cb_ca = run(SelectMode::ChangeAware);

    assert_eq!(
        common::alert_book_sans_sla(&cb_full),
        common::alert_book_sans_sla(&cb_ca),
        "long-horizon carried series diverged from the full run"
    );
    let active = cb_ca.alerts.active();
    assert_eq!(active.len(), 1, "exactly the one cpu alert, still open");
    let alert = &active[0];
    assert!(alert.series.contains("case=uniformgridcpu"));
    // the carried series kept feeding the alert every round — had it
    // gone stale at the TAIL_SCAN_SLACK boundary, times_seen would have
    // frozen at the opening round
    assert!(
        alert.times_seen > rounds,
        "times_seen {} must grow through all {rounds} carried rounds",
        alert.times_seen
    );
    // and the carried points really were the only thing keeping the
    // series inside the capped walk
    let last_measured = cb_ca
        .db
        .points_iter("lbm")
        .filter(|p| {
            p.tags.get("case").map(|c| c == "uniformgridcpu").unwrap_or(false)
                && p.tags.get("carried").is_none()
        })
        .map(|p| p.ts)
        .max()
        .unwrap();
    let newer_triggers: std::collections::BTreeSet<i64> = cb_ca
        .db
        .points_iter("lbm")
        .filter(|p| p.ts > last_measured)
        .map(|p| p.ts)
        .collect();
    assert!(
        newer_triggers.len() > lookback * TAIL_SCAN_SLACK,
        "fixture must push the last measured point past the cap ({} distinct newer triggers)",
        newer_triggers.len()
    );
}

#[test]
fn config_rebuild_over_carried_store_matches_requery() {
    // a regress.* knob change invalidates the detector fingerprint and
    // rebuilds the carried state from a store full of carried=1 points —
    // the rebuilt verdicts must equal a from-scratch re-query's. Both
    // runs are change-aware with identical schedules, so the whole book
    // (latency stamps included) must agree byte for byte.
    let run = |incremental: bool| {
        let mut repo = Repository::new("walberla");
        let mut cb = CbSystem::new();
        cb.set_select_mode(SelectMode::ChangeAware);
        cb.set_incremental_detection(incremental);
        for i in 0..4 {
            push_and_run(&mut cb, &mut repo, "src/lbm/gpu/tune.cu", &format!("rev {i}\n"), misdeclared_job);
        }
        // plant lands, deferred (carried round)…
        push_and_run(&mut cb, &mut repo, "src/lbm/cpu/kernel.c", "slow kernel\n", misdeclared_job);
        // …caught on the next declared-component touch
        let r = push_and_run(&mut cb, &mut repo, "src/lbm/gpu/tune.cu", "rev 4\n", misdeclared_job);
        assert_eq!(r.regressions.opened, 1);
        // a few more carried rounds stack carried=1 points into the store
        for i in 0..3 {
            let r = push_and_run(&mut cb, &mut repo, "src/lbm/cpu/other.c", &format!("cpu {i}\n"), misdeclared_job);
            assert_eq!(r.jobs_skipped, 1);
        }
        let fp_before = cb.det_state.config_fingerprint().to_string();
        // the knob change forces the rebuild over the carried store
        cb.apply_regress_config(&BenchConfig::parse("regress.lbm-mlups.min_rel_change = 0.01\n"));
        push_and_run(&mut cb, &mut repo, "src/lbm/gpu/tune.cu", "rev 5\n", misdeclared_job);
        if incremental {
            assert_ne!(
                cb.det_state.config_fingerprint(),
                fp_before,
                "knob change must re-fingerprint the carried state"
            );
        }
        // and back to stock for one more round
        cb.apply_regress_config(&BenchConfig::default());
        push_and_run(&mut cb, &mut repo, "src/lbm/gpu/tune.cu", "rev 6\n", misdeclared_job);
        cb
    };
    let cb_inc = run(true);
    let cb_req = run(false);
    assert_eq!(
        common::alert_book(&cb_inc),
        common::alert_book(&cb_req),
        "rebuild over carried points must match the full re-query, byte for byte"
    );
    assert!(!cb_inc.alerts.active().is_empty());
}
