//! Allocation budget of the interned columnar ingest path (ISSUE 10).
//!
//! The per-point path (`Point::parse_line` + `Db::insert` per line)
//! builds every point as two `BTreeMap`s of owned `String`s; the
//! columnar path (`Db::ingest_lines`) interns measurement/tag/field
//! strings once per distinct value and appends rows to
//! structure-of-arrays columns. This test pins the economy as an
//! **in-run A/B ratio** — portable across allocators and libstd
//! versions, unlike absolute counts — plus loose absolute pins that
//! keep both paths in their expected regimes.
//!
//! Own test binary on purpose: integration test binaries run their
//! `#[test]`s in parallel threads sharing one global allocator, so any
//! sibling test's allocations would race the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// `series` series reporting at `triggers` trigger timestamps — the
/// upload shape `coordinator::collect_pipeline` produces.
fn lp_batch(series: usize, triggers: usize) -> String {
    let mut out = String::new();
    for t in 0..triggers {
        for s in 0..series {
            out.push_str(&format!(
                "lbm,case=uniformgridcpu,collision_op=op{},node=node{:02} mlups={}.5 {}\n",
                s % 4,
                s / 4,
                400 + s,
                t as i64 * 1_000_000_000
            ));
        }
    }
    out
}

#[test]
fn columnar_ingest_allocates_at_most_a_quarter_of_the_per_point_path() {
    use cbench::tsdb::{Db, Point};

    // single-threaded: worker threads would interleave their own
    // allocations into the measured windows
    cbench::par::set_threads(1);
    let text = lp_batch(100, 100);
    let n = text.lines().count();
    assert_eq!(n, 10_000);

    // warm up lazy statics and allocator internals outside the windows
    {
        let mut db = Db::new();
        assert_eq!(db.ingest_lines(&text).unwrap(), n);
    }

    let legacy_allocs = {
        let mut db = Db::new();
        let a0 = ALLOCS.load(Ordering::Relaxed);
        for line in text.lines() {
            db.insert(Point::parse_line(line).unwrap());
        }
        let d = ALLOCS.load(Ordering::Relaxed) - a0;
        assert_eq!(db.len(), n);
        d
    };
    let columnar_allocs = {
        let mut db = Db::new();
        let a0 = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(db.ingest_lines(&text).unwrap(), n);
        ALLOCS.load(Ordering::Relaxed) - a0
    };
    cbench::par::set_threads(0);

    let l = legacy_allocs as f64 / n as f64;
    let c = columnar_allocs as f64 / n as f64;
    assert!(
        c <= 0.25 * l,
        "columnar ingest allocates {c:.2}/point vs {l:.2}/point per-point — \
         ratio {:.3} above the 0.25 budget",
        c / l
    );
    // regime pins: the baseline really is the owned-Point shape, and the
    // columnar path really is amortized-append + interner hits
    assert!(l >= 8.0, "per-point baseline unexpectedly cheap: {l:.2} allocs/point");
    assert!(c <= 6.0, "columnar path left its regime: {c:.2} allocs/point");
}
