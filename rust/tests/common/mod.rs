//! Shared campaign-fixture helpers for the integration/property suites:
//! a seeded RNG (tests must not depend on host entropy), roster presets
//! over the real project matrices, and byte-compare helpers for the
//! determinism contracts. Each test binary compiles its own copy via
//! `mod common;` — keep everything here deterministic and allocation-only
//! (no clocks, no environment reads).
#![allow(dead_code)]

use cbench::coordinator::campaign::{CampaignProject, ProjectKind};
use cbench::coordinator::{CbSystem, PreparedJob};
use cbench::sched::JobOutcome;

/// Tiny deterministic xorshift64* generator — enough to randomize test
/// campaigns without pulling a dependency or host entropy.
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // avoid the all-zero fixed point; splatter the seed bits
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform draw in `0..n` (n > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// Toy job roster: `spec` is `(host, duration, count)` — each entry
/// becomes `count` jobs pinned to `host`, uploading one `mlups` point.
pub fn toy_jobs(tag: &str, spec: &[(&str, f64, usize)]) -> Vec<PreparedJob> {
    let mut jobs = Vec::new();
    for (host, dur, count) in spec {
        for i in 0..*count {
            let dur = *dur;
            jobs.push(PreparedJob {
                ci: cbench::ci::CiJob::new(&format!("{tag}-{host}-{i}"), "benchmark")
                    .var("HOST", host),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: dur,
                    stdout: format!("TAG case=toy\nTAG collision_op=srt\nMETRIC mlups={dur}\n"),
                    exit_code: 0,
                }),
            });
        }
    }
    jobs
}

/// The icx36 slice of the real waLBerla matrix — cheap but faithful
/// (honors the commit's `benchmark.cfg` penalty and the jobs'
/// CB_COMPONENTS declarations).
pub fn icx36_walberla_jobs(p: &CampaignProject, commit: &str) -> Vec<PreparedJob> {
    ProjectKind::Walberla
        .jobs_for(&p.repo, commit)
        .into_iter()
        .filter(|j| j.ci.get("HOST") == Some("icx36"))
        .collect()
}

/// One-host slice of whichever real matrix the project uses: the full
/// matrix filtered to jobs on its first job's HOST. Keeps campaign tests
/// fast while preserving the matrix's tags, penalties and component
/// declarations.
pub fn one_host_slice(p: &CampaignProject, commit: &str) -> Vec<PreparedJob> {
    let jobs = p.kind.jobs_for(&p.repo, commit);
    let host = jobs
        .first()
        .and_then(|j| j.ci.get("HOST"))
        .map(|h| h.to_string());
    match host {
        Some(h) => jobs.into_iter().filter(|j| j.ci.get("HOST") == Some(h.as_str())).collect(),
        None => jobs,
    }
}

/// Every point of every measurement as line protocol, insertion order —
/// the byte-compare surface for replay-identity assertions.
pub fn db_dump(cb: &CbSystem) -> String {
    let mut dump = String::new();
    let measurements: Vec<String> = cb.db.measurements().cloned().collect();
    for m in &measurements {
        for p in cb.db.points_iter(m) {
            dump.push_str(&p.to_line());
            dump.push('\n');
        }
    }
    dump
}

/// The benchmark points of one measurement, sorted, with the
/// carried-forward markers removed: under the `select::` safety contract
/// a change-aware campaign's store differs from the full run's ONLY by
/// the `carried=1` / `carried_from=…` tags on skipped jobs' points.
pub fn sorted_lines_sans_carried(cb: &CbSystem, measurement: &str) -> Vec<String> {
    let mut lines: Vec<String> = cb
        .db
        .points_iter(measurement)
        .map(|p| strip_carried_tags(&p.to_line()))
        .collect();
    lines.sort();
    lines
}

/// Remove the `carried=1` and `carried_from=XXXXXXXX` tag entries from a
/// line-protocol line (tags sit between the measurement name and the
/// first space, comma-separated).
pub fn strip_carried_tags(line: &str) -> String {
    let (head, rest) = match line.split_once(' ') {
        Some((h, r)) => (h, r),
        None => return line.to_string(),
    };
    let kept: Vec<&str> = head
        .split(',')
        .filter(|part| {
            !part.starts_with("carried=") && !part.starts_with("carried_from=")
        })
        .collect();
    format!("{} {}", kept.join(","), rest)
}

/// The alert book rendered to its canonical persisted form — byte
/// equality here is the "identical alert book" acceptance everywhere.
pub fn alert_book(cb: &CbSystem) -> String {
    cb.alerts.to_json().to_string_pretty()
}

/// The carried detector state rendered to its persisted form.
pub fn detector_state(cb: &CbSystem) -> String {
    cb.det_state.to_json().to_string_pretty()
}

/// Alert book bytes with the cluster-latency stamps (`sla_*`) dropped:
/// change-aware selection legitimately shrinks those latencies (fewer
/// jobs contend on the cluster), so cross-select-mode equality is
/// asserted on everything else — verdicts, fingerprints, states,
/// trigger-clock timestamps, archive ids — byte for byte.
pub fn alert_book_sans_sla(cb: &CbSystem) -> String {
    alert_book(cb)
        .lines()
        .filter(|l| !l.trim_start().starts_with("\"sla_"))
        .collect::<Vec<_>>()
        .join("\n")
}
