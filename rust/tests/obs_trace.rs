//! End-to-end tests of the observability layer (`obs::`): the
//! cluster-time trace a campaign records replays byte-identically, spans
//! nest properly, the critical-path walk attributes the *entire*
//! makespan exactly, alert SLAs decompose into components that sum back,
//! and `--self-metrics` uploads the coordinator's own throughput as a
//! detector-watched measurement.

mod common;

use cbench::ci::CiJob;
use cbench::coordinator::campaign::{
    run_campaign_with, CampaignConfig, CampaignProject, ProjectKind,
};
use cbench::coordinator::{CbSystem, PreparedJob};
use cbench::obs::trace::{critical_path, Span};
use cbench::sched::JobOutcome;
use common::{icx36_walberla_jobs, toy_jobs};
use std::collections::HashMap;

/// A drained + backfilled streaming campaign: one hour-limit job that
/// must wait for the maintenance resume edge, two short-limit jobs that
/// backfill the gap — the trace shows queue-wait, maintenance, run and
/// backfill, all on scheduler-clock values.
fn drained_run() -> (CbSystem, f64) {
    let mut cb = CbSystem::new();
    let mut projects = vec![CampaignProject::new("alpha", ProjectKind::Walberla)];
    let cfg = CampaignConfig {
        pushes: 1,
        penalty: 0.0,
        seed: 11,
        drains: vec![("icx36".to_string(), 100.0, 3000.0)],
        ..CampaignConfig::default()
    };
    let out = run_campaign_with(&mut cb, &mut projects, &cfg, |_p, _c| {
        let mut jobs = vec![PreparedJob {
            ci: CiJob::new("big-icx36", "benchmark")
                .var("HOST", "icx36")
                .var("SLURM_TIMELIMIT", "60"),
            payload: Box::new(|_n, _t| JobOutcome {
                duration: 120.0,
                stdout: "METRIC v=1\n".into(),
                exit_code: 0,
            }),
        }];
        jobs.extend(
            toy_jobs("small", &[("icx36", 20.0, 2)])
                .into_iter()
                .map(|j| PreparedJob { ci: j.ci.var("SLURM_TIMELIMIT", "1"), payload: j.payload }),
        );
        jobs
    })
    .unwrap();
    (cb, out.makespan)
}

#[test]
fn trace_replays_byte_identical_across_runs() {
    // the same contract as sched::timeline(): identical submissions =>
    // identical trace, in every export format, byte for byte
    let (cb1, mk1) = drained_run();
    let (cb2, mk2) = drained_run();
    assert!(!cb1.trace.is_empty());
    assert_eq!(mk1, mk2);
    assert_eq!(cb1.trace.len(), cb2.trace.len());
    assert_eq!(
        cb1.trace.to_json().to_string_pretty(),
        cb2.trace.to_json().to_string_pretty(),
        "native trace JSON must replay byte-identically"
    );
    assert_eq!(
        cb1.trace.chrome_json().to_string_compact(),
        cb2.trace.chrome_json().to_string_compact(),
        "chrome export must replay byte-identically"
    );
    assert_eq!(cb1.trace.tree_text(), cb2.trace.tree_text());
}

#[test]
fn spans_nest_within_their_parents() {
    let (cb, _) = drained_run();
    let spans = cb.trace.spans();
    let by_id: HashMap<u64, &Span> = spans.iter().map(|s| (s.id, s)).collect();
    assert!(spans.iter().any(|s| s.cat == "campaign"), "root span exists");
    assert!(spans.iter().any(|s| s.cat == "maint"), "drain window recorded");
    for s in spans {
        assert!(s.t1 >= s.t0, "{}", s.name);
        if s.parent != 0 {
            let p = by_id
                .get(&s.parent)
                .unwrap_or_else(|| panic!("parent of `{}` missing from trace", s.name));
            assert!(
                s.t0 >= p.t0 && s.t1 <= p.t1,
                "span `{}` [{};{}] escapes parent `{}` [{};{}]",
                s.name,
                s.t0,
                s.t1,
                p.name,
                p.t0,
                p.t1
            );
        }
    }
    // every run span closes its job envelope; queue spans start at the
    // pipeline submission (they explain the wait, not the work)
    for s in spans.iter().filter(|s| s.cat == "run") {
        let j = by_id[&s.parent];
        assert_eq!(j.cat, "job");
        assert_eq!(s.t1, j.t1, "job `{}` ends when its run ends", j.name);
    }
    for s in spans.iter().filter(|s| s.cat == "queue") {
        let j = by_id[&s.parent];
        assert_eq!(s.t0, j.t0, "queue wait starts at submission of `{}`", j.name);
    }
}

#[test]
fn critical_path_attributes_the_entire_makespan_exactly() {
    let (cb, makespan) = drained_run();
    let cp = critical_path(cb.trace.spans()).unwrap();
    // bit-exact agreement with the campaign's own makespan: both are the
    // same two scheduler timestamps subtracted
    assert_eq!(cp.makespan, makespan);
    assert!(cp.covers_exactly(), "segments must tile [t0, t_end] with bit-equal boundaries");
    assert_eq!(cp.attributed(), cp.makespan);
    assert_eq!(cp.attributed_pct(), 100.0);
    assert!(!cp.segments.is_empty());
    assert_eq!(cp.segments.first().unwrap().t0, cp.t0);
    assert_eq!(cp.segments.last().unwrap().t1, cp.t_end);
    // the drained roster's path: the big job's queue-wait, the window,
    // its run — all three must appear
    assert!(cp.by_category.contains_key("run"), "{:?}", cp.by_category);
    assert!(cp.by_category.contains_key("maintenance"), "{:?}", cp.by_category);
    assert!(cp.by_category.contains_key("queue-wait"), "{:?}", cp.by_category);
    // per-node partition: run + maint + wait + idle == makespan per node
    assert!(!cp.per_node.is_empty());
    for (node, b) in &cp.per_node {
        let sum = b.run + b.maint + b.wait + b.idle;
        assert!(
            (sum - cp.makespan).abs() < 1e-6,
            "node {node}: partition {sum} != makespan {}",
            cp.makespan
        );
    }
    // idle nodes from the root span's inventory still show up
    assert!(cp.per_node.len() > 1, "idle Testcluster nodes must be listed too");
    assert!(cp.per_repo.contains_key("alpha"));
    assert!(cp.per_repo["alpha"].jobs >= 3);
    // the JSON the CLI prints as CRITPATH_JSON carries the exactness flag
    let j = cp.to_json();
    assert_eq!(j.get("attributed_pct").and_then(|v| v.as_f64()), Some(100.0));
}

#[test]
fn sla_decomposes_and_self_metrics_upload_under_detection() {
    let mut cb = CbSystem::new();
    cb.set_self_metrics(true);
    let mut projects = vec![
        CampaignProject::new("nhr-walberla", ProjectKind::Walberla),
        CampaignProject::new("proxy-walberla", ProjectKind::Walberla),
    ];
    let out = run_campaign_with(
        &mut cb,
        &mut projects,
        &CampaignConfig {
            pushes: 3,
            inject_at: 3,
            penalty: 0.15,
            seed: 5,
            ..CampaignConfig::default()
        },
        icx36_walberla_jobs,
    )
    .unwrap();
    assert!(out.alerts_opened() > 0, "planted regression must open alerts");
    for r in &out.reports {
        assert!(r.submitted_at <= r.first_started_at, "#{}", r.pipeline_id);
        assert!(r.first_started_at <= r.first_result_at, "#{}", r.pipeline_id);
    }

    // every opened benchmark alert decomposes its SLA into queue + run +
    // collect + detect components that sum back to sla_secs
    let opened: Vec<_> = cb
        .alerts
        .alerts
        .iter()
        .filter(|a| a.measurement == "lbm" && a.sla_secs.is_some())
        .collect();
    assert!(!opened.is_empty());
    for a in &opened {
        let sla = a.sla_secs.unwrap();
        let q = a.sla_queue_secs.expect("queue component stamped");
        let r = a.sla_run_secs.expect("run component stamped");
        let c = a.sla_collect_secs.expect("collect component stamped");
        let d = a.sla_detect_secs.expect("detect component stamped");
        assert!(q >= 0.0 && r >= 0.0 && c >= 0.0, "alert #{}: {q} {r} {c}", a.id);
        assert!(d >= -1e-9, "detect remainder must not be negative: {d}");
        assert!(
            ((q + r + c + d) - sla).abs() <= 1e-9 * sla.max(1.0),
            "alert #{}: {q}+{r}+{c}+{d} != {sla}",
            a.id
        );
        assert!(r > 0.0, "the offending pipeline did run");
    }

    // self-metrics landed under their own measurement, tagged for the
    // stock `self-throughput` policy (component+repo grouping)
    assert!(cb.db.points_iter("cbench_self").count() > 0);
    let comps = cb.db.tag_values("cbench_self", "component");
    assert!(comps.contains(&"tsdb_insert".to_string()), "{comps:?}");
    assert!(comps.contains(&"job_parse".to_string()), "{comps:?}");
    for p in cb.db.points_iter("cbench_self") {
        assert_eq!(p.tags.get("repo").map(|s| s.as_str()), Some("cbench"));
        assert!(p.fields.get("points_per_sec").copied().unwrap_or(0.0) > 0.0);
        assert!(p.fields.get("ops").copied().unwrap_or(0.0) >= 1.0);
    }
}
