//! End-to-end tests of the overlapped execution model: several
//! repositories' pipelines share one event-driven scheduler
//! (`cbench campaign`), the simulated makespan beats the back-to-back
//! sequential baseline, and the whole thing is deterministic — same seed
//! and submissions replay to a byte-identical timeline and TSDB.

mod common;

use cbench::ci::CiJob;
use cbench::coordinator::campaign::{
    campaign_push_events, default_projects, run_campaign, run_campaign_with, CampaignConfig,
    CampaignProject, ProjectKind,
};
use cbench::coordinator::{CbSystem, PreparedJob};
use cbench::regress::bisect_pipeline;
use cbench::sched::JobOutcome;
use cbench::vcs::PushEvent;
use common::{icx36_walberla_jobs, toy_jobs};

#[test]
fn real_matrices_overlap_strictly_beats_sequential() {
    // walberla (55 jobs over 11 nodes, GPU-heavy bottleneck) + fe2ti
    // (100 jobs over 3 nodes): disjoint bottlenecks, so the overlapped
    // makespan must be strictly below running the two matrices
    // back-to-back — the acceptance number of the sched:: refactor
    let mut cb = CbSystem::new();
    let mut projects = default_projects(2);
    assert_eq!(projects[0].kind, ProjectKind::Walberla);
    assert_eq!(projects[1].kind, ProjectKind::Fe2ti);
    let out = run_campaign(
        &mut cb,
        &mut projects,
        &CampaignConfig { pushes: 1, penalty: 0.0, seed: 3, ..CampaignConfig::default() },
    )
    .unwrap();
    assert_eq!(out.reports.len(), 2);
    assert!(out.total_jobs() >= 155, "55 walberla + 100 fe2ti jobs");
    assert!(
        out.makespan < out.sequential_baseline,
        "overlapped makespan {} must be strictly below sequential {}",
        out.makespan,
        out.sequential_baseline
    );
    assert!(out.overlap_speedup() > 1.0);
    // every pipeline uploaded under its own repo tag
    for r in &out.reports {
        assert!(r.points_uploaded > 0, "{}", r.repo);
        assert!(r.standalone_duration > 0.0, "{}", r.repo);
        assert!(r.duration >= r.standalone_duration, "{}", r.repo);
    }
    assert!(cb.db.tag_values("lbm", "repo").contains(&"walberla-0".to_string()));
    assert!(cb.db.tag_values("fe2ti", "repo").contains(&"fe2ti-1".to_string()));

    // the pipelines really interleaved: some job of the later-submitted
    // pipeline started before the earlier pipeline's last job ended
    let batches: Vec<u64> = out.reports.iter().map(|r| r.pipeline_id).collect();
    let span = |b: u64| {
        let (mut first_start, mut last_end) = (f64::MAX, 0.0f64);
        for j in cb.scheduler.jobs().filter(|j| j.spec.batch == b) {
            if let (Some(s), Some(e)) = (j.start_time, j.end_time) {
                first_start = first_start.min(s);
                last_end = last_end.max(e);
            }
        }
        (first_start, last_end)
    };
    let (_, end_a) = span(batches[0].min(batches[1]));
    let (start_b, _) = span(batches[0].max(batches[1]));
    assert!(
        start_b < end_a,
        "pipeline 2 first start {start_b} should precede pipeline 1 last end {end_a}"
    );
}

#[test]
fn campaign_replays_byte_identical() {
    // scheduler determinism: same seed + same submissions => identical
    // simulated timeline and identical TSDB contents, across two
    // interleaved pipelines (satellite acceptance of the sched:: refactor)
    fn run_once(seed: u64) -> (String, String, f64, f64) {
        let mut cb = CbSystem::new();
        let mut projects = vec![
            CampaignProject::new("alpha", ProjectKind::Walberla),
            CampaignProject::new("beta", ProjectKind::Walberla).priority(1),
        ];
        let out = run_campaign_with(
            &mut cb,
            &mut projects,
            &CampaignConfig { pushes: 2, penalty: 0.0, seed, ..CampaignConfig::default() },
            |p, _commit| {
                if p.name == "alpha" {
                    toy_jobs("a", &[("icx36", 10.0, 3), ("rome1", 5.0, 1)])
                } else {
                    toy_jobs("b", &[("rome1", 20.0, 2), ("skylakesp2", 8.0, 1)])
                }
            },
        )
        .unwrap();
        let timeline = cb.scheduler.timeline();
        let mut dump = String::new();
        let measurements: Vec<String> = cb.db.measurements().cloned().collect();
        for m in &measurements {
            for p in cb.db.points_iter(m) {
                dump.push_str(&p.to_line());
                dump.push('\n');
            }
        }
        (timeline, dump, out.makespan, out.sequential_baseline)
    }

    let (tl1, db1, mk1, seq1) = run_once(7);
    let (tl2, db2, mk2, seq2) = run_once(7);
    assert!(!tl1.is_empty() && !db1.is_empty());
    assert_eq!(tl1, tl2, "timeline must replay byte-identically");
    assert_eq!(db1, db2, "TSDB contents must replay byte-identically");
    assert_eq!(mk1, mk2);
    assert_eq!(seq1, seq2);
    assert!(mk1 < seq1, "toy workload overlaps strictly: {mk1} vs {seq1}");

    // a different seed changes commit ids (and thus the TSDB commit tags)
    // but the schedule itself — same job set — is unchanged
    let (tl3, db3, mk3, _) = run_once(8);
    assert_eq!(tl1, tl3, "schedule does not depend on commit content");
    assert_ne!(db1, db3, "commit tags differ under a different seed");
    assert_eq!(mk1, mk3);
}

#[test]
fn drained_campaign_replays_byte_identical_with_backfill() {
    // maintenance windows + backfill are part of the deterministic
    // schedule: the same drained roster replays to the same timeline,
    // and backfilled starts appear in it (the `bkfill` records)
    fn run_once() -> (String, f64, usize) {
        let mut cb = CbSystem::new();
        let mut projects = vec![CampaignProject::new("alpha", ProjectKind::Walberla)];
        let cfg = CampaignConfig {
            pushes: 1,
            penalty: 0.0,
            seed: 11,
            drains: vec![("icx36".to_string(), 100.0, 3000.0)],
            ..CampaignConfig::default()
        };
        let out = run_campaign_with(&mut cb, &mut projects, &cfg, |_p, _c| {
            let mut jobs = Vec::new();
            // one hour-limit job that must wait for the resume edge...
            jobs.push(PreparedJob {
                ci: CiJob::new("big-icx36", "benchmark")
                    .var("HOST", "icx36")
                    .var("SLURM_TIMELIMIT", "60"),
                payload: Box::new(|_n, _t| JobOutcome {
                    duration: 120.0,
                    stdout: "METRIC v=1\n".into(),
                    exit_code: 0,
                }),
            });
            // ...and short-limit jobs that backfill the gap in front
            jobs.extend(toy_jobs("small", &[("icx36", 20.0, 2)]).into_iter().map(|j| {
                PreparedJob { ci: j.ci.var("SLURM_TIMELIMIT", "1"), payload: j.payload }
            }));
            jobs
        })
        .unwrap();
        (cb.scheduler.timeline(), out.makespan, out.jobs_backfilled())
    }
    let (tl1, mk1, bk1) = run_once();
    let (tl2, mk2, bk2) = run_once();
    assert_eq!(tl1, tl2, "drained roster must replay byte-identically");
    assert_eq!(mk1, mk2);
    assert_eq!(bk1, bk2);
    assert_eq!(bk1, 2, "both short-limit jobs backfill the gap");
    assert!(tl1.contains("drain"), "window must be on the timeline");
    assert!(tl1.contains("bkfill"), "backfilled starts must be on the timeline");
    assert_eq!(mk1, 3120.0, "big job rides the resume edge at 3000");
}

#[test]
fn injected_regression_surfaces_through_overlapped_campaign() {
    // two waLBerla repos share the cluster; push round 3 plants the
    // kernel-regen penalty in both — the per-repo grouped policies open
    // alerts for each repository separately
    let mut cb = CbSystem::new();
    let mut projects = vec![
        CampaignProject::new("nhr-walberla", ProjectKind::Walberla),
        CampaignProject::new("proxy-walberla", ProjectKind::Walberla),
    ];
    let out = run_campaign_with(
        &mut cb,
        &mut projects,
        &CampaignConfig { pushes: 3, inject_at: 3, penalty: 0.15, seed: 5, ..CampaignConfig::default() },
        |p, commit| {
            // the icx36 slice of the real matrix, penalty-aware via the
            // commit's benchmark.cfg — cheap but faithful
            ProjectKind::Walberla
                .jobs_for(&p.repo, commit)
                .into_iter()
                .filter(|j| j.ci.get("HOST") == Some("icx36"))
                .collect()
        },
    )
    .unwrap();
    assert_eq!(out.reports.len(), 6);
    let opened = out.alerts_opened();
    assert!(opened > 0, "planted regression must open alerts");
    let active = cb.alerts.active();
    assert!(!active.is_empty());
    // alerts are per-repository series (the repo group tag), so one
    // repo's regression cannot hide behind another's healthy numbers
    assert!(active.iter().any(|a| a.series.contains("repo=nhr-walberla")));
    assert!(active.iter().any(|a| a.series.contains("repo=proxy-walberla")));
}

#[test]
fn streaming_equals_batch_and_shrinks_first_upload_and_alert_sla() {
    // the tentpole acceptance: same submissions => identical timeline,
    // identical benchmark TSDB and identical alert set under streaming
    // and batch collection — but the streaming first upload strictly
    // precedes the batch one, and the alert SLA is tighter
    let run = |streaming: bool| {
        let mut cb = CbSystem::new();
        let mut projects = vec![
            CampaignProject::new("nhr-walberla", ProjectKind::Walberla),
            CampaignProject::new("proxy-walberla", ProjectKind::Walberla),
        ];
        let out = run_campaign_with(
            &mut cb,
            &mut projects,
            &CampaignConfig {
                pushes: 3,
                inject_at: 3,
                penalty: 0.15,
                seed: 5,
                streaming,
                ..CampaignConfig::default()
            },
            icx36_walberla_jobs,
        )
        .unwrap();
        (out, cb)
    };
    let (s, cb_s) = run(true);
    let (b, cb_b) = run(false);

    // byte-identical replay across modes: collection never touches the
    // schedule, and the collection order is the same (completion, pid)
    assert_eq!(
        cb_s.scheduler.timeline(),
        cb_b.scheduler.timeline(),
        "streaming must not perturb the deterministic timeline"
    );
    let dump = |cb: &CbSystem| cb.db.points_iter("lbm").map(|p| p.to_line()).collect::<Vec<_>>();
    assert_eq!(dump(&cb_s), dump(&cb_b), "identical final TSDB benchmark contents");
    let alert_set = |cb: &CbSystem| {
        cb.alerts
            .alerts
            .iter()
            .map(|a| (a.id, a.fingerprint.clone(), a.state, a.opened_ts))
            .collect::<Vec<_>>()
    };
    assert_eq!(alert_set(&cb_s), alert_set(&cb_b), "identical alert set");
    assert!(s.alerts_opened() > 0, "planted regression must open alerts");
    assert_eq!(s.makespan, b.makespan);

    // streaming's first upload strictly precedes the batch collect's
    assert!(
        s.first_upload_at() < b.first_upload_at(),
        "streaming first upload {} must precede batch {}",
        s.first_upload_at(),
        b.first_upload_at()
    );
    assert_eq!(b.first_upload_at(), b.makespan, "batch uploads only at makespan");
    // every streaming pipeline was collected at its own completion
    for r in &s.reports {
        assert_eq!(r.collected_at, r.finished_at, "pipeline #{}", r.pipeline_id);
    }

    // alert SLA: both openers' SLAs are recorded; the best streaming SLA
    // beats batch's (where every alert waits for the whole roster)
    let best_sla = |o: &cbench::coordinator::campaign::CampaignOutcome| {
        o.reports
            .iter()
            .filter_map(|r| r.alert_sla)
            .fold(f64::INFINITY, f64::min)
    };
    assert!(s.worst_alert_sla().is_some() && b.worst_alert_sla().is_some());
    assert!(
        best_sla(&s) < best_sla(&b),
        "streaming SLA {} must beat batch {}",
        best_sla(&s),
        best_sla(&b)
    );
    assert!(s.worst_alert_sla().unwrap() <= b.worst_alert_sla().unwrap());
    // the opened alerts themselves carry the SLA stamp
    assert!(cb_s
        .alerts
        .alerts
        .iter()
        .all(|a| a.sla_secs.is_some()));
}

#[test]
fn streaming_campaign_replays_byte_identical() {
    // determinism of the new default: two identical streaming runs agree
    // on the timeline AND the full TSDB including the campaign
    // meta-points (latencies are simulated-clock values, not host time)
    let run_once = || {
        let mut cb = CbSystem::new();
        let mut projects = vec![
            CampaignProject::new("nhr-walberla", ProjectKind::Walberla),
            CampaignProject::new("proxy-walberla", ProjectKind::Walberla),
        ];
        run_campaign_with(
            &mut cb,
            &mut projects,
            &CampaignConfig { pushes: 2, penalty: 0.0, seed: 7, ..CampaignConfig::default() },
            icx36_walberla_jobs,
        )
        .unwrap();
        let mut dump = String::new();
        let measurements: Vec<String> = cb.db.measurements().cloned().collect();
        for m in &measurements {
            for p in cb.db.points_iter(m) {
                dump.push_str(&p.to_line());
                dump.push('\n');
            }
        }
        (cb.scheduler.timeline(), dump)
    };
    let (tl1, db1) = run_once();
    let (tl2, db2) = run_once();
    assert_eq!(tl1, tl2);
    assert_eq!(db1, db2, "campaign meta-points must replay byte-identically too");
}

#[test]
fn incremental_detection_equals_requery_across_streaming_campaign() {
    // the tentpole equivalence, end to end: the same streaming campaign
    // run with incremental (state-carried) detection and with the full
    // tail re-query must produce the identical timeline, TSDB and —
    // crucially — the byte-identical alert book (ids, fingerprints,
    // opened/resolved timestamps, SLA stamps)
    let run = |incremental: bool| {
        let mut cb = CbSystem::new();
        let mut projects = vec![
            CampaignProject::new("nhr-walberla", ProjectKind::Walberla),
            CampaignProject::new("proxy-walberla", ProjectKind::Walberla),
        ];
        let out = run_campaign_with(
            &mut cb,
            &mut projects,
            &CampaignConfig {
                pushes: 4,
                inject_at: 3,
                penalty: 0.15,
                seed: 5,
                incremental,
                ..CampaignConfig::default()
            },
            icx36_walberla_jobs,
        )
        .unwrap();
        (out, cb)
    };
    let (out_inc, cb_inc) = run(true);
    let (out_req, cb_req) = run(false);
    assert!(cb_inc.incremental_detection() && !cb_req.incremental_detection());
    assert_eq!(cb_inc.scheduler.timeline(), cb_req.scheduler.timeline());
    let dump = |cb: &CbSystem| cb.db.points_iter("lbm").map(|p| p.to_line()).collect::<Vec<_>>();
    assert_eq!(dump(&cb_inc), dump(&cb_req));
    assert!(out_inc.alerts_opened() > 0, "planted regression must open alerts");
    assert_eq!(
        cb_inc.alerts.to_json().to_string_pretty(),
        cb_req.alerts.to_json().to_string_pretty(),
        "alert books must be byte-identical across detection modes"
    );
    // per-pipeline ingest summaries agree report by report
    let sums = |o: &cbench::coordinator::campaign::CampaignOutcome| {
        o.reports.iter().map(|r| r.regressions.clone()).collect::<Vec<_>>()
    };
    assert_eq!(sums(&out_inc), sums(&out_req));
}

#[test]
fn campaign_resumes_from_manifest_store_with_carried_detector_state() {
    // run 1 persists the manifest store + detector state; two fresh
    // systems resume it — one continuing incrementally from the carried
    // state, one re-querying — run the same follow-up campaign, and must
    // agree on the final alert book byte for byte. The closing save then
    // proves the dirty-shard contract: shards the follow-up never
    // touched stay on disk as-is.
    let dir = std::env::temp_dir().join("cbench_campaign_resume");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store = dir.join("tsdb");
    let state = dir.join("state.json");

    let mut cb = CbSystem::new();
    let mut projects = vec![CampaignProject::new("walberla-0", ProjectKind::Walberla)];
    let cfg1 = CampaignConfig { pushes: 2, penalty: 0.0, seed: 21, ..CampaignConfig::default() };
    run_campaign_with(&mut cb, &mut projects, &cfg1, icx36_walberla_jobs).unwrap();
    cb.db.save(&store).unwrap();
    // re-partition finely (2 s shards) so the follow-up appends into new
    // shards instead of rewriting one giant partition
    let mut fine = cbench::tsdb::Db::load_with_shard_span(&store, 2_000_000_000).unwrap();
    fine.save(&store).unwrap();
    cb.det_state.save(&state).unwrap();

    let resume = |incremental: bool| {
        let mut cb = CbSystem::new();
        cb.adopt_db(cbench::tsdb::Db::load(&store).unwrap());
        cb.det_state = cbench::regress::DetectorState::load(&state).unwrap();
        cb.set_incremental_detection(incremental);
        let mut projects = vec![CampaignProject::new("walberla-0", ProjectKind::Walberla)];
        let cfg2 = CampaignConfig {
            pushes: 3,
            inject_at: 2,
            penalty: 0.15,
            seed: 22,
            incremental,
            ..CampaignConfig::default()
        };
        let out = run_campaign_with(&mut cb, &mut projects, &cfg2, icx36_walberla_jobs).unwrap();
        (out, cb)
    };
    let (out_inc, cb_inc) = resume(true);
    let (_, cb_req) = resume(false);
    assert!(out_inc.alerts_opened() > 0, "follow-up regression found on resumed history");
    assert_eq!(
        cb_inc.alerts.to_json().to_string_pretty(),
        cb_req.alerts.to_json().to_string_pretty(),
        "carried state and re-query agree on the resumed run's alerts"
    );
    // closing incremental save: cold shards kept, only touched ones written
    let mut cb_inc = cb_inc;
    let rep = cb_inc.db.save_report(&store).unwrap();
    assert!(rep.shards_written >= 1, "{rep:?}");
    assert!(rep.shards_kept >= 1, "cold shards must stay untouched: {rep:?}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn campaign_bisect_rebuilds_chains_and_finds_injected_commit() {
    // close the ROADMAP gap end to end: a campaign plants a regression,
    // the alert names the campaign repository, and a *rebuilt* campaign
    // chain (same config, fresh projects) bisects to the injected round
    let cfg = CampaignConfig {
        pushes: 4,
        inject_at: 3,
        penalty: 0.15,
        seed: 9,
        ..CampaignConfig::default()
    };
    let mut cb = CbSystem::new();
    let mut projects = vec![CampaignProject::new("walberla-0", ProjectKind::Walberla)];
    let out = run_campaign_with(&mut cb, &mut projects, &cfg, icx36_walberla_jobs).unwrap();
    assert!(out.alerts_opened() > 0);
    let alert = {
        let active = cb.alerts.active();
        let mut best = active[0];
        for &a in &active {
            if a.confidence > best.confidence {
                best = a;
            }
        }
        best.clone()
    };
    assert_eq!(alert.group.get("repo").map(|s| s.as_str()), Some("walberla-0"));

    // rebuild the chains from nothing but the campaign arguments
    let mut rebuilt = vec![CampaignProject::new("walberla-0", ProjectKind::Walberla)];
    let events = campaign_push_events(&mut rebuilt, &cfg);
    let chain: Vec<PushEvent> = events.into_iter().map(|(_, e)| e).collect();
    assert_eq!(chain.len(), 4);
    // the rebuilt commits are the ones the campaign benchmarked: their
    // ids appear as commit tags in the campaign's TSDB
    let commits = cb.db.tag_values("lbm", "commit");
    for ev in &chain {
        assert!(commits.contains(&ev.commit_id[..8].to_string()), "{}", ev.commit_id);
    }

    let mut cb2 = CbSystem::new();
    let report = bisect_pipeline(
        &mut cb2,
        &rebuilt[0].repo,
        "master",
        &chain[0].commit_id,
        &chain[3].commit_id,
        &alert.measurement,
        &alert.field,
        &alert.group,
        alert.direction,
        0.08,
        |repo, commit| {
            ProjectKind::Walberla
                .jobs_for(repo, commit)
                .into_iter()
                .filter(|j| j.ci.get("HOST") == Some("icx36"))
                .collect()
        },
    )
    .unwrap();
    // push round 3 (index 2) planted the kernel-regen penalty
    assert_eq!(report.first_bad.as_deref(), Some(chain[2].commit_id.as_str()));
    assert!(report.pipeline_runs <= report.linear_runs + 1);
}
