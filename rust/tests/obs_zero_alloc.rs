//! The disabled-path cost contract of `obs::`: with metrics recording
//! off (the default) and a disabled trace recorder, the instrumentation
//! hooks that sit on the TSDB/coordinator hot paths must not allocate at
//! all — a counting global allocator proves it.
//!
//! This lives in its own test binary on purpose: integration test
//! binaries run their `#[test]`s in parallel threads sharing one global
//! allocator, so any sibling test's allocations would race the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn disabled_observability_paths_allocate_nothing() {
    use cbench::obs::metrics as om;
    use cbench::obs::trace::TraceRecorder;

    assert!(!om::enabled(), "metrics recording must default to off");
    let mut rec = TraceRecorder::disabled();

    // warm up any lazy statics outside the measured window
    om::add(om::Counter::LpLines, 1);
    let t = om::Timer::start();
    t.stop(om::TimedOp::LpParse);
    rec.span(0, "run", "warmup", "repo", "node", 0.0, 1.0);

    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        om::add(om::Counter::InsertPoints, i);
        let t = om::Timer::start();
        t.stop(om::TimedOp::Insert);
        rec.span(0, "run", "hot", "repo", "node", 0.0, 1.0);
        rec.span_m(0, "job", "hot2", "repo", "node", 0.0, 2.0, &[("k", "v")]);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(after - before, 0, "disabled obs hooks must not allocate");
    assert!(rec.is_empty(), "disabled recorder must record nothing");
    assert_eq!(om::get(om::Counter::InsertPoints), 0, "disabled counters stay zero");
}
