//! End-to-end tests of the regress:: loop: a planted waLBerla regression
//! flows commit -> pipeline -> TSDB -> detector -> alert -> bisection,
//! exactly the path `cbench pipeline --inject-regression` +
//! `cbench regress <detect|bisect>` drives from the CLI.

use cbench::coordinator::{
    detect_regressions, walberla_pipeline::walberla_pipeline_jobs, CbSystem, PreparedJob,
};
use cbench::regress::{bisect_pipeline, AlertBook, Detector, Direction, Policy};
use cbench::tsdb::{Db, Point};
use cbench::vcs::{PushEvent, Repository};

const N_COMMITS: usize = 8;
const BAD_AT: usize = 5; // 1-based: commit #5 plants the regression

/// The same deterministic history `cbench pipeline --commits 8
/// --inject-regression 5` builds.
fn history() -> (Repository, Vec<PushEvent>) {
    let mut repo = Repository::new("walberla");
    let mut events = Vec::new();
    for i in 0..N_COMMITS {
        let ev = if i + 1 == BAD_AT {
            repo.commit_change(
                "master",
                "dev",
                &format!("change #{i} (kernel regen, perf bug)"),
                i as f64 * 60.0,
                "benchmark.cfg",
                "lbm_efficiency_penalty = 0.15\n",
            )
        } else {
            repo.commit_change(
                "master",
                "dev",
                &format!("change #{i}"),
                i as f64 * 60.0,
                "src/kernel.c",
                &format!("// rev {i}\n"),
            )
        };
        events.push(ev);
    }
    (repo, events)
}

/// The icx36 slice of the waLBerla matrix — 4 collision operators +
/// FSLBM, enough to exercise detection without the full 55-job fan-out.
fn icx36_jobs(repo: &Repository, commit: &str) -> Vec<PreparedJob> {
    walberla_pipeline_jobs(repo, commit)
        .into_iter()
        .filter(|j| j.ci.get("HOST") == Some("icx36"))
        .collect()
}

#[test]
fn injected_regression_detected_with_confidence_and_suspect_commit() {
    let (repo, events) = history();
    let mut cb = CbSystem::new();
    for (i, ev) in events.iter().enumerate() {
        let r = cb
            .execute_pipeline(ev, true, icx36_jobs(&repo, &ev.commit_id), "lbm")
            .unwrap();
        // the coordinator's post-upload hook opens the alerts exactly at
        // the injected commit, not before
        if i + 1 < BAD_AT {
            assert_eq!(r.regressions.opened, 0, "pipeline {}", i + 1);
        } else if i + 1 == BAD_AT {
            assert_eq!(r.regressions.opened, 4, "one alert per collision operator");
        }
    }
    let bad_short = &events[BAD_AT - 1].commit_id[..8];

    // detector over the final TSDB: all four series still flagged, each
    // locating the injected commit via the CUSUM split
    let findings = Detector::with_default_policies().detect(&cb.db);
    assert_eq!(findings.len(), 4);
    for f in &findings {
        assert!(f.rel_change < -0.10, "{}: rel {}", f.series, f.rel_change);
        assert!(f.confidence > 0.8, "{}: conf {}", f.series, f.confidence);
        assert!(f.best_p().unwrap() < 0.05, "{}", f.series);
        assert_eq!(
            f.suspect_commit.as_deref(),
            Some(bad_short),
            "{} suspects the wrong commit",
            f.series
        );
    }

    // alert book round-trips through JSON with the suspect commit intact
    let path = std::env::temp_dir().join("cbench_regress_e2e_alerts.json");
    cb.alerts.save(&path).unwrap();
    let book = AlertBook::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(book.active().len(), 4);
    assert!(book
        .active()
        .iter()
        .all(|a| a.suspect_commit.as_deref() == Some(bad_short)));

    // the legacy API still answers over the same data (older window=1
    // semantics: by commit 8 the drop is 3 pipelines old, so clean)
    let legacy = detect_regressions(&cb.db, "lbm", "mlups", &["case", "collision_op"], 0.1, true);
    assert!(legacy.is_empty());
}

#[test]
fn bisection_pins_injected_commit_with_log_runs() {
    let (repo, events) = history();
    // an alert for the srt series, as detection would produce it
    let mut cb_hist = CbSystem::new();
    for ev in &events {
        cb_hist
            .execute_pipeline(ev, true, icx36_jobs(&repo, &ev.commit_id), "lbm")
            .unwrap();
    }
    let alert = cb_hist
        .alerts
        .active()
        .into_iter()
        .find(|a| a.series.contains("collision_op=srt"))
        .expect("srt alert open")
        .clone();

    let mut cb = CbSystem::new();
    let report = bisect_pipeline(
        &mut cb,
        &repo,
        "master",
        &events.first().unwrap().commit_id,
        &events.last().unwrap().commit_id,
        "lbm",
        "mlups",
        &alert.group,
        Direction::HigherIsBetter,
        0.08,
        |r, c| icx36_jobs(r, c),
    )
    .unwrap();

    assert_eq!(
        report.first_bad.as_deref(),
        Some(events[BAD_AT - 1].commit_id.as_str()),
        "bisection must pin commit #{BAD_AT}"
    );
    assert_eq!(report.candidates, N_COMMITS - 1);
    assert!(
        report.pipeline_runs < report.linear_runs,
        "binary search used {} runs, linear needs {}",
        report.pipeline_runs,
        report.linear_runs
    );
}

#[test]
fn shim_keeps_legacy_semantics_while_detector_sees_history() {
    // series where the drop happened one pipeline *before* the latest:
    // the legacy last-vs-prev check is blind, the windowed detector not
    let mut db = Db::new();
    for (i, v) in [1000.0, 1000.0, 1000.0, 1000.0, 840.0, 842.0].iter().enumerate() {
        db.insert(
            Point::new("lbm", i as i64 * 1_000_000_000)
                .tag("case", "uniformgridcpu")
                .tag("node", "icx36")
                .tag("collision_op", "srt")
                .field("mlups", *v),
        );
    }
    let legacy = detect_regressions(&db, "lbm", "mlups", &["collision_op"], 0.1, true);
    assert!(legacy.is_empty(), "legacy semantics: prev->last is only -0.2%");

    let findings = Detector::with_default_policies().detect(&db);
    assert_eq!(findings.len(), 1, "windowed detector sees the regime change");
    assert!(findings[0].rel_change < -0.15);

    // and the shim still fires on a fresh last-point drop, exactly like
    // the seed behavior it wraps
    db.insert(
        Point::new("lbm", 6_000_000_000)
            .tag("case", "uniformgridcpu")
            .tag("node", "icx36")
            .tag("collision_op", "srt")
            .field("mlups", 600.0),
    );
    let legacy = detect_regressions(&db, "lbm", "mlups", &["collision_op"], 0.1, true);
    assert_eq!(legacy.len(), 1);
    assert_eq!(legacy[0].before, 842.0);
    assert_eq!(legacy[0].after, 600.0);
}

#[test]
fn custom_policy_watches_runtime_with_opposite_direction() {
    // the UniformGrid jobs also report runtime (∝ 1/MLUPs, lower is
    // better) — a custom policy over the time-like metric catches the
    // same planted penalty with the opposite sign convention
    let (repo, events) = history();
    let mut cb = CbSystem::new();
    for ev in &events {
        cb.execute_pipeline(ev, true, icx36_jobs(&repo, &ev.commit_id), "lbm")
            .unwrap();
    }
    let det = Detector::new().policy(
        Policy::new("uniform-runtime", "lbm", "runtime")
            .group_by(&["case", "node", "collision_op"])
            .direction(Direction::LowerIsBetter)
            .thresholds(0.05, 0.05, 0.5),
    );
    let findings = det.detect(&cb.db);
    let uniform: Vec<_> = findings
        .iter()
        .filter(|f| f.series.contains("uniformgridcpu"))
        .collect();
    assert_eq!(uniform.len(), 4, "all four operators slowed down");
    for f in uniform {
        // 15% throughput penalty = 1/0.85 - 1 ≈ +17.6% runtime
        assert!(f.rel_change > 0.15, "runtime rose: {}", f.rel_change);
        assert_eq!(f.direction, Direction::LowerIsBetter);
        assert!(f.confidence > 0.8);
    }
}
