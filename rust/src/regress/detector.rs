//! Policy-driven regression detection over the TSDB.
//!
//! A [`Policy`] names one measurement/field, how to group it into series
//! (the MLUP/s-up vs TTS-down convention lives in [`Direction`]), and the
//! statistical knobs: baseline/recent window sizes, the minimum relative
//! change worth flagging, and the significance level. [`Detector::detect`]
//! evaluates every policy against the database and emits confidence-scored
//! [`Finding`]s — the input to the alert lifecycle
//! ([`crate::regress::alerts`]) and the bisection driver
//! ([`crate::regress::bisect`]).
//!
//! Unlike the seed's last-vs-previous check, a series is split into a
//! *baseline* regime and a *recent* regime — by CUSUM change-point
//! location when the series carries a visible level shift, by trailing
//! windows otherwise — and the regimes are compared with Welch's t-test
//! and Mann–Whitney U (or a z-score when the recent regime is a single
//! pipeline execution).

use super::stats::{
    cusum_changepoint, mann_whitney, mean, normal_two_sided_p, welch_t, BaselineStats,
};
use crate::tsdb::{Db, GroupedSeries, Query};
use std::collections::BTreeMap;

/// Sign convention for "worse": throughput-like metrics regress when they
/// drop, time-like metrics when they rise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
}

impl Direction {
    /// Map a relative change onto an "adverse" magnitude: positive values
    /// mean the metric moved the wrong way.
    pub fn adverse(self, rel: f64) -> f64 {
        match self {
            Direction::HigherIsBetter => -rel,
            Direction::LowerIsBetter => rel,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Direction::HigherIsBetter => "higher-is-better",
            Direction::LowerIsBetter => "lower-is-better",
        }
    }
    pub fn from_name(s: &str) -> Option<Direction> {
        match s {
            "higher-is-better" => Some(Direction::HigherIsBetter),
            "lower-is-better" => Some(Direction::LowerIsBetter),
            _ => None,
        }
    }
}

/// One detection policy: which series to watch and how suspicious to be.
#[derive(Debug, Clone)]
pub struct Policy {
    pub name: String,
    pub measurement: String,
    pub field: String,
    pub group_by: Vec<String>,
    pub direction: Direction,
    /// Maximum number of points forming the baseline regime.
    pub baseline_window: usize,
    /// Number of trailing points forming the recent regime when no change
    /// point is located (1 = the latest pipeline execution).
    pub recent_window: usize,
    /// Minimum adverse relative change (vs the baseline mean) to flag.
    pub min_rel_change: f64,
    /// Significance level: findings whose best p-value exceeds this are
    /// suppressed as noise (set to 1.0 to disable the statistical gate).
    pub alpha: f64,
    /// Findings below this confidence are dropped.
    pub min_confidence: f64,
    /// Split the series at a located CUSUM change point instead of a
    /// fixed trailing window when the shift is clear enough.
    pub use_changepoint: bool,
    /// Materialize full series instead of the bounded `tail(n)` pushdown.
    /// The pushdown restricts the scan to the trailing distinct
    /// timestamps, which excludes series that stopped reporting (and, on
    /// unscoped multi-tenant queries, shrinks per-tenant windows) — the
    /// legacy `detect_regressions` shim opts out to keep its exact
    /// pre-pushdown semantics at pre-pushdown cost.
    pub scan_full_history: bool,
}

impl Policy {
    pub fn new(name: &str, measurement: &str, field: &str) -> Policy {
        Policy {
            name: name.to_string(),
            measurement: measurement.to_string(),
            field: field.to_string(),
            group_by: Vec::new(),
            direction: Direction::HigherIsBetter,
            baseline_window: 8,
            recent_window: 1,
            min_rel_change: 0.05,
            alpha: 0.05,
            min_confidence: 0.5,
            use_changepoint: true,
            scan_full_history: false,
        }
    }
    pub fn group_by(mut self, tags: &[&str]) -> Policy {
        self.group_by = tags.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn direction(mut self, d: Direction) -> Policy {
        self.direction = d;
        self
    }
    pub fn windows(mut self, baseline: usize, recent: usize) -> Policy {
        self.baseline_window = baseline.max(1);
        self.recent_window = recent.max(1);
        self
    }
    pub fn thresholds(mut self, min_rel_change: f64, alpha: f64, min_confidence: f64) -> Policy {
        self.min_rel_change = min_rel_change;
        self.alpha = alpha;
        self.min_confidence = min_confidence;
        self
    }
    pub fn changepoint(mut self, on: bool) -> Policy {
        self.use_changepoint = on;
        self
    }
    pub fn full_history(mut self, on: bool) -> Policy {
        self.scan_full_history = on;
        self
    }
}

/// Minimum normalized CUSUM excursion for a change-point split to be
/// trusted over the plain trailing window.
const CUSUM_MIN_STAT: f64 = 0.9;

/// A confidence-scored regression finding on one series.
#[derive(Debug, Clone)]
pub struct Finding {
    pub policy: String,
    pub measurement: String,
    pub field: String,
    /// Group label, e.g. `collision_op=srt,node=icx36`.
    pub series: String,
    pub group: BTreeMap<String, String>,
    pub direction: Direction,
    pub baseline: BaselineStats,
    /// Mean of the recent regime.
    pub current: f64,
    /// (current - baseline.mean) / baseline.mean.
    pub rel_change: f64,
    /// Welch's t-test p-value (baseline vs recent), when both regimes
    /// carry at least 2 points.
    pub p_welch: Option<f64>,
    /// Mann–Whitney U p-value, same requirement.
    pub p_mann_whitney: Option<f64>,
    /// z-score p-value of the recent mean against the baseline spread,
    /// when the recent regime is a single point.
    pub p_z: Option<f64>,
    /// Timestamp of the first point of the degraded regime.
    pub change_ts: i64,
    /// `commit` tag of the point at `change_ts`, when present.
    pub suspect_commit: Option<String>,
    /// Combined score in [0, 1].
    pub confidence: f64,
    /// The newest point of this series is a carried-forward value
    /// (`carried=1`, written by change-aware selection for skipped jobs)
    /// rather than a fresh measurement. Carried findings keep existing
    /// alerts alive but are not evidence of anything new: the alert book
    /// must neither open a fresh alert from one nor auto-resolve on the
    /// series' absence from a finding set.
    pub carried: bool,
}

impl Finding {
    /// Best available p-value across the tests that ran.
    pub fn best_p(&self) -> Option<f64> {
        [self.p_welch, self.p_mann_whitney, self.p_z]
            .into_iter()
            .flatten()
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
    }
}

/// Evaluate one already-grouped series against a policy.
///
/// `points` must be time-ordered (the TSDB guarantees this). Returns a
/// finding when the recent regime is adversely shifted beyond the policy
/// thresholds; `suspect_commit` is left empty for the caller to fill.
pub fn evaluate_series(
    policy: &Policy,
    series_label: &str,
    group: &BTreeMap<String, String>,
    points: &[(i64, f64)],
) -> Option<Finding> {
    if points.len() < 2 {
        return None;
    }
    // Rolling-baseline horizon: only the trailing baseline_window +
    // recent_window points participate. This keeps the check O(window)
    // on the per-pipeline hot path and, more importantly, stops an *old*
    // level shift deep in the history from anchoring the CUSUM split and
    // masking a fresh regression — shifts older than the horizon have
    // aged into the baseline (the rolling-threshold model CB suites use).
    let lookback = (policy.baseline_window + policy.recent_window).max(2);
    let points = &points[points.len().saturating_sub(lookback)..];
    let values: Vec<f64> = points.iter().map(|p| p.1).collect();

    // --- split into baseline / recent regimes ---
    let mut split = values.len() - policy.recent_window.min(values.len() - 1);
    if policy.use_changepoint {
        let c = cusum_changepoint(&values);
        if let Some(idx) = c.index {
            if c.stat >= CUSUM_MIN_STAT && idx >= 1 && idx < values.len() {
                split = idx;
            }
        }
    }
    let base_start = split.saturating_sub(policy.baseline_window);
    let baseline_vals = &values[base_start..split];
    let recent_vals = &values[split..];
    if baseline_vals.is_empty() || recent_vals.is_empty() {
        return None;
    }

    let baseline = BaselineStats::of(baseline_vals);
    if baseline.mean.abs() < 1e-300 {
        return None;
    }
    let current = mean(recent_vals);
    let rel_change = (current - baseline.mean) / baseline.mean;
    let adverse = policy.direction.adverse(rel_change);
    if !(adverse > policy.min_rel_change) {
        return None;
    }
    // the *latest* point must still be adverse — a regression that a later
    // commit already fixed should not stay flagged
    let last = *values.last().unwrap();
    let last_adverse = policy.direction.adverse((last - baseline.mean) / baseline.mean);
    if !(last_adverse > 0.5 * policy.min_rel_change) {
        return None;
    }

    // --- statistical evidence ---
    let p_welch = welch_t(baseline_vals, recent_vals).map(|t| t.p);
    let p_mann_whitney = mann_whitney(baseline_vals, recent_vals).map(|t| t.p);
    let p_z = if recent_vals.len() == 1 && baseline.n >= 2 {
        Some(if baseline.sd > 0.0 {
            normal_two_sided_p((current - baseline.mean) / baseline.sd)
        } else if current == baseline.mean {
            1.0
        } else {
            0.0
        })
    } else {
        None
    };
    let best_p = [p_welch, p_mann_whitney, p_z]
        .into_iter()
        .flatten()
        .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    if let Some(p) = best_p {
        if p > policy.alpha {
            return None;
        }
    }

    // --- confidence: how far past the threshold + how significant ---
    let c_rel = (adverse / (2.0 * policy.min_rel_change)).clamp(0.0, 1.0);
    let c_stat = best_p.map(|p| 1.0 - p.clamp(0.0, 1.0)).unwrap_or(c_rel);
    let confidence = 0.5 * c_rel + 0.5 * c_stat;
    if confidence < policy.min_confidence {
        return None;
    }

    Some(Finding {
        policy: policy.name.clone(),
        measurement: policy.measurement.clone(),
        field: policy.field.clone(),
        series: series_label.to_string(),
        group: group.clone(),
        direction: policy.direction,
        baseline,
        current,
        rel_change,
        p_welch,
        p_mann_whitney,
        p_z,
        change_ts: points[split].0,
        suspect_commit: None,
        confidence,
        carried: false,
    })
}

/// Canonical `policy/series` fingerprint — the alert-dedup key shared by
/// the detector (which reports what it *evaluated*) and the alert book
/// (which auto-resolves only series that were evaluated and came back
/// healthy).
pub fn series_fingerprint(policy: &str, series: &str) -> String {
    format!("{policy}/{series}")
}

/// Look up the `commit` tag of the point of `measurement` at timestamp
/// `ts` whose tags agree with `group` (group values of `<none>` match an
/// absent tag) — maps a located change point back to the offending commit.
pub fn commit_at(
    db: &Db,
    measurement: &str,
    group: &BTreeMap<String, String>,
    ts: i64,
) -> Option<String> {
    // shard-index lookup: one O(log shards + log shard_size) probe per
    // finding instead of a full-history scan (same pushdown as the query
    // layer — only the shard containing `ts` is touched)
    db.points_in_range(measurement, Some(ts), Some(ts))
        .find(|p| {
            group.iter().all(|(k, v)| match p.tags.get(k) {
                Some(t) => t == v,
                None => v == "<none>",
            })
        })
        .and_then(|p| p.tags.get("commit").cloned())
}

/// Is the point of `measurement` at timestamp `ts` whose tags agree with
/// `group` (and which carries `field`) a carried-forward value? Change-aware
/// selection writes `carried=1` on points it synthesizes for skipped jobs;
/// the detector probes the newest in-window point of each series so those
/// series are judged but never treated as fresh evidence. The `field`
/// filter matters: a fieldless or foreign-field point at the same (ts,
/// group) must not shadow the real series point; among matches the *last*
/// wins, mirroring how the query layer's field extraction keeps the final
/// value per timestamp.
pub fn carried_at(
    db: &Db,
    measurement: &str,
    group: &BTreeMap<String, String>,
    ts: i64,
    field: &str,
) -> bool {
    db.points_in_range(measurement, Some(ts), Some(ts))
        .filter(|p| {
            p.fields.contains_key(field)
                && group.iter().all(|(k, v)| match p.tags.get(k) {
                    Some(t) => t == v,
                    None => v == "<none>",
                })
        })
        .last()
        .map(|p| p.tags.get(crate::select::CARRIED_TAG).map(|v| v == "1").unwrap_or(false))
        .unwrap_or(false)
}

/// Evaluate one policy over the database, reporting both the findings
/// and the fingerprints of every series that carried enough data to
/// judge (the absence of a finding for an *evaluated* series means
/// "healthy"; for an unevaluated one it means nothing — e.g. a fresh
/// TSDB must not auto-resolve carried-over alerts).
pub fn evaluate_policy_run(policy: &Policy, db: &Db) -> (Vec<Finding>, Vec<String>) {
    evaluate_policy_run_scoped(policy, db, &[])
}

/// [`evaluate_policy_run`] restricted to series matching `scope` tag
/// pairs — but only for tags the policy actually groups by, so a scope
/// of `[("repo", "walberla-0")]` narrows a repo-grouped policy to that
/// repository's series (the multi-tenant per-pipeline check) while
/// leaving repo-agnostic custom policies untouched. Scoping also
/// tightens the `tail(n)` pushdown bound: distinct timestamps are
/// counted among the scoped points only, so co-tenant repositories
/// uploading at interleaved trigger times cannot shrink each other's
/// detection window.
pub fn evaluate_policy_run_scoped(
    policy: &Policy,
    db: &Db,
    scope: &[(&str, &str)],
) -> (Vec<Finding>, Vec<String>) {
    let refs: Vec<&str> = policy.group_by.iter().map(|s| s.as_str()).collect();
    let mut findings = Vec::new();
    let mut evaluated = Vec::new();
    // tail(n) pushdown: the policy only ever looks at its rolling horizon
    // (baseline + recent window), so the query is bounded to the trailing
    // distinct timestamps instead of materializing the full series — the
    // per-pipeline check cost stops growing with history length.
    let lookback = (policy.baseline_window + policy.recent_window).max(2);
    let mut q = Query::new(&policy.measurement, &policy.field).group_by(&refs);
    if !policy.scan_full_history {
        q = q.tail(lookback);
    }
    for (k, v) in scope {
        if policy.group_by.iter().any(|g| g == k) {
            q = q.where_tag(k, v);
        }
    }
    // per-series evaluation is embarrassingly parallel (each series
    // reads its own points; commit_at is a read-only range probe on the
    // now-Sync Db) — fan out and merge in series order, so fingerprints
    // and findings come back exactly as the serial loop produced them
    let series: Vec<GroupedSeries> = q.run(db).into_iter().filter(|s| s.points.len() >= 2).collect();
    let results = crate::par::map(series, |s| {
        let label = s.label();
        // change-aware selection: a series whose newest point is carried
        // forward from an earlier commit is judged (open alerts stay
        // updated, times_seen advances in lockstep with a full run) but
        // is NOT fresh evidence — it must not count as "evaluated" for
        // auto-resolve, and its findings must not open new alerts.
        let carried = s
            .points
            .last()
            .map(|&(ts, _)| carried_at(db, &policy.measurement, &s.group, ts, &policy.field))
            .unwrap_or(false);
        let f = evaluate_series(policy, &label, &s.group, &s.points).map(|mut f| {
            f.suspect_commit = commit_at(db, &policy.measurement, &s.group, f.change_ts);
            f.carried = carried;
            f
        });
        (label, carried, f)
    });
    for (label, carried, f) in results {
        if !carried {
            evaluated.push(series_fingerprint(&policy.name, &label));
        }
        if let Some(f) = f {
            findings.push(f);
        }
    }
    (findings, evaluated)
}

/// Evaluate one policy over the database.
pub fn evaluate_policy(policy: &Policy, db: &Db) -> Vec<Finding> {
    evaluate_policy_run(policy, db).0
}

/// The detector: a set of policies evaluated together.
#[derive(Debug, Clone, Default)]
pub struct Detector {
    pub policies: Vec<Policy>,
}

impl Detector {
    pub fn new() -> Detector {
        Detector::default()
    }

    /// The stock policies for the two instrumented applications: waLBerla
    /// throughput (MLUP/s, higher is better) and FE2TI time-to-solution
    /// (lower is better), grouped exactly like the dashboards. Since the
    /// multi-repo coordinator landed, `repo` is part of every group: two
    /// repositories sharing one Testcluster must not mix their series
    /// (points without a repo tag group under `repo=<none>` as before).
    pub fn with_default_policies() -> Detector {
        Detector::new()
            .policy(
                Policy::new("lbm-mlups", "lbm", "mlups")
                    .group_by(&["case", "node", "collision_op", "gpu", "repo"])
                    .direction(Direction::HigherIsBetter)
                    .thresholds(0.08, 0.05, 0.5),
            )
            .policy(
                Policy::new("fe2ti-tts", "fe2ti", "tts")
                    .group_by(&["case", "node", "solver", "compiler", "parallelization", "repo"])
                    .direction(Direction::LowerIsBetter)
                    .thresholds(0.10, 0.05, 0.5),
            )
            // the benchmarker benchmarked: the coordinator uploads its own
            // ingest/parse/sync throughput as `cbench_self` (obs::metrics)
            // and the same detector watches it. Host-time rates are noisy,
            // hence the wide 30% threshold — the statistical gate does the
            // rest (an injected slowdown is caught; jitter is not).
            .policy(
                Policy::new("self-throughput", "cbench_self", "points_per_sec")
                    .group_by(&["component", "repo"])
                    .direction(Direction::HigherIsBetter)
                    .thresholds(0.30, 0.05, 0.5),
            )
    }

    pub fn policy(mut self, p: Policy) -> Detector {
        self.policies.push(p);
        self
    }

    /// Evaluate every policy.
    pub fn detect(&self, db: &Db) -> Vec<Finding> {
        self.detect_full(db).0
    }

    /// Evaluate every policy, also returning the fingerprints of every
    /// series with enough data to judge (see [`evaluate_policy_run`]).
    pub fn detect_full(&self, db: &Db) -> (Vec<Finding>, Vec<String>) {
        let mut findings = Vec::new();
        let mut evaluated = Vec::new();
        for p in &self.policies {
            let (f, e) = evaluate_policy_run(p, db);
            findings.extend(f);
            evaluated.extend(e);
        }
        (findings, evaluated)
    }

    /// Evaluate only the policies watching `measurement` (the post-upload
    /// hook of `coordinator::collect_pipeline`). Returns the findings and
    /// the evaluated-series fingerprints, so the alert book knows which
    /// absent findings mean "recovered" (and which series simply were
    /// not measurable).
    pub fn detect_measurement(&self, db: &Db, measurement: &str) -> (Vec<Finding>, Vec<String>) {
        self.detect_measurement_scoped(db, measurement, &[])
    }

    /// [`Detector::detect_measurement`] restricted to series matching
    /// `scope` (see [`evaluate_policy_run_scoped`]). The multi-repo
    /// coordinator scopes each pipeline's post-upload check to the
    /// triggering repository: a commit's tuned `regress.*` config judges
    /// only its own repo's series and cannot open, update, or
    /// auto-resolve a co-tenant's alerts.
    pub fn detect_measurement_scoped(
        &self,
        db: &Db,
        measurement: &str,
        scope: &[(&str, &str)],
    ) -> (Vec<Finding>, Vec<String>) {
        let mut findings = Vec::new();
        let mut evaluated = Vec::new();
        for p in self.policies.iter().filter(|p| p.measurement == measurement) {
            let (f, e) = evaluate_policy_run_scoped(p, db, scope);
            findings.extend(f);
            evaluated.extend(e);
        }
        (findings, evaluated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tsdb::Point;
    use crate::util::rng::Rng;

    fn series(vals: &[f64]) -> Vec<(i64, f64)> {
        vals.iter().enumerate().map(|(i, &v)| (i as i64, v)).collect()
    }

    fn policy() -> Policy {
        Policy::new("t", "m", "v").thresholds(0.08, 0.05, 0.5)
    }

    #[test]
    fn clean_series_yields_no_finding() {
        let mut rng = Rng::new(1);
        let vals: Vec<f64> = (0..20).map(|_| rng.gauss(1000.0, 5.0)).collect();
        let g = BTreeMap::new();
        assert!(evaluate_series(&policy(), "all", &g, &series(&vals)).is_none());
    }

    #[test]
    fn step_drop_is_found_with_high_confidence() {
        // the drop is 4 pipelines old: still inside the rolling horizon
        // (baseline_window 8 + recent 1), with enough baseline points in
        // the tail for the two-sample tests to run
        let mut rng = Rng::new(2);
        let vals: Vec<f64> = (0..20)
            .map(|i| {
                if i < 16 {
                    rng.gauss(1000.0, 5.0)
                } else {
                    rng.gauss(820.0, 5.0)
                }
            })
            .collect();
        let g = BTreeMap::new();
        let f = evaluate_series(&policy(), "all", &g, &series(&vals)).expect("finding");
        assert!(f.rel_change < -0.15, "rel={}", f.rel_change);
        assert!(f.confidence > 0.8, "conf={}", f.confidence);
        // change located at the step (timestamps are indices here)
        assert!((f.change_ts - 16).abs() <= 2, "change_ts={}", f.change_ts);
        assert!(f.best_p().unwrap() < 0.01);
        assert!(f.baseline.n >= 2, "two-sample tests had a real baseline");
    }

    #[test]
    fn old_shift_outside_horizon_does_not_mask_fresh_regression() {
        // an ancient optimization (500 -> 1000) followed much later by a
        // fresh -10% drop: the rolling horizon must anchor on the fresh
        // drop, not the big historical jump
        let mut vals = vec![500.0; 10];
        vals.extend(vec![1000.0; 10]);
        vals.extend(vec![900.0; 2]);
        let g = BTreeMap::new();
        let f = evaluate_series(&policy(), "all", &g, &series(&vals)).expect("finding");
        assert!((f.rel_change + 0.10).abs() < 1e-9, "rel={}", f.rel_change);
        assert_eq!(f.baseline.mean, 1000.0);
        // located at the fresh drop (index 20), not the old jump (10)
        assert_eq!(f.change_ts, 20);
    }

    #[test]
    fn improvement_is_not_a_regression() {
        let vals: Vec<f64> = (0..12).map(|i| if i < 8 { 1000.0 } else { 1300.0 }).collect();
        let g = BTreeMap::new();
        assert!(evaluate_series(&policy(), "all", &g, &series(&vals)).is_none());
        // but the same shape on a lower-is-better metric is one
        let p = policy().direction(Direction::LowerIsBetter);
        assert!(evaluate_series(&p, "all", &g, &series(&vals)).is_some());
    }

    #[test]
    fn fixed_regression_is_not_flagged() {
        // bad regime in the middle, last commits recovered
        let vals: Vec<f64> =
            [1000.0, 1000.0, 1000.0, 800.0, 800.0, 800.0, 1000.0, 1000.0].to_vec();
        let g = BTreeMap::new();
        assert!(evaluate_series(&policy(), "all", &g, &series(&vals)).is_none());
    }

    #[test]
    fn small_drift_below_threshold_is_suppressed() {
        // a clean 3% step: located by CUSUM, but below min_rel_change=8%
        let vals: Vec<f64> = (0..20).map(|i| if i < 15 { 1000.0 } else { 970.0 }).collect();
        let g = BTreeMap::new();
        assert!(evaluate_series(&policy(), "all", &g, &series(&vals)).is_none());
    }

    #[test]
    fn single_new_point_uses_z_test() {
        let vals = [1000.0, 1001.0, 999.0, 1000.5, 999.5, 800.0];
        let g = BTreeMap::new();
        let p = policy().changepoint(false);
        let f = evaluate_series(&p, "all", &g, &series(&vals)).expect("finding");
        assert!(f.p_z.is_some());
        assert!(f.p_welch.is_none());
        assert!(f.p_z.unwrap() < 1e-6);
        assert_eq!(f.baseline.n, 5);
        assert_eq!(f.current, 800.0);
    }

    #[test]
    fn detector_finds_injected_commit_in_db() {
        let mut db = Db::new();
        for i in 0..8i64 {
            let v = if i < 4 { 1000.0 } else { 850.0 };
            db.insert(
                Point::new("lbm", i * 1_000_000_000)
                    .tag("case", "uniformgridcpu")
                    .tag("node", "icx36")
                    .tag("collision_op", "srt")
                    .tag("commit", &format!("c{i:07}"))
                    .field("mlups", v),
            );
        }
        let det = Detector::with_default_policies();
        let findings = det.detect(&db);
        assert_eq!(findings.len(), 1);
        let f = &findings[0];
        assert_eq!(f.suspect_commit.as_deref(), Some("c0000004"));
        assert_eq!(f.change_ts, 4_000_000_000);
        assert!(f.confidence > 0.8);
        assert!(f.series.contains("collision_op=srt"));
        // gpu tag absent -> grouped as <none>
        assert!(f.group["gpu"] == "<none>");
        let (fs, evaluated) = det.detect_measurement(&db, "lbm");
        assert_eq!(fs.len(), 1);
        // evaluated fingerprints name the concrete series, not the policy
        assert_eq!(evaluated.len(), 1);
        assert!(evaluated[0].starts_with("lbm-mlups/"), "{}", evaluated[0]);
        assert!(evaluated[0].contains("collision_op=srt"));
        assert!(det.detect_measurement(&db, "fe2ti").0.is_empty());
        assert!(det.detect_measurement(&db, "fe2ti").1.is_empty());
    }

    #[test]
    fn carried_newest_series_is_judged_but_not_evaluated() {
        // same injected regression as detector_finds_injected_commit_in_db,
        // but the newest point is a carried-forward copy of the previous
        // one: the finding survives (tagged carried) while the series
        // drops out of the evaluated set, so the alert book can keep an
        // open alert alive without treating the carry as fresh evidence
        let mut db = Db::new();
        for i in 0..8i64 {
            let v = if i < 4 { 1000.0 } else { 850.0 };
            let mut p = Point::new("lbm", i * 1_000_000_000)
                .tag("case", "uniformgridcpu")
                .tag("node", "icx36")
                .tag("collision_op", "srt")
                .tag("commit", &format!("c{i:07}"))
                .field("mlups", v);
            if i == 7 {
                p = p.tag(crate::select::CARRIED_TAG, "1");
            }
            db.insert(p);
        }
        let det = Detector::with_default_policies();
        let (findings, evaluated) = det.detect_measurement(&db, "lbm");
        assert_eq!(findings.len(), 1);
        assert!(findings[0].carried);
        assert!(evaluated.is_empty(), "carried-newest series must not auto-resolve");
        // a fresh measurement at the tail flips both back
        db.insert(
            Point::new("lbm", 8_000_000_000)
                .tag("case", "uniformgridcpu")
                .tag("node", "icx36")
                .tag("collision_op", "srt")
                .tag("commit", "c0000008")
                .field("mlups", 850.0),
        );
        let (findings, evaluated) = det.detect_measurement(&db, "lbm");
        assert_eq!(findings.len(), 1);
        assert!(!findings[0].carried);
        assert_eq!(evaluated.len(), 1);
    }

    #[test]
    fn carried_at_requires_the_probed_field() {
        // a fieldless annotation point sharing (ts, group) with the real
        // series point must not shadow it
        let mut db = Db::new();
        db.insert(Point::new("m", 5).tag("node", "a").field("v", 1.0));
        db.insert(
            Point::new("m", 5)
                .tag("node", "a")
                .tag(crate::select::CARRIED_TAG, "1")
                .field("other", 2.0),
        );
        let mut g = BTreeMap::new();
        g.insert("node".to_string(), "a".to_string());
        assert!(!carried_at(&db, "m", &g, 5, "v"));
        assert!(carried_at(&db, "m", &g, 5, "other"));
        assert!(!carried_at(&db, "m", &g, 6, "v"));
    }

    #[test]
    fn commit_at_respects_group_and_none() {
        let mut db = Db::new();
        db.insert(
            Point::new("m", 5)
                .tag("node", "a")
                .tag("commit", "abc")
                .field("v", 1.0),
        );
        db.insert(
            Point::new("m", 5)
                .tag("node", "b")
                .tag("gpu", "h100")
                .tag("commit", "def")
                .field("v", 2.0),
        );
        let mut g = BTreeMap::new();
        g.insert("node".to_string(), "b".to_string());
        assert_eq!(commit_at(&db, "m", &g, 5).as_deref(), Some("def"));
        g.insert("gpu".to_string(), "<none>".to_string());
        assert_eq!(commit_at(&db, "m", &g, 5), None);
        g.insert("gpu".to_string(), "h100".to_string());
        assert_eq!(commit_at(&db, "m", &g, 5).as_deref(), Some("def"));
        assert_eq!(commit_at(&db, "m", &g, 6), None);
    }
}
