//! Incremental per-series detection state — the "don't redo old work"
//! contract applied to the detector itself.
//!
//! Before this module, every per-pipeline regression check re-ran the
//! bounded `tail(n)` query from scratch: walk the TSDB backwards, find
//! the trailing window, regroup the series, evaluate. The pushdown made
//! that flat in *history depth*, but each collect still re-derived the
//! whole window from storage — and on a lazily-loaded manifest store it
//! re-materialized the newest shards every time. [`DetectorState`]
//! carries the window **across collects** instead: per-series rolling
//! baselines plus the bookkeeping needed to reproduce the query path's
//! staleness semantics, updated from the points a collect appended, so
//! per-pipeline detection reads only what is new.
//!
//! # The equivalence contract
//!
//! `DetectorState::sync` + [`DetectorState::detect_measurement_scoped`]
//! produce **byte-identical findings and evaluated-series fingerprints**
//! to `Detector::detect_measurement_scoped` (the full re-query path) on
//! the same database — same series, same order, same numbers, same
//! suspect commits. That includes the subtle parts of the query
//! semantics:
//!
//! * the unscoped `tail(n)` bound counts the trailing distinct
//!   timestamps of the whole measurement (field-agnostic, like
//!   `Db::tail_start_ts`);
//! * the repo-scoped bound counts distinct timestamps among *matching*
//!   points only, with the `n ×` [`TAIL_SCAN_SLACK`] cap on the global
//!   reverse walk (a tenant staler than the cap is "not measured
//!   anymore", exactly as the query treats it);
//! * per-series trailing windows keep insertion order within equal
//!   timestamps, and series are only *evaluated* when ≥ 2 points
//!   survive the bound.
//!
//! `rust/tests/property.rs` holds the randomized equivalence suite;
//! `campaign_e2e` pins byte-identical alert books across whole
//! campaigns. Policies that opt out of the pushdown
//! (`Policy::scan_full_history`) and scopes the state does not model
//! fall back to the re-query path verbatim, so the contract holds
//! unconditionally.
//!
//! # Invalidation
//!
//! The state is valid only for the detector configuration it was built
//! under: [`detector_fingerprint`] serializes every policy knob, and a
//! mismatch at sync time clears and rebuilds the state (per-commit
//! `regress.*` overrides therefore rebuild on the override commit and
//! again on the next stock commit — config changes are the explicit
//! cost). Rebuilds are *bounded*: they reverse-walk only the trailing
//! `max(lookback × TAIL_SCAN_SLACK)` distinct timestamps, never the full
//! history. The same applies when the database itself changed behind the
//! state's back — each measurement carries a watermark (last ingested
//! timestamp, point count at it, a hash of the last ingested line, and
//! the total point count), so rewound/replaced databases and
//! out-of-order inserts below the watermark are detected and trigger a
//! rebuild. (An in-place edit of *old* points that keeps the total count
//! and the newest line identical is outside the watermark's reach — the
//! TSDB upload path is append-only, so that shape does not occur in the
//! system.)
//!
//! # Persistence
//!
//! [`DetectorState::save`]/[`DetectorState::load`] round-trip the state
//! as JSON next to the alert book (`cbench_detector_state.json` by
//! convention — `--save-state` on the CLI), so a resumed `cbench
//! pipeline`/`campaign` run continues incrementally instead of
//! re-deriving its windows from the TSDB.

use super::detector::{
    commit_at, evaluate_policy_run_scoped, evaluate_series, series_fingerprint, Detector, Finding,
    Policy,
};
use crate::tsdb::{Db, Point, TAIL_SCAN_SLACK};
use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::path::Path;

/// Canonical serialization of a detector's full policy configuration —
/// the state's validity key. Any knob change (windows, thresholds,
/// direction, grouping, policy order, policy count) changes the
/// fingerprint and invalidates carried state.
pub fn detector_fingerprint(det: &Detector) -> String {
    use std::fmt::Write as _;
    let mut s = format!("v1:{}", det.policies.len());
    for p in &det.policies {
        let _ = write!(
            s,
            ";{}|{}|{}|{:?}|{}|{}|{}|{}|{}|{}|{}|{}",
            p.name,
            p.measurement,
            p.field,
            p.group_by,
            p.direction.name(),
            p.baseline_window,
            p.recent_window,
            p.min_rel_change,
            p.alpha,
            p.min_confidence,
            p.use_changepoint,
            p.scan_full_history
        );
    }
    s
}

/// The rolling horizon a policy evaluates (mirrors the detector).
fn lookback_of(p: &Policy) -> usize {
    (p.baseline_window + p.recent_window).max(2)
}

/// FNV-1a over a line — the watermark's cheap content check.
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Replicates `GroupedSeries::label()` for a state-derived group.
fn group_label(group: &BTreeMap<String, String>) -> String {
    if group.is_empty() {
        return "all".to_string();
    }
    group
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// Per-measurement ingestion bookkeeping.
#[derive(Debug, Clone, Default)]
struct MeasState {
    /// Distinct-timestamp counter; the value assigned to the newest
    /// distinct timestamp. Differences of `seq` are ranks in the query
    /// path's capped global reverse walk.
    seq: u64,
    /// Trailing distinct timestamps (capacity: the measurement's largest
    /// policy lookback) — the unscoped `tail(n)` bound.
    distinct: VecDeque<i64>,
    /// Watermark: last ingested timestamp, how many points were ingested
    /// at it, and the FNV hash of the last ingested line.
    wm_ts: i64,
    wm_n: usize,
    wm_hash: u64,
    /// `db.n_points(measurement)` at the end of the last sync — detects
    /// out-of-order inserts landing below the watermark.
    db_points: usize,
}

/// Per-policy rolling windows.
#[derive(Debug, Clone, Default)]
struct PolicyState {
    /// Trailing `lookback` points per series, keyed exactly like the
    /// query layer groups them: `(tag, value-or-"<none>")` pairs in
    /// `group_by` order — iteration order therefore matches the query's
    /// group order, which keeps findings and alert ids byte-identical.
    /// The third element marks carried-forward points (`carried=1`,
    /// written by change-aware selection): the evaluation only reads it
    /// on the newest in-window point, mirroring the requery path's
    /// `carried_at` probe.
    series: BTreeMap<Vec<(String, String)>, VecDeque<(i64, f64, bool)>>,
    /// Repo-scoped bound trackers: per `repo` tag value, the trailing
    /// `lookback` distinct timestamps carrying a matching point, with
    /// the global distinct-ts `seq` at which each occurred (for the
    /// `TAIL_SCAN_SLACK` cap arithmetic).
    scoped: BTreeMap<String, VecDeque<(i64, u64)>>,
}

/// Incremental detection state carried across collects (see the module
/// docs for the equivalence and invalidation contract).
#[derive(Debug, Clone, Default)]
pub struct DetectorState {
    /// [`detector_fingerprint`] of the configuration this state is
    /// valid for.
    config: String,
    measurements: BTreeMap<String, MeasState>,
    /// Keyed by policy *index* (names need not be unique).
    policies: BTreeMap<usize, PolicyState>,
}

impl DetectorState {
    pub fn new() -> DetectorState {
        DetectorState::default()
    }

    /// The configuration fingerprint this state was built under (empty
    /// for a fresh state).
    pub fn config_fingerprint(&self) -> &str {
        &self.config
    }

    /// True when no measurement has been ingested yet.
    pub fn is_empty(&self) -> bool {
        self.measurements.is_empty()
    }

    /// Bring the state up to date with `db` under `det`'s configuration:
    /// a config change clears and rebuilds (bounded), an intact state
    /// ingests only the points appended since the last sync, and any
    /// watermark inconsistency (replaced/rewound database, out-of-order
    /// insert below the watermark) rebuilds the affected measurement.
    pub fn sync(&mut self, det: &Detector, db: &Db) {
        let timer = crate::obs::metrics::Timer::start();
        let fp = detector_fingerprint(det);
        if fp != self.config {
            self.config = fp;
            self.measurements.clear();
            self.policies.clear();
        }
        let mut by_meas: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, p) in det.policies.iter().enumerate() {
            // full-history policies always fall back to the query path —
            // no state is kept for them
            if !p.scan_full_history {
                by_meas.entry(p.measurement.as_str()).or_default().push(i);
            }
        }
        let work: Vec<(String, Vec<usize>)> = by_meas
            .into_iter()
            .map(|(m, v)| (m.to_string(), v))
            .collect();
        for (m, pol_idx) in work {
            self.sync_measurement(det, db, &m, &pol_idx);
        }
        timer.stop(crate::obs::metrics::TimedOp::DetectorSync);
    }

    fn sync_measurement(&mut self, det: &Detector, db: &Db, m: &str, pol_idx: &[usize]) {
        let distinct_cap = pol_idx
            .iter()
            .map(|&i| lookback_of(&det.policies[i]))
            .max()
            .unwrap_or(2);
        let carried = matches!(self.measurements.get(m), Some(ms) if ms.seq > 0);
        if !(carried && self.catch_up(det, db, m, pol_idx, distinct_cap)) {
            self.rebuild_measurement(det, db, m, pol_idx, distinct_cap);
        }
    }

    /// Ingest everything the database appended past the watermark.
    /// Returns `false` (caller rebuilds) on any inconsistency.
    fn catch_up(
        &mut self,
        det: &Detector,
        db: &Db,
        m: &str,
        pol_idx: &[usize],
        distinct_cap: usize,
    ) -> bool {
        let (wm_ts, wm_n, wm_hash, db_points) = {
            let ms = self.measurements.get(m).expect("caller checked");
            (ms.wm_ts, ms.wm_n, ms.wm_hash, ms.db_points)
        };
        let n_db = db.n_points(m);
        if n_db < db_points {
            return false; // database shrank behind the state
        }
        let mut skipped = 0usize;
        let mut ingested = 0usize;
        let mut last_ingested: Option<&Point> = None;
        // the walk starts at the watermark timestamp: everything before
        // it was ingested in earlier syncs; the first `wm_n` points at it
        // too (insertion order within a timestamp is stable)
        for p in db.points_in_range(m, Some(wm_ts), None) {
            if skipped < wm_n {
                if p.ts != wm_ts {
                    return false; // fewer points at the watermark than recorded
                }
                skipped += 1;
                if skipped == wm_n && fnv64(&p.to_line()) != wm_hash {
                    return false; // content changed under the watermark
                }
                continue;
            }
            self.ingest_point(det, pol_idx, m, p, distinct_cap);
            ingested += 1;
            last_ingested = Some(p);
        }
        if skipped != wm_n {
            return false;
        }
        if db_points + ingested != n_db {
            return false; // a point landed below the watermark
        }
        let ms = self.measurements.get_mut(m).expect("present");
        ms.db_points = n_db;
        // the watermark hash is only ever read for the LAST ingested
        // line, so it is computed once per sync, not per point
        if let Some(p) = last_ingested {
            ms.wm_hash = fnv64(&p.to_line());
        }
        true
    }

    /// Bounded cold rebuild: reverse-walk only the trailing
    /// `max(lookback × TAIL_SCAN_SLACK)` distinct timestamps — anything
    /// older is invisible to the bounded query path by construction — and
    /// re-ingest forward from there. Never O(full history).
    fn rebuild_measurement(
        &mut self,
        det: &Detector,
        db: &Db,
        m: &str,
        pol_idx: &[usize],
        distinct_cap: usize,
    ) {
        self.measurements.remove(m);
        for i in pol_idx {
            self.policies.remove(i);
        }
        let depth = pol_idx
            .iter()
            .map(|&i| lookback_of(&det.policies[i]).saturating_mul(TAIL_SCAN_SLACK))
            .max()
            .unwrap_or(0);
        let mut n_dist = 0usize;
        let mut last: Option<i64> = None;
        let mut t_start: Option<i64> = None;
        for p in db.points_iter(m).rev() {
            if last != Some(p.ts) {
                n_dist += 1;
                last = Some(p.ts);
                t_start = Some(p.ts);
                if n_dist == depth {
                    break;
                }
            }
        }
        let Some(t_start) = t_start else {
            return; // empty measurement: no state, nothing evaluable
        };
        let mut last_ingested: Option<&Point> = None;
        for p in db.points_in_range(m, Some(t_start), None) {
            self.ingest_point(det, pol_idx, m, p, distinct_cap);
            last_ingested = Some(p);
        }
        if let Some(ms) = self.measurements.get_mut(m) {
            ms.db_points = db.n_points(m);
            if let Some(p) = last_ingested {
                ms.wm_hash = fnv64(&p.to_line());
            }
        }
    }

    fn ingest_point(
        &mut self,
        det: &Detector,
        pol_idx: &[usize],
        m: &str,
        p: &Point,
        distinct_cap: usize,
    ) {
        crate::obs::metrics::add(crate::obs::metrics::Counter::SyncPoints, 1);
        let seq = {
            let ms = self.measurements.entry(m.to_string()).or_default();
            if ms.seq == 0 || p.ts != ms.wm_ts {
                ms.seq += 1;
                ms.distinct.push_back(p.ts);
                while ms.distinct.len() > distinct_cap {
                    ms.distinct.pop_front();
                }
                ms.wm_ts = p.ts;
                ms.wm_n = 0;
            }
            ms.wm_n += 1;
            // NOTE: wm_hash is NOT updated here — the callers stamp the
            // hash of the last ingested line once per walk (it is only
            // ever compared against the final watermark point)
            ms.seq
        };
        for &i in pol_idx {
            let pol = &det.policies[i];
            if !p.fields.contains_key(&pol.field) {
                continue;
            }
            let lookback = lookback_of(pol);
            let ps = self.policies.entry(i).or_default();
            let key: Vec<(String, String)> = pol
                .group_by
                .iter()
                .map(|t| {
                    (
                        t.clone(),
                        p.tags.get(t).cloned().unwrap_or_else(|| "<none>".to_string()),
                    )
                })
                .collect();
            let buf = ps.series.entry(key).or_default();
            let is_carried = p
                .tags
                .get(crate::select::CARRIED_TAG)
                .map(|v| v == "1")
                .unwrap_or(false);
            buf.push_back((p.ts, p.fields[&pol.field], is_carried));
            while buf.len() > lookback {
                buf.pop_front();
            }
            if pol.group_by.iter().any(|g| g == "repo") {
                if let Some(r) = p.tags.get("repo") {
                    let dq = ps.scoped.entry(r.clone()).or_default();
                    if dq.back().map(|&(ts, _)| ts != p.ts).unwrap_or(true) {
                        dq.push_back((p.ts, seq));
                        while dq.len() > lookback {
                            dq.pop_front();
                        }
                    }
                }
            }
        }
    }

    /// Evaluate `measurement`'s policies from carried state — the
    /// incremental equivalent of `Detector::detect_measurement_scoped`.
    /// The caller must [`DetectorState::sync`] first; `db` is only read
    /// for suspect-commit lookups and for the verbatim fallback paths
    /// (full-history policies, scopes the state does not model).
    pub fn detect_measurement_scoped(
        &self,
        det: &Detector,
        db: &Db,
        measurement: &str,
        scope: &[(&str, &str)],
    ) -> (Vec<Finding>, Vec<String>) {
        let mut findings = Vec::new();
        let mut evaluated = Vec::new();
        for (i, pol) in det.policies.iter().enumerate() {
            if pol.measurement != measurement {
                continue;
            }
            // the query path applies only the scope tags the policy
            // groups by — replicate that projection exactly
            let applied: Vec<(&str, &str)> = scope
                .iter()
                .filter(|(k, _)| pol.group_by.iter().any(|g| g == k))
                .copied()
                .collect();
            let supported = !pol.scan_full_history
                && (applied.is_empty()
                    || (applied.len() == 1 && applied[0].0 == "repo" && applied[0].1 != "<none>"));
            if !supported {
                let (f, e) = evaluate_policy_run_scoped(pol, db, scope);
                findings.extend(f);
                evaluated.extend(e);
                continue;
            }
            let lookback = lookback_of(pol);
            let Some(ms) = self.measurements.get(measurement) else {
                continue; // nothing ingested: nothing evaluable
            };
            let Some(ps) = self.policies.get(&i) else {
                continue;
            };
            let t0 = if applied.is_empty() {
                unscoped_bound(ms, lookback)
            } else {
                scoped_bound(ps, ms, applied[0].1, lookback)
            };
            let Some(t0) = t0 else {
                continue;
            };
            let repo_filter = applied.first().map(|&(_, v)| v);
            // serial filter pass first (cheap), then fan the per-series
            // evaluations across the par pool and merge in series order —
            // identical fingerprint/finding order to the serial loop for
            // any thread count (ps.series is a BTreeMap: stable order)
            let mut cands: Vec<(&Vec<(String, String)>, Vec<(i64, f64)>, bool)> = Vec::new();
            for (key, buf) in &ps.series {
                if let Some(r) = repo_filter {
                    // a series whose repo group is "<none>" comes from
                    // points without the tag — the query's where_tag
                    // excludes those, and the "<none>" scope value itself
                    // took the fallback above
                    match key.iter().find(|(k, _)| k == "repo") {
                        Some((_, v)) if v == r => {}
                        _ => continue,
                    }
                }
                let mut pts: Vec<(i64, f64)> = Vec::with_capacity(buf.len());
                let mut newest_carried = false;
                for &(ts, v, c) in buf.iter().filter(|&&(ts, _, _)| ts >= t0) {
                    pts.push((ts, v));
                    // within equal timestamps insertion order holds, so
                    // the final flag matches the requery path's
                    // last-match-wins `carried_at` probe
                    newest_carried = c;
                }
                if pts.len() < 2 {
                    continue;
                }
                cands.push((key, pts, newest_carried));
            }
            let results = crate::par::map(cands, |(key, pts, carried)| {
                let group: BTreeMap<String, String> = key.iter().cloned().collect();
                let label = group_label(&group);
                let f = evaluate_series(pol, &label, &group, &pts).map(|mut f| {
                    f.suspect_commit = commit_at(db, &pol.measurement, &group, f.change_ts);
                    f.carried = carried;
                    f
                });
                (label, carried, f)
            });
            for (label, carried, f) in results {
                // same rule as the requery path: a carried-newest series
                // is judged but never counts as evaluated (no
                // auto-resolve from a skipped job)
                if !carried {
                    evaluated.push(series_fingerprint(&pol.name, &label));
                }
                if let Some(f) = f {
                    findings.push(f);
                }
            }
        }
        (findings, evaluated)
    }

    // --- persistence -------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut meas = Json::obj();
        for (m, ms) in &self.measurements {
            meas = meas.set(
                m,
                Json::obj()
                    .set("seq", ms.seq.to_string())
                    .set(
                        "distinct",
                        Json::Arr(ms.distinct.iter().map(|t| Json::Str(t.to_string())).collect()),
                    )
                    .set("wm_ts", ms.wm_ts.to_string())
                    .set("wm_n", ms.wm_n)
                    .set("wm_hash", ms.wm_hash.to_string())
                    .set("db_points", ms.db_points),
            );
        }
        let mut pols = Json::obj();
        for (i, ps) in &self.policies {
            let series: Vec<Json> = ps
                .series
                .iter()
                .map(|(key, buf)| {
                    Json::obj()
                        .set(
                            "key",
                            Json::Arr(
                                key.iter()
                                    .map(|(k, v)| {
                                        Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())])
                                    })
                                    .collect(),
                            ),
                        )
                        .set(
                            "points",
                            Json::Arr(
                                buf.iter()
                                    .map(|&(ts, v, carried)| {
                                        // real points keep the legacy
                                        // 2-element shape (byte-stable
                                        // with pre-select states);
                                        // carried ones append a 1
                                        let mut pt =
                                            vec![Json::Str(ts.to_string()), Json::Num(v)];
                                        if carried {
                                            pt.push(Json::Num(1.0));
                                        }
                                        Json::Arr(pt)
                                    })
                                    .collect(),
                            ),
                        )
                })
                .collect();
            let mut scoped = Json::obj();
            for (r, dq) in &ps.scoped {
                scoped = scoped.set(
                    r,
                    Json::Arr(
                        dq.iter()
                            .map(|&(ts, seq)| {
                                Json::Arr(vec![Json::Str(ts.to_string()), Json::Str(seq.to_string())])
                            })
                            .collect(),
                    ),
                );
            }
            pols = pols.set(
                &i.to_string(),
                Json::obj().set("series", Json::Arr(series)).set("scoped", scoped),
            );
        }
        Json::obj()
            .set("version", 1)
            .set("config", self.config.as_str())
            .set("measurements", meas)
            .set("policies", pols)
    }

    pub fn from_json(j: &Json) -> Result<DetectorState, String> {
        let parse_i64 = |v: &Json, what: &str| -> Result<i64, String> {
            v.as_str()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| format!("detector state: bad {what}"))
        };
        let mut st = DetectorState {
            config: j
                .get("config")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            ..DetectorState::default()
        };
        if let Some(meas) = j.get("measurements").and_then(|v| v.as_obj()) {
            for (m, e) in meas {
                let mut ms = MeasState {
                    seq: e
                        .get("seq")
                        .and_then(|v| v.as_str())
                        .and_then(|s| s.parse().ok())
                        .ok_or("detector state: bad seq")?,
                    wm_ts: parse_i64(e.get("wm_ts").unwrap_or(&Json::Null), "wm_ts")?,
                    wm_n: e.get("wm_n").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize,
                    wm_hash: e
                        .get("wm_hash")
                        .and_then(|v| v.as_str())
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(0),
                    db_points: e.get("db_points").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize,
                    ..MeasState::default()
                };
                for t in e.get("distinct").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                    ms.distinct.push_back(parse_i64(t, "distinct ts")?);
                }
                st.measurements.insert(m.clone(), ms);
            }
        }
        if let Some(pols) = j.get("policies").and_then(|v| v.as_obj()) {
            for (i, e) in pols {
                let idx: usize = i
                    .parse()
                    .map_err(|_| format!("detector state: bad policy index `{i}`"))?;
                let mut ps = PolicyState::default();
                for s in e.get("series").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                    let mut key = Vec::new();
                    for kv in s.get("key").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                        let pair = kv.as_arr().unwrap_or(&[]);
                        match (pair.first().and_then(|v| v.as_str()), pair.get(1).and_then(|v| v.as_str())) {
                            (Some(k), Some(v)) => key.push((k.to_string(), v.to_string())),
                            _ => return Err("detector state: bad series key".into()),
                        }
                    }
                    let mut buf = VecDeque::new();
                    for pt in s.get("points").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                        let pair = pt.as_arr().unwrap_or(&[]);
                        let ts = parse_i64(pair.first().unwrap_or(&Json::Null), "series ts")?;
                        let v = pair
                            .get(1)
                            .and_then(|v| v.as_f64())
                            .ok_or("detector state: bad series value")?;
                        // optional third element: carried marker (absent
                        // in pre-select states — those points are real)
                        let carried = pair
                            .get(2)
                            .and_then(|v| v.as_f64())
                            .map(|n| n == 1.0)
                            .unwrap_or(false);
                        buf.push_back((ts, v, carried));
                    }
                    ps.series.insert(key, buf);
                }
                if let Some(sc) = e.get("scoped").and_then(|v| v.as_obj()) {
                    for (r, arr) in sc {
                        let mut dq = VecDeque::new();
                        for pt in arr.as_arr().unwrap_or(&[]) {
                            let pair = pt.as_arr().unwrap_or(&[]);
                            let ts = parse_i64(pair.first().unwrap_or(&Json::Null), "scoped ts")?;
                            let seq: u64 = pair
                                .get(1)
                                .and_then(|v| v.as_str())
                                .and_then(|s| s.parse().ok())
                                .ok_or("detector state: bad scoped seq")?;
                            dq.push_back((ts, seq));
                        }
                        ps.scoped.insert(r.clone(), dq);
                    }
                }
                st.policies.insert(idx, ps);
            }
        }
        Ok(st)
    }

    /// Persist as pretty JSON (convention: next to the alert book).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Load a previously saved state; a missing file is a fresh state
    /// (the first sync then does a bounded rebuild). A state whose
    /// configuration or watermarks no longer match is not an error —
    /// sync detects and rebuilds.
    pub fn load(path: &Path) -> std::io::Result<DetectorState> {
        if !path.exists() {
            return Ok(DetectorState::new());
        }
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        DetectorState::from_json(&j)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// The unscoped `tail(n)` bound: trailing `lookback`-th distinct
/// timestamp of the measurement, or the earliest tracked one when fewer
/// exist (`Db::tail_start_ts` semantics).
fn unscoped_bound(ms: &MeasState, lookback: usize) -> Option<i64> {
    if ms.distinct.is_empty() {
        return None;
    }
    if ms.distinct.len() >= lookback {
        Some(ms.distinct[ms.distinct.len() - lookback])
    } else {
        ms.distinct.front().copied()
    }
}

/// The repo-scoped bound: trailing `lookback`-th distinct *matching*
/// timestamp, visiting only matches whose rank in the global distinct-ts
/// walk is within `lookback × TAIL_SCAN_SLACK` — the query path's capped
/// reverse walk, computed from the carried seq numbers instead of a scan.
fn scoped_bound(ps: &PolicyState, ms: &MeasState, repo: &str, lookback: usize) -> Option<i64> {
    let dq = ps.scoped.get(repo)?;
    let cap = lookback.saturating_mul(TAIL_SCAN_SLACK) as u64;
    let mut distinct = 0usize;
    let mut last: Option<i64> = None;
    for &(ts, seq) in dq.iter().rev() {
        // rank 1 = the measurement's newest distinct timestamp
        if ms.seq - seq + 1 > cap {
            break;
        }
        distinct += 1;
        last = Some(ts);
        if distinct == lookback {
            break;
        }
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regress::detector::Direction;
    use crate::tsdb::Point;

    fn db_with(points: &[(&str, i64, &str, f64)]) -> Db {
        // (measurement, ts, repo, value)
        let mut db = Db::new();
        for (m, ts, repo, v) in points {
            let mut p = Point::new(m, *ts).field("v", *v);
            if !repo.is_empty() {
                p = p.tag("repo", repo);
            }
            db.insert(p);
        }
        db
    }

    fn det() -> Detector {
        Detector::new().policy(
            Policy::new("p", "m", "v")
                .group_by(&["repo"])
                .direction(Direction::HigherIsBetter)
                .windows(4, 1)
                .thresholds(0.08, 1.0, 0.0)
                .changepoint(false),
        )
    }

    fn dump(f: &[Finding]) -> Vec<String> {
        f.iter()
            .map(|f| {
                format!(
                    "{}|{}|{}|{}|{}|{:?}|{:?}|{:?}|{}|{:?}|{}",
                    f.policy,
                    f.series,
                    f.baseline.mean,
                    f.current,
                    f.rel_change,
                    f.p_welch,
                    f.p_mann_whitney,
                    f.p_z,
                    f.change_ts,
                    f.suspect_commit,
                    f.confidence
                )
            })
            .collect()
    }

    fn assert_equivalent(det: &Detector, st: &DetectorState, db: &Db, scope: &[(&str, &str)]) {
        let (f_inc, e_inc) = st.detect_measurement_scoped(det, db, "m", scope);
        let (f_req, e_req) = det.detect_measurement_scoped(db, "m", scope);
        assert_eq!(e_inc, e_req, "evaluated fingerprints differ");
        assert_eq!(dump(&f_inc), dump(&f_req), "findings differ");
    }

    #[test]
    fn incremental_matches_requery_on_simple_series() {
        let det = det();
        let mut db = Db::new();
        let mut st = DetectorState::new();
        for (i, v) in [1000.0, 1001.0, 999.0, 1000.0, 800.0].iter().enumerate() {
            db.insert(
                Point::new("m", (i as i64 + 1) * 1_000_000_000)
                    .tag("repo", "a")
                    .field("v", *v),
            );
            st.sync(&det, &db);
            assert_equivalent(&det, &st, &db, &[("repo", "a")]);
            assert_equivalent(&det, &st, &db, &[]);
        }
        // the drop is found incrementally
        let (f, _) = st.detect_measurement_scoped(&det, &db, "m", &[("repo", "a")]);
        assert_eq!(f.len(), 1);
        assert!((f[0].rel_change + 0.2).abs() < 1e-9);
    }

    #[test]
    fn carried_newest_matches_requery_and_roundtrips() {
        let det = det();
        let mut db = Db::new();
        let mut st = DetectorState::new();
        // injected regression whose newest point is a carried-forward
        // copy (change-aware selection skipped the job this pipeline)
        for (i, v) in [1000.0, 1001.0, 999.0, 1000.0, 800.0].iter().enumerate() {
            let mut p = Point::new("m", (i as i64 + 1) * 1_000_000_000)
                .tag("repo", "a")
                .field("v", *v);
            if i == 4 {
                p = p.tag(crate::select::CARRIED_TAG, "1");
            }
            db.insert(p);
        }
        st.sync(&det, &db);
        assert_equivalent(&det, &st, &db, &[("repo", "a")]);
        assert_equivalent(&det, &st, &db, &[]);
        let (f, evaluated) = st.detect_measurement_scoped(&det, &db, "m", &[("repo", "a")]);
        assert_eq!(f.len(), 1);
        assert!(f[0].carried);
        assert!(evaluated.is_empty(), "carried-newest series must not auto-resolve");
        // the marker survives the JSON round trip
        let path = std::env::temp_dir().join("cbench_detector_state_carried.json");
        st.save(&path).unwrap();
        let back = DetectorState::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_equivalent(&det, &back, &db, &[("repo", "a")]);
        let (f2, e2) = back.detect_measurement_scoped(&det, &db, "m", &[("repo", "a")]);
        assert_eq!(dump(&f2), dump(&f));
        assert!(f2[0].carried);
        assert!(e2.is_empty());
    }

    #[test]
    fn config_change_invalidates_and_rebuilds() {
        let d1 = det();
        let mut db = Db::new();
        let mut st = DetectorState::new();
        for i in 0..6i64 {
            db.insert(Point::new("m", i * 10).tag("repo", "a").field("v", 1000.0));
        }
        st.sync(&d1, &db);
        let fp1 = st.config_fingerprint().to_string();
        assert!(!st.is_empty());
        // a knob change rebuilds under the new fingerprint...
        let mut d2 = det();
        d2.policies[0].baseline_window = 2;
        st.sync(&d2, &db);
        assert_ne!(st.config_fingerprint(), fp1);
        assert_equivalent(&d2, &st, &db, &[("repo", "a")]);
        // ...and switching back rebuilds again, still equivalent
        st.sync(&d1, &db);
        assert_eq!(st.config_fingerprint(), fp1);
        assert_equivalent(&d1, &st, &db, &[("repo", "a")]);
    }

    #[test]
    fn replaced_db_is_detected_via_watermark() {
        let det = det();
        let mut st = DetectorState::new();
        let db1 = db_with(&[("m", 10, "a", 1.0), ("m", 20, "a", 2.0)]);
        st.sync(&det, &db1);
        // a different database with the same shape (content differs)
        let db2 = db_with(&[("m", 10, "a", 5.0), ("m", 20, "a", 6.0)]);
        st.sync(&det, &db2);
        assert_equivalent(&det, &st, &db2, &[("repo", "a")]);
        // and a shorter database (rewound history)
        let db3 = db_with(&[("m", 10, "a", 5.0)]);
        st.sync(&det, &db3);
        assert_equivalent(&det, &st, &db3, &[("repo", "a")]);
    }

    #[test]
    fn out_of_order_insert_below_watermark_triggers_rebuild() {
        let det = det();
        let mut db = Db::new();
        let mut st = DetectorState::new();
        for i in 1..=5i64 {
            db.insert(Point::new("m", i * 10).tag("repo", "a").field("v", i as f64));
        }
        st.sync(&det, &db);
        // a late import lands *below* the watermark
        db.insert(Point::new("m", 15).tag("repo", "a").field("v", 99.0));
        st.sync(&det, &db);
        assert_equivalent(&det, &st, &db, &[("repo", "a")]);
    }

    #[test]
    fn stale_tenant_outside_cap_matches_query_semantics() {
        let det = det();
        let lookback = 5; // windows(4,1)
        let cap = lookback * TAIL_SCAN_SLACK;
        let mut db = Db::new();
        let mut st = DetectorState::new();
        db.insert(Point::new("m", 0).tag("repo", "old").field("v", 1.0));
        db.insert(Point::new("m", 1).tag("repo", "old").field("v", 1.0));
        for ts in 2..(cap as i64 + 10) {
            db.insert(Point::new("m", ts).tag("repo", "live").field("v", ts as f64));
        }
        st.sync(&det, &db);
        assert_equivalent(&det, &st, &db, &[("repo", "old")]);
        assert_equivalent(&det, &st, &db, &[("repo", "live")]);
        let (_, evaluated) = st.detect_measurement_scoped(&det, &db, "m", &[("repo", "old")]);
        assert!(evaluated.is_empty(), "tenant beyond the capped walk is stale");
    }

    #[test]
    fn state_json_roundtrip_preserves_equivalence() {
        let det = det();
        let mut db = Db::new();
        let mut st = DetectorState::new();
        for i in 1..=7i64 {
            for r in ["a", "b"] {
                db.insert(
                    Point::new("m", i * 10 + (r == "b") as i64)
                        .tag("repo", r)
                        .field("v", 100.0 + i as f64),
                );
            }
        }
        st.sync(&det, &db);
        let path = std::env::temp_dir().join("cbench_detector_state_test.json");
        st.save(&path).unwrap();
        let back = DetectorState::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.config_fingerprint(), st.config_fingerprint());
        assert_equivalent(&det, &back, &db, &[("repo", "a")]);
        assert_equivalent(&det, &back, &db, &[("repo", "b")]);
        // a reloaded state keeps syncing incrementally
        let mut back = back;
        db.insert(Point::new("m", 100).tag("repo", "a").field("v", 50.0));
        back.sync(&det, &db);
        assert_equivalent(&det, &back, &db, &[("repo", "a")]);
    }

    #[test]
    fn full_history_policies_fall_back_verbatim() {
        let det = Detector::new().policy(
            Policy::new("legacy", "m", "v")
                .group_by(&["repo"])
                .windows(1, 1)
                .thresholds(0.1, 1.0, 0.0)
                .changepoint(false)
                .full_history(true),
        );
        let db = db_with(&[
            ("m", 1, "a", 1000.0),
            ("m", 2, "b", 500.0),
            ("m", 3, "a", 800.0),
            ("m", 4, "b", 505.0),
        ]);
        let mut st = DetectorState::new();
        st.sync(&det, &db);
        assert!(st.is_empty(), "full-history policies keep no state");
        assert_equivalent(&det, &st, &db, &[]);
        assert_equivalent(&det, &st, &db, &[("repo", "a")]);
    }
}
