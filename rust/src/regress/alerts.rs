//! Alert lifecycle over detector findings.
//!
//! Findings are ephemeral (recomputed on every detection run); alerts are
//! durable. The [`AlertBook`] deduplicates findings per series fingerprint,
//! tracks each alert through open → acknowledged → resolved, auto-resolves
//! alerts whose series recovered, persists itself as JSON next to the TSDB
//! file, and archives alerts as [`crate::datastore`] records linked into
//! the offending pipeline's collection (the Fig. 5 FAIR graph gains the
//! "this run regressed" node).

use super::detector::{series_fingerprint, Direction, Finding};
use crate::datastore::{DataStore, Id};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertState {
    Open,
    Acknowledged,
    Resolved,
}

impl AlertState {
    pub fn name(self) -> &'static str {
        match self {
            AlertState::Open => "open",
            AlertState::Acknowledged => "acknowledged",
            AlertState::Resolved => "resolved",
        }
    }
    pub fn from_name(s: &str) -> Option<AlertState> {
        match s {
            "open" => Some(AlertState::Open),
            "acknowledged" => Some(AlertState::Acknowledged),
            "resolved" => Some(AlertState::Resolved),
            _ => None,
        }
    }
}

/// One durable alert.
#[derive(Debug, Clone)]
pub struct Alert {
    pub id: u64,
    /// `policy/series` — the dedup key.
    pub fingerprint: String,
    pub policy: String,
    pub measurement: String,
    pub field: String,
    pub series: String,
    pub group: BTreeMap<String, String>,
    pub direction: Direction,
    pub state: AlertState,
    pub opened_ts: i64,
    pub last_seen_ts: i64,
    pub resolved_ts: Option<i64>,
    /// How many detection runs re-confirmed it.
    pub times_seen: usize,
    pub confidence: f64,
    pub baseline_mean: f64,
    pub baseline_sd: f64,
    pub current: f64,
    pub rel_change: f64,
    pub change_ts: i64,
    /// Alert SLA in simulated cluster seconds: time from the offending
    /// pipeline's submission (the regression "landing" on the cluster) to
    /// this alert opening at the post-upload detection. The landing is
    /// the pipeline at the alert's located change point — when detection
    /// lags the regressing push (widened windows), the SLA spans every
    /// pipeline in between; for change points in carried-over history it
    /// falls back to the detecting pipeline's submission. Streaming
    /// collect bounds it by pipeline completion; batch collect pays the
    /// whole campaign makespan. Set by `coordinator::collect_pipeline`;
    /// `None` for alerts opened outside a pipeline (e.g. `regress detect`).
    pub sla_secs: Option<f64>,
    /// SLA breakdown (set together with `sla_secs` from the offending
    /// pipeline's record): time its first job waited in the queue.
    pub sla_queue_secs: Option<f64>,
    /// …time its jobs ran (first start → last end).
    pub sla_run_secs: Option<f64>,
    /// …its collect latency (last job end → points uploaded).
    pub sla_collect_secs: Option<f64>,
    /// …detection lag (its upload → the alert actually opening; >0 when
    /// the detector needed later pipelines to confirm the change). The
    /// four components sum to `sla_secs` exactly — detect is computed as
    /// the remainder.
    pub sla_detect_secs: Option<f64>,
    /// Commit tag at the located change point (detection-time guess).
    pub suspect_commit: Option<String>,
    /// First bad commit confirmed by bisection.
    pub first_bad_commit: Option<String>,
    /// Datastore record archiving this alert, once archived.
    pub archive_record: Option<Id>,
    /// Collection of the pipeline execution that triggered the alert.
    pub pipeline_collection: Option<Id>,
}

/// Counters returned by one [`AlertBook::ingest`] run.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct IngestSummary {
    pub opened: usize,
    pub updated: usize,
    pub auto_resolved: usize,
    /// Ids of the alerts this ingest opened (for attribution: the caller
    /// knows which pipeline execution surfaced exactly these).
    pub opened_ids: Vec<u64>,
}

/// The durable alert store.
#[derive(Debug, Clone, Default)]
pub struct AlertBook {
    next_id: u64,
    pub alerts: Vec<Alert>,
}

impl AlertBook {
    pub fn new() -> AlertBook {
        AlertBook {
            next_id: 1,
            alerts: Vec::new(),
        }
    }

    /// Fold one detection run into the book. `evaluated_fingerprints`
    /// names every `policy/series` the detector had enough data to judge
    /// (see `detector::evaluate_policy_run`): active alerts among those
    /// that no longer produce a finding are auto-resolved (recovered).
    /// Alerts whose series were *not* evaluated — different measurement,
    /// or a fresh TSDB without history — are left untouched.
    pub fn ingest(
        &mut self,
        findings: &[Finding],
        evaluated_fingerprints: &[String],
        now_ts: i64,
    ) -> IngestSummary {
        let mut summary = IngestSummary::default();
        let mut seen: Vec<String> = Vec::with_capacity(findings.len());
        for f in findings {
            let fp = series_fingerprint(&f.policy, &f.series);
            seen.push(fp.clone());
            if let Some(a) = self
                .alerts
                .iter_mut()
                .find(|a| a.fingerprint == fp && a.state != AlertState::Resolved)
            {
                a.last_seen_ts = now_ts;
                a.times_seen += 1;
                a.confidence = a.confidence.max(f.confidence);
                a.current = f.current;
                a.rel_change = f.rel_change;
                if a.suspect_commit.is_none() {
                    a.suspect_commit = f.suspect_commit.clone();
                }
                summary.updated += 1;
            } else if f.carried {
                // a carried-forward series (change-aware selection skipped
                // its job this pipeline) may keep an existing alert alive —
                // handled above — but it is not fresh evidence: the value
                // was measured on an earlier commit and any alert it could
                // open either already exists or will open when the series
                // is next measured for real. Opening here would double-book
                // the same regression under a new pipeline's attribution.
                seen.pop();
            } else {
                if self.next_id == 0 {
                    self.next_id = 1;
                }
                let id = self.next_id;
                self.next_id += 1;
                self.alerts.push(Alert {
                    id,
                    fingerprint: fp,
                    policy: f.policy.clone(),
                    measurement: f.measurement.clone(),
                    field: f.field.clone(),
                    series: f.series.clone(),
                    group: f.group.clone(),
                    direction: f.direction,
                    state: AlertState::Open,
                    opened_ts: now_ts,
                    last_seen_ts: now_ts,
                    resolved_ts: None,
                    times_seen: 1,
                    confidence: f.confidence,
                    baseline_mean: f.baseline.mean,
                    baseline_sd: f.baseline.sd,
                    current: f.current,
                    rel_change: f.rel_change,
                    change_ts: f.change_ts,
                    sla_secs: None,
                    sla_queue_secs: None,
                    sla_run_secs: None,
                    sla_collect_secs: None,
                    sla_detect_secs: None,
                    suspect_commit: f.suspect_commit.clone(),
                    first_bad_commit: None,
                    archive_record: None,
                    pipeline_collection: None,
                });
                summary.opened += 1;
                summary.opened_ids.push(id);
            }
        }
        // recovered series: evaluated again and no longer found
        for a in &mut self.alerts {
            if a.state != AlertState::Resolved
                && evaluated_fingerprints.iter().any(|fp| *fp == a.fingerprint)
                && !seen.iter().any(|fp| *fp == a.fingerprint)
            {
                a.state = AlertState::Resolved;
                a.resolved_ts = Some(now_ts);
                summary.auto_resolved += 1;
            }
        }
        summary
    }

    /// One-shot schema migration for books written by the PR-1-era
    /// binary. Back then the stock policies did not group by `repo`, so
    /// persisted fingerprints/series lack the `repo=` segment and can
    /// never match a per-repo evaluation again — stale open alerts would
    /// survive forever instead of auto-resolving (the ROADMAP known gap).
    ///
    /// The only producer of such books was the single-repo
    /// `cbench pipeline <fe2ti|walberla>` flow, whose repository name is
    /// fixed per measurement, so the missing segment is reconstructable:
    /// `lbm` series belonged to the `walberla` repository, `fe2ti` series
    /// to `fe2ti`. Alerts of custom (non-stock) policies are left
    /// untouched. Runs automatically in [`AlertBook::load`]; idempotent.
    /// Returns how many alerts were rewritten.
    pub fn migrate_pr1_fingerprints(&mut self) -> usize {
        // stock policy -> the repo its PR-1-era series implicitly meant
        let stock = [("lbm-mlups", "walberla"), ("fe2ti-tts", "fe2ti")];
        let mut migrated = 0;
        for a in &mut self.alerts {
            let Some(&(_, repo)) = stock.iter().find(|(p, _)| *p == a.policy) else {
                continue;
            };
            if a.group.is_empty() && !a.series.is_empty() {
                // very old books may miss the group map; the series label
                // is `k=v,...` and authoritative
                for kv in a.series.split(',') {
                    if let Some((k, v)) = kv.split_once('=') {
                        a.group.insert(k.to_string(), v.to_string());
                    }
                }
            }
            if a.group.contains_key("repo") {
                continue; // already post-PR-2
            }
            a.group.insert("repo".to_string(), repo.to_string());
            // rebuild the label in canonical (sorted-tag) order — `repo`
            // is not always the last segment (e.g. before `solver`)
            a.series = a
                .group
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(",");
            a.fingerprint = series_fingerprint(&a.policy, &a.series);
            migrated += 1;
        }
        migrated
    }

    /// Forget datastore-scoped ids (archive records, pipeline
    /// collections). Call after loading a book into a *different*
    /// datastore than the one it was built against — ids are sequential
    /// per store, so stale ones would address unrelated records.
    pub fn detach_store(&mut self) {
        for a in &mut self.alerts {
            a.archive_record = None;
            a.pipeline_collection = None;
        }
    }

    /// Alerts still needing attention (open or acknowledged).
    pub fn active(&self) -> Vec<&Alert> {
        self.alerts.iter().filter(|a| a.state != AlertState::Resolved).collect()
    }

    pub fn get(&self, id: u64) -> Option<&Alert> {
        self.alerts.iter().find(|a| a.id == id)
    }
    pub fn get_mut(&mut self, id: u64) -> Option<&mut Alert> {
        self.alerts.iter_mut().find(|a| a.id == id)
    }

    pub fn acknowledge(&mut self, id: u64) -> Result<(), String> {
        let a = self.get_mut(id).ok_or_else(|| format!("no alert #{id}"))?;
        if a.state == AlertState::Resolved {
            return Err(format!("alert #{id} is already resolved"));
        }
        a.state = AlertState::Acknowledged;
        Ok(())
    }

    pub fn resolve(&mut self, id: u64, now_ts: i64) -> Result<(), String> {
        let a = self.get_mut(id).ok_or_else(|| format!("no alert #{id}"))?;
        a.state = AlertState::Resolved;
        a.resolved_ts = Some(now_ts);
        Ok(())
    }

    /// Archive alerts as datastore records: one `regression-alert` record
    /// per alert (created once, refreshed on state changes), added to
    /// `alerts_coll` and — when known — to the offending pipeline's
    /// collection. Returns how many records were newly created.
    ///
    /// Runs on the coordinator's per-upload path, so alerts whose
    /// archived state already matches are skipped — a book full of
    /// long-resolved history costs one metadata lookup each, not a
    /// re-serialization.
    pub fn archive(&mut self, store: &mut DataStore, alerts_coll: Id) -> usize {
        let mut created = 0;
        for a in &mut self.alerts {
            let rid = match a.archive_record {
                Some(rid) => {
                    let unchanged = store
                        .record(rid)
                        .and_then(|r| r.meta.get("state"))
                        .map(|s| s == a.state.name())
                        .unwrap_or(false);
                    if unchanged {
                        continue;
                    }
                    rid
                }
                None => {
                    let Ok(rid) = store.create_record(
                        &format!("regress-alert-{}", a.id),
                        &format!("regression alert: {} {}.{}", a.series, a.measurement, a.field),
                        "regression-alert",
                    ) else {
                        continue;
                    };
                    a.archive_record = Some(rid);
                    store.add_to_collection(alerts_coll, rid).ok();
                    if let Some(pc) = a.pipeline_collection {
                        store.add_to_collection(pc, rid).ok();
                    }
                    created += 1;
                    rid
                }
            };
            store.attach_file(rid, "alert.json", &alert_to_json(a).to_string_pretty()).ok();
            store.set_meta(rid, "state", a.state.name()).ok();
            store.set_meta(rid, "series", &a.series).ok();
            store.set_meta(rid, "confidence", &format!("{:.3}", a.confidence)).ok();
            if let Some(c) = &a.suspect_commit {
                store.set_meta(rid, "suspect_commit", c).ok();
            }
            if let Some(c) = &a.first_bad_commit {
                store.set_meta(rid, "first_bad_commit", c).ok();
            }
        }
        created
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("next_id", self.next_id as i64)
            .set(
                "alerts",
                Json::Arr(self.alerts.iter().map(alert_to_json).collect()),
            )
    }

    pub fn from_json(j: &Json) -> Result<AlertBook, String> {
        let mut book = AlertBook::new();
        book.next_id = j
            .get("next_id")
            .and_then(|v| v.as_f64())
            .map(|v| v as u64)
            .unwrap_or(1)
            .max(1);
        for a in j
            .get("alerts")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
        {
            book.alerts.push(alert_from_json(a)?);
        }
        Ok(book)
    }

    /// Persist as pretty JSON (convention: next to the TSDB file).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Load a previously saved book; a missing file is an empty book.
    /// PR-1-era fingerprints (no `repo=` group segment) are rewritten on
    /// the way in — see [`AlertBook::migrate_pr1_fingerprints`].
    pub fn load(path: &Path) -> std::io::Result<AlertBook> {
        if !path.exists() {
            return Ok(AlertBook::new());
        }
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        let mut book = AlertBook::from_json(&j)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        book.migrate_pr1_fingerprints();
        Ok(book)
    }
}

fn opt_str(j: &Json, key: &str) -> Option<String> {
    j.get(key).and_then(|v| v.as_str()).map(String::from)
}
fn opt_num(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}

pub fn alert_to_json(a: &Alert) -> Json {
    let mut group = Json::obj();
    for (k, v) in &a.group {
        group = group.set(k, v.as_str());
    }
    let mut j = Json::obj()
        .set("id", a.id as i64)
        .set("fingerprint", a.fingerprint.as_str())
        .set("policy", a.policy.as_str())
        .set("measurement", a.measurement.as_str())
        .set("field", a.field.as_str())
        .set("series", a.series.as_str())
        .set("group", group)
        .set("direction", a.direction.name())
        .set("state", a.state.name())
        .set("opened_ts", a.opened_ts as f64)
        .set("last_seen_ts", a.last_seen_ts as f64)
        .set("times_seen", a.times_seen)
        .set("confidence", a.confidence)
        .set("baseline_mean", a.baseline_mean)
        .set("baseline_sd", a.baseline_sd)
        .set("current", a.current)
        .set("rel_change", a.rel_change)
        .set("change_ts", a.change_ts as f64);
    if let Some(ts) = a.resolved_ts {
        j = j.set("resolved_ts", ts as f64);
    }
    if let Some(s) = a.sla_secs {
        j = j.set("sla_secs", s);
    }
    if let Some(s) = a.sla_queue_secs {
        j = j.set("sla_queue_secs", s);
    }
    if let Some(s) = a.sla_run_secs {
        j = j.set("sla_run_secs", s);
    }
    if let Some(s) = a.sla_collect_secs {
        j = j.set("sla_collect_secs", s);
    }
    if let Some(s) = a.sla_detect_secs {
        j = j.set("sla_detect_secs", s);
    }
    if let Some(c) = &a.suspect_commit {
        j = j.set("suspect_commit", c.as_str());
    }
    if let Some(c) = &a.first_bad_commit {
        j = j.set("first_bad_commit", c.as_str());
    }
    if let Some(r) = a.archive_record {
        j = j.set("archive_record", r as i64);
    }
    if let Some(c) = a.pipeline_collection {
        j = j.set("pipeline_collection", c as i64);
    }
    j
}

fn alert_from_json(j: &Json) -> Result<Alert, String> {
    let mut group = BTreeMap::new();
    if let Some(obj) = j.get("group").and_then(|v| v.as_obj()) {
        for (k, v) in obj {
            if let Some(s) = v.as_str() {
                group.insert(k.clone(), s.to_string());
            }
        }
    }
    Ok(Alert {
        id: opt_num(j, "id").ok_or("alert missing id")? as u64,
        fingerprint: opt_str(j, "fingerprint").ok_or("alert missing fingerprint")?,
        policy: opt_str(j, "policy").unwrap_or_default(),
        measurement: opt_str(j, "measurement").unwrap_or_default(),
        field: opt_str(j, "field").unwrap_or_default(),
        series: opt_str(j, "series").unwrap_or_default(),
        group,
        direction: opt_str(j, "direction")
            .and_then(|s| Direction::from_name(&s))
            .unwrap_or(Direction::HigherIsBetter),
        state: opt_str(j, "state")
            .and_then(|s| AlertState::from_name(&s))
            .ok_or("alert missing state")?,
        opened_ts: opt_num(j, "opened_ts").unwrap_or(0.0) as i64,
        last_seen_ts: opt_num(j, "last_seen_ts").unwrap_or(0.0) as i64,
        resolved_ts: opt_num(j, "resolved_ts").map(|v| v as i64),
        times_seen: opt_num(j, "times_seen").unwrap_or(1.0) as usize,
        confidence: opt_num(j, "confidence").unwrap_or(0.0),
        baseline_mean: opt_num(j, "baseline_mean").unwrap_or(f64::NAN),
        baseline_sd: opt_num(j, "baseline_sd").unwrap_or(f64::NAN),
        current: opt_num(j, "current").unwrap_or(f64::NAN),
        rel_change: opt_num(j, "rel_change").unwrap_or(0.0),
        change_ts: opt_num(j, "change_ts").unwrap_or(0.0) as i64,
        sla_secs: opt_num(j, "sla_secs"),
        sla_queue_secs: opt_num(j, "sla_queue_secs"),
        sla_run_secs: opt_num(j, "sla_run_secs"),
        sla_collect_secs: opt_num(j, "sla_collect_secs"),
        sla_detect_secs: opt_num(j, "sla_detect_secs"),
        suspect_commit: opt_str(j, "suspect_commit"),
        first_bad_commit: opt_str(j, "first_bad_commit"),
        archive_record: opt_num(j, "archive_record").map(|v| v as Id),
        pipeline_collection: opt_num(j, "pipeline_collection").map(|v| v as Id),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regress::stats::BaselineStats;

    fn finding(policy: &str, series: &str, conf: f64) -> Finding {
        let mut group = BTreeMap::new();
        for kv in series.split(',') {
            if let Some((k, v)) = kv.split_once('=') {
                group.insert(k.to_string(), v.to_string());
            }
        }
        Finding {
            policy: policy.to_string(),
            measurement: "lbm".into(),
            field: "mlups".into(),
            series: series.to_string(),
            group,
            direction: Direction::HigherIsBetter,
            baseline: BaselineStats::of(&[1000.0, 1000.0, 1000.0]),
            current: 850.0,
            rel_change: -0.15,
            p_welch: Some(0.001),
            p_mann_whitney: None,
            p_z: None,
            change_ts: 5_000_000_000,
            suspect_commit: Some("abcd1234".into()),
            confidence: conf,
            carried: false,
        }
    }

    #[test]
    fn ingest_opens_updates_and_resolves() {
        let mut book = AlertBook::new();
        let evaluated = vec!["lbm-mlups/node=icx36".to_string()];
        let f = finding("lbm-mlups", "node=icx36", 0.9);

        let s1 = book.ingest(&[f.clone()], &evaluated, 1);
        assert_eq!(
            s1,
            IngestSummary { opened: 1, updated: 0, auto_resolved: 0, opened_ids: vec![1] }
        );
        assert_eq!(book.active().len(), 1);
        assert_eq!(book.alerts[0].suspect_commit.as_deref(), Some("abcd1234"));

        // same finding again: dedup, not a second alert
        let s2 = book.ingest(&[f.clone()], &evaluated, 2);
        assert_eq!(
            s2,
            IngestSummary { opened: 0, updated: 1, auto_resolved: 0, opened_ids: vec![] }
        );
        assert_eq!(book.alerts.len(), 1);
        assert_eq!(book.alerts[0].times_seen, 2);
        assert_eq!(book.alerts[0].last_seen_ts, 2);

        // series evaluated healthy: auto-resolve
        let s3 = book.ingest(&[], &evaluated, 3);
        assert_eq!(s3.auto_resolved, 1);
        assert_eq!(book.alerts[0].state, AlertState::Resolved);
        assert_eq!(book.alerts[0].resolved_ts, Some(3));
        assert!(book.active().is_empty());

        // regression recurs: a *new* alert opens
        let s4 = book.ingest(&[f], &evaluated, 4);
        assert_eq!(s4.opened, 1);
        assert_eq!(s4.opened_ids, vec![2]);
        assert_eq!(book.alerts.len(), 2);
        assert_ne!(book.alerts[1].id, book.alerts[0].id);
    }

    #[test]
    fn carried_findings_update_but_never_open() {
        let mut book = AlertBook::new();
        let evaluated = vec!["lbm-mlups/node=icx36".to_string()];
        let mut carried = finding("lbm-mlups", "node=icx36", 0.9);
        carried.carried = true;

        // no open alert yet: a carried finding opens nothing — the value
        // was measured on an earlier commit, whose pipeline already had
        // its chance to open (and attribute) the alert
        let s = book.ingest(&[carried.clone()], &[], 1);
        assert_eq!(s, IngestSummary::default());
        assert!(book.alerts.is_empty());

        // open it for real, then keep it alive through carried pipelines
        book.ingest(&[finding("lbm-mlups", "node=icx36", 0.9)], &evaluated, 2);
        let s = book.ingest(&[carried], &[], 3);
        assert_eq!(
            s,
            IngestSummary { opened: 0, updated: 1, auto_resolved: 0, opened_ids: vec![] }
        );
        assert_eq!(book.alerts.len(), 1);
        assert_eq!(book.alerts[0].times_seen, 2);
        assert_eq!(book.alerts[0].last_seen_ts, 3);
        assert_eq!(book.alerts[0].state, AlertState::Open);
    }

    #[test]
    fn unevaluated_series_do_not_resolve() {
        let mut book = AlertBook::new();
        book.ingest(
            &[finding("lbm-mlups", "node=a", 0.8)],
            &["lbm-mlups/node=a".to_string()],
            1,
        );
        // a run that evaluated other series (or nothing at all — e.g. a
        // fresh TSDB) must not touch this alert
        let s = book.ingest(&[], &["fe2ti-tts/case=fe2ti216".to_string()], 2);
        assert_eq!(s.auto_resolved, 0);
        let s = book.ingest(&[], &[], 3);
        assert_eq!(s.auto_resolved, 0);
        assert_eq!(book.active().len(), 1);
    }

    #[test]
    fn ack_and_manual_resolve() {
        let mut book = AlertBook::new();
        let evaluated = vec!["p/node=a".to_string()];
        book.ingest(&[finding("p", "node=a", 0.8)], &evaluated, 1);
        let id = book.alerts[0].id;
        book.acknowledge(id).unwrap();
        assert_eq!(book.alerts[0].state, AlertState::Acknowledged);
        // acknowledged alerts still update
        let s = book.ingest(&[finding("p", "node=a", 0.95)], &evaluated, 2);
        assert_eq!(s.updated, 1);
        assert_eq!(book.alerts[0].confidence, 0.95);
        book.resolve(id, 3).unwrap();
        assert_eq!(book.alerts[0].state, AlertState::Resolved);
        assert!(book.acknowledge(id).is_err());
        assert!(book.acknowledge(999).is_err());
    }

    #[test]
    fn detach_store_clears_stale_ids() {
        let mut book = AlertBook::new();
        book.ingest(&[finding("p", "node=a", 0.8)], &["p/node=a".to_string()], 1);
        book.alerts[0].archive_record = Some(7);
        book.alerts[0].pipeline_collection = Some(3);
        book.detach_store();
        assert_eq!(book.alerts[0].archive_record, None);
        assert_eq!(book.alerts[0].pipeline_collection, None);
        // a fresh store archives them cleanly instead of clobbering id 7
        let mut store = DataStore::new();
        let coll = store.create_collection("alerts", "alerts");
        assert_eq!(book.archive(&mut store, coll), 1);
        assert_eq!(store.n_records(), 1);
    }

    #[test]
    fn json_roundtrip_preserves_alerts() {
        let mut book = AlertBook::new();
        book.ingest(
            &[finding("lbm-mlups", "collision_op=srt,node=icx36", 0.9)],
            &["lbm-mlups".to_string()],
            7,
        );
        book.alerts[0].first_bad_commit = Some("feedface".into());
        book.alerts[0].sla_secs = Some(182.25);
        book.alerts[0].sla_queue_secs = Some(100.0);
        book.alerts[0].sla_run_secs = Some(60.25);
        book.alerts[0].sla_collect_secs = Some(12.0);
        book.alerts[0].sla_detect_secs = Some(10.0);
        book.acknowledge(book.alerts[0].id).unwrap();

        let j = book.to_json();
        let back = AlertBook::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.alerts.len(), 1);
        let a = &back.alerts[0];
        assert_eq!(a.state, AlertState::Acknowledged);
        assert_eq!(a.series, "collision_op=srt,node=icx36");
        assert_eq!(a.group["node"], "icx36");
        assert_eq!(a.first_bad_commit.as_deref(), Some("feedface"));
        assert_eq!(a.sla_secs, Some(182.25));
        assert_eq!(a.sla_queue_secs, Some(100.0));
        assert_eq!(a.sla_run_secs, Some(60.25));
        assert_eq!(a.sla_collect_secs, Some(12.0));
        assert_eq!(a.sla_detect_secs, Some(10.0));
        assert_eq!(a.opened_ts, 7);
        assert!((a.rel_change + 0.15).abs() < 1e-12);
        // ids keep counting after reload
        let f2 = finding("lbm-mlups", "node=rome1", 0.7);
        let mut back = back;
        back.ingest(&[f2], &["lbm-mlups".to_string()], 8);
        assert_eq!(back.alerts[1].id, a.id + 1);
    }

    #[test]
    fn pr1_era_fingerprints_migrate_on_load_and_auto_resolve() {
        // a synthesized old-format book: stock-policy alerts without the
        // `repo=` group segment (written by the PR-1 binary)
        let old = r#"{
  "next_id": 3,
  "alerts": [
    {
      "id": 1,
      "fingerprint": "lbm-mlups/case=uniformgridcpu,collision_op=srt,gpu=<none>,node=icx36",
      "policy": "lbm-mlups",
      "measurement": "lbm",
      "field": "mlups",
      "series": "case=uniformgridcpu,collision_op=srt,gpu=<none>,node=icx36",
      "group": {"case": "uniformgridcpu", "collision_op": "srt", "gpu": "<none>", "node": "icx36"},
      "direction": "higher-is-better",
      "state": "open",
      "opened_ts": 1, "last_seen_ts": 1, "times_seen": 1,
      "confidence": 0.9, "baseline_mean": 1000.0, "baseline_sd": 1.0,
      "current": 800.0, "rel_change": -0.2, "change_ts": 1
    },
    {
      "id": 2,
      "fingerprint": "fe2ti-tts/case=fe2ti216,node=icx36,solver=ilu",
      "policy": "fe2ti-tts",
      "measurement": "fe2ti",
      "field": "tts",
      "series": "case=fe2ti216,node=icx36,solver=ilu",
      "group": {"case": "fe2ti216", "node": "icx36", "solver": "ilu"},
      "direction": "lower-is-better",
      "state": "open",
      "opened_ts": 1, "last_seen_ts": 1, "times_seen": 1,
      "confidence": 0.8, "baseline_mean": 40.0, "baseline_sd": 1.0,
      "current": 55.0, "rel_change": 0.37, "change_ts": 1
    }
  ]
}"#;
        let path = std::env::temp_dir().join("cbench_alerts_pr1_migration.json");
        std::fs::write(&path, old).unwrap();
        let mut book = AlertBook::load(&path).unwrap();

        // the missing repo segment is reconstructed in canonical tag order
        assert_eq!(
            book.alerts[0].series,
            "case=uniformgridcpu,collision_op=srt,gpu=<none>,node=icx36,repo=walberla"
        );
        assert_eq!(
            book.alerts[0].fingerprint,
            "lbm-mlups/case=uniformgridcpu,collision_op=srt,gpu=<none>,node=icx36,repo=walberla"
        );
        // `repo` sorts *before* `solver` — the label must be re-sorted,
        // not appended
        assert_eq!(
            book.alerts[1].series,
            "case=fe2ti216,node=icx36,repo=fe2ti,solver=ilu"
        );
        assert_eq!(book.alerts[1].group["repo"], "fe2ti");

        // round-trip: save + reload is idempotent (no second migration)
        book.save(&path).unwrap();
        let mut again = AlertBook::load(&path).unwrap();
        assert_eq!(again.alerts[0].fingerprint, book.alerts[0].fingerprint);
        assert_eq!(again.alerts[1].fingerprint, book.alerts[1].fingerprint);
        assert_eq!(again.migrate_pr1_fingerprints(), 0, "idempotent");
        std::fs::remove_file(&path).ok();

        // and the point of it all: a healthy per-repo evaluation under the
        // new fingerprints auto-resolves the stale PR-1 alert
        let evaluated = vec![book.alerts[0].fingerprint.clone()];
        let s = book.ingest(&[], &evaluated, 9);
        assert_eq!(s.auto_resolved, 1);
        assert_eq!(book.alerts[0].state, AlertState::Resolved);
        assert_eq!(book.alerts[1].state, AlertState::Open, "unevaluated stays open");
    }

    #[test]
    fn migration_leaves_custom_policies_and_new_books_alone() {
        let mut book = AlertBook::new();
        book.ingest(
            &[finding("custom-policy", "node=a", 0.8)],
            &["custom-policy/node=a".to_string()],
            1,
        );
        book.ingest(
            &[finding("lbm-mlups", "node=b,repo=walberla-0", 0.9)],
            &["lbm-mlups/node=b,repo=walberla-0".to_string()],
            1,
        );
        let before: Vec<String> = book.alerts.iter().map(|a| a.fingerprint.clone()).collect();
        assert_eq!(book.migrate_pr1_fingerprints(), 0);
        let after: Vec<String> = book.alerts.iter().map(|a| a.fingerprint.clone()).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn archive_creates_linked_records_once() {
        let mut store = DataStore::new();
        let coll = store.create_collection("alerts", "regression alerts");
        let pipe = store.create_collection("pipeline-9", "pipeline");
        let mut book = AlertBook::new();
        book.ingest(&[finding("p", "node=a", 0.8)], &["p".to_string()], 1);
        book.alerts[0].pipeline_collection = Some(pipe);

        assert_eq!(book.archive(&mut store, coll), 1);
        // second archive refreshes, does not duplicate
        assert_eq!(book.archive(&mut store, coll), 0);
        assert_eq!(store.n_records(), 1);
        let rid = book.alerts[0].archive_record.unwrap();
        let rec = store.record(rid).unwrap();
        assert_eq!(rec.record_type, "regression-alert");
        assert!(rec.files["alert.json"].contains("node=a"));
        assert_eq!(rec.meta["suspect_commit"], "abcd1234");
        assert!(store.collection(coll).unwrap().records.contains(&rid));
        assert!(store.collection(pipe).unwrap().records.contains(&rid));
    }
}
