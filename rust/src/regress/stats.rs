//! Noise-aware change-detection primitives.
//!
//! The naive last-vs-previous comparison the seed shipped is blind to run-
//! to-run noise; real CB suites (Bencher's thresholds, the ROOT framework)
//! test a candidate window against a *baseline* window with proper
//! statistics. This module provides the numerical kernel for that:
//!
//! * [`BaselineStats`] — mean/stddev/median/IQR over a window,
//! * [`welch_t`] — Welch's unequal-variance t-test (two-sided p),
//! * [`mann_whitney`] — Mann–Whitney U with tie-corrected normal
//!   approximation (robust to non-normal timing noise),
//! * [`cusum_changepoint`] — offline CUSUM change-point *location*,
//! * special functions ([`ln_gamma`], [`betai`], [`erf`], [`normal_cdf`])
//!   implemented from scratch — the vendored crate set has no statrs.

use crate::util::stats::percentile_sorted;

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n-1 denominator); 0 for n < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Robust summary of a baseline window.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineStats {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub median: f64,
    /// Interquartile range (p75 - p25) — outlier-robust spread.
    pub iqr: f64,
}

impl BaselineStats {
    pub fn of(xs: &[f64]) -> BaselineStats {
        if xs.is_empty() {
            return BaselineStats {
                n: 0,
                mean: f64::NAN,
                sd: f64::NAN,
                median: f64::NAN,
                iqr: f64::NAN,
            };
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        BaselineStats {
            n: xs.len(),
            mean: mean(xs),
            sd: variance(xs).sqrt(),
            median: percentile_sorted(&s, 50.0),
            iqr: percentile_sorted(&s, 75.0) - percentile_sorted(&s, 25.0),
        }
    }
}

/// Result of a two-sample test: the test statistic and a two-sided p-value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TwoSampleTest {
    pub stat: f64,
    pub p: f64,
}

const LG_COEF: [f64; 9] = [
    0.99999999999980993,
    676.5203681218851,
    -1259.1392167224028,
    771.32342877765313,
    -176.61502916214059,
    12.507343278686905,
    -0.13857109526572012,
    9.9843695780195716e-6,
    1.5056327351493116e-7,
];

/// Natural log of the gamma function (Lanczos approximation, g = 7).
pub fn ln_gamma(x: f64) -> f64 {
    let pi = std::f64::consts::PI;
    if x < 0.5 {
        // reflection formula
        (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = LG_COEF[0];
        let t = x + 7.5;
        for (i, &c) in LG_COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * pi).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Continued-fraction kernel for the incomplete beta function.
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAXIT: usize = 300;
    const EPS: f64 = 3e-14;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAXIT {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularized incomplete beta function I_x(a, b).
pub fn betai(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let bt =
        (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln()).exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * betacf(a, b, x) / a
    } else {
        1.0 - bt * betacf(b, a, 1.0 - x) / b
    }
}

/// Error function (Abramowitz & Stegun 7.1.26, |err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let s = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - ((((1.061405429 * t - 1.453152027) * t + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    s * y
}

/// Standard normal CDF.
pub fn normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Two-sided p-value for a z-statistic under the standard normal.
pub fn normal_two_sided_p(z: f64) -> f64 {
    (2.0 * (1.0 - normal_cdf(z.abs()))).clamp(0.0, 1.0)
}

/// Two-sided p-value for a t-statistic with `df` degrees of freedom,
/// via the identity p = I_{df/(df+t^2)}(df/2, 1/2).
pub fn student_t_two_sided_p(t: f64, df: f64) -> f64 {
    if !t.is_finite() {
        return 0.0;
    }
    if df <= 0.0 {
        return 1.0;
    }
    betai(df / 2.0, 0.5, df / (df + t * t)).clamp(0.0, 1.0)
}

/// Welch's unequal-variance t-test between samples `a` and `b`.
/// Returns `None` when either sample has fewer than 2 points.
pub fn welch_t(a: &[f64], b: &[f64]) -> Option<TwoSampleTest> {
    if a.len() < 2 || b.len() < 2 {
        return None;
    }
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let (ma, mb) = (mean(a), mean(b));
    let (va, vb) = (variance(a), variance(b));
    let se2 = va / na + vb / nb;
    if se2 <= 0.0 {
        // both samples are constant: identical means are indistinguishable,
        // different means are a certain shift
        return Some(if ma == mb {
            TwoSampleTest { stat: 0.0, p: 1.0 }
        } else {
            TwoSampleTest {
                stat: f64::INFINITY,
                p: 0.0,
            }
        });
    }
    let t = (ma - mb) / se2.sqrt();
    let df = se2 * se2
        / ((va / na) * (va / na) / (na - 1.0) + (vb / nb) * (vb / nb) / (nb - 1.0));
    Some(TwoSampleTest {
        stat: t,
        p: student_t_two_sided_p(t, df),
    })
}

/// Mann–Whitney U test (tie-corrected normal approximation with
/// continuity correction). Returns `None` when the pooled sample is too
/// small for the approximation (either side empty, or fewer than 4 total).
pub fn mann_whitney(a: &[f64], b: &[f64]) -> Option<TwoSampleTest> {
    if a.is_empty() || b.is_empty() || a.len() + b.len() < 4 {
        return None;
    }
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let mut all: Vec<(f64, u8)> = a
        .iter()
        .map(|&x| (x, 0u8))
        .chain(b.iter().map(|&x| (x, 1u8)))
        .collect();
    all.sort_by(|p, q| p.0.partial_cmp(&q.0).unwrap_or(std::cmp::Ordering::Equal));
    let n = all.len();
    let mut ranks = vec![0.0f64; n];
    let mut tie_term = 0.0;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && all[j + 1].0 == all[i].0 {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for r in ranks.iter_mut().take(j + 1).skip(i) {
            *r = avg;
        }
        let t = (j - i + 1) as f64;
        if t > 1.0 {
            tie_term += t * t * t - t;
        }
        i = j + 1;
    }
    let ra: f64 = all
        .iter()
        .zip(&ranks)
        .filter(|((_, g), _)| *g == 0)
        .map(|(_, r)| *r)
        .sum();
    let u = ra - na * (na + 1.0) / 2.0;
    let mu = na * nb / 2.0;
    let nt = na + nb;
    let sigma2 = na * nb / 12.0 * ((nt + 1.0) - tie_term / (nt * (nt - 1.0)));
    if sigma2 <= 0.0 {
        // every pooled value identical
        return Some(TwoSampleTest { stat: 0.0, p: 1.0 });
    }
    let z = if u > mu {
        (u - mu - 0.5) / sigma2.sqrt()
    } else if u < mu {
        (u - mu + 0.5) / sigma2.sqrt()
    } else {
        0.0
    };
    Some(TwoSampleTest {
        stat: z,
        p: normal_two_sided_p(z),
    })
}

/// Offline CUSUM change-point estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cusum {
    /// Index of the *first point of the new regime* (series is split as
    /// `xs[..index]` / `xs[index..]`), when a shift is located.
    pub index: Option<usize>,
    /// Normalized excursion max|S_t| / (sd * sqrt(n)) — larger means a
    /// cleaner step; values above ~0.9 indicate a real level shift.
    pub stat: f64,
}

/// Locate a mean shift with the classic cumulative-sum estimator:
/// S_t = sum_{i<=t}(x_i - mean); the change is right after argmax |S_t|.
/// Needs at least 4 points and non-degenerate spread.
pub fn cusum_changepoint(xs: &[f64]) -> Cusum {
    if xs.len() < 4 {
        return Cusum {
            index: None,
            stat: 0.0,
        };
    }
    let m = mean(xs);
    let sd = variance(xs).sqrt();
    if sd < 1e-300 {
        return Cusum {
            index: None,
            stat: 0.0,
        };
    }
    let mut s = 0.0;
    let mut best = 0.0;
    let mut best_t = 0usize;
    // the last prefix is the full sum (== 0); stop one short so the split
    // always leaves a non-empty tail
    for (t, &x) in xs.iter().enumerate().take(xs.len() - 1) {
        s += x - m;
        if s.abs() > best {
            best = s.abs();
            best_t = t;
        }
    }
    let stat = best / (sd * (xs.len() as f64).sqrt());
    if best == 0.0 {
        return Cusum {
            index: None,
            stat: 0.0,
        };
    }
    Cusum {
        index: Some(best_t + 1),
        stat,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gamma_and_beta_reference_values() {
        assert!(ln_gamma(1.0).abs() < 1e-10);
        assert!(ln_gamma(2.0).abs() < 1e-10);
        // Γ(0.5) = sqrt(pi)
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
        // Γ(5) = 24
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        // I_x(1,1) = x
        assert!((betai(1.0, 1.0, 0.3) - 0.3).abs() < 1e-10);
        // symmetry: I_x(a,b) = 1 - I_{1-x}(b,a)
        let lhs = betai(2.5, 1.5, 0.4);
        let rhs = 1.0 - betai(1.5, 2.5, 0.6);
        assert!((lhs - rhs).abs() < 1e-10);
    }

    #[test]
    fn normal_cdf_reference_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.959964) - 0.975).abs() < 1e-4);
        assert!((normal_cdf(-1.959964) - 0.025).abs() < 1e-4);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
    }

    #[test]
    fn t_distribution_reference_p() {
        // t=1, df=8 -> two-sided p = 0.34659 (tables)
        assert!((student_t_two_sided_p(1.0, 8.0) - 0.34659).abs() < 1e-3);
        // t=2.306, df=8 -> p = 0.05
        assert!((student_t_two_sided_p(2.306, 8.0) - 0.05).abs() < 2e-3);
        assert_eq!(student_t_two_sided_p(0.0, 8.0), 1.0);
    }

    #[test]
    fn welch_separates_shifted_samples() {
        let a = [100.0, 101.0, 99.0, 100.5, 99.5, 100.2];
        let b = [90.0, 91.0, 89.5, 90.2, 90.8, 89.9];
        let r = welch_t(&a, &b).unwrap();
        assert!(r.p < 1e-4, "p={}", r.p);
        assert!(r.stat > 0.0);
        // same sample against itself: p = 1 territory
        let r2 = welch_t(&a, &a).unwrap();
        assert!(r2.p > 0.99, "p={}", r2.p);
        // textbook check: a=[1..5], b=[2..6] -> t=-1, df=8, p~0.347
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [2.0, 3.0, 4.0, 5.0, 6.0];
        let r3 = welch_t(&x, &y).unwrap();
        assert!((r3.stat + 1.0).abs() < 1e-12);
        assert!((r3.p - 0.34659).abs() < 1e-3);
        assert!(welch_t(&[1.0], &y).is_none());
    }

    #[test]
    fn welch_constant_samples() {
        let r = welch_t(&[5.0, 5.0, 5.0], &[5.0, 5.0]).unwrap();
        assert_eq!(r.p, 1.0);
        let r = welch_t(&[5.0, 5.0, 5.0], &[4.0, 4.0]).unwrap();
        assert_eq!(r.p, 0.0);
    }

    #[test]
    fn mann_whitney_separates_shifted_samples() {
        let mut rng = Rng::new(11);
        let a: Vec<f64> = (0..30).map(|_| rng.gauss(100.0, 2.0)).collect();
        let b: Vec<f64> = (0..30).map(|_| rng.gauss(92.0, 2.0)).collect();
        let r = mann_whitney(&a, &b).unwrap();
        assert!(r.p < 1e-4, "p={}", r.p);
        // a sample against itself: U sits exactly at its mean, p = 1
        let r2 = mann_whitney(&a, &a).unwrap();
        assert!(r2.stat.abs() < 1e-12 && r2.p > 0.999, "p={}", r2.p);
        // ties collapse to p=1 when everything is identical
        let r3 = mann_whitney(&[1.0, 1.0, 1.0], &[1.0, 1.0]).unwrap();
        assert_eq!(r3.p, 1.0);
        assert!(mann_whitney(&[1.0], &[2.0]).is_none());
    }

    #[test]
    fn cusum_locates_step_in_noise() {
        let mut rng = Rng::new(7);
        for &cp in &[20usize, 35, 50] {
            let xs: Vec<f64> = (0..70)
                .map(|i| {
                    if i < cp {
                        rng.gauss(100.0, 2.0)
                    } else {
                        rng.gauss(88.0, 2.0)
                    }
                })
                .collect();
            let c = cusum_changepoint(&xs);
            let idx = c.index.expect("change point found");
            assert!(
                (idx as i64 - cp as i64).abs() <= 2,
                "cp={cp} located at {idx}"
            );
            assert!(c.stat > 0.9, "stat={}", c.stat);
        }
    }

    #[test]
    fn cusum_quiet_series_has_low_stat() {
        // For pure noise the normalized stat follows the Brownian-bridge
        // sup distribution: P(stat > 2.0) ~ 3e-4, while a clean 6-sigma
        // step lands well above 3. Assert the comfortable margins only.
        let mut rng = Rng::new(3);
        let quiet: Vec<f64> = (0..60).map(|_| rng.gauss(100.0, 2.0)).collect();
        let cq = cusum_changepoint(&quiet);
        assert!(cq.stat < 2.0, "stat={}", cq.stat);
        let stepped: Vec<f64> = (0..60)
            .map(|i| if i < 30 { rng.gauss(100.0, 2.0) } else { rng.gauss(86.0, 2.0) })
            .collect();
        assert!(cusum_changepoint(&stepped).stat > cq.stat + 0.5);
        // degenerate inputs
        assert_eq!(cusum_changepoint(&[1.0, 2.0]).index, None);
        assert_eq!(cusum_changepoint(&[5.0; 10]).index, None);
    }

    #[test]
    fn cusum_step_without_noise_is_exact() {
        let xs: Vec<f64> = (0..8).map(|i| if i < 4 { 10.0 } else { 8.0 }).collect();
        let c = cusum_changepoint(&xs);
        assert_eq!(c.index, Some(4));
        assert!(c.stat > 0.9);
    }

    #[test]
    fn baseline_stats_summary() {
        let b = BaselineStats::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(b.n, 5);
        assert_eq!(b.median, 3.0);
        assert!(b.iqr < b.sd); // IQR shrugs off the outlier
        assert_eq!(BaselineStats::of(&[]).n, 0);
    }
}
