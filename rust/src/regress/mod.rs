//! `regress::` — statistical regression detection, alerting, and
//! automatic commit bisection.
//!
//! The paper's whole point is that continuous benchmarking "reveals
//! performance degradation introduced by code changes immediately" (§7);
//! this subsystem closes that loop over the rest of the stack:
//!
//! 1. [`stats`] — noise-aware change-detection primitives: baseline
//!    windows, Welch's t-test, Mann–Whitney U, CUSUM change-point
//!    location (from scratch; the vendored crate set has no statrs).
//! 2. [`detector`] — per-series policies (measurement + field + group-by
//!    tags + direction) evaluated against a baseline window instead of a
//!    single prior point, emitting confidence-scored [`Finding`]s.
//! 3. [`alerts`] — findings get a lifecycle (open → acknowledged →
//!    resolved), deduplicated per series, persisted as JSON next to the
//!    TSDB and archived as datastore records linked to the offending
//!    pipeline's collection.
//! 4. [`bisect`] — re-runs the pipeline on intermediate commits through
//!    [`crate::coordinator::CbSystem`] and binary-searches the first bad
//!    commit for an open alert.
//!
//! `coordinator::collect_pipeline` runs the detector after every upload
//! (serialized per pipeline even when execution overlaps on the shared
//! `sched::` event scheduler) — **incrementally** by default: [`state`]
//! carries per-series rolling windows across collects so each check
//! ingests only the points its pipeline appended instead of re-querying
//! the tail window, with byte-identical findings/alerts guaranteed (and
//! property-tested) against the full re-query path.
//! `coordinator::detect_regressions` is a thin shim over
//! [`detector::Policy`] with a 1-point window (API and semantics
//! preserved); bisection probes ride the same scheduler as live
//! pipelines; `cbench regress <detect|alerts|bisect>` drives the loop
//! from the CLI.

pub mod alerts;
pub mod bisect;
pub mod detector;
pub mod state;
pub mod stats;

pub use alerts::{alert_to_json, Alert, AlertBook, AlertState, IngestSummary};
pub use bisect::{bisect_chain, bisect_pipeline, chain_between, resolve_short, BisectReport};
pub use detector::{Detector, Direction, Finding, Policy};
pub use state::{detector_fingerprint, DetectorState};
pub use stats::{cusum_changepoint, mann_whitney, welch_t, BaselineStats, Cusum, TwoSampleTest};
