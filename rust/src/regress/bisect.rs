//! Automatic first-bad-commit search for an open alert.
//!
//! `git bisect` for the CB loop: given a commit chain with a known-good
//! start and a known-bad end, re-run the benchmark pipeline on midpoint
//! commits (through the real [`crate::coordinator::CbSystem`], so every
//! probe schedules, parses, uploads and archives like a normal pipeline)
//! and binary-search the first commit whose series value is adversely
//! shifted against the good baseline. O(log n) pipeline re-runs instead
//! of the O(n) a linear replay would need — on real clusters each re-run
//! costs node-hours, so this is the difference between "we bisect every
//! alert automatically" and "someone does it by hand next week".

use super::detector::Direction;
use crate::coordinator::{CbSystem, PreparedJob};
use crate::select::SelectMode;
use crate::tsdb::Query;
use crate::vcs::{PushEvent, Repository};
use std::collections::BTreeMap;

/// Outcome of one bisection.
#[derive(Debug, Clone)]
pub struct BisectReport {
    /// Commits strictly after the good anchor up to and including the bad
    /// anchor (the search space).
    pub candidates: usize,
    /// Every probed commit: (id, measured value, classified bad?).
    pub tested: Vec<(String, f64, bool)>,
    /// First commit classified bad; `None` when the trusted-bad anchor
    /// measured clean (no regression on this chain — wrong chain
    /// arguments, or the alert is stale).
    pub first_bad: Option<String>,
    /// Pipeline executions spent (anchor re-runs + probes).
    pub pipeline_runs: usize,
    /// Pipeline executions a linear front-to-back replay would spend.
    pub linear_runs: usize,
}

/// The linear commit chain on `branch` from `good` (inclusive) to `bad`
/// (inclusive), oldest first.
pub fn chain_between(
    repo: &Repository,
    branch: &str,
    good: &str,
    bad: &str,
) -> anyhow::Result<Vec<String>> {
    let ids: Vec<String> = repo.log(branch).iter().rev().map(|c| c.id.clone()).collect();
    let gi = ids
        .iter()
        .position(|i| i == good)
        .ok_or_else(|| anyhow::anyhow!("good commit {good} not on branch `{branch}`"))?;
    let bi = ids
        .iter()
        .position(|i| i == bad)
        .ok_or_else(|| anyhow::anyhow!("bad commit {bad} not on branch `{branch}`"))?;
    anyhow::ensure!(
        gi < bi,
        "good commit must be an ancestor of the bad commit ({gi} vs {bi})"
    );
    Ok(ids[gi..=bi].to_vec())
}

/// Resolve a short (8-char TSDB tag) commit id against a branch history.
pub fn resolve_short(repo: &Repository, branch: &str, short: &str) -> Option<String> {
    repo.log(branch)
        .iter()
        .find(|c| c.id.starts_with(short))
        .map(|c| c.id.clone())
}

/// Binary-search the first bad commit over `chain` (oldest first;
/// `chain[0]` is trusted good, the last element trusted bad) with an
/// arbitrary classifier. Returns (first_bad_index, probes).
pub fn bisect_chain(
    chain_len: usize,
    mut is_bad: impl FnMut(usize) -> anyhow::Result<bool>,
) -> anyhow::Result<(usize, usize)> {
    anyhow::ensure!(chain_len >= 2, "need at least a good and a bad commit");
    let mut lo = 0usize;
    let mut hi = chain_len - 1;
    let mut probes = 0usize;
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        probes += 1;
        if is_bad(mid)? {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Ok((hi, probes))
}

/// Bisect a regressed series by re-running the benchmark pipeline on
/// midpoint commits.
///
/// * `series_tags` — the alert's group tags identifying the series
///   (`<none>` values are ignored, matching absent tags loosely);
/// * `threshold` — the policy's `min_rel_change`; a probe is *bad* when
///   its adverse relative change vs the good baseline exceeds half of it
///   (midpoint rule, robust to partial regressions);
/// * `jobs_for` — the pipeline's job matrix for a commit (the same
///   function the coordinator uses on push events).
#[allow(clippy::too_many_arguments)]
pub fn bisect_pipeline(
    cb: &mut CbSystem,
    repo: &Repository,
    branch: &str,
    good: &str,
    bad: &str,
    measurement: &str,
    field: &str,
    series_tags: &BTreeMap<String, String>,
    direction: Direction,
    threshold: f64,
    jobs_for: impl FnMut(&Repository, &str) -> Vec<PreparedJob>,
) -> anyhow::Result<BisectReport> {
    // A probe must re-measure the commit it visits. Under change-aware
    // selection a probe job whose CB_COMPONENTS declaration the probed
    // commit does not touch would be skipped and carried forward from
    // the selector's last measured run — the probe would "measure" a
    // stale value and the search would walk to a confidently wrong
    // first-bad commit. Force the full matrix for the whole bisection
    // and restore the caller's mode afterwards (also on error).
    let saved_select = cb.select_mode();
    cb.set_select_mode(SelectMode::Full);
    let out = bisect_pipeline_full(
        cb,
        repo,
        branch,
        good,
        bad,
        measurement,
        field,
        series_tags,
        direction,
        threshold,
        jobs_for,
    );
    cb.set_select_mode(saved_select);
    out
}

#[allow(clippy::too_many_arguments)]
fn bisect_pipeline_full(
    cb: &mut CbSystem,
    repo: &Repository,
    branch: &str,
    good: &str,
    bad: &str,
    measurement: &str,
    field: &str,
    series_tags: &BTreeMap<String, String>,
    direction: Direction,
    threshold: f64,
    mut jobs_for: impl FnMut(&Repository, &str) -> Vec<PreparedJob>,
) -> anyhow::Result<BisectReport> {
    let chain = chain_between(repo, branch, good, bad)?;
    let candidates = chain.len() - 1;
    let mut runs = 0usize;
    let mut tested: Vec<(String, f64, bool)> = Vec::new();

    let mut measure = |cb: &mut CbSystem, commit: &str| -> anyhow::Result<f64> {
        let ev = PushEvent {
            repo: repo.name.clone(),
            branch: branch.to_string(),
            commit_id: commit.to_string(),
            // honest change metadata — selection is forced off above, so
            // this is informational only (and keeps replay artifacts
            // identical whatever mode the caller was in)
            changed: repo.get(commit).map(|c| c.changed.clone()).unwrap_or_default(),
        };
        let jobs = jobs_for(repo, commit);
        anyhow::ensure!(!jobs.is_empty(), "pipeline produced no jobs for {commit}");
        // probes ride the same event-driven scheduler as live pipelines:
        // submit, let the event queue advance, collect — so a bisection
        // interleaves with in-flight CB work instead of owning the cluster
        let pid = cb.submit_pipeline(&ev, false, jobs, measurement, 0)?;
        cb.collect_pipeline(pid)?;
        let ts = cb.last_trigger_ts();
        let mut q = Query::new(measurement, field).range(ts, ts);
        for (k, v) in series_tags {
            if v != "<none>" {
                q = q.where_tag(k, v);
            }
        }
        let vals: Vec<f64> = q
            .run(&cb.db)
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.1))
            .collect();
        anyhow::ensure!(
            !vals.is_empty(),
            "probe of {commit} produced no `{measurement}.{field}` point for the series"
        );
        Ok(vals.iter().sum::<f64>() / vals.len() as f64)
    };

    let baseline = measure(cb, &chain[0])?;
    runs += 1;
    anyhow::ensure!(
        baseline.abs() > 1e-300,
        "good-commit baseline is zero; cannot form relative changes"
    );
    let is_bad_value =
        |v: f64| direction.adverse((v - baseline) / baseline) > 0.5 * threshold;

    // sanity-probe the trusted-bad anchor: if this chain shows no
    // regression at its tip (stale alert, or the chain was rebuilt with
    // different arguments), report that instead of walking the search to
    // a confidently wrong "first bad" commit
    let bad_val = measure(cb, chain.last().unwrap())?;
    runs += 1;
    let anchor_bad = is_bad_value(bad_val);
    tested.push((chain.last().unwrap().clone(), bad_val, anchor_bad));
    if !anchor_bad {
        return Ok(BisectReport {
            candidates,
            tested,
            first_bad: None,
            pipeline_runs: runs,
            linear_runs: candidates,
        });
    }

    let (first_bad_idx, _probes) = bisect_chain(chain.len(), |mid| {
        let v = measure(cb, &chain[mid])?;
        runs += 1;
        let bad = is_bad_value(v);
        tested.push((chain[mid].clone(), v, bad));
        Ok(bad)
    })?;

    Ok(BisectReport {
        candidates,
        tested,
        first_bad: Some(chain[first_bad_idx].clone()),
        pipeline_runs: runs,
        linear_runs: candidates,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_with_chain(n: usize, bad_at: usize) -> (Repository, Vec<String>) {
        let mut repo = Repository::new("r");
        let mut ids = Vec::new();
        for i in 0..n {
            let content = if i + 1 >= bad_at { "slow" } else { "fast" };
            let ev = repo.commit_change(
                "master",
                "dev",
                &format!("c{i}"),
                i as f64,
                "perf.cfg",
                &format!("{content} {i}\n"),
            );
            ids.push(ev.commit_id);
        }
        (repo, ids)
    }

    #[test]
    fn chain_between_slices_history() {
        let (repo, ids) = repo_with_chain(6, 4);
        let chain = chain_between(&repo, "master", &ids[1], &ids[4]).unwrap();
        assert_eq!(chain, ids[1..=4].to_vec());
        assert!(chain_between(&repo, "master", &ids[4], &ids[1]).is_err());
        assert!(chain_between(&repo, "master", "nope", &ids[1]).is_err());
    }

    #[test]
    fn resolve_short_matches_prefix() {
        let (repo, ids) = repo_with_chain(3, 99);
        let short = &ids[1][..8];
        assert_eq!(resolve_short(&repo, "master", short).as_deref(), Some(ids[1].as_str()));
        assert!(resolve_short(&repo, "master", "zzzzzzzz").is_none());
    }

    #[test]
    fn bisect_chain_finds_every_position_with_log_probes() {
        for n in [2usize, 3, 5, 8, 16, 33] {
            for bad in 1..n {
                let (idx, probes) = bisect_chain(n, |i| Ok(i >= bad)).unwrap();
                assert_eq!(idx, bad, "n={n} bad={bad}");
                // strictly fewer probes than a linear scan for n > 3
                let log2 = (n as f64).log2().ceil() as usize;
                assert!(probes <= log2, "n={n} bad={bad}: {probes} > {log2}");
            }
        }
        assert!(bisect_chain(1, |_| Ok(true)).is_err());
    }

    #[test]
    fn bisect_pipeline_locates_first_bad_commit() {
        use crate::ci::CiJob;
        use crate::slurm::JobOutcome;

        let n = 8;
        let bad_at = 5; // 1-based commit #5
        let (repo, ids) = repo_with_chain(n, bad_at);
        let mut cb = CbSystem::new();
        let jobs_for = |repo: &Repository, commit: &str| -> Vec<PreparedJob> {
            let slow = repo
                .get(commit)
                .map(|c| c.tree.get("perf.cfg").map(|t| t.contains("slow")).unwrap_or(false))
                .unwrap_or(false);
            let mlups = if slow { 850.0 } else { 1000.0 };
            vec![PreparedJob {
                ci: CiJob::new("probe-icx36", "benchmark").var("HOST", "icx36"),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: 10.0,
                    stdout: format!("TAG collision_op=srt\nMETRIC mlups={mlups}\n"),
                    exit_code: 0,
                }),
            }]
        };
        let mut tags = BTreeMap::new();
        tags.insert("collision_op".to_string(), "srt".to_string());
        tags.insert("node".to_string(), "icx36".to_string());
        let report = bisect_pipeline(
            &mut cb,
            &repo,
            "master",
            &ids[0],
            &ids[n - 1],
            "lbm",
            "mlups",
            &tags,
            Direction::HigherIsBetter,
            0.08,
            jobs_for,
        )
        .unwrap();
        assert_eq!(report.first_bad.as_deref(), Some(ids[bad_at - 1].as_str()));
        assert_eq!(report.candidates, n - 1);
        assert!(
            report.pipeline_runs < report.linear_runs,
            "{} probes vs {} linear",
            report.pipeline_runs,
            report.linear_runs
        );
        // every probe classified consistently with the plant
        for (cid, _v, bad) in &report.tested {
            let idx = ids.iter().position(|i| i == cid).unwrap();
            assert_eq!(*bad, idx + 1 >= bad_at, "commit {idx}");
        }
    }

    #[test]
    fn clean_chain_reports_inconclusive_not_a_scapegoat() {
        use crate::ci::CiJob;
        use crate::slurm::JobOutcome;

        // no regression anywhere: the bad-anchor sanity probe must catch
        // it and return None instead of blaming the last commit
        let (repo, ids) = repo_with_chain(6, 99);
        let mut cb = CbSystem::new();
        let jobs_for = |_repo: &Repository, _commit: &str| -> Vec<PreparedJob> {
            vec![PreparedJob {
                ci: CiJob::new("probe-icx36", "benchmark").var("HOST", "icx36"),
                payload: Box::new(|_n, _t| JobOutcome {
                    duration: 10.0,
                    stdout: "TAG collision_op=srt\nMETRIC mlups=1000\n".into(),
                    exit_code: 0,
                }),
            }]
        };
        let mut tags = BTreeMap::new();
        tags.insert("collision_op".to_string(), "srt".to_string());
        let report = bisect_pipeline(
            &mut cb,
            &repo,
            "master",
            &ids[0],
            &ids[5],
            "lbm",
            "mlups",
            &tags,
            Direction::HigherIsBetter,
            0.08,
            jobs_for,
        )
        .unwrap();
        assert_eq!(report.first_bad, None);
        // only the two anchors were spent
        assert_eq!(report.pipeline_runs, 2);
    }

    #[test]
    fn probes_force_the_full_matrix_under_change_aware_selection() {
        use crate::ci::CiJob;
        use crate::select::{self, SelectMode, COMPONENTS_VAR};
        use crate::slurm::JobOutcome;

        // every commit touches only src/lbm/cpu/** while the probe job
        // declares lbm/gpu: under change-aware selection a naive probe
        // would skip the job and "measure" the carried-forward value of
        // the last measured run — here the fast baseline — so the bad
        // anchor would read clean and the bisection would walk away from
        // the planted commit
        let n = 6;
        let bad_at = 4; // 1-based
        let mut repo = Repository::new("r");
        let mut ids = Vec::new();
        for i in 0..n {
            let content = if i + 1 >= bad_at { "slow" } else { "fast" };
            let ev = repo.commit_change(
                "master",
                "dev",
                &format!("c{i}"),
                i as f64,
                "src/lbm/cpu/kernel.c",
                &format!("{content} {i}\n"),
            );
            ids.push(ev.commit_id);
        }
        let jobs_for = |repo: &Repository, commit: &str| -> Vec<PreparedJob> {
            let slow = repo
                .get(commit)
                .map(|c| {
                    c.tree
                        .get("src/lbm/cpu/kernel.c")
                        .map(|t| t.contains("slow"))
                        .unwrap_or(false)
                })
                .unwrap_or(false);
            let mlups = if slow { 850.0 } else { 1000.0 };
            vec![PreparedJob {
                ci: CiJob::new("probe-icx36", "benchmark")
                    .var("HOST", "icx36")
                    .var(COMPONENTS_VAR, "lbm/gpu"),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: 10.0,
                    stdout: format!("TAG collision_op=srt\nMETRIC mlups={mlups}\n"),
                    exit_code: 0,
                }),
            }]
        };

        let mut cb = CbSystem::new();
        cb.set_select_mode(SelectMode::ChangeAware);
        // leave the state a change-aware campaign would: one measured run
        // recorded in the selector for the probe job (empty change list =
        // unknown surface = always runs, even change-aware)
        let warm = PushEvent {
            repo: "r".to_string(),
            branch: "master".to_string(),
            commit_id: ids[0].clone(),
            changed: vec![],
        };
        let pid = cb
            .submit_pipeline(&warm, false, jobs_for(&repo, &ids[0]), "lbm", 0)
            .unwrap();
        cb.collect_pipeline(pid).unwrap();
        assert!(cb.selector().last("r", "probe-icx36").is_some());
        // the trap is armed: without the force-full fix this probe job
        // would be skipped for any of the chain's commits
        let touched = select::touched(&repo.get(&ids[2]).unwrap().changed);
        assert!(cb.selector().can_skip("r", &jobs_for(&repo, &ids[2])[0].ci, &touched));

        let mut tags = BTreeMap::new();
        tags.insert("collision_op".to_string(), "srt".to_string());
        tags.insert("node".to_string(), "icx36".to_string());
        let report = bisect_pipeline(
            &mut cb,
            &repo,
            "master",
            &ids[0],
            &ids[n - 1],
            "lbm",
            "mlups",
            &tags,
            Direction::HigherIsBetter,
            0.08,
            jobs_for,
        )
        .unwrap();

        // every probe measured its commit's true value, not a carry-over
        for (cid, v, _) in &report.tested {
            let idx = ids.iter().position(|i| i == cid).unwrap();
            let want = if idx + 1 >= bad_at { 850.0 } else { 1000.0 };
            assert_eq!(*v, want, "probe of commit {idx} carried a stale value");
        }
        assert_eq!(report.first_bad.as_deref(), Some(ids[bad_at - 1].as_str()));
        // the caller's selection mode survives the bisection
        assert_eq!(cb.select_mode(), SelectMode::ChangeAware);
    }
}
