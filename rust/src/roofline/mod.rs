//! Roofline model and plot generation (paper §4.4, Figs. 7–8).
//!
//! The pipeline measures per-node ceilings with likwid-bench-class
//! microbenchmarks (peak FLOP/s + stream/copy/load bandwidths), stores
//! them in the TSDB, and relates every benchmark result to them: a run is
//! a point (operational intensity, achieved GFLOP/s) under the ceilings.
//! The plotting script's output (Fig. 7) is regenerated here as SVG.

use crate::cluster::microbench::{project_node_microbench, MicrobenchKind};
use crate::cluster::nodes::NodeModel;

/// The machine ceilings of one node.
#[derive(Debug, Clone)]
pub struct Ceilings {
    pub peak_gflops: f64,
    /// (name, GB/s) per measured bandwidth variant.
    pub bandwidths: Vec<(String, f64)>,
}

impl Ceilings {
    pub fn of(node: &NodeModel) -> Ceilings {
        let mut bandwidths = Vec::new();
        for kind in [MicrobenchKind::Stream, MicrobenchKind::Copy, MicrobenchKind::Load] {
            let r = project_node_microbench(node, kind);
            bandwidths.push((kind.name().to_string(), r.value));
        }
        Ceilings {
            peak_gflops: project_node_microbench(node, MicrobenchKind::PeakFlops).value,
            bandwidths,
        }
    }

    /// Attainable GFLOP/s at operational intensity `oi` using bandwidth
    /// variant `bw_name` (default stream).
    pub fn attainable(&self, oi: f64, bw_name: &str) -> f64 {
        let bw = self
            .bandwidths
            .iter()
            .find(|(n, _)| n == bw_name)
            .map(|(_, v)| *v)
            .unwrap_or(self.bandwidths[0].1);
        (oi * bw).min(self.peak_gflops)
    }

    /// The ridge point: OI where the machine turns compute-bound.
    pub fn ridge(&self) -> f64 {
        self.peak_gflops / self.bandwidths[0].1
    }
}

/// One measured run in the roofline plane.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub label: String,
    /// Group for coloring (e.g. solver name — Fig. 7's green/yellow/blue).
    pub group: String,
    pub oi: f64,
    pub gflops: f64,
}

impl RooflinePoint {
    /// Fraction of the attainable performance at this OI.
    pub fn efficiency(&self, ceil: &Ceilings) -> f64 {
        self.gflops / ceil.attainable(self.oi, "stream")
    }
}

/// Render a log-log roofline SVG: ceilings + scatter points.
pub fn roofline_svg(node: &NodeModel, points: &[RooflinePoint], title: &str) -> String {
    let ceil = Ceilings::of(node);
    let (w, h) = (760.0, 520.0);
    let (ml, mr, mt, mb) = (70.0, 160.0, 40.0, 50.0);
    let (pw, ph) = (w - ml - mr, h - mt - mb);
    // log ranges
    let oi_min: f64 = 0.01;
    let oi_max: f64 = 100.0;
    let gf_min: f64 = 0.1;
    let gf_max = ceil.peak_gflops * 2.0;
    let x = |oi: f64| ml + (oi.max(oi_min).log10() - oi_min.log10()) / (oi_max / oi_min).log10() * pw;
    let y = |gf: f64| mt + ph - (gf.max(gf_min).log10() - gf_min.log10()) / (gf_max / gf_min).log10() * ph;

    let mut s = String::new();
    s.push_str(&format!(
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" font-family="monospace">"#
    ));
    s.push_str(&format!(
        r#"<rect width="{w}" height="{h}" fill="white"/><text x="{ml}" y="24" font-size="15">{title} — {} </text>"#,
        node.host
    ));
    // axes box
    s.push_str(&format!(
        r#"<rect x="{ml}" y="{mt}" width="{pw}" height="{ph}" fill="none" stroke="black"/>"#
    ));
    // bandwidth ceilings (diagonals) + peak (horizontal)
    let colors = ["#888", "#bbb", "#555"];
    for (i, (name, bw)) in ceil.bandwidths.iter().enumerate() {
        let oi_ridge = ceil.peak_gflops / bw;
        s.push_str(&format!(
            r#"<line x1="{:.1}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="{}" stroke-dasharray="4 2"/>"#,
            x(oi_min),
            y(oi_min * bw),
            x(oi_ridge.min(oi_max)),
            y((oi_ridge.min(oi_max)) * bw),
            colors[i % colors.len()]
        ));
        s.push_str(&format!(
            r#"<text x="{:.1}" y="{:.1}" font-size="10" fill="{}">{name} {bw:.0} GB/s</text>"#,
            x(oi_min) + 4.0,
            y(oi_min * bw) - 4.0,
            colors[i % colors.len()]
        ));
    }
    s.push_str(&format!(
        r#"<line x1="{:.1}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="black"/>"#,
        x(ceil.ridge()),
        y(ceil.peak_gflops),
        x(oi_max),
        y(ceil.peak_gflops)
    ));
    s.push_str(&format!(
        r#"<text x="{:.1}" y="{:.1}" font-size="11">peak {:.0} GFLOP/s</text>"#,
        x(ceil.ridge()),
        y(ceil.peak_gflops) - 6.0,
        ceil.peak_gflops
    ));
    // points, colored by group (Fig. 7: PARDISO green / UMFPACK yellow / ILU blue)
    let group_colors = ["#2a9d2a", "#e0b000", "#2a5fd0", "#d04a2a", "#8a2ad0"];
    let mut groups: Vec<&str> = Vec::new();
    for p in points {
        if !groups.contains(&p.group.as_str()) {
            groups.push(&p.group);
        }
    }
    for p in points {
        let gi = groups.iter().position(|g| *g == p.group).unwrap();
        s.push_str(&format!(
            r#"<circle cx="{:.1}" cy="{:.1}" r="5" fill="{}" fill-opacity="0.8"><title>{}: oi={:.3} gf={:.2}</title></circle>"#,
            x(p.oi),
            y(p.gflops),
            group_colors[gi % group_colors.len()],
            p.label,
            p.oi,
            p.gflops
        ));
    }
    // legend
    for (i, g) in groups.iter().enumerate() {
        let ly = mt + 16.0 * i as f64 + 10.0;
        s.push_str(&format!(
            r#"<circle cx="{:.1}" cy="{ly:.1}" r="5" fill="{}"/><text x="{:.1}" y="{:.1}" font-size="11">{g}</text>"#,
            w - mr + 14.0,
            group_colors[i % group_colors.len()],
            w - mr + 24.0,
            ly + 4.0
        ));
    }
    // axis labels
    s.push_str(&format!(
        r#"<text x="{:.1}" y="{:.1}" font-size="12">operational intensity [FLOP/byte]</text>"#,
        ml + pw / 2.0 - 100.0,
        h - 12.0
    ));
    s.push_str(&format!(
        r#"<text x="16" y="{:.1}" font-size="12" transform="rotate(-90 16 {:.1})">GFLOP/s</text>"#,
        mt + ph / 2.0,
        mt + ph / 2.0
    ));
    s.push_str("</svg>");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::node;

    #[test]
    fn ceilings_and_ridge() {
        let n = node("icx36").unwrap();
        let c = Ceilings::of(&n);
        assert_eq!(c.peak_gflops, n.peak_gflops());
        assert_eq!(c.bandwidths.len(), 3);
        // memory-bound region
        assert!((c.attainable(0.1, "stream") - 0.1 * 237.0).abs() < 1e-9);
        // compute-bound region
        assert_eq!(c.attainable(1000.0, "stream"), c.peak_gflops);
        assert!((c.ridge() - c.peak_gflops / 237.0).abs() < 1e-9);
    }

    #[test]
    fn unknown_bw_falls_back_to_first() {
        let c = Ceilings::of(&node("rome1").unwrap());
        assert_eq!(c.attainable(0.5, "nosuch"), c.attainable(0.5, "stream"));
    }

    #[test]
    fn point_efficiency() {
        let n = node("icx36").unwrap();
        let c = Ceilings::of(&n);
        let p = RooflinePoint {
            label: "ilu".into(),
            group: "ilu".into(),
            oi: 0.12,
            gflops: 0.12 * 237.0 * 0.75,
        };
        assert!((p.efficiency(&c) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn svg_renders_groups_and_ceilings() {
        let n = node("icx36").unwrap();
        let pts = vec![
            RooflinePoint { label: "a".into(), group: "pardiso".into(), oi: 2.0, gflops: 150.0 },
            RooflinePoint { label: "b".into(), group: "ilu".into(), oi: 0.12, gflops: 22.0 },
        ];
        let svg = roofline_svg(&n, &pts, "fe2ti216");
        assert!(svg.starts_with("<svg"));
        assert!(svg.contains("peak"));
        assert!(svg.contains("pardiso") && svg.contains("ilu"));
        assert!(svg.contains("stream"));
        assert!(svg.ends_with("</svg>"));
    }
}
