//! # obs::metrics — host-side self-metrics for the infrastructure's hot paths
//!
//! The paper's premise is that performance must be watched continuously —
//! including the benchmarking system's own overhead (the ROOT framework and
//! "Continuous benchmarking: keeping pace with an evolving ecosystem" both
//! monitor the harness itself). This module provides the measurement side:
//! a fixed set of process-global monotone counters plus fixed-bucket
//! latency histograms around the real hot paths — line-protocol parse,
//! TSDB insert, job-output parse, `DetectorState::sync`, shard
//! materialization, dirty-shard save.
//!
//! Design constraints, in order:
//!
//! 1. **Near-zero cost when disabled** (the default): every recording call
//!    starts with one `Relaxed` load of an `AtomicBool`; [`Timer::start`]
//!    never reads the clock while disabled. No path here allocates —
//!    counters and histograms are fixed-size static atomic arrays — so
//!    instrumented hot loops stay allocation-free (asserted by the
//!    counting-allocator test in `rust/tests/obs_trace.rs`).
//! 2. **No locks**: everything is `AtomicU64` with `Relaxed` ordering.
//!    Counts are monotone; readers take snapshots and difference them.
//! 3. **Host time, not cluster time**: these are wall-clock nanoseconds of
//!    the *process*, unlike `obs::trace` which records deterministic
//!    simulated cluster time. Self-metrics values are therefore noisy and
//!    are kept out of byte-identical replay contracts — the coordinator
//!    only uploads them into the TSDB (measurement `cbench_self`) when
//!    explicitly enabled.
//!
//! The aggregates flow back through the standard pipeline: the coordinator
//! differences [`counters`] snapshots per collect, derives points/sec
//! rates, and inserts `cbench_self` points that the stock
//! `self-throughput` detector policy watches like any workload series.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

/// Number of counter slots (must match [`Counter::ALL`]).
pub const N_COUNTERS: usize = 18;

/// Monotone process-global counters. `*Ns` slots accumulate wall-clock
/// nanoseconds measured by [`Timer`]; the rest count operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Line-protocol lines parsed (batch ingest + shard materialization).
    LpLines,
    /// Nanoseconds spent in line-protocol batch parses.
    LpParseNs,
    /// Points inserted into a `tsdb::Db`.
    InsertPoints,
    /// Nanoseconds spent inside `Db::insert`.
    InsertNs,
    /// Job stdout logs parsed into points by the coordinator.
    JobsParsed,
    /// Nanoseconds spent parsing job stdout.
    JobParseNs,
    /// Points ingested by `DetectorState::sync` (catch-up + rebuild).
    SyncPoints,
    /// Nanoseconds spent inside `DetectorState::sync`.
    SyncNs,
    /// Shard bodies materialized from their backing file.
    ShardLoads,
    /// Points parsed by those materializations.
    ShardLoadPoints,
    /// Nanoseconds spent materializing shard bodies.
    ShardLoadNs,
    /// Clean, cold shard bodies evicted under the LRU body cap.
    ShardEvictions,
    /// Re-materializations of a previously evicted body.
    ShardRemats,
    /// Shard files rewritten by `Db::save_report`.
    SaveShardsWritten,
    /// Nanoseconds spent inside `Db::save_report`.
    SaveNs,
    /// Interner lookups that found an existing symbol/tag set.
    InternHits,
    /// Interner lookups that allocated a new symbol/tag set.
    InternMisses,
    /// Columnar shard bodies materialized into owned `Point` rows
    /// (the public-API boundary cost the columnar store avoids paying
    /// on the ingest/save paths).
    ColMaterializations,
}

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::LpLines,
        Counter::LpParseNs,
        Counter::InsertPoints,
        Counter::InsertNs,
        Counter::JobsParsed,
        Counter::JobParseNs,
        Counter::SyncPoints,
        Counter::SyncNs,
        Counter::ShardLoads,
        Counter::ShardLoadPoints,
        Counter::ShardLoadNs,
        Counter::ShardEvictions,
        Counter::ShardRemats,
        Counter::SaveShardsWritten,
        Counter::SaveNs,
        Counter::InternHits,
        Counter::InternMisses,
        Counter::ColMaterializations,
    ];

    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Counter::LpLines => "lp_lines",
            Counter::LpParseNs => "lp_parse_ns",
            Counter::InsertPoints => "insert_points",
            Counter::InsertNs => "insert_ns",
            Counter::JobsParsed => "jobs_parsed",
            Counter::JobParseNs => "job_parse_ns",
            Counter::SyncPoints => "sync_points",
            Counter::SyncNs => "sync_ns",
            Counter::ShardLoads => "shard_loads",
            Counter::ShardLoadPoints => "shard_load_points",
            Counter::ShardLoadNs => "shard_load_ns",
            Counter::ShardEvictions => "shard_evictions",
            Counter::ShardRemats => "shard_remats",
            Counter::SaveShardsWritten => "save_shards_written",
            Counter::SaveNs => "save_ns",
            Counter::InternHits => "intern_hits",
            Counter::InternMisses => "intern_misses",
            Counter::ColMaterializations => "col_materializations",
        }
    }
}

/// Number of timed-operation histogram rows (must match [`TimedOp::ALL`]).
pub const N_OPS: usize = 6;

/// Log2-bucket latency histogram slots per [`Timer`]-wrapped operation.
pub const HIST_BUCKETS: usize = 32;

/// Operations wrapped by [`Timer`]: each owns a `*Ns` counter and one
/// fixed-bucket histogram row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimedOp {
    LpParse,
    Insert,
    JobParse,
    DetectorSync,
    ShardLoad,
    Save,
}

impl TimedOp {
    pub const ALL: [TimedOp; N_OPS] = [
        TimedOp::LpParse,
        TimedOp::Insert,
        TimedOp::JobParse,
        TimedOp::DetectorSync,
        TimedOp::ShardLoad,
        TimedOp::Save,
    ];

    pub fn idx(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            TimedOp::LpParse => "lp_parse",
            TimedOp::Insert => "insert",
            TimedOp::JobParse => "job_parse",
            TimedOp::DetectorSync => "detector_sync",
            TimedOp::ShardLoad => "shard_load",
            TimedOp::Save => "save",
        }
    }

    /// The counter accumulating this operation's total nanoseconds.
    pub fn ns_counter(self) -> Counter {
        match self {
            TimedOp::LpParse => Counter::LpParseNs,
            TimedOp::Insert => Counter::InsertNs,
            TimedOp::JobParse => Counter::JobParseNs,
            TimedOp::DetectorSync => Counter::SyncNs,
            TimedOp::ShardLoad => Counter::ShardLoadNs,
            TimedOp::Save => Counter::SaveNs,
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
#[allow(clippy::declare_interior_mutable_const)]
const ZERO_ROW: [AtomicU64; HIST_BUCKETS] = [ZERO; HIST_BUCKETS];

static COUNTERS: [AtomicU64; N_COUNTERS] = [ZERO; N_COUNTERS];
static HIST: [[AtomicU64; HIST_BUCKETS]; N_OPS] = [ZERO_ROW; N_OPS];

/// Turn recording on/off process-wide. Off (the default) reduces every
/// recording call to one relaxed bool load.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Add `v` to a counter (no-op while disabled).
pub fn add(c: Counter, v: u64) {
    if v != 0 && enabled() {
        COUNTERS[c.idx()].fetch_add(v, Ordering::Relaxed);
    }
}

/// Current value of one counter.
pub fn get(c: Counter) -> u64 {
    COUNTERS[c.idx()].load(Ordering::Relaxed)
}

/// Snapshot of every counter, indexed by [`Counter::idx`]. Readers
/// difference two snapshots to get a window's worth of activity.
pub fn counters() -> [u64; N_COUNTERS] {
    let mut out = [0u64; N_COUNTERS];
    for (i, slot) in COUNTERS.iter().enumerate() {
        out[i] = slot.load(Ordering::Relaxed);
    }
    out
}

/// Snapshot of one operation's latency histogram (bucket `i` counts
/// observations with `ns < 2^(i+1)`, last bucket is open-ended).
pub fn hist(op: TimedOp) -> [u64; HIST_BUCKETS] {
    let mut out = [0u64; HIST_BUCKETS];
    for (i, slot) in HIST[op.idx()].iter().enumerate() {
        out[i] = slot.load(Ordering::Relaxed);
    }
    out
}

/// Zero every counter and histogram (bench/test setup).
pub fn reset() {
    for slot in COUNTERS.iter() {
        slot.store(0, Ordering::Relaxed);
    }
    for row in HIST.iter() {
        for slot in row.iter() {
            slot.store(0, Ordering::Relaxed);
        }
    }
}

/// The fixed-bucket index of a duration: `floor(log2(ns))`, clamped.
pub fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        return 0;
    }
    ((63 - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
}

/// `ops` per second given `ns` total nanoseconds (0.0 when unmeasured).
pub fn rate_per_sec(ops: u64, ns: u64) -> f64 {
    if ns == 0 {
        0.0
    } else {
        ops as f64 * 1e9 / ns as f64
    }
}

/// Scope timer: reads the clock only while recording is enabled, and on
/// [`Timer::stop`] adds the elapsed nanoseconds to the operation's `*Ns`
/// counter and its histogram row. Returns the elapsed ns (0 if disabled).
#[must_use = "a timer records nothing until stop() is called"]
pub struct Timer(Option<Instant>);

impl Timer {
    pub fn start() -> Timer {
        Timer(if enabled() { Some(Instant::now()) } else { None })
    }

    pub fn stop(self, op: TimedOp) -> u64 {
        match self.0 {
            Some(t0) => {
                let ns = t0.elapsed().as_nanos() as u64;
                // the timer only exists because recording was enabled at
                // start(); an enable-flag flip mid-flight is harmless
                COUNTERS[op.ns_counter().idx()].fetch_add(ns, Ordering::Relaxed);
                HIST[op.idx()][bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
                ns
            }
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_floor_log2_clamped() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn rate_handles_zero_time() {
        assert_eq!(rate_per_sec(100, 0), 0.0);
        assert_eq!(rate_per_sec(5, 1_000_000_000), 5.0);
    }

    #[test]
    fn enum_tables_are_consistent() {
        for (i, c) in Counter::ALL.iter().enumerate() {
            assert_eq!(c.idx(), i, "{}", c.name());
        }
        for (i, op) in TimedOp::ALL.iter().enumerate() {
            assert_eq!(op.idx(), i, "{}", op.name());
            // every timed op's ns counter exists in the table
            assert!(op.ns_counter().idx() < N_COUNTERS);
        }
    }

    // Enable/disable gating is a single test: the registry is process
    // global and the disabled-phase equality asserts must run before any
    // test in this binary ever enables recording (tests run in parallel).
    #[test]
    fn gate_and_counters() {
        // phase 1 — disabled (process default): adds and timers are inert
        assert!(!enabled());
        let before = get(Counter::ShardEvictions);
        add(Counter::ShardEvictions, 7);
        let t = Timer::start();
        let ns = t.stop(TimedOp::Save);
        assert_eq!(ns, 0);
        assert_eq!(get(Counter::ShardEvictions), before);

        // phase 2 — enabled: counters advance (>=: other threads may also
        // record while the gate is open)
        set_enabled(true);
        add(Counter::ShardEvictions, 3);
        let t = Timer::start();
        std::hint::black_box(fibonacci(18));
        let ns = t.stop(TimedOp::Save);
        set_enabled(false);
        assert!(ns > 0);
        assert!(get(Counter::ShardEvictions) >= before + 3);
        assert!(get(Counter::SaveNs) >= ns);
        let h = hist(TimedOp::Save);
        assert!(h.iter().sum::<u64>() >= 1);

        // phase 3 — contention: the slots are process-global relaxed
        // atomics, so adds and timers from par:: worker threads must
        // aggregate without losing updates (`--self-metrics on` with
        // `--threads > 1` depends on this). 8 threads x 1000 adds each,
        // plus a timer per thread; totals must grow by at least the sum.
        let c0 = get(Counter::LpLines);
        let h0: u64 = hist(TimedOp::LpParse).iter().sum();
        set_enabled(true);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        add(Counter::LpLines, 1);
                    }
                    let t = Timer::start();
                    std::hint::black_box(fibonacci(12));
                    t.stop(TimedOp::LpParse);
                });
            }
        });
        set_enabled(false);
        assert!(get(Counter::LpLines) >= c0 + 8 * 1000, "lost counter updates under contention");
        assert!(hist(TimedOp::LpParse).iter().sum::<u64>() >= h0 + 8, "lost histogram samples under contention");
    }

    fn fibonacci(n: u64) -> u64 {
        if n < 2 {
            n
        } else {
            fibonacci(n - 1) + fibonacci(n - 2)
        }
    }
}
