//! # obs::trace — deterministic cluster-time tracing of the pipeline lifecycle
//!
//! Records what the benchmarking infrastructure itself did with the
//! cluster's time: one span per pipeline, job, queue-wait, run, collect,
//! detect and alert-open step, plus campaign roots and maintenance
//! windows. All timestamps are **simulated cluster seconds** (the
//! scheduler's clock), never wall clock, so a replayed campaign produces a
//! byte-identical trace — the same contract as `sched::timeline()`.
//!
//! ## Span model and id scheme
//!
//! A [`Span`] is `(id, parent, cat, name, repo, node, t0, t1, meta)`.
//! Ids are **stable**: FNV-1a over `(cat, name, repo, node)`, where the
//! name embeds the identifying coordinates — pipeline spans are named
//! `p<pid> <repo> @<commit8>`, job-level spans `p<pid>/j<seq>/<job>` — so
//! a span's id is a pure function of `(repo, push, pid, job seq)` and a
//! re-recorded campaign assigns identical ids. `parent = 0` marks a root.
//! Zero-length spans (`t0 == t1`) are instants (detect, alert-open).
//!
//! Categories: `campaign` (root, carries the node inventory in meta),
//! `pipeline`, `job` (submit→end envelope), `queue` (submit→start),
//! `run` (start→end, meta carries the submit time), `collect`
//! (last job end→collected), `detect`, `alert-open`, `maint`
//! (maintenance window clipped to the campaign interval).
//!
//! ## Exports
//!
//! * [`TraceRecorder::tree_text`] — indented span tree (`cbench trace show`)
//! * [`TraceRecorder::chrome_json`] — Chrome trace-event JSON
//!   (`cbench trace export --chrome`), one lane per node/repo, opens in
//!   Perfetto or `chrome://tracing`
//! * [`TraceRecorder::to_json`]/[`load`](TraceRecorder::load) — the
//!   persisted form written by `--save-trace`
//!
//! ## Critical path
//!
//! [`critical_path`] walks the span DAG *backward* from the campaign end:
//! the segment ending at `t` is whatever explains `t` — a run finishing
//! there, a maintenance window lifting there, the blocked job's
//! queue-wait back to its submit, or a collect phase — and the walk
//! continues from that segment's start. Every boundary is a timestamp
//! *copied* from the spans (never arithmetic), so adjacent segments meet
//! exactly and the chain sums to the makespan with zero float drift —
//! `attributed_pct` is emitted as exactly `100` only when the chain covers
//! `[t0, t_end]` with bit-exact endpoints.

use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// FNV-1a over a part list (with separators, so `("ab","c") != ("a","bc")`).
/// Returns a nonzero id — 0 is the "no parent" sentinel.
pub fn fnv64(parts: &[&str]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for p in parts {
        for b in p.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= 0x1f;
        h = h.wrapping_mul(0x100000001b3);
    }
    if h == 0 {
        1
    } else {
        h
    }
}

/// One traced interval (or instant, when `t0 == t1`) of cluster time.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub id: u64,
    /// Parent span id; 0 for roots.
    pub parent: u64,
    pub cat: String,
    pub name: String,
    /// Repository the work belongs to ("" for infrastructure spans).
    pub repo: String,
    /// Node the work ran on ("" when not node-bound).
    pub node: String,
    pub t0: f64,
    pub t1: f64,
    /// Extra key/value arguments (e.g. `submit` on run spans).
    pub meta: Vec<(String, String)>,
}

impl Span {
    pub fn dur(&self) -> f64 {
        (self.t1 - self.t0).max(0.0)
    }

    pub fn meta_str(&self, key: &str) -> Option<&str> {
        self.meta
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta_str(key).and_then(|v| v.parse().ok())
    }
}

/// Append-only deterministic span recorder, carried by the coordinator.
/// Enabled by default — recording costs a Vec push of already-known
/// values on the (simulated) collect path, never on job hot paths — and
/// fully inert when disabled: [`TraceRecorder::span`] returns 0 without
/// hashing or allocating.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    enabled: bool,
    root: u64,
    spans: Vec<Span>,
}

impl Default for TraceRecorder {
    fn default() -> TraceRecorder {
        TraceRecorder::new()
    }
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder { enabled: true, root: 0, spans: Vec::new() }
    }

    pub fn disabled() -> TraceRecorder {
        TraceRecorder { enabled: false, root: 0, spans: Vec::new() }
    }

    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }
    pub fn len(&self) -> usize {
        self.spans.len()
    }
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }
    pub fn clear(&mut self) {
        self.spans.clear();
        self.root = 0;
    }

    /// Open a root span (a campaign). Subsequent [`TraceRecorder::root`]
    /// calls return its id so pipeline spans can attach to it; `end_root`
    /// closes it. Returns 0 when disabled.
    pub fn begin_root(&mut self, name: &str, t0: f64, meta: &[(&str, &str)]) -> u64 {
        if !self.enabled {
            return 0;
        }
        let id = self.span_m(0, "campaign", name, "", "", t0, t0, meta);
        self.root = id;
        id
    }

    /// Close the current root span at `t1` (keeps the larger end if
    /// pipeline spans already pushed it out).
    pub fn end_root(&mut self, t1: f64) {
        if !self.enabled {
            return;
        }
        let root = self.root;
        if let Some(s) = self.spans.iter_mut().find(|s| s.id == root) {
            s.t1 = s.t1.max(t1);
        }
    }

    /// Id of the open root span (0 when none — spans become roots).
    pub fn root(&self) -> u64 {
        self.root
    }

    /// Record a span. The id is the stable FNV of
    /// `(cat, name, repo, node)` — see the module docs for the scheme.
    pub fn span(
        &mut self,
        parent: u64,
        cat: &str,
        name: &str,
        repo: &str,
        node: &str,
        t0: f64,
        t1: f64,
    ) -> u64 {
        self.span_m(parent, cat, name, repo, node, t0, t1, &[])
    }

    /// [`TraceRecorder::span`] with meta key/value arguments attached.
    #[allow(clippy::too_many_arguments)]
    pub fn span_m(
        &mut self,
        parent: u64,
        cat: &str,
        name: &str,
        repo: &str,
        node: &str,
        t0: f64,
        t1: f64,
        meta: &[(&str, &str)],
    ) -> u64 {
        if !self.enabled {
            return 0;
        }
        let id = fnv64(&[cat, name, repo, node]);
        self.spans.push(Span {
            id,
            parent,
            cat: cat.to_string(),
            name: name.to_string(),
            repo: repo.to_string(),
            node: node.to_string(),
            t0,
            t1,
            meta: meta
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
        });
        id
    }

    /// Spans sorted by `(t0, t1, id)` — the deterministic export order.
    fn sorted(&self) -> Vec<&Span> {
        let mut v: Vec<&Span> = self.spans.iter().collect();
        v.sort_by(|a, b| {
            a.t0.total_cmp(&b.t0)
                .then(a.t1.total_cmp(&b.t1))
                .then(a.id.cmp(&b.id))
        });
        v
    }

    /// Indented span tree (`cbench trace show`). Children nest under
    /// their parent sorted by `(t0, t1, id)`; orphans print as roots.
    pub fn tree_text(&self) -> String {
        let sorted = self.sorted();
        let known: BTreeSet<u64> = sorted.iter().map(|s| s.id).collect();
        let mut children: BTreeMap<u64, Vec<&Span>> = BTreeMap::new();
        let mut roots: Vec<&Span> = Vec::new();
        for s in &sorted {
            if s.parent != 0 && known.contains(&s.parent) && s.parent != s.id {
                children.entry(s.parent).or_default().push(s);
            } else {
                roots.push(s);
            }
        }
        let mut out = String::new();
        // manual stack: (span, depth), children pushed in reverse so the
        // earliest child prints first
        let mut stack: Vec<(&Span, usize)> = roots.into_iter().rev().map(|s| (s, 0)).collect();
        let mut seen: BTreeSet<u64> = BTreeSet::new();
        while let Some((s, depth)) = stack.pop() {
            let indent = "  ".repeat(depth);
            let tag = if s.t1 > s.t0 {
                format!("t={:.3}..{:.3} ({:.3} s)", s.t0, s.t1, s.t1 - s.t0)
            } else {
                format!("t={:.3} (instant)", s.t0)
            };
            out.push_str(&format!("{indent}{} [{}] {}", s.name, s.cat, tag));
            if !s.node.is_empty() {
                out.push_str(&format!(" node={}", s.node));
            }
            if !s.repo.is_empty() {
                out.push_str(&format!(" repo={}", s.repo));
            }
            out.push('\n');
            if seen.insert(s.id) {
                if let Some(kids) = children.get(&s.id) {
                    for k in kids.iter().rev() {
                        stack.push((k, depth + 1));
                    }
                }
            }
        }
        out
    }

    /// Chrome trace-event JSON (the `chrome://tracing` / Perfetto format):
    /// one lane ("thread") per node, repo or the cluster itself, complete
    /// events (`ph:"X"`) for intervals and instants (`ph:"i"`) for
    /// zero-length spans, timestamps in microseconds of cluster time.
    pub fn chrome_json(&self) -> Json {
        let lane_of = |s: &Span| -> String {
            if !s.node.is_empty() {
                format!("node {}", s.node)
            } else if !s.repo.is_empty() {
                format!("repo {}", s.repo)
            } else {
                "cluster".to_string()
            }
        };
        let lanes: BTreeSet<String> = self.spans.iter().map(|s| lane_of(s)).collect();
        let tid: BTreeMap<&str, i64> = lanes
            .iter()
            .enumerate()
            .map(|(i, l)| (l.as_str(), i as i64 + 1))
            .collect();
        let mut events: Vec<Json> = Vec::new();
        for (lane, t) in &tid {
            events.push(
                Json::obj()
                    .set("ph", "M")
                    .set("pid", 1i64)
                    .set("tid", *t)
                    .set("name", "thread_name")
                    .set("args", Json::obj().set("name", *lane)),
            );
        }
        for s in self.sorted() {
            let mut args = Json::obj()
                .set("id", format!("{:016x}", s.id))
                .set("parent", format!("{:016x}", s.parent));
            if !s.repo.is_empty() {
                args = args.set("repo", s.repo.as_str());
            }
            if !s.node.is_empty() {
                args = args.set("node", s.node.as_str());
            }
            for (k, v) in &s.meta {
                args = args.set(k, v.as_str());
            }
            let lane = lane_of(s);
            let mut ev = Json::obj()
                .set("pid", 1i64)
                .set("tid", tid[lane.as_str()])
                .set("name", s.name.as_str())
                .set("cat", s.cat.as_str())
                .set("ts", s.t0 * 1e6)
                .set("args", args);
            ev = if s.t1 > s.t0 {
                ev.set("ph", "X").set("dur", (s.t1 - s.t0) * 1e6)
            } else {
                ev.set("ph", "i").set("s", "t")
            };
            events.push(ev);
        }
        Json::obj()
            .set("displayTimeUnit", "ms")
            .set("traceEvents", Json::Arr(events))
    }

    /// The persisted form (`--save-trace` / `cbench trace --trace FILE`).
    pub fn to_json(&self) -> Json {
        let spans: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                let mut meta = Json::obj();
                for (k, v) in &s.meta {
                    meta = meta.set(k, v.as_str());
                }
                Json::obj()
                    .set("id", format!("{:016x}", s.id))
                    .set("parent", format!("{:016x}", s.parent))
                    .set("cat", s.cat.as_str())
                    .set("name", s.name.as_str())
                    .set("repo", s.repo.as_str())
                    .set("node", s.node.as_str())
                    .set("t0", s.t0)
                    .set("t1", s.t1)
                    .set("meta", meta)
            })
            .collect();
        Json::obj()
            .set("version", 1i64)
            .set("root", format!("{:016x}", self.root))
            .set("spans", Json::Arr(spans))
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TraceRecorder> {
        let hex = |s: &str| u64::from_str_radix(s, 16).map_err(|e| anyhow::anyhow!("bad id: {e}"));
        let mut rec = TraceRecorder::new();
        rec.root = match j.get("root").and_then(|v| v.as_str()) {
            Some(s) => hex(s)?,
            None => 0,
        };
        let spans = j
            .get("spans")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("trace file has no spans array"))?;
        for s in spans {
            let str_of = |k: &str| s.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string();
            let num_of = |k: &str| -> anyhow::Result<f64> {
                s.get(k)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow::anyhow!("span missing {k}"))
            };
            let meta: Vec<(String, String)> = s
                .get("meta")
                .and_then(|v| v.as_obj())
                .map(|m| {
                    m.iter()
                        .filter_map(|(k, v)| v.as_str().map(|v| (k.clone(), v.to_string())))
                        .collect()
                })
                .unwrap_or_default();
            rec.spans.push(Span {
                id: hex(&str_of("id"))?,
                parent: hex(&str_of("parent"))?,
                cat: str_of("cat"),
                name: str_of("name"),
                repo: str_of("repo"),
                node: str_of("node"),
                t0: num_of("t0")?,
                t1: num_of("t1")?,
                meta,
            });
        }
        Ok(rec)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("cannot write trace {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> anyhow::Result<TraceRecorder> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read trace {}: {e} — record one with `cbench campaign --save-trace {}`",
                path.display(),
                path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad trace file: {e}"))?;
        TraceRecorder::from_json(&j)
    }
}

/// One segment of the critical-path chain.
#[derive(Debug, Clone, PartialEq)]
pub struct CritSegment {
    pub t0: f64,
    pub t1: f64,
    /// `run` | `queue-wait` | `maintenance` | `collect` | `idle`.
    pub cat: String,
    /// The span name that explains this segment.
    pub what: String,
    pub node: String,
    pub repo: String,
}

impl CritSegment {
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// Where one node's campaign time went (`run`/`maint`/`wait` from a
/// boundary sweep, `idle` by subtraction so the four sum to the makespan
/// exactly).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeBreakdown {
    pub run: f64,
    pub maint: f64,
    pub wait: f64,
    pub idle: f64,
}

/// Per-repository run/queue-wait totals (raw span sums across nodes).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RepoBreakdown {
    pub run: f64,
    pub wait: f64,
    pub jobs: usize,
}

/// Output of [`critical_path`].
#[derive(Debug, Clone)]
pub struct CritPath {
    pub t0: f64,
    pub t_end: f64,
    pub makespan: f64,
    /// The chain, oldest first; adjacent segments share an endpoint.
    pub segments: Vec<CritSegment>,
    pub by_category: BTreeMap<String, f64>,
    pub per_node: BTreeMap<String, NodeBreakdown>,
    pub per_repo: BTreeMap<String, RepoBreakdown>,
}

impl CritPath {
    /// Time span covered by the chain — equals `makespan` bit-exactly
    /// when [`CritPath::covers_exactly`] holds, because both are the same
    /// two endpoint values subtracted.
    pub fn attributed(&self) -> f64 {
        match self.segments.first() {
            Some(s) => self.t_end - s.t0,
            None => 0.0,
        }
    }

    /// True when the chain tiles `[t0, t_end]` exactly: bit-equal shared
    /// endpoints between adjacent segments, first start == `t0`, last
    /// end == `t_end`.
    pub fn covers_exactly(&self) -> bool {
        if self.segments.is_empty() {
            return self.makespan == 0.0;
        }
        let contiguous = self
            .segments
            .windows(2)
            .all(|w| w[0].t1 == w[1].t0);
        contiguous
            && self.segments.first().map(|s| s.t0) == Some(self.t0)
            && self.segments.last().map(|s| s.t1) == Some(self.t_end)
    }

    pub fn attributed_pct(&self) -> f64 {
        if self.covers_exactly() {
            100.0
        } else if self.makespan > 0.0 {
            100.0 * self.attributed() / self.makespan
        } else {
            0.0
        }
    }

    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "critical path: makespan {:.3} s over [{:.3}, {:.3}] — {} segments, {:.1}% attributed\n",
            self.makespan,
            self.t0,
            self.t_end,
            self.segments.len(),
            self.attributed_pct()
        ));
        out.push_str("\nchain (oldest first):\n");
        for s in &self.segments {
            out.push_str(&format!(
                "  [{:>10.3} ..{:>10.3}] {:>11} {:>9.3} s  {}{}{}\n",
                s.t0,
                s.t1,
                s.cat,
                s.dur(),
                s.what,
                if s.node.is_empty() { String::new() } else { format!("  node={}", s.node) },
                if s.repo.is_empty() { String::new() } else { format!("  repo={}", s.repo) },
            ));
        }
        out.push_str("\nby category (of the chain):\n");
        for (cat, secs) in &self.by_category {
            let pct = if self.makespan > 0.0 { 100.0 * secs / self.makespan } else { 0.0 };
            out.push_str(&format!("  {cat:>11} {secs:>10.3} s  {pct:>5.1}%\n"));
        }
        if !self.per_node.is_empty() {
            out.push_str("\nper node (full partition; run+maint+wait+idle = makespan):\n");
            out.push_str(&format!(
                "  {:<12} {:>10} {:>10} {:>10} {:>10}\n",
                "node", "run", "maint", "wait", "idle"
            ));
            for (node, b) in &self.per_node {
                out.push_str(&format!(
                    "  {:<12} {:>10.3} {:>10.3} {:>10.3} {:>10.3}\n",
                    node, b.run, b.maint, b.wait, b.idle
                ));
            }
        }
        if !self.per_repo.is_empty() {
            out.push_str("\nper repo (raw span sums across nodes):\n");
            out.push_str(&format!(
                "  {:<12} {:>5} {:>10} {:>10}\n",
                "repo", "jobs", "run", "queue-wait"
            ));
            for (repo, b) in &self.per_repo {
                out.push_str(&format!(
                    "  {:<12} {:>5} {:>10.3} {:>10.3}\n",
                    repo, b.jobs, b.run, b.wait
                ));
            }
        }
        out
    }

    /// The single-line `CRITPATH_JSON` payload.
    pub fn to_json(&self) -> Json {
        let mut by_cat = Json::obj();
        for (k, v) in &self.by_category {
            by_cat = by_cat.set(k, *v);
        }
        let mut nodes = Json::obj();
        for (n, b) in &self.per_node {
            nodes = nodes.set(
                n,
                Json::obj()
                    .set("run", b.run)
                    .set("maint", b.maint)
                    .set("wait", b.wait)
                    .set("idle", b.idle),
            );
        }
        let mut repos = Json::obj();
        for (r, b) in &self.per_repo {
            repos = repos.set(
                r,
                Json::obj()
                    .set("run", b.run)
                    .set("wait", b.wait)
                    .set("jobs", b.jobs),
            );
        }
        Json::obj()
            .set("makespan_s", self.makespan)
            .set("t0", self.t0)
            .set("t_end", self.t_end)
            .set("segments", self.segments.len())
            .set("attributed_s", self.attributed())
            .set("attributed_pct", self.attributed_pct())
            .set("by_category", by_cat)
            .set("per_node", nodes)
            .set("per_repo", repos)
    }
}

/// Walk the span DAG backward from the campaign end and attribute the
/// makespan to a contiguous chain of run / queue-wait / maintenance /
/// collect / idle segments (see the module docs for the algorithm and the
/// exactness argument). Also computes the full per-node time partition
/// and per-repo totals.
pub fn critical_path(spans: &[Span]) -> anyhow::Result<CritPath> {
    anyhow::ensure!(
        !spans.is_empty(),
        "empty trace — run a campaign or pipeline with tracing enabled first"
    );
    let campaign = spans.iter().find(|s| s.cat == "campaign");
    let (t0, t_end) = match campaign {
        Some(c) => (c.t0, c.t1),
        None => spans
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), s| {
                (a.min(s.t0), b.max(s.t1))
            }),
    };
    anyhow::ensure!(t_end >= t0, "degenerate trace interval");
    let makespan = t_end - t0;

    let runs: Vec<&Span> = spans.iter().filter(|s| s.cat == "run").collect();
    let maints: Vec<&Span> = spans.iter().filter(|s| s.cat == "maint").collect();
    let queues: Vec<&Span> = spans.iter().filter(|s| s.cat == "queue").collect();
    let collects: Vec<&Span> = spans.iter().filter(|s| s.cat == "collect").collect();

    // --- the chain: walk backward from t_end ---
    let mut segs: Vec<CritSegment> = Vec::new(); // newest-first while building
    let mut t = t_end;
    // the job whose start we are currently explaining:
    // (submit time, node, repo, name)
    let mut floor: Option<(f64, String, String, String)> = None;
    while t > t0 {
        let t_before = t;
        if let Some(f) = &floor {
            if t <= f.0 {
                floor = None;
            }
        }
        // deterministic pick among candidates ending exactly at t: the
        // latest-starting span, ties broken by smallest id
        let pick = |cands: &[&Span], node: Option<&str>| -> Option<Span> {
            cands
                .iter()
                .filter(|s| s.t1 == t && s.t0 < t && node.map_or(true, |n| s.node == n))
                .max_by(|a, b| a.t0.total_cmp(&b.t0).then(b.id.cmp(&a.id)))
                .map(|s| (*s).clone())
        };
        let node = floor.as_ref().map(|f| f.1.clone());
        // 1) a run finishing exactly at t (on the blocked job's node, if
        //    one is being explained) — the cluster computed until t
        if let Some(r) = pick(&runs, node.as_deref()) {
            segs.push(CritSegment {
                t0: r.t0,
                t1: t,
                cat: "run".to_string(),
                what: r.name.clone(),
                node: r.node.clone(),
                repo: r.repo.clone(),
            });
            let submit = r.meta_f64("submit").unwrap_or(r.t0).max(t0);
            floor = Some((submit, r.node, r.repo, r.name));
            t = r.t0;
            continue;
        }
        // 2) a maintenance window lifting exactly at t blocked the node
        if let Some(m) = pick(&maints, node.as_deref()) {
            let start = floor
                .as_ref()
                .map(|f| f.0.max(m.t0))
                .unwrap_or(m.t0)
                .max(t0);
            if start < t {
                segs.push(CritSegment {
                    t0: start,
                    t1: t,
                    cat: "maintenance".to_string(),
                    what: m.name.clone(),
                    node: m.node.clone(),
                    repo: String::new(),
                });
                t = start;
                continue;
            }
        }
        // 3) nothing ended at t but a job was waiting: queue-wait back to
        //    its submission (priority / fair-share / wake ordering)
        if let Some(f) = floor.take() {
            if f.0 < t {
                segs.push(CritSegment {
                    t0: f.0,
                    t1: t,
                    cat: "queue-wait".to_string(),
                    what: f.3,
                    node: f.1,
                    repo: f.2,
                });
                t = f.0;
                continue;
            }
        }
        // 4) a collect phase ending exactly at t (campaign tails, and the
        //    inter-pipeline gap of sequential runs)
        if let Some(c) = pick(&collects, None) {
            segs.push(CritSegment {
                t0: c.t0,
                t1: t,
                cat: "collect".to_string(),
                what: c.name.clone(),
                node: String::new(),
                repo: c.repo.clone(),
            });
            t = c.t0;
            continue;
        }
        // 5) unexplained: idle gap back to the latest earlier span edge
        let prev = spans
            .iter()
            .flat_map(|s| [s.t0, s.t1])
            .filter(|&e| e < t)
            .fold(t0, f64::max);
        segs.push(CritSegment {
            t0: prev,
            t1: t,
            cat: "idle".to_string(),
            what: "gap".to_string(),
            node: String::new(),
            repo: String::new(),
        });
        t = prev;
        anyhow::ensure!(t < t_before, "critical-path walk stalled at t={t}");
    }
    segs.reverse();

    let mut by_category: BTreeMap<String, f64> = BTreeMap::new();
    for s in &segs {
        *by_category.entry(s.cat.clone()).or_insert(0.0) += s.dur();
    }

    // --- per-node partition: boundary sweep, priority run > maint >
    // wait, idle by subtraction so the four sum to the makespan exactly
    let mut node_names: BTreeSet<String> = runs
        .iter()
        .chain(&maints)
        .chain(&queues)
        .filter(|s| !s.node.is_empty())
        .map(|s| s.node.clone())
        .collect();
    if let Some(c) = campaign {
        if let Some(hosts) = c.meta_str("nodes") {
            node_names.extend(hosts.split(',').filter(|h| !h.is_empty()).map(String::from));
        }
    }
    let mut per_node: BTreeMap<String, NodeBreakdown> = BTreeMap::new();
    for node in &node_names {
        let mut edges: Vec<f64> = vec![t0, t_end];
        for s in runs.iter().chain(&maints).chain(&queues) {
            if &s.node == node {
                for e in [s.t0, s.t1] {
                    if e > t0 && e < t_end {
                        edges.push(e);
                    }
                }
            }
        }
        edges.sort_by(f64::total_cmp);
        edges.dedup();
        let covered = |set: &[&Span], a: f64, b: f64| {
            set.iter().any(|s| &s.node == node && s.t0 <= a && b <= s.t1)
        };
        let mut b = NodeBreakdown::default();
        for w in edges.windows(2) {
            let len = w[1] - w[0];
            if covered(&runs, w[0], w[1]) {
                b.run += len;
            } else if covered(&maints, w[0], w[1]) {
                b.maint += len;
            } else if covered(&queues, w[0], w[1]) {
                b.wait += len;
            }
        }
        b.idle = (makespan - b.run - b.maint - b.wait).max(0.0);
        per_node.insert(node.clone(), b);
    }

    let mut per_repo: BTreeMap<String, RepoBreakdown> = BTreeMap::new();
    for s in &runs {
        if s.repo.is_empty() {
            continue;
        }
        let e = per_repo.entry(s.repo.clone()).or_default();
        e.run += s.dur();
        e.jobs += 1;
    }
    for s in &queues {
        if s.repo.is_empty() {
            continue;
        }
        per_repo.entry(s.repo.clone()).or_default().wait += s.dur();
    }

    Ok(CritPath { t0, t_end, makespan, segments: segs, by_category, per_node, per_repo })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_recorder() -> TraceRecorder {
        let mut rec = TraceRecorder::new();
        let root = rec.begin_root("campaign", 0.0, &[("nodes", "a,b")]);
        let p = rec.span_m(root, "pipeline", "p1 fe2ti @abcdef12", "fe2ti", "", 0.0, 100.0, &[]);
        let j1 = rec.span(p, "job", "p1/j0/cg", "fe2ti", "a", 0.0, 40.0);
        rec.span_m(j1, "run", "p1/j0/cg", "fe2ti", "a", 0.0, 40.0, &[("submit", "0")]);
        rec.span(root, "maint", "maint/a/0", "", "a", 40.0, 45.0);
        let j2 = rec.span(p, "job", "p1/j1/asm", "fe2ti", "a", 0.0, 90.0);
        rec.span(j2, "queue", "p1/j1/asm", "fe2ti", "a", 0.0, 45.0);
        rec.span_m(j2, "run", "p1/j1/asm", "fe2ti", "a", 45.0, 90.0, &[("submit", "0")]);
        rec.span(p, "collect", "collect p1", "fe2ti", "", 90.0, 100.0);
        rec.span(p, "detect", "detect p1", "fe2ti", "", 100.0, 100.0);
        rec.end_root(100.0);
        rec
    }

    #[test]
    fn ids_are_stable_and_exports_are_byte_identical() {
        let a = sample_recorder();
        let b = sample_recorder();
        assert_eq!(
            a.spans().iter().map(|s| s.id).collect::<Vec<_>>(),
            b.spans().iter().map(|s| s.id).collect::<Vec<_>>()
        );
        assert_eq!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
        assert_eq!(
            a.chrome_json().to_string_pretty(),
            b.chrome_json().to_string_pretty()
        );
        assert_eq!(a.tree_text(), b.tree_text());
        // distinct spans get distinct ids
        let mut ids: Vec<u64> = a.spans().iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), a.len());
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut rec = TraceRecorder::disabled();
        assert_eq!(rec.begin_root("campaign", 0.0, &[]), 0);
        assert_eq!(rec.span(0, "run", "x", "r", "n", 0.0, 1.0), 0);
        rec.end_root(5.0);
        assert!(rec.is_empty());
    }

    #[test]
    fn json_roundtrip_preserves_spans() {
        let rec = sample_recorder();
        let back = TraceRecorder::from_json(&rec.to_json()).unwrap();
        assert_eq!(back.spans().len(), rec.spans().len());
        for (a, b) in rec.spans().iter().zip(back.spans()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.parent, b.parent);
            assert_eq!(a.cat, b.cat);
            assert_eq!(a.t0, b.t0);
            assert_eq!(a.t1, b.t1);
        }
        // re-serializing the loaded trace is byte-identical
        assert_eq!(rec.to_json().to_string_pretty(), back.to_json().to_string_pretty());
    }

    #[test]
    fn critical_path_tiles_the_makespan_exactly() {
        let rec = sample_recorder();
        let cp = critical_path(rec.spans()).unwrap();
        assert_eq!(cp.makespan, 100.0);
        assert!(cp.covers_exactly(), "chain: {:?}", cp.segments);
        assert_eq!(cp.attributed(), cp.makespan);
        assert_eq!(cp.attributed_pct(), 100.0);
        // collect(90..100) <- run asm(45..90) <- maint(40..45) <- run cg(0..40)
        let cats: Vec<&str> = cp.segments.iter().map(|s| s.cat.as_str()).collect();
        assert_eq!(cats, ["run", "maintenance", "run", "collect"]);
        assert_eq!(cp.by_category["run"], 85.0);
        assert_eq!(cp.by_category["maintenance"], 5.0);
        assert_eq!(cp.by_category["collect"], 10.0);
        // per-node partition sums to the makespan for every node
        for (node, b) in &cp.per_node {
            let total = b.run + b.maint + b.wait + b.idle;
            assert!((total - cp.makespan).abs() < 1e-9, "{node}: {total}");
        }
        // node b was idle the whole campaign (inventory via root meta)
        assert_eq!(cp.per_node["b"].idle, 100.0);
        assert_eq!(cp.per_repo["fe2ti"].jobs, 2);
        assert_eq!(cp.per_repo["fe2ti"].run, 85.0);
        assert_eq!(cp.per_repo["fe2ti"].wait, 45.0);
    }

    #[test]
    fn spans_nest_within_parents() {
        let rec = sample_recorder();
        let by_id: BTreeMap<u64, &Span> = rec.spans().iter().map(|s| (s.id, s)).collect();
        for s in rec.spans() {
            if s.parent == 0 {
                continue;
            }
            let p = by_id.get(&s.parent).expect("parent exists");
            assert!(
                p.t0 <= s.t0 && s.t1 <= p.t1,
                "{} [{}..{}] escapes parent {} [{}..{}]",
                s.name,
                s.t0,
                s.t1,
                p.name,
                p.t0,
                p.t1
            );
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("cbench_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let rec = sample_recorder();
        rec.save(&path).unwrap();
        let back = TraceRecorder::load(&path).unwrap();
        assert_eq!(rec.to_json().to_string_pretty(), back.to_json().to_string_pretty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
