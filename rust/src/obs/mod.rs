//! # obs — self-observability for the benchmarking infrastructure
//!
//! The paper argues performance must be watched continuously as systems
//! evolve; this module turns that lens on cbench itself, in two
//! complementary time domains:
//!
//! * [`trace`] — **cluster time** (deterministic, simulated): a span
//!   recorder threaded through the pipeline lifecycle (push → submit →
//!   queue-wait → run → collect → detect → alert-open), with stable span
//!   ids, Chrome trace-event export and critical-path analysis that
//!   attributes a campaign's makespan to queue-wait vs run vs collect vs
//!   maintenance segments per node and per repo. Byte-identical on
//!   replay — the same contract as `sched::timeline()`.
//! * [`metrics`] — **host time** (wall clock, noisy): fixed-slot atomic
//!   counters and log2-bucket histograms around the real hot paths
//!   (line-protocol parse, TSDB insert, shard materialization,
//!   dirty-shard save, detector-state sync). Near-zero cost when
//!   disabled; aggregates are uploaded into the TSDB as the
//!   `cbench_self` measurement so the standard regression detector
//!   watches the infrastructure's own throughput across commits.

pub mod metrics;
pub mod trace;
