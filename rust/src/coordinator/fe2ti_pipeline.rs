//! FE2TI pipeline (paper §4.5.1): the >80-job matrix over nodes ×
//! compilers × solvers × parallelization modes, generated per commit.

use super::{BenchConfig, PreparedJob};
use crate::apps::fe2ti::bench::{run_fe2ti_benchmark, Fe2tiCase, Fe2tiRun, Parallelization};
use crate::apps::fe2ti::solvers::{BlasLib, Compiler, SolverConfig, SolverKind};
use crate::ci::CiJob;
use crate::slurm::JobOutcome;
use crate::vcs::Repository;

/// The three Testcluster nodes the FE2TI pipeline currently targets
/// (paper: skylakesp2, icx36, rome1).
pub const FE2TI_NODES: [&str; 3] = ["skylakesp2", "icx36", "rome1"];

/// Compilers available per node: the Intel toolchain is installed on the
/// Intel boxes; rome1 (AMD) builds with gcc only ("when possible, the
/// Intel compiler is also used").
pub fn compilers_for(host: &str) -> Vec<Compiler> {
    if host == "rome1" {
        vec![Compiler::Gcc]
    } else {
        vec![Compiler::Gcc, Compiler::Intel]
    }
}

/// Build the job matrix for one commit. `cfg` comes from the commit's
/// `benchmark.cfg` — the `umfpack_blas = blis` entry is the Fig. 10b fix.
pub fn fe2ti_job_matrix(cfg: &BenchConfig, rve_n: usize, sample_rves: usize) -> Vec<PreparedJob> {
    let mut jobs = Vec::new();
    let blas_fix = match cfg.get("umfpack_blas") {
        Some("blis") => Some(BlasLib::Blis),
        Some("mkl") => Some(BlasLib::Mkl),
        Some("reference") => Some(BlasLib::Reference),
        _ => None,
    };

    for host in FE2TI_NODES {
        for compiler in compilers_for(host) {
            for kind in SolverKind::paper_set() {
                let mut solver = SolverConfig::new(kind, compiler);
                // the BLAS fix only affects the gcc/UMFPACK build
                if compiler == Compiler::Gcc {
                    if let Some(b) = blas_fix {
                        solver = solver.with_blas(b);
                    }
                }
                // fe2ti216: three parallelization modes
                for par in [
                    Parallelization::MpiOnly,
                    Parallelization::OmpOnly,
                    Parallelization::Hybrid,
                ] {
                    jobs.push(prepare_job(
                        Fe2tiCase::Fe2ti216,
                        solver,
                        par,
                        host,
                        rve_n,
                        sample_rves,
                    ));
                }
                // fe2ti1728: pure MPI impossible (unequal loads) — omp + hybrid
                for par in [Parallelization::OmpOnly, Parallelization::Hybrid] {
                    jobs.push(prepare_job(
                        Fe2tiCase::Fe2ti1728,
                        solver,
                        par,
                        host,
                        rve_n,
                        sample_rves,
                    ));
                }
            }
        }
    }
    jobs
}

fn prepare_job(
    case: Fe2tiCase,
    solver: SolverConfig,
    par: Parallelization,
    host: &str,
    rve_n: usize,
    sample_rves: usize,
) -> PreparedJob {
    let name = format!(
        "{}-{}-{}-{}",
        case.name(),
        solver.label(),
        par.name(),
        host
    );
    let ci = CiJob::new(&name, "benchmark")
        .var("HOST", host)
        .var("SLURM_TIMELIMIT", "120")
        .var("SCRIPT", &format!("fe2ti_{}.sh", case.name()))
        .var(
            crate::select::COMPONENTS_VAR,
            &format!("fe2ti/{}", solver.kind.name()),
        );
    let payload = Box::new(move |node: &crate::cluster::nodes::NodeModel, _t: f64| {
        let mut run = Fe2tiRun::new(case, solver, par);
        run.rve_n = rve_n;
        run.sample_rves = sample_rves;
        let r = run_fe2ti_benchmark(&run, node, 1);
        let stdout = format!(
            "TAG case={}\nTAG solver={}\nTAG compiler={}\nTAG parallelization={}\nTAG blas={}\n\
             METRIC tts={:.6}\nMETRIC micro_time={:.6}\nMETRIC macro_time={:.6}\n\
             METRIC comm_time={:.6}\nMETRIC gflops={:.4}\nMETRIC oi={:.5}\n\
             METRIC vec_ratio={:.4}\nMETRIC flops={:.6e}\nMETRIC bytes={:.6e}\n\
             METRIC newton_iters={}\nMETRIC verification_error={:.3e}\n",
            case.name(),
            solver.kind.name(),
            solver.compiler.name(),
            par.name(),
            solver.umfpack_blas.name(),
            r.tts,
            r.micro_time,
            r.macro_time,
            r.comm_time,
            r.gflops,
            r.oi,
            r.vector_ratio,
            r.work.flops,
            r.work.bytes,
            r.newton_iters,
            r.verification_error,
        );
        JobOutcome {
            // simulated job duration: projected TTS + build/setup overhead
            duration: r.tts + 30.0,
            stdout,
            exit_code: if r.verification_error < 0.05 { 0 } else { 1 },
        }
    });
    PreparedJob { ci, payload }
}

/// Full pipeline entry: read the commit's config and build the matrix.
pub fn fe2ti_pipeline_jobs(repo: &Repository, commit_id: &str) -> Vec<PreparedJob> {
    let cfg = BenchConfig::from_commit(repo, commit_id);
    // n=8 RVEs (512 dof): the smallest size in the asymptotic regime where
    // direct-solver fill dominates (DESIGN.md §2 scale note)
    fe2ti_job_matrix(&cfg, 8, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_has_more_than_80_jobs() {
        // paper §4.5.1: "more than 80 different benchmark jobs"
        let jobs = fe2ti_job_matrix(&BenchConfig::default(), 5, 1);
        // 3 nodes: skylake+icx have 2 compilers, rome1 has 1 -> 5 builds;
        // 4 solvers × (3 + 2) par modes = 20 jobs per build -> 100 total
        assert_eq!(jobs.len(), 100);
        assert!(jobs.len() > 80);
    }

    #[test]
    fn job_names_unique_and_hosts_valid() {
        let jobs = fe2ti_job_matrix(&BenchConfig::default(), 5, 1);
        let mut names: Vec<&str> = jobs.iter().map(|j| j.ci.name.as_str()).collect();
        let n = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), n, "job names must be unique");
        for j in &jobs {
            assert!(FE2TI_NODES.contains(&j.ci.get("HOST").unwrap()));
        }
    }

    #[test]
    fn rome1_has_no_intel_builds() {
        let jobs = fe2ti_job_matrix(&BenchConfig::default(), 5, 1);
        assert!(!jobs
            .iter()
            .any(|j| j.ci.name.contains("rome1") && j.ci.name.contains("intel")));
    }

    #[test]
    fn blas_fix_config_changes_matrix_solver() {
        let cfg = BenchConfig::parse("umfpack_blas = blis");
        let jobs = fe2ti_job_matrix(&cfg, 5, 1);
        // same job count; the personality change shows up in the payload's
        // TAG blas= output, checked in the integration test
        assert_eq!(jobs.len(), 100);
    }
}
