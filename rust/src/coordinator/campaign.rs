//! Multi-repo campaign driver: many repositories, one Testcluster,
//! **streaming collection**.
//!
//! The paper runs one pipeline at a time; exaCB (Badwaik et al.) and the
//! NEST CB study (Vogelsang et al.) both show that continuous
//! benchmarking at scale means *many* projects sharing one execution
//! backend concurrently — and that detection latency is what makes the
//! loop actionable. This module is that coordinator:
//!
//! * a [`CampaignProject`] wraps a watched [`Repository`] plus its
//!   pipeline flavour ([`ProjectKind`]) and scheduling priority;
//! * [`run_campaign`] generates push events for every project
//!   ([`campaign_push_events`] — deterministic and rebuildable, which is
//!   what campaign-aware bisection replays) and submits **all** resulting
//!   pipelines onto the shared event-driven scheduler, where they
//!   interleave job-by-job as simulated time advances;
//! * **streaming collect** (the default): the driver steps the event
//!   queue one simulated instant at a time
//!   ([`crate::sched::SimScheduler::step_epoch`]) and collects each
//!   pipeline — parse, shard upload, regression detection, alert
//!   bookkeeping — *at the instant its last job finished*, while the rest
//!   of the roster is still running. Upload + detection stay serialized
//!   per pipeline in `(completion time, pipeline id)` order, which is
//!   exactly the order batch collection uses, so the two modes produce
//!   identical TSDB benchmark contents, identical alert sets and a
//!   byte-identical scheduler timeline — streaming only moves *when* the
//!   results exist, which is the point: the first upload lands at the
//!   first pipeline's completion instead of after the whole roster, and
//!   the alert SLA (cluster-time from a regression landing to its alert
//!   opening, [`crate::regress::Alert::sla_secs`]) is bounded by one
//!   pipeline's duration instead of the campaign makespan;
//! * **overlapped collects** (automatic under `--threads > 1`): a
//!   completed pipeline's log parsing runs on a background thread while
//!   the scheduler keeps stepping epochs for the rest of the roster;
//!   gathers and the serialized commits (upload → detection → alerting →
//!   trace) stay on the driver thread in `(completion, pid)` FIFO order,
//!   so the host wall-clock of a big collect overlaps the simulation
//!   without changing a single byte of its output (see
//!   [`super::CbSystem::gather_collect`] /
//!   [`super::CbSystem::commit_collect`]);
//! * **batch collect** (`streaming: false`, `cbench campaign --collect
//!   batch`) keeps the PR-2 drain-then-collect model for A/B latency
//!   comparisons;
//! * each pipeline's triggering commit gets to tune its own detection
//!   (`regress.*` overrides in `benchmark.cfg`,
//!   [`super::detector_with_config`]) before its results are judged;
//! * the [`CampaignOutcome`] reports the overlapped **makespan** against
//!   the *sequential back-to-back baseline*, plus first-upload time and
//!   worst alert SLA, plus one `campaign` TSDB point per pipeline
//!   (wall/standalone durations, first/last-result latencies, alert SLA)
//!   for the dashboards.

use super::{BenchConfig, CbSystem, CollectInputs, JobMetrics, PipelineReport, PreparedJob};
use crate::select::SelectMode;
use crate::tsdb::Point;
use crate::vcs::{PushEvent, Repository};
use std::collections::VecDeque;
use std::thread::JoinHandle;

/// Which benchmark pipeline a project runs on push.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProjectKind {
    Fe2ti,
    Walberla,
}

impl ProjectKind {
    pub fn name(self) -> &'static str {
        match self {
            ProjectKind::Fe2ti => "fe2ti",
            ProjectKind::Walberla => "walberla",
        }
    }
    pub fn from_name(s: &str) -> Option<ProjectKind> {
        match s {
            "fe2ti" => Some(ProjectKind::Fe2ti),
            "walberla" => Some(ProjectKind::Walberla),
            _ => None,
        }
    }
    /// TSDB measurement this pipeline uploads into.
    pub fn measurement(self) -> &'static str {
        match self {
            ProjectKind::Fe2ti => "fe2ti",
            ProjectKind::Walberla => "lbm",
        }
    }
    /// The job matrix for one commit of `repo`.
    pub fn jobs_for(self, repo: &Repository, commit_id: &str) -> Vec<PreparedJob> {
        match self {
            ProjectKind::Fe2ti => super::fe2ti_pipeline::fe2ti_pipeline_jobs(repo, commit_id),
            ProjectKind::Walberla => {
                super::walberla_pipeline::walberla_pipeline_jobs(repo, commit_id)
            }
        }
    }
    /// waLBerla reaches the HPC runner through the proxy-repo trigger API
    /// (paper §4.5.2); FE2TI pushes directly.
    pub fn via_trigger_api(self) -> bool {
        matches!(self, ProjectKind::Walberla)
    }
}

/// One watched repository in a campaign.
#[derive(Debug)]
pub struct CampaignProject {
    /// Display/repo name; doubles as the fair-share owner and the `repo`
    /// tag on every uploaded point.
    pub name: String,
    pub kind: ProjectKind,
    /// Scheduling priority of this project's jobs (higher first).
    pub priority: i64,
    pub repo: Repository,
}

impl CampaignProject {
    pub fn new(name: &str, kind: ProjectKind) -> CampaignProject {
        CampaignProject {
            name: name.to_string(),
            kind,
            priority: 0,
            repo: Repository::new(name),
        }
    }
    pub fn priority(mut self, p: i64) -> CampaignProject {
        self.priority = p;
        self
    }
}

/// The stock campaign roster: `n` projects alternating waLBerla / FE2TI
/// (two repos already mix an 11-node LBM matrix with the 3-node 100-job
/// FE2TI matrix — the disjoint bottlenecks overlap scheduling feeds on).
pub fn default_projects(n: usize) -> Vec<CampaignProject> {
    (0..n)
        .map(|i| {
            let kind = if i % 2 == 0 {
                ProjectKind::Walberla
            } else {
                ProjectKind::Fe2ti
            };
            CampaignProject::new(&format!("{}-{}", kind.name(), i), kind)
        })
        .collect()
}

/// Campaign parameters.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Push rounds: every project pushes once per round.
    pub pushes: usize,
    /// 1-based push round that plants the waLBerla kernel-regen
    /// regression (`lbm_efficiency_penalty` in `benchmark.cfg`) into
    /// every project; 0 = none. FE2TI pipelines ignore the knob, so in a
    /// mixed campaign only the LBM series regress — the realistic shape.
    pub inject_at: usize,
    pub penalty: f64,
    /// Salts the simulated commit contents: same seed + same projects →
    /// identical commit chain, timeline and TSDB, byte for byte.
    pub seed: u64,
    /// Timelimit-aware conservative backfill on the shared scheduler
    /// (`cbench campaign --backfill on|off`; on by default). Off, a node
    /// whose head-of-queue job is blocked by a maintenance window idles
    /// until the job's shadow start.
    pub backfill: bool,
    /// Maintenance windows applied before the first submission:
    /// `(host, from, until)` in simulated seconds (`cbench campaign
    /// --drain NODE@FROM..TO`). Campaigns never call `resume`, so `until`
    /// must be finite — an open-ended drain would silently strand every
    /// job pinned to that node ([`run_campaign_with`] rejects it).
    pub drains: Vec<(String, f64, f64)>,
    /// Streaming collect (default): each pipeline's results are parsed,
    /// uploaded and fed to regression detection at its completion instant
    /// on the simulated clock, while other pipelines still run. `false`
    /// restores batch collection (drain the cluster, then collect) for
    /// A/B latency comparisons — same final TSDB benchmark contents,
    /// alert set and timeline, later uploads (`cbench campaign --collect
    /// streaming|batch`). Under `--threads > 1` the streaming driver
    /// additionally overlaps each pipeline's log parsing with the
    /// scheduler on background threads — byte-identical output, less
    /// host wall-clock (self-metrics runs stay serial).
    pub streaming: bool,
    /// Incremental detection (default): per-pipeline checks update the
    /// carried `regress::DetectorState` from the new points instead of
    /// re-querying the tail window. `false` restores the full re-query on
    /// every collect (`cbench campaign --detect incremental|requery`) —
    /// same findings, same alert book, byte for byte (the equivalence is
    /// property-tested); only the work done per check differs.
    pub incremental: bool,
    /// Benchmark selection mode (`cbench campaign --select
    /// change-aware|full`, full by default). Change-aware consults the
    /// push's touched components against each job's `CB_COMPONENTS`
    /// declaration, skips jobs the push cannot affect, and carries their
    /// last measured points forward (`carried=1`) — same alert book as a
    /// full run, fewer cluster hours (see `crate::select`).
    pub select: SelectMode,
}

impl Default for CampaignConfig {
    fn default() -> CampaignConfig {
        CampaignConfig {
            pushes: 2,
            inject_at: 0,
            penalty: 0.15,
            seed: 42,
            backfill: true,
            drains: Vec::new(),
            streaming: true,
            incremental: true,
            select: SelectMode::Full,
        }
    }
}

/// Outcome of one campaign run.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    /// Per-pipeline reports, in collection (= completion) order.
    pub reports: Vec<PipelineReport>,
    /// Simulated wall-clock from first submission to last completion with
    /// pipelines overlapped on the shared scheduler.
    pub makespan: f64,
    /// What the same job set costs run back-to-back, one pipeline at a
    /// time on an idle cluster (Σ standalone durations) — the
    /// pre-`sched::` execution model.
    pub sequential_baseline: f64,
    /// Collect mode the roster ran under.
    pub streaming: bool,
}

impl CampaignOutcome {
    /// Sequential-over-overlapped ratio; > 1 means overlap won.
    pub fn overlap_speedup(&self) -> f64 {
        if self.makespan > 0.0 {
            self.sequential_baseline / self.makespan
        } else {
            1.0
        }
    }
    pub fn total_jobs(&self) -> usize {
        self.reports.iter().map(|r| r.jobs_total).sum()
    }
    /// Jobs that actually ran (the matrix minus change-aware skips).
    pub fn jobs_selected(&self) -> usize {
        self.total_jobs() - self.jobs_skipped()
    }
    /// Jobs change-aware selection skipped (0 under `--select full`).
    pub fn jobs_skipped(&self) -> usize {
        self.reports.iter().map(|r| r.jobs_skipped).sum()
    }
    /// Cluster hours the skips saved (Σ of the skipped jobs' last
    /// measured durations, in hours).
    pub fn cluster_hours_saved(&self) -> f64 {
        self.reports.iter().map(|r| r.saved_cluster_s).sum::<f64>() / 3600.0
    }
    /// Standalone-makespan seconds the skips saved, summed per pipeline
    /// (each pipeline's critical path with vs without its skipped jobs).
    pub fn makespan_saved_s(&self) -> f64 {
        self.reports.iter().map(|r| r.saved_makespan_s).sum()
    }
    /// Job starts the scheduler backfilled into maintenance-window gaps.
    pub fn jobs_backfilled(&self) -> usize {
        self.reports.iter().map(|r| r.jobs_backfilled).sum()
    }
    pub fn alerts_opened(&self) -> usize {
        self.reports.iter().map(|r| r.regressions.opened).sum()
    }
    /// Simulated instant of the earliest upload + detection — under
    /// streaming collect the first pipeline's completion; under batch
    /// collect the roster makespan (everything waits for the drain).
    pub fn first_upload_at(&self) -> f64 {
        self.reports
            .iter()
            .map(|r| r.collected_at)
            .fold(f64::INFINITY, f64::min)
    }
    /// Worst alert SLA across the roster: the longest cluster-time any
    /// regression sat on the cluster before its alert opened (`None`
    /// when no alert opened).
    pub fn worst_alert_sla(&self) -> Option<f64> {
        self.reports
            .iter()
            .filter_map(|r| r.alert_sla)
            .fold(None, |acc, s| Some(acc.map_or(s, |a: f64| a.max(s))))
    }
}

/// Run a campaign with the stock per-kind job matrices.
pub fn run_campaign(
    cb: &mut CbSystem,
    projects: &mut [CampaignProject],
    cfg: &CampaignConfig,
) -> anyhow::Result<CampaignOutcome> {
    run_campaign_with(cb, projects, cfg, |p, commit_id| {
        p.kind.jobs_for(&p.repo, commit_id)
    })
}

/// Source paths a campaign's non-inject pushes rotate through — one per
/// benchmark component group, so change-aware selection (`--select
/// change-aware`) sees pushes that plausibly touch only part of the
/// matrix. Inject rounds always touch `benchmark.cfg` (config surface →
/// affects everything → the planted regression is measured, never
/// carried past).
pub const CAMPAIGN_TOUCH_PATHS: [&str; 5] = [
    "src/lbm/cpu/stream_collide.c",
    "src/lbm/gpu/stream_collide.cu",
    "src/lbm/fslbm/free_surface.c",
    "src/fe2ti/pardiso/factor.c",
    "src/fe2ti/solver_common.c",
];

/// The deterministic push rounds of a campaign: every project commits
/// once per round, round `inject_at` (1-based) planting the waLBerla
/// kernel-regen penalty. Returns `(project index, push event)` in
/// submission order. Commit ids depend only on (author, message, parent,
/// tree), so replaying this with the same projects and config rebuilds
/// the **exact commit chains** a previous campaign benchmarked — that is
/// what `cbench regress bisect --campaign` leans on to bisect a campaign
/// alert without any saved repository state.
pub fn campaign_push_events(
    projects: &mut [CampaignProject],
    cfg: &CampaignConfig,
) -> Vec<(usize, PushEvent)> {
    let mut events: Vec<(usize, PushEvent)> = Vec::new();
    for r in 0..cfg.pushes {
        for (pi, p) in projects.iter_mut().enumerate() {
            let t = r as f64 * 60.0;
            let ev = if cfg.inject_at > 0 && r + 1 == cfg.inject_at {
                p.repo.commit_change(
                    "master",
                    "dev",
                    &format!("push #{r} (kernel regen, perf bug)"),
                    t,
                    "benchmark.cfg",
                    &format!("lbm_efficiency_penalty = {}\n", cfg.penalty),
                )
            } else {
                // rotate the touched surface deterministically through the
                // component tree (seed- and round-dependent, never
                // mode-dependent: commit chains must replay identically
                // under --select full and change-aware — bisection rebuilds
                // them). The contents stay seed+round-salted only, so the
                // benchmark values a job measures do not depend on which
                // path was touched.
                let path = CAMPAIGN_TOUCH_PATHS
                    [(cfg.seed as usize + r) % CAMPAIGN_TOUCH_PATHS.len()];
                p.repo.commit_change(
                    "master",
                    "dev",
                    &format!("push #{r}"),
                    t,
                    path,
                    &format!("// seed {} rev {r}\n", cfg.seed),
                )
            };
            events.push((pi, ev));
        }
    }
    events
}

/// Collect one pipeline under its commit's detection config and insert
/// its `campaign` meta-point (shared by the streaming and batch paths).
fn collect_one(
    cb: &mut CbSystem,
    projects: &[CampaignProject],
    pid: u64,
    pi: usize,
    ev: &PushEvent,
    reports: &mut Vec<PipelineReport>,
) -> anyhow::Result<()> {
    // the triggering commit tunes its own detection
    let commit_cfg = BenchConfig::from_commit(&projects[pi].repo, &ev.commit_id);
    cb.apply_regress_config(&commit_cfg);
    let r = cb.collect_pipeline(pid)?;
    finish_one(cb, projects, pi, r, reports);
    Ok(())
}

/// Join the oldest background parse and run its serialized commit:
/// detection config first (the triggering commit tunes its own detection
/// — [`CbSystem::gather_collect`] never reads the detector, so applying
/// it at commit time is exactly where the serial path's application
/// lands), then upload + detection + alerting + trace, then the
/// `campaign` meta-point. FIFO only: the in-flight queue holds pipelines
/// in `(completion, pid)` order and commits must not reorder it.
fn commit_front(
    cb: &mut CbSystem,
    projects: &[CampaignProject],
    inflight: &mut VecDeque<(JoinHandle<(CollectInputs, Vec<JobMetrics>)>, usize, PushEvent)>,
    reports: &mut Vec<PipelineReport>,
) -> anyhow::Result<()> {
    let (h, pi, ev) = inflight.pop_front().expect("commit_front on an empty queue");
    let (inputs, parsed) = match h.join() {
        Ok(v) => v,
        // a panicking parse worker must fail the campaign loudly, not
        // silently drop a pipeline's results
        Err(payload) => std::panic::resume_unwind(payload),
    };
    let commit_cfg = BenchConfig::from_commit(&projects[pi].repo, &ev.commit_id);
    cb.apply_regress_config(&commit_cfg);
    let r = cb.commit_collect(inputs, parsed)?;
    finish_one(cb, projects, pi, r, reports);
    Ok(())
}

/// Shared tail of every collect path: one `campaign` meta-point per
/// pipeline for the dashboards, then file the report.
fn finish_one(
    cb: &mut CbSystem,
    projects: &[CampaignProject],
    pi: usize,
    r: PipelineReport,
    reports: &mut Vec<PipelineReport>,
) {
    let mut p = Point::new("campaign", r.trigger_ts)
        .tag("repo", &r.repo)
        .tag("kind", projects[pi].kind.name())
        .tag("commit", &r.commit_id[..8.min(r.commit_id.len())])
        .field("duration", r.duration)
        .field("standalone", r.standalone_duration)
        .field("jobs", r.jobs_total as f64)
        .field("failed", r.jobs_failed as f64)
        .field("backfilled", r.jobs_backfilled as f64)
        .field("head_of_line", (r.jobs_total - r.jobs_backfilled) as f64)
        .field("points", r.points_uploaded as f64)
        .field("skipped", r.jobs_skipped as f64)
        .field("carried", r.points_carried as f64)
        .field("saved_cluster_s", r.saved_cluster_s)
        .field("saved_makespan_s", r.saved_makespan_s)
        .field("first_result_latency", r.first_result_latency())
        .field("collect_latency", r.collect_latency());
    if let Some(sla) = r.alert_sla {
        p = p.field("alert_sla", sla);
    }
    cb.db.insert(p);
    reports.push(r);
}

/// Run a campaign with a custom job-matrix provider (tests, downsized
/// smoke runs). `jobs_for(project, commit_id)` is called once per push
/// event, at submit time.
pub fn run_campaign_with(
    cb: &mut CbSystem,
    projects: &mut [CampaignProject],
    cfg: &CampaignConfig,
    mut jobs_for: impl FnMut(&CampaignProject, &str) -> Vec<PreparedJob>,
) -> anyhow::Result<CampaignOutcome> {
    anyhow::ensure!(!projects.is_empty(), "campaign needs at least one project");
    anyhow::ensure!(cfg.pushes > 0, "campaign needs at least one push round");
    anyhow::ensure!(
        cfg.inject_at <= cfg.pushes,
        "--inject-regression {} is past the last push round ({})",
        cfg.inject_at,
        cfg.pushes
    );
    // scheduler policy for this campaign: backfill mode + maintenance
    // windows land before the first submission so the whole roster is
    // dispatched (and replays) under one deterministic configuration
    cb.scheduler.set_backfill(cfg.backfill);
    // detection mode: incremental state-carried checks (default) vs the
    // full tail re-query A/B reference — identical results either way
    cb.set_incremental_detection(cfg.incremental);
    // selection mode: full matrix (default) vs change-aware skipping —
    // identical alert book either way, fewer cluster hours change-aware
    cb.set_select_mode(cfg.select);
    for (host, from, until) in &cfg.drains {
        // a campaign never resumes nodes, so an open-ended drain would
        // strand that node's jobs forever while the run "succeeds"
        anyhow::ensure!(
            until.is_finite(),
            "campaign drain on `{host}` needs a finite end time — open-ended \
             drains are only usable with an explicit scheduler resume"
        );
        cb.scheduler
            .maintenance(host, *from, *until)
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    let t0 = cb.scheduler.now();

    // root trace span: the campaign envelope. Its `nodes` meta is the
    // critical-path walk's node inventory — a node that stayed idle all
    // campaign leaves no job spans, but still must show up (100% idle)
    // in the per-node attribution.
    let node_list = cb.scheduler.hosts().join(",");
    cb.trace.begin_root("campaign", t0, &[("nodes", &node_list)]);

    // --- push rounds: every project commits once per round ---
    let events = campaign_push_events(projects, cfg);

    // --- submit phase: every pipeline goes onto the shared scheduler ---
    let mut submitted: Vec<(u64, usize, PushEvent)> = Vec::new();
    for (pi, ev) in &events {
        let p = &projects[*pi];
        let jobs = jobs_for(p, &ev.commit_id);
        anyhow::ensure!(
            !jobs.is_empty(),
            "project `{}` produced no jobs for {}",
            p.name,
            &ev.commit_id[..8.min(ev.commit_id.len())]
        );
        let pid = cb.submit_pipeline(
            ev,
            p.kind.via_trigger_api(),
            jobs,
            p.kind.measurement(),
            p.priority,
        )?;
        submitted.push((pid, *pi, ev.clone()));
    }

    let mut reports = Vec::with_capacity(submitted.len());
    if cfg.streaming {
        // --- streaming collect: advance the shared event queue one
        // simulated instant at a time; a pipeline is collected (parse →
        // shard upload → detection → alerting) at the instant its last
        // job finished, while the rest of the roster keeps running.
        // Pipelines completing at the same instant are collected in
        // submission (pipeline-id) order — exactly the (finished_at,
        // pid) order of batch collection, so the two modes agree on
        // everything except *when* the uploads happen.
        //
        // With more than one worker thread configured, collects
        // *overlap* the scheduler: each completed pipeline's gather
        // (scheduler snapshot) runs here, its log parsing runs on a
        // background thread, and only the serialized commit (upload,
        // detection, alerting, trace) comes back to this thread — in
        // the same (completion, pid) FIFO order the serial path uses,
        // so the output is byte-identical for any `--threads` value.
        // Self-metrics runs stay serial: uploads difference the global
        // host-time counters at commit, and a parse still in flight
        // would shift which collect its deltas land in.
        let overlap = crate::par::threads() > 1 && !cb.self_metrics();
        // cap outstanding parses at threads-1 (this thread is the
        // scheduler); the oldest is force-committed when the cap hits
        let max_inflight = crate::par::threads().saturating_sub(1).max(1);
        let mut inflight: VecDeque<(
            JoinHandle<(CollectInputs, Vec<JobMetrics>)>,
            usize,
            PushEvent,
        )> = VecDeque::new();
        let mut remaining = submitted;
        loop {
            let mut i = 0;
            while i < remaining.len() {
                if cb.pipeline_done(remaining[i].0) {
                    let (pid, pi, ev) = remaining.remove(i);
                    if overlap {
                        while inflight.len() >= max_inflight {
                            commit_front(cb, projects, &mut inflight, &mut reports)?;
                        }
                        let inputs = cb.gather_collect(pid)?;
                        let h = std::thread::spawn(move || {
                            // serial inside the worker: total parallelism
                            // stays bounded by the configured thread count
                            let parsed = CbSystem::parse_collect(&inputs, false);
                            (inputs, parsed)
                        });
                        inflight.push_back((h, pi, ev));
                    } else {
                        collect_one(cb, projects, pid, pi, &ev, &mut reports)?;
                    }
                } else {
                    i += 1;
                }
            }
            // opportunistic commits between epochs: drain every
            // background parse that already finished, FIFO only —
            // never join past an unfinished front, stepping must not
            // block on a straggling parse
            while inflight.front().is_some_and(|(h, _, _)| h.is_finished()) {
                commit_front(cb, projects, &mut inflight, &mut reports)?;
            }
            if remaining.is_empty() {
                break;
            }
            if cb.scheduler.step_epoch().is_none() {
                // queue drained with pipelines still incomplete (stranded
                // jobs — e.g. a library caller draining a node without a
                // resume): flush the in-flight parses (order!), then
                // collect what exists so the campaign reports instead of
                // spinning
                while !inflight.is_empty() {
                    commit_front(cb, projects, &mut inflight, &mut reports)?;
                }
                for (pid, pi, ev) in std::mem::take(&mut remaining) {
                    collect_one(cb, projects, pid, pi, &ev, &mut reports)?;
                }
                break;
            }
        }
        // flush the in-flight tail. Commits never advance the simulated
        // clock, so makespan and timeline are exactly the serial ones.
        while !inflight.is_empty() {
            commit_front(cb, projects, &mut inflight, &mut reports)?;
        }
    } else {
        // --- batch collect (A/B reference): drain the whole roster,
        // then collect serialized per pipeline in completion order ---
        cb.scheduler.run_until_idle();
        let mut order: Vec<(f64, u64, usize, PushEvent)> = submitted
            .into_iter()
            .map(|(pid, pi, ev)| {
                (
                    cb.pipeline_finished_at(pid).unwrap_or(f64::MAX),
                    pid,
                    pi,
                    ev,
                )
            })
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (_, pid, pi, ev) in order {
            collect_one(cb, projects, pid, pi, &ev, &mut reports)?;
        }
    }

    let t_end = cb.scheduler.now();
    // maintenance windows as cluster-lane spans (clipped to the
    // campaign), then close the root — makespan == root span duration
    if cb.trace.is_enabled() {
        let root = cb.trace.root();
        let hosts: Vec<String> = cb.scheduler.hosts().to_vec();
        for host in &hosts {
            let windows: Vec<(f64, f64)> = cb.scheduler.maintenance_windows(host).to_vec();
            for (i, (from, until)) in windows.iter().enumerate() {
                if *until <= t0 || *from >= t_end {
                    continue;
                }
                let a = from.max(t0);
                let b = until.min(t_end);
                cb.trace
                    .span(root, "maint", &format!("maint/{host}/{i}"), "", host, a, b);
            }
        }
    }
    cb.trace.end_root(t_end);

    let makespan = t_end - t0;
    let sequential_baseline = reports.iter().map(|r| r.standalone_duration).sum();
    Ok(CampaignOutcome {
        reports,
        makespan,
        sequential_baseline,
        streaming: cfg.streaming,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ci::CiJob;
    use crate::sched::JobOutcome;

    fn toy_jobs(tag: &str, spec: &[(&str, f64, usize)]) -> Vec<PreparedJob> {
        let mut jobs = Vec::new();
        for (host, dur, count) in spec {
            for i in 0..*count {
                let dur = *dur;
                jobs.push(PreparedJob {
                    ci: CiJob::new(&format!("{tag}-{host}-{i}"), "benchmark").var("HOST", host),
                    payload: Box::new(move |_n, _t| JobOutcome {
                        duration: dur,
                        stdout: format!("TAG op=x\nMETRIC v={dur}\n"),
                        exit_code: 0,
                    }),
                });
            }
        }
        jobs
    }

    #[test]
    fn campaign_overlaps_disjoint_bottlenecks() {
        // alpha bottlenecks on icx36 (30 s), beta on rome1 (40 s):
        // back-to-back = 70 s/push, overlapped = max(30, 45) per wave
        let mut cb = CbSystem::new();
        let mut projects = vec![
            CampaignProject::new("alpha", ProjectKind::Walberla),
            CampaignProject::new("beta", ProjectKind::Walberla),
        ];
        let cfg = CampaignConfig { pushes: 1, penalty: 0.0, seed: 1, ..CampaignConfig::default() };
        let out = run_campaign_with(&mut cb, &mut projects, &cfg, |p, _c| {
            if p.name == "alpha" {
                toy_jobs("a", &[("icx36", 10.0, 3), ("rome1", 5.0, 1)])
            } else {
                toy_jobs("b", &[("rome1", 20.0, 2), ("skylakesp2", 8.0, 1)])
            }
        })
        .unwrap();
        assert_eq!(out.reports.len(), 2);
        assert_eq!(out.total_jobs(), 7);
        // standalone: alpha max(30, 5) = 30; beta max(40, 8) = 40
        assert_eq!(out.sequential_baseline, 70.0);
        // overlapped: rome1 carries 5 + 40 = 45, icx36 carries 30
        assert_eq!(out.makespan, 45.0);
        assert!(out.overlap_speedup() > 1.5);
        // both repos tagged in the shared TSDB + campaign meta-points
        assert_eq!(cb.db.tag_values("lbm", "repo"), vec!["alpha", "beta"]);
        assert_eq!(cb.db.n_points("campaign"), 2);
    }

    fn toy_jobs_tl(tag: &str, spec: &[(&str, f64, f64, usize)]) -> Vec<PreparedJob> {
        // (host, duration, timelimit minutes, count)
        let mut jobs = Vec::new();
        for (host, dur, tl, count) in spec {
            for i in 0..*count {
                let dur = *dur;
                jobs.push(PreparedJob {
                    ci: CiJob::new(&format!("{tag}-{host}-{i}"), "benchmark")
                        .var("HOST", host)
                        .var("SLURM_TIMELIMIT", &format!("{tl}")),
                    payload: Box::new(move |_n, _t| JobOutcome {
                        duration: dur,
                        stdout: format!("TAG op=x\nMETRIC v={dur}\n"),
                        exit_code: 0,
                    }),
                });
            }
        }
        jobs
    }

    #[test]
    fn drained_campaign_backfills_and_beats_backfill_off() {
        // icx36 drains over [100, 2000): the long job (60 min limit)
        // is pushed past the window; with backfill on, the short-limit
        // jobs run inside the gap instead of idling behind it
        let run = |backfill: bool| {
            let mut cb = CbSystem::new();
            let mut projects = vec![CampaignProject::new("alpha", ProjectKind::Walberla)];
            let cfg = CampaignConfig {
                pushes: 1,
                penalty: 0.0,
                seed: 1,
                backfill,
                drains: vec![("icx36".to_string(), 100.0, 2000.0)],
                ..CampaignConfig::default()
            };
            run_campaign_with(&mut cb, &mut projects, &cfg, |_p, _c| {
                let mut jobs = toy_jobs_tl("big", &[("icx36", 300.0, 60.0, 1)]);
                jobs.extend(toy_jobs_tl("small", &[("icx36", 30.0, 1.0, 2)]));
                jobs
            })
            .unwrap()
        };
        let on = run(true);
        let off = run(false);
        // off: nothing fits before the window -> 2000 + 300 + 30 + 30
        // on: both 1-min-limit jobs run in the gap -> 2000 + 300
        assert_eq!(off.makespan, 2360.0);
        assert_eq!(on.makespan, 2300.0);
        assert!(on.makespan < off.makespan);
        assert_eq!(on.jobs_backfilled(), 2);
        assert_eq!(off.jobs_backfilled(), 0);
        // the per-pipeline meta point records the utilization split
        assert_eq!(on.reports[0].jobs_backfilled, 2);
        assert_eq!(on.reports[0].jobs_total, 3);
    }

    #[test]
    fn streaming_collects_at_completion_and_matches_batch() {
        // alpha's pipeline drains icx36 at t=30, beta's drains rome1 at
        // t=45: streaming uploads alpha's results at 30 while beta still
        // runs; batch uploads both only after the roster drained at 45
        let run = |streaming: bool| {
            let mut cb = CbSystem::new();
            let mut projects = vec![
                CampaignProject::new("alpha", ProjectKind::Walberla),
                CampaignProject::new("beta", ProjectKind::Walberla),
            ];
            let cfg = CampaignConfig {
                pushes: 1,
                penalty: 0.0,
                seed: 1,
                streaming,
                ..CampaignConfig::default()
            };
            let out = run_campaign_with(&mut cb, &mut projects, &cfg, |p, _c| {
                if p.name == "alpha" {
                    toy_jobs("a", &[("icx36", 10.0, 3)])
                } else {
                    toy_jobs("b", &[("rome1", 15.0, 3)])
                }
            })
            .unwrap();
            (out, cb)
        };
        let (s, cb_s) = run(true);
        let (b, cb_b) = run(false);
        assert!(s.streaming && !b.streaming);
        // identical schedule, collection order, and benchmark TSDB
        assert_eq!(s.makespan, b.makespan);
        assert_eq!(cb_s.scheduler.timeline(), cb_b.scheduler.timeline());
        let pids = |o: &CampaignOutcome| o.reports.iter().map(|r| r.pipeline_id).collect::<Vec<_>>();
        assert_eq!(pids(&s), pids(&b));
        let dump = |cb: &CbSystem| {
            cb.db.points_iter("lbm").map(|p| p.to_line()).collect::<Vec<_>>()
        };
        assert_eq!(dump(&cb_s), dump(&cb_b));
        // streaming collected alpha at its own completion instant...
        assert_eq!(s.reports[0].repo, "alpha");
        assert_eq!(s.reports[0].finished_at, 30.0);
        assert_eq!(s.reports[0].collected_at, 30.0);
        assert_eq!(s.first_upload_at(), 30.0);
        // ...while batch only uploads once the roster drained
        assert_eq!(b.first_upload_at(), b.makespan);
        assert!(s.first_upload_at() < b.first_upload_at());
        // latency bookkeeping: first result at 10 s, upload at completion
        assert_eq!(s.reports[0].first_result_latency(), 10.0);
        assert_eq!(s.reports[0].collect_latency(), 30.0);
        assert_eq!(b.reports[0].collect_latency(), 45.0);
        // the campaign meta-points carry the latency fields
        assert!(cb_s
            .db
            .points_iter("campaign")
            .all(|p| p.fields.contains_key("first_result_latency")
                && p.fields.contains_key("collect_latency")));
    }

    #[test]
    fn change_aware_campaign_skips_jobs_and_keeps_the_schedule_shape() {
        // three component-declaring jobs; seed 0 rotates the touched path
        // through lbm/cpu, lbm/gpu, lbm/fslbm, fe2ti/pardiso — after the
        // cold first round every later round skips whatever it cannot
        // affect, carrying the last measured points forward
        let run = |select: SelectMode| {
            let mut cb = CbSystem::new();
            let mut projects = vec![CampaignProject::new("alpha", ProjectKind::Walberla)];
            let cfg = CampaignConfig {
                pushes: 4,
                penalty: 0.0,
                seed: 0,
                select,
                ..CampaignConfig::default()
            };
            let out = run_campaign_with(&mut cb, &mut projects, &cfg, |_p, _c| {
                let mut jobs = toy_jobs("cpu", &[("icx36", 10.0, 2)]);
                jobs.extend(toy_jobs("gpu", &[("rome1", 20.0, 1)]));
                for j in &mut jobs {
                    let comp = if j.ci.name.starts_with("cpu") { "lbm/cpu" } else { "lbm/gpu" };
                    j.ci = j.ci.clone().var(crate::select::COMPONENTS_VAR, comp);
                }
                jobs
            })
            .unwrap();
            (out, cb)
        };
        let (full, cb_full) = run(SelectMode::Full);
        let (ca, cb_ca) = run(SelectMode::ChangeAware);
        assert_eq!(full.jobs_skipped(), 0);
        assert_eq!(full.total_jobs(), ca.total_jobs(), "jobs_total counts the matrix");
        // round 0 is cold (nothing to carry); round 1 touches lbm/gpu
        // (cpu skips); rounds 2/3 touch fslbm / fe2ti (everything skips)
        assert_eq!(ca.jobs_skipped(), 2 + 3 + 3);
        assert!(ca.jobs_selected() < full.jobs_selected());
        assert!(ca.cluster_hours_saved() > 0.0);
        assert!(ca.makespan_saved_s() > 0.0);
        // every pipeline still uploads the full point set (carried or
        // measured), and the alert books agree byte for byte
        assert_eq!(cb_full.db.n_points("lbm"), cb_ca.db.n_points("lbm"));
        assert_eq!(
            cb_full.alerts.to_json().to_string_pretty(),
            cb_ca.alerts.to_json().to_string_pretty()
        );
    }

    #[test]
    fn campaign_rejects_degenerate_configs() {
        let mut cb = CbSystem::new();
        let cfg = CampaignConfig::default();
        let mut empty: Vec<CampaignProject> = Vec::new();
        assert!(run_campaign(&mut cb, &mut empty, &cfg).is_err());
        let mut projects = vec![CampaignProject::new("a", ProjectKind::Walberla)];
        let bad = CampaignConfig { pushes: 0, ..CampaignConfig::default() };
        assert!(run_campaign(&mut cb, &mut projects, &bad).is_err());
        let bad = CampaignConfig { pushes: 2, inject_at: 3, ..CampaignConfig::default() };
        assert!(run_campaign(&mut cb, &mut projects, &bad).is_err());
    }

    #[test]
    fn default_projects_alternate_kinds() {
        let ps = default_projects(4);
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0].kind, ProjectKind::Walberla);
        assert_eq!(ps[1].kind, ProjectKind::Fe2ti);
        assert_eq!(ps[2].kind, ProjectKind::Walberla);
        assert_eq!(ps[0].name, "walberla-0");
        assert_eq!(ps[1].name, "fe2ti-1");
        // names are unique — they double as repo/owner identities
        let mut names: Vec<&str> = ps.iter().map(|p| p.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 4);
    }
}
