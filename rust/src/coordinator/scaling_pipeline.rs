//! Automated multi-node weak-scaling campaigns — the paper's §7 future
//! work ("add support for multi-node benchmarks and automate weak scaling
//! runs and their evaluation"), implemented as a first-class pipeline.
//!
//! A scaling campaign runs one benchmark at a ladder of node counts on a
//! production partition (Fritz or JUWELS models), uploads one TSDB point
//! per rung tagged with `nodes=<n>`, and evaluates the ladder
//! automatically: per-phase parallel efficiency plus a verdict on which
//! phase breaks scaling first. This turns the manual Fig. 11/12/14 runs
//! into pipeline jobs.

use super::{CbSystem, PreparedJob};
use crate::apps::fe2ti::bench::Parallelization;
use crate::apps::fe2ti::macroscale::{macro_solve, MacroMesh, MacroSolver};
use crate::apps::fe2ti::solvers::SolverConfig;
use crate::apps::walberla::fslbm::gravity_wave_phases;
use crate::ci::CiJob;
use crate::cluster::WorkProfile;
use crate::mpisim::CommModel;
use crate::slurm::JobOutcome;
use crate::tsdb::{Aggregate, Query};
use crate::vcs::PushEvent;

/// Which scaling campaign to run.
#[derive(Debug, Clone, Copy)]
pub enum ScalingCase {
    /// Fig. 11: FE2TI, 216 RVEs/node on Fritz.
    Fe2tiFritz { solver: SolverConfig, par: Parallelization },
    /// Fig. 14: GravityWaveFSLBM, 64³ cells/core on Fritz.
    FslbmFritz,
    /// Fig. 12: macro-solver comparison on JUWELS.
    MacroJuwels { solver: MacroSolver, par: Parallelization },
}

impl ScalingCase {
    pub fn name(&self) -> String {
        match self {
            ScalingCase::Fe2tiFritz { solver, par } => {
                format!("scaling-fe2ti-{}-{}", solver.kind.name(), par.name())
            }
            ScalingCase::FslbmFritz => "scaling-fslbm".to_string(),
            ScalingCase::MacroJuwels { solver, par } => format!(
                "scaling-macro-{}-{}",
                match solver {
                    MacroSolver::SequentialDirect => "pardiso",
                    MacroSolver::Bddc => "bddc",
                },
                par.name()
            ),
        }
    }
    pub fn host(&self) -> &'static str {
        match self {
            ScalingCase::MacroJuwels { .. } => "juwels",
            _ => "fritz",
        }
    }
    pub fn ladder(&self) -> Vec<usize> {
        match self {
            ScalingCase::MacroJuwels { .. } => vec![9, 27, 100, 300, 900],
            _ => vec![1, 2, 4, 8, 16, 32, 64],
        }
    }
}

/// Build the job ladder for a campaign: one multi-node job per rung.
pub fn scaling_jobs(case: ScalingCase) -> Vec<PreparedJob> {
    let mut jobs = Vec::new();
    for nodes in case.ladder() {
        let name = format!("{}-n{nodes}", case.name());
        let ci = CiJob::new(&name, "scaling")
            .var("HOST", case.host())
            .var("NODES", &nodes.to_string())
            .var("SLURM_TIMELIMIT", "240")
            .var("SCRIPT", "weak_scaling.sh")
            // nominal: scaling campaigns bypass submit_pipeline's selector
            // today, but the declaration keeps the map total
            .var(crate::select::COMPONENTS_VAR, "scaling");
        let payload = Box::new(move |node: &crate::cluster::nodes::NodeModel, _t: f64| {
            let comm = CommModel::default();
            match case {
                ScalingCase::Fe2tiFritz { solver, par } => {
                    let (tts, micro, macro_t) =
                        crate::report::fe2ti_figs::weak_scaling_point_public(
                            node, nodes, solver, par,
                        );
                    JobOutcome {
                        duration: tts + 60.0,
                        stdout: format!(
                            "TAG campaign=fe2ti\nTAG nodes={nodes}\nMETRIC tts={tts:.6}\n\
                             METRIC micro_time={micro:.6}\nMETRIC macro_time={macro_t:.6}\n"
                        ),
                        exit_code: 0,
                    }
                }
                ScalingCase::FslbmFritz => {
                    let g = crate::mpisim::Geometry::pure_mpi(nodes, node.cores());
                    let wpc = WorkProfile::new(550.0, 500.0);
                    let ph = gravity_wave_phases(node, &g, 64, &comm, &wpc);
                    JobOutcome {
                        duration: ph.total() * 200.0 + 60.0,
                        stdout: format!(
                            "TAG campaign=fslbm\nTAG nodes={nodes}\nMETRIC total={:.6}\n\
                             METRIC compute={:.6}\nMETRIC sync={:.6}\nMETRIC comm={:.6}\n",
                            ph.total(),
                            ph.compute,
                            ph.sync,
                            ph.comm
                        ),
                        exit_code: 0,
                    }
                }
                ScalingCase::MacroJuwels { solver, par } => {
                    let elements = (192 * nodes).div_ceil(27);
                    let mesh = MacroMesh { ex: elements, ey: 1, ez: 1 };
                    let geometry = par.geometry(nodes, node.cores());
                    match macro_solve(&mesh, 1.0, solver, &geometry, &comm) {
                        Ok(m) => {
                            let serial =
                                WorkProfile::new(m.serial_work.flops, m.serial_work.bytes)
                                    .parallel(0.0);
                            let par_w =
                                WorkProfile::new(m.parallel_work.flops, m.parallel_work.bytes)
                                    .efficiency(0.4);
                            let t = node.exec_time(&serial, 1)
                                + node.exec_time(&par_w, geometry.cores_per_node())
                                + m.comm_time;
                            JobOutcome {
                                duration: t * 6.0 + 60.0,
                                stdout: format!(
                                    "TAG campaign=macro\nTAG nodes={nodes}\nMETRIC macro_time={:.6}\n",
                                    t * 6.0
                                ),
                                exit_code: 0,
                            }
                        }
                        Err(e) => JobOutcome {
                            duration: 1.0,
                            stdout: format!("macro solve failed: {e}\n"),
                            exit_code: 1,
                        },
                    }
                }
            }
        });
        jobs.push(PreparedJob { ci, payload });
    }
    jobs
}

/// Automated evaluation of a finished campaign: weak-scaling efficiency
/// per rung (t(1-node rung)/t(n)) and the phase that degrades first.
#[derive(Debug, Clone)]
pub struct ScalingVerdict {
    pub field: String,
    /// (nodes, value, efficiency vs first rung).
    pub rungs: Vec<(usize, f64, f64)>,
    /// Efficiency at the top rung.
    pub final_efficiency: f64,
}

pub fn evaluate_scaling(cb: &CbSystem, measurement: &str, field: &str) -> Option<ScalingVerdict> {
    let mut rungs = Vec::new();
    for s in Query::new(measurement, field).group_by(&["nodes"]).run(&cb.db) {
        let nodes: usize = s.group.get("nodes")?.parse().ok()?;
        rungs.push((nodes, s.aggregate(Aggregate::Last)));
    }
    if rungs.is_empty() {
        return None;
    }
    rungs.sort_by_key(|(n, _)| *n);
    let base = rungs[0].1;
    let rungs: Vec<(usize, f64, f64)> = rungs
        .into_iter()
        .map(|(n, v)| (n, v, base / v))
        .collect();
    Some(ScalingVerdict {
        field: field.to_string(),
        final_efficiency: rungs.last().unwrap().2,
        rungs,
    })
}

/// Run a campaign through the CB system and return the verdict for `field`.
pub fn run_scaling_campaign(
    cb: &mut CbSystem,
    event: &PushEvent,
    case: ScalingCase,
    field: &str,
) -> anyhow::Result<ScalingVerdict> {
    // production partitions are separate scheduler domains: extend the
    // cluster with the target host if missing
    let measurement = format!("{}", case.name());
    let jobs = scaling_jobs(case);
    cb.execute_scaling_pipeline(event, case.host(), jobs, &measurement)?;
    evaluate_scaling(cb, &measurement, field)
        .ok_or_else(|| anyhow::anyhow!("no scaling data for {field}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::fe2ti::solvers::{Compiler, SolverKind};

    fn event() -> PushEvent {
        PushEvent {
            repo: "fe2ti".into(),
            branch: "master".into(),
            commit_id: "0123456789abcdef".into(),
            changed: vec![],
        }
    }

    #[test]
    fn fslbm_campaign_runs_and_scores() {
        let mut cb = CbSystem::new();
        let v = run_scaling_campaign(&mut cb, &event(), ScalingCase::FslbmFritz, "total").unwrap();
        assert_eq!(v.rungs.len(), 7);
        assert_eq!(v.rungs[0].0, 1);
        // weak scaling degrades but stays above 80% (Fig. 14: ~13% loss)
        assert!(v.final_efficiency < 1.0);
        assert!(v.final_efficiency > 0.8, "eff={}", v.final_efficiency);
        // compute phase alone scales perfectly
        let vc = evaluate_scaling(&cb, "scaling-fslbm", "compute").unwrap();
        assert!((vc.final_efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fe2ti_campaign_micro_flat_tts_degrades() {
        let mut cb = CbSystem::new();
        let case = ScalingCase::Fe2tiFritz {
            solver: SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel),
            par: Parallelization::MpiOnly,
        };
        let v = run_scaling_campaign(&mut cb, &event(), case, "tts").unwrap();
        assert!(v.final_efficiency < 0.95, "tts must degrade: {v:?}");
        let vm = evaluate_scaling(&cb, &case.name(), "micro_time").unwrap();
        assert!(vm.final_efficiency > 0.95, "micro must stay flat: {vm:?}");
    }

    #[test]
    fn macro_campaign_bddc_beats_pardiso_at_scale() {
        let mut cb = CbSystem::new();
        let pardiso = ScalingCase::MacroJuwels {
            solver: MacroSolver::SequentialDirect,
            par: Parallelization::Hybrid,
        };
        let bddc = ScalingCase::MacroJuwels {
            solver: MacroSolver::Bddc,
            par: Parallelization::Hybrid,
        };
        let vp = run_scaling_campaign(&mut cb, &event(), pardiso, "macro_time").unwrap();
        let vb = run_scaling_campaign(&mut cb, &event(), bddc, "macro_time").unwrap();
        let top_p = vp.rungs.last().unwrap().1;
        let top_b = vb.rungs.last().unwrap().1;
        assert!(top_b < top_p, "bddc {top_b} must beat pardiso {top_p} at 900 nodes");
    }
}
