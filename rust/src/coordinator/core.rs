//! The ownership core of a CB installation — the paper's "upload →
//! detect → alert" loop as a self-contained, scheduler-free value.
//!
//! [`CoreHandle`] bundles exactly the state that survives a pipeline
//! (the sharded TSDB, the alert lifecycle, the carried incremental
//! detector state and the active/base policy pair) and the operations
//! the continuous-benchmarking loop performs on it: batched
//! line-protocol ingest, scoped statistical detection, alert-book
//! folding, and per-tenant `regress.*` threshold overrides.
//!
//! Two frontends share it:
//!
//! * [`crate::coordinator::CbSystem`] embeds one `CoreHandle` and layers
//!   the simulated cluster on top (scheduler, datastore archival,
//!   tracing). `CbSystem` derefs to its core, so `cb.db` / `cb.alerts` /
//!   `cb.det_state` keep reading naturally at every existing call site.
//! * [`crate::serve`] keeps one `CoreHandle` **per project** behind an
//!   `RwLock` — the multi-tenant benchmark-as-a-service facade. Nothing
//!   in here touches the scheduler or any global, so per-project cores
//!   are fully independent: two projects never contend on a lock and can
//!   never see each other's series.
//!
//! The detection semantics are byte-identical to what
//! `CbSystem::check_regressions` did before the extraction — the
//! incremental state-path/re-query equivalence contract
//! (`regress::state`) is proven over this code.

use crate::coordinator::{detector_with_config, BenchConfig};
use crate::regress::{AlertBook, Detector, DetectorState, IngestSummary};
use crate::tsdb::Db;

/// Outcome of one [`CoreHandle::ingest_and_detect`] call: how many points
/// landed and what the post-ingest detection did to the alert book.
#[derive(Debug, Clone, Default)]
pub struct IngestDetectOutcome {
    /// Line-protocol points ingested (the whole batch, atomically).
    pub points: usize,
    /// Distinct `(measurement, repo-tag)` scopes the batch touched — one
    /// scoped detection ran per entry.
    pub scopes: usize,
    /// Folded alert-book deltas across all scoped detections.
    pub summary: IngestSummary,
}

/// The shared continuous-benchmarking core: TSDB + detector (+ carried
/// incremental state) + alert book. See the module docs for who owns one.
pub struct CoreHandle {
    pub db: Db,
    /// Durable alert lifecycle fed by the detector.
    pub alerts: AlertBook,
    /// Incremental per-series detection state carried across ingests —
    /// judged from by default, invalidated (bounded rebuild) whenever the
    /// detector fingerprint changes (see [`crate::regress::state`]).
    pub det_state: DetectorState,
    /// Active policies: the base set with the current per-tenant
    /// `regress.*` overrides applied. Use [`CoreHandle::install_detector`]
    /// for durable changes — direct assignment is overwritten by the next
    /// [`CoreHandle::apply_regress_config`].
    pub detector: Detector,
    /// Pristine policies that `regress.*` overrides derive from.
    pub(crate) base_detector: Detector,
    /// `false` restores the full tail re-query on every check (the A/B
    /// reference; `--detect requery`).
    pub(crate) incremental_detection: bool,
}

impl Default for CoreHandle {
    fn default() -> Self {
        Self::new()
    }
}

impl CoreHandle {
    pub fn new() -> CoreHandle {
        let detector = Detector::with_default_policies();
        CoreHandle {
            db: Db::new(),
            alerts: AlertBook::new(),
            det_state: DetectorState::new(),
            base_detector: detector.clone(),
            detector,
            incremental_detection: true,
        }
    }

    /// Install a new detector as the *base* policy set: per-tenant
    /// `regress.*` overrides ([`CoreHandle::apply_regress_config`]) are
    /// derived from it, so custom policies installed here survive
    /// subsequent config applications.
    pub fn install_detector(&mut self, det: Detector) {
        self.base_detector = det.clone();
        self.detector = det;
    }

    /// Swap in the base policies overridden by `regress.<policy>.<knob>`
    /// entries (see [`detector_with_config`]). A config without overrides
    /// restores the base sensitivity. A change to any knob changes the
    /// detector fingerprint, which invalidates the carried incremental
    /// state at its next sync (bounded rebuild — never O(history)).
    pub fn apply_regress_config(&mut self, cfg: &BenchConfig) {
        self.detector = detector_with_config(&self.base_detector, cfg);
    }

    /// Toggle incremental detection (on by default): `false` makes every
    /// check re-query the tail window from the TSDB — the A/B reference
    /// the equivalence tests compare against.
    pub fn set_incremental_detection(&mut self, on: bool) {
        self.incremental_detection = on;
    }
    pub fn incremental_detection(&self) -> bool {
        self.incremental_detection
    }

    /// Run the scoped statistical regression check against the current
    /// TSDB and fold the findings into the alert book (opened /
    /// re-confirmed / auto-resolved). `owner_repo` scopes the check to
    /// that repository's series for `repo`-grouped policies: a tenant's
    /// detection judges only its own series, and co-tenant trigger
    /// timestamps don't shrink its window. `now_ts` stamps the alert
    /// bookkeeping (opened/last-seen times).
    pub fn detect_and_ingest(
        &mut self,
        measurement: &str,
        owner_repo: Option<&str>,
        now_ts: i64,
    ) -> IngestSummary {
        let scope: Vec<(&str, &str)> = owner_repo.iter().map(|r| ("repo", *r)).collect();
        // incremental by default: sync the carried per-series state with
        // the points appended since the last check, then judge from state
        // — proven byte-identical to the full tail re-query below
        let (findings, evaluated) = if self.incremental_detection {
            self.det_state.sync(&self.detector, &self.db);
            self.det_state
                .detect_measurement_scoped(&self.detector, &self.db, measurement, &scope)
        } else {
            self.detector
                .detect_measurement_scoped(&self.db, measurement, &scope)
        };
        self.alerts.ingest(&findings, &evaluated, now_ts)
    }

    /// The service-facade ingest path: parse a line-protocol batch
    /// (atomic — a malformed line fails the whole batch and nothing is
    /// ingested), insert it, then run one scoped detection per distinct
    /// `(measurement, repo-tag)` pair the batch touched, folding every
    /// outcome into the alert book. Points without a `repo` tag get an
    /// unscoped detection of their measurement.
    ///
    /// Mirrors [`Db::ingest_lines`]'s instrumentation (`LpParse` timer
    /// covers the parse only) and `CbSystem`'s per-collect detection
    /// semantics, so a served project behaves exactly like a pipeline
    /// tenant.
    pub fn ingest_and_detect(&mut self, text: &str) -> Result<IngestDetectOutcome, String> {
        // columnar ingest; the distinct (measurement, repo) scopes come
        // out of the interned tag sets — deterministic BTreeSet order,
        // no second walk over owned Points
        let (n, scopes) = self.db.ingest_lines_scoped(text, "repo")?;
        let now_ts = self.db.newest_ts().unwrap_or(0);
        let mut summary = IngestSummary::default();
        for (m, repo) in &scopes {
            let s = self.detect_and_ingest(m, repo.as_deref(), now_ts);
            summary.opened += s.opened;
            summary.updated += s.updated;
            summary.auto_resolved += s.auto_resolved;
            summary.opened_ids.extend(s.opened_ids);
        }
        Ok(IngestDetectOutcome { points: n, scopes: scopes.len(), summary })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lp_series(repo: &str, n: usize, val: impl Fn(usize) -> f64) -> String {
        (0..n)
            .map(|i| {
                format!(
                    "lbm,case=uniform,node=icx36,collision_op=srt,gpu=false,repo={repo} mlups={} {}\n",
                    val(i),
                    (i as i64 + 1) * 1_000_000_000
                )
            })
            .collect()
    }

    #[test]
    fn ingest_and_detect_opens_alert_on_injected_drop() {
        let mut core = CoreHandle::new();
        // healthy baseline, then a 40% drop
        let out = core
            .ingest_and_detect(&lp_series("p1", 10, |i| 800.0 + (i % 3) as f64))
            .unwrap();
        assert_eq!(out.points, 10);
        assert_eq!(out.summary.opened, 0);
        let out = core
            .ingest_and_detect(
                "lbm,case=uniform,node=icx36,collision_op=srt,gpu=false,repo=p1 mlups=480 11000000000\n",
            )
            .unwrap();
        assert_eq!(out.summary.opened, 1, "drop must open an alert");
        assert_eq!(core.alerts.active().len(), 1);
    }

    #[test]
    fn ingest_is_atomic_on_malformed_batches() {
        let mut core = CoreHandle::new();
        let bad = "lbm,repo=p1 mlups=1 1000000000\nnot a line\n";
        assert!(core.ingest_and_detect(bad).is_err());
        assert_eq!(core.db.len(), 0, "malformed batch must not partially ingest");
    }

    #[test]
    fn scoped_detection_isolates_tenants() {
        let mut core = CoreHandle::new();
        core.ingest_and_detect(&lp_series("a", 10, |i| 800.0 + (i % 3) as f64)).unwrap();
        core.ingest_and_detect(&lp_series("b", 10, |i| 400.0 + (i % 3) as f64)).unwrap();
        // tenant a regresses; tenant b stays healthy
        let out = core
            .ingest_and_detect(
                "lbm,case=uniform,node=icx36,collision_op=srt,gpu=false,repo=a mlups=450 12000000000\n",
            )
            .unwrap();
        assert_eq!(out.summary.opened, 1);
        let a = core.alerts.active()[0];
        assert_eq!(a.group.get("repo").map(|s| s.as_str()), Some("a"));
    }
}
