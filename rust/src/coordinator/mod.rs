//! The continuous-benchmarking coordinator: the paper's system
//! contribution (§3–§4), wired end to end.
//!
//! On every push to a watched repository the coordinator:
//!
//! 1. creates a CI pipeline (GitLab analogue, [`crate::ci`]),
//! 2. instantiates the benchmark job matrix — node × compiler × solver ×
//!   parallelization for FE2TI, node × collision operator for waLBerla
//!   (>80 jobs per FE2TI pipeline, like the paper),
//! 3. assembles per-job batch scripts (Listing 1) and submits them to the
//!   Slurm-like scheduler over the simulated Testcluster,
//! 4. parses each job's output (likwid-style counters), uploads metric
//!   points to the TSDB (fields) tagged with the run parameters (tags)
//!   and the pipeline trigger time (timestamp),
//! 5. archives raw artifacts as linked records in the Kadi4Mat-like store
//!   (one collection per pipeline execution, Fig. 5),
//! 6. refreshes the Grafana-like dashboards and the roofline plots.
//!
//! Build configuration lives in the repository tree (`benchmark.cfg`), so
//! *commits change measured performance* — the mechanism behind the
//! paper's Fig. 10b BLAS-fix story and the regression-detection example.

pub mod fe2ti_pipeline;
pub mod scaling_pipeline;
pub mod walberla_pipeline;

use crate::ci::{CiJob, Pipeline, PipelineFactory, Runner};
use crate::cluster::machinestate::machine_state;
use crate::cluster::nodes::catalogue;
use crate::datastore::{DataStore, Id};
use crate::regress::{AlertBook, Detector, Direction, IngestSummary, Policy};
use crate::slurm::{JobSpec, Payload, Scheduler};
use crate::tsdb::{Db, Point};
use crate::vcs::{PushEvent, Repository};
use std::collections::BTreeMap;

/// Repository-side benchmark configuration (parsed from `benchmark.cfg`
/// in the commit tree). Line format: `key = value`.
#[derive(Debug, Clone, Default)]
pub struct BenchConfig {
    pub entries: BTreeMap<String, String>,
}

impl BenchConfig {
    pub fn parse(text: &str) -> BenchConfig {
        let mut entries = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                entries.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        BenchConfig { entries }
    }
    pub fn from_commit(repo: &Repository, commit_id: &str) -> BenchConfig {
        repo.get(commit_id)
            .and_then(|c| c.tree.get("benchmark.cfg"))
            .map(|t| BenchConfig::parse(t))
            .unwrap_or_default()
    }
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// One executed benchmark job's parsed metrics.
#[derive(Debug, Clone)]
pub struct JobMetrics {
    pub job_name: String,
    pub node: String,
    /// Tag → value.
    pub tags: BTreeMap<String, String>,
    /// Field → value.
    pub fields: BTreeMap<String, f64>,
    pub raw_log: String,
}

/// Parse `METRIC key=value` and `TAG key=value` lines from a job log —
/// the §4.3 "collected and parsed" step.
pub fn parse_job_output(job_name: &str, node: &str, log: &str) -> JobMetrics {
    let mut tags = BTreeMap::new();
    let mut fields = BTreeMap::new();
    for line in log.lines() {
        if let Some(rest) = line.strip_prefix("METRIC ") {
            if let Some((k, v)) = rest.split_once('=') {
                if let Ok(v) = v.trim().parse::<f64>() {
                    fields.insert(k.trim().to_string(), v);
                }
            }
        } else if let Some(rest) = line.strip_prefix("TAG ") {
            if let Some((k, v)) = rest.split_once('=') {
                tags.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
    }
    JobMetrics {
        job_name: job_name.to_string(),
        node: node.to_string(),
        tags,
        fields,
        raw_log: log.to_string(),
    }
}

/// A job ready for submission: CI spec + the closure that runs it.
pub struct PreparedJob {
    pub ci: CiJob,
    pub payload: Payload,
}

/// Summary of one pipeline execution.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub pipeline_id: u64,
    pub commit_id: String,
    pub jobs_total: usize,
    pub jobs_completed: usize,
    pub jobs_failed: usize,
    pub points_uploaded: usize,
    pub records_created: usize,
    pub collection: Id,
    /// Simulated wall time the whole pipeline took on the cluster.
    pub duration: f64,
    /// Outcome of the post-upload regression check (alerts opened /
    /// re-confirmed / auto-resolved by this execution).
    pub regressions: IngestSummary,
}

/// The whole CB installation.
pub struct CbSystem {
    pub scheduler: Scheduler,
    pub db: Db,
    pub store: DataStore,
    pub runner: Runner,
    pub pipelines: PipelineFactory,
    pub executed: Vec<PipelineReport>,
    /// Statistical regression detector run after every upload.
    pub detector: Detector,
    /// Durable alert lifecycle fed by the detector.
    pub alerts: AlertBook,
    root_collection: Id,
    /// Collection grouping the archived regression alerts (lazy).
    alerts_collection: Option<Id>,
    /// Simulated "trigger time" counter: advances per pipeline (ns).
    trigger_clock: i64,
}

impl Default for CbSystem {
    fn default() -> Self {
        Self::new()
    }
}

impl CbSystem {
    pub fn new() -> CbSystem {
        let mut store = DataStore::new();
        let root_collection = store.create_collection("cb-project", "CB project-level collection");
        CbSystem {
            scheduler: Scheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect()),
            db: Db::new(),
            store,
            runner: Runner::hpc(),
            pipelines: PipelineFactory::new(),
            executed: Vec::new(),
            detector: Detector::with_default_policies(),
            alerts: AlertBook::new(),
            root_collection,
            alerts_collection: None,
            trigger_clock: 0,
        }
    }

    /// Adopt an existing TSDB (e.g. reloaded from the file a previous
    /// `cbench pipeline` run saved) and fast-forward the trigger clock
    /// past its newest point, so this run's pipelines append strictly
    /// increasing timestamps to the carried-over history instead of
    /// overwriting it.
    pub fn adopt_db(&mut self, db: Db) {
        let mut max_ts = 0i64;
        for m in db.measurements() {
            if let Some(p) = db.points(m).last() {
                max_ts = max_ts.max(p.ts);
            }
        }
        self.db = db;
        self.trigger_clock = self.trigger_clock.max(max_ts);
    }

    /// Run the regression detector for `measurement` against the current
    /// TSDB, fold the findings into the alert book, and archive any newly
    /// opened alerts as datastore records linked to `collection` (the
    /// pipeline execution that surfaced them). Called by
    /// [`CbSystem::execute_pipeline`] after every upload.
    pub fn check_regressions(&mut self, measurement: &str, collection: Id) -> IngestSummary {
        let (findings, evaluated) = self.detector.detect_measurement(&self.db, measurement);
        let now = self.trigger_clock;
        let summary = self.alerts.ingest(&findings, &evaluated, now);
        // attribute exactly the alerts this execution opened to its
        // collection (the Fig. 5 provenance link)
        for id in &summary.opened_ids {
            if let Some(a) = self.alerts.get_mut(*id) {
                a.pipeline_collection = Some(collection);
            }
        }
        if summary.opened > 0 || summary.auto_resolved > 0 {
            let coll = match self.alerts_collection {
                Some(c) => c,
                None => {
                    let c = self
                        .store
                        .create_collection("regression-alerts", "regression alert archive");
                    self.store.add_child_collection(self.root_collection, c).ok();
                    self.alerts_collection = Some(c);
                    c
                }
            };
            self.alerts.archive(&mut self.store, coll);
        }
        summary
    }

    /// Execute a pipeline: submit all jobs, wait, parse, upload, archive.
    pub fn execute_pipeline(
        &mut self,
        event: &PushEvent,
        via_trigger_api: bool,
        jobs: Vec<PreparedJob>,
        measurement: &str,
    ) -> anyhow::Result<PipelineReport> {
        self.trigger_clock += 1_000_000_000; // pipelines 1 s apart
        let trigger_ts = self.trigger_clock;

        let mut ci_jobs = Vec::new();
        let mut submitted = Vec::new();
        let start = self.scheduler.now();
        for j in jobs {
            anyhow::ensure!(
                self.runner.accepts(&j.ci),
                "no runner serves job `{}` tags {:?}",
                j.ci.name,
                j.ci.tags
            );
            let host = j
                .ci
                .get("HOST")
                .ok_or_else(|| anyhow::anyhow!("job `{}` missing HOST", j.ci.name))?
                .to_string();
            let spec = JobSpec {
                name: j.ci.name.clone(),
                nodelist: host,
                timelimit_min: j.ci.timelimit_min(),
            };
            let id = self
                .scheduler
                .sbatch(spec, j.payload)
                .map_err(|e| anyhow::anyhow!(e))?;
            submitted.push((id, j.ci.clone()));
            ci_jobs.push(j.ci);
        }
        let pipeline: Pipeline = self.pipelines.create(event.clone(), via_trigger_api, ci_jobs);

        // sbatch --wait
        self.scheduler.wait_all();

        // per-execution collection (Fig. 5)
        let coll = self.store.create_collection(
            &format!("pipeline-{}", pipeline.id),
            &format!(
                "{} pipeline #{} @ {}",
                event.repo,
                pipeline.id,
                &event.commit_id[..8.min(event.commit_id.len())]
            ),
        );
        self.store
            .add_child_collection(self.root_collection, coll)
            .ok();

        let mut completed = 0;
        let mut failed = 0;
        let mut points = 0;
        let mut records = 0;
        for (slurm_id, ci) in &submitted {
            let job = self.scheduler.job(*slurm_id).expect("job exists");
            let state = job.state;
            let log = job.log.clone();
            let node_host = job.spec.nodelist.clone();
            let node = self.scheduler.node(&node_host).unwrap().clone();
            if state == crate::slurm::JobState::Completed {
                completed += 1;
            } else {
                failed += 1;
            }

            // --- parse + upload (fields & tags, trigger time as ts) ---
            let metrics = parse_job_output(&ci.name, &node_host, &log);
            if !metrics.fields.is_empty() {
                let mut p = Point::new(measurement, trigger_ts);
                p.tags.insert("node".into(), node_host.clone());
                p.tags.insert("commit".into(), event.commit_id[..8].to_string());
                p.tags.insert("repo".into(), event.repo.clone());
                p.tags.insert("branch".into(), event.branch.clone());
                for (k, v) in &metrics.tags {
                    p.tags.insert(k.clone(), v.clone());
                }
                for (k, v) in &metrics.fields {
                    p.fields.insert(k.clone(), *v);
                }
                self.db.insert(p);
                points += 1;
            }

            // --- archive records: job log + likwid + machinestate ---
            let rid_job = self
                .store
                .create_record(
                    &format!("p{}-job-{}", pipeline.id, ci.name),
                    &format!("job log {}", ci.name),
                    "job-log",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            self.store.attach_file(rid_job, "slurm.log", &log).ok();
            self.store.set_meta(rid_job, "node", &node_host).ok();
            self.store.set_meta(rid_job, "state", &format!("{state:?}")).ok();
            let rid_perf = self
                .store
                .create_record(
                    &format!("p{}-perf-{}", pipeline.id, ci.name),
                    &format!("likwid output {}", ci.name),
                    "likwid-output",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            self.store.attach_file(rid_perf, "perfctr.txt", &metrics.raw_log).ok();
            let rid_ms = self
                .store
                .create_record(
                    &format!("p{}-ms-{}", pipeline.id, ci.name),
                    &format!("machinestate {}", ci.name),
                    "machinestate",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            let ms = machine_state(&node, &ci.name, self.scheduler.now());
            self.store
                .attach_file(rid_ms, "machinestate.json", &ms.to_string_pretty())
                .ok();
            for rid in [rid_job, rid_perf, rid_ms] {
                self.store.add_to_collection(coll, rid).ok();
                records += 1;
            }
            self.store.link(rid_perf, rid_job, "belongs to").ok();
            self.store.link(rid_ms, rid_job, "recorded on").ok();
        }

        // --- §4.4 closing the loop: statistical regression check ---
        let regressions = self.check_regressions(measurement, coll);

        let report = PipelineReport {
            pipeline_id: pipeline.id,
            commit_id: event.commit_id.clone(),
            jobs_total: submitted.len(),
            jobs_completed: completed,
            jobs_failed: failed,
            points_uploaded: points,
            records_created: records,
            collection: coll,
            duration: self.scheduler.now() - start,
            regressions,
        };
        self.executed.push(report.clone());
        Ok(report)
    }

    /// Current trigger timestamp (ns) of the most recent pipeline.
    pub fn last_trigger_ts(&self) -> i64 {
        self.trigger_clock
    }

    /// Execute a multi-node scaling pipeline on a *production* partition
    /// (Fritz/JUWELS node models, not part of the single-node Testcluster;
    /// paper §7 future work). Jobs run on their own scheduler domain; the
    /// parsed metrics land in the shared TSDB, and one summary record per
    /// campaign is archived.
    pub fn execute_scaling_pipeline(
        &mut self,
        event: &PushEvent,
        host: &str,
        jobs: Vec<PreparedJob>,
        measurement: &str,
    ) -> anyhow::Result<usize> {
        self.trigger_clock += 1_000_000_000;
        let trigger_ts = self.trigger_clock;
        let node = catalogue()
            .into_iter()
            .find(|n| n.host == host && !n.testcluster)
            .ok_or_else(|| anyhow::anyhow!("`{host}` is not a production partition"))?;
        let mut sched = crate::slurm::Scheduler::new(vec![node.clone()]);
        let mut ids = Vec::new();
        for j in jobs {
            let spec = JobSpec {
                name: j.ci.name.clone(),
                nodelist: host.to_string(),
                timelimit_min: j.ci.timelimit_min(),
            };
            ids.push((sched.sbatch(spec, j.payload).map_err(|e| anyhow::anyhow!(e))?, j.ci));
        }
        sched.wait_all();
        let mut points = 0;
        let mut summary = String::new();
        for (id, ci) in &ids {
            let job = sched.job(*id).expect("job exists");
            let metrics = parse_job_output(&ci.name, host, &job.log);
            if !metrics.fields.is_empty() {
                let mut p = Point::new(measurement, trigger_ts);
                p.tags.insert("node".into(), host.to_string());
                p.tags.insert("commit".into(), event.commit_id[..8].to_string());
                for (k, v) in &metrics.tags {
                    p.tags.insert(k.clone(), v.clone());
                }
                for (k, v) in &metrics.fields {
                    p.fields.insert(k.clone(), *v);
                }
                self.db.insert(p);
                points += 1;
            }
            summary.push_str(&job.log);
            summary.push('\n');
        }
        let rid = self
            .store
            .create_record(
                &format!("scaling-{measurement}-{trigger_ts}"),
                &format!("weak-scaling campaign {measurement} on {host}"),
                "scaling-campaign",
            )
            .map_err(|e| anyhow::anyhow!(e))?;
        self.store.attach_file(rid, "campaign.log", &summary).ok();
        Ok(points)
    }
}

/// A detected performance change between consecutive pipeline executions
/// of one tagged series.
#[derive(Debug, Clone)]
pub struct PerfChange {
    pub series: String,
    pub before: f64,
    pub after: f64,
    /// Relative change of the metric ((after-before)/before).
    pub rel_change: f64,
}

/// Compare the last two points of every grouped series of
/// `measurement.field` and report changes beyond `threshold` (relative).
/// `higher_is_better` controls the sign convention for *regressions*:
/// for MLUP/s a drop is a regression; for TTS a rise is.
///
/// This is CB's raison d'être: "reveals performance degradation introduced
/// by code changes immediately" (paper §7). Since the `regress::`
/// subsystem landed this is a thin shim over
/// [`crate::regress::detector`]: a policy with a 1-point baseline window,
/// no change-point splitting and no statistical gate reproduces the
/// legacy last-vs-previous semantics exactly, while new callers should
/// use [`Detector`] with real windows.
pub fn detect_regressions(
    db: &Db,
    measurement: &str,
    field: &str,
    group_by: &[&str],
    threshold: f64,
    higher_is_better: bool,
) -> Vec<PerfChange> {
    let policy = Policy::new("legacy-last-vs-prev", measurement, field)
        .group_by(group_by)
        .direction(if higher_is_better {
            Direction::HigherIsBetter
        } else {
            Direction::LowerIsBetter
        })
        .windows(1, 1)
        .thresholds(threshold, 1.0, 0.0)
        .changepoint(false);
    crate::regress::detector::evaluate_policy(&policy, db)
        .into_iter()
        .map(|f| PerfChange {
            series: f.series,
            before: f.baseline.mean,
            after: f.current,
            rel_change: f.rel_change,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::JobOutcome;

    fn dummy_job(name: &str, host: &str, metrics: &str) -> PreparedJob {
        let out = metrics.to_string();
        PreparedJob {
            ci: CiJob::new(name, "benchmark").var("HOST", host),
            payload: Box::new(move |_n, _t| JobOutcome {
                duration: 10.0,
                stdout: out,
                exit_code: 0,
            }),
        }
    }

    fn event() -> PushEvent {
        PushEvent {
            repo: "fe2ti".into(),
            branch: "master".into(),
            commit_id: "abcdef1234567890".into(),
        }
    }

    #[test]
    fn bench_config_parses() {
        let cfg = BenchConfig::parse("# comment\numfpack_blas = blis\nlbm_penalty = 0.15\n");
        assert_eq!(cfg.get("umfpack_blas"), Some("blis"));
        assert_eq!(cfg.get_f64("lbm_penalty", 0.0), 0.15);
        assert_eq!(cfg.get_f64("missing", 1.0), 1.0);
    }

    #[test]
    fn parse_job_output_extracts_metrics_and_tags() {
        let log = "noise\nMETRIC tts=40.5\nMETRIC gflops=25\nTAG solver=ilu\nother\n";
        let m = parse_job_output("j", "icx36", log);
        assert_eq!(m.fields["tts"], 40.5);
        assert_eq!(m.tags["solver"], "ilu");
        assert_eq!(m.fields.len(), 2);
    }

    #[test]
    fn pipeline_executes_uploads_and_archives() {
        let mut cb = CbSystem::new();
        let jobs = vec![
            dummy_job("bench-icx36", "icx36", "METRIC tts=40\nTAG solver=ilu\n"),
            dummy_job("bench-rome1", "rome1", "METRIC tts=80\nTAG solver=ilu\n"),
        ];
        let r = cb.execute_pipeline(&event(), false, jobs, "fe2ti").unwrap();
        assert_eq!(r.jobs_total, 2);
        assert_eq!(r.jobs_completed, 2);
        assert_eq!(r.points_uploaded, 2);
        assert_eq!(r.records_created, 6); // 3 records per job
        assert_eq!(cb.db.len(), 2);
        // points tagged with commit + node
        let pts = cb.db.points("fe2ti");
        assert_eq!(pts[0].tags["commit"], "abcdef12");
        assert!(cb.store.n_links() >= 4);
    }

    #[test]
    fn successive_pipelines_get_increasing_timestamps() {
        let mut cb = CbSystem::new();
        let r1 = cb
            .execute_pipeline(&event(), false, vec![dummy_job("a", "icx36", "METRIC x=1\n")], "m")
            .unwrap();
        let r2 = cb
            .execute_pipeline(&event(), false, vec![dummy_job("a2", "icx36", "METRIC x=2\n")], "m")
            .unwrap();
        assert!(r2.pipeline_id > r1.pipeline_id);
        let pts = cb.db.points("m");
        assert!(pts[1].ts > pts[0].ts);
    }

    #[test]
    fn job_without_host_rejected() {
        let mut cb = CbSystem::new();
        let j = PreparedJob {
            ci: CiJob::new("nohost", "benchmark"),
            payload: Box::new(|_n, _t| JobOutcome {
                duration: 1.0,
                stdout: String::new(),
                exit_code: 0,
            }),
        };
        assert!(cb.execute_pipeline(&event(), false, vec![j], "m").is_err());
    }

    #[test]
    fn regression_detection_flags_drops_only() {
        let mut db = Db::new();
        for (ts, op, v) in [(1, "srt", 1000.0), (2, "srt", 800.0), (1, "trt", 900.0), (2, "trt", 910.0)] {
            db.insert(
                Point::new("lbm", ts)
                    .tag("collision_op", op)
                    .field("mlups", v),
            );
        }
        let regs = detect_regressions(&db, "lbm", "mlups", &["collision_op"], 0.1, true);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].series, "collision_op=srt");
        assert!((regs[0].rel_change + 0.2).abs() < 1e-12);
        // TTS convention: a rise is a regression
        let mut db2 = Db::new();
        db2.insert(Point::new("fe2ti", 1).tag("s", "x").field("tts", 10.0));
        db2.insert(Point::new("fe2ti", 2).tag("s", "x").field("tts", 13.0));
        let regs2 = detect_regressions(&db2, "fe2ti", "tts", &["s"], 0.1, false);
        assert_eq!(regs2.len(), 1);
    }

    #[test]
    fn pipeline_regression_check_opens_and_resolves_alerts() {
        let mut cb = CbSystem::new();
        let run = |cb: &mut CbSystem, mlups: f64| {
            let j = PreparedJob {
                ci: CiJob::new("uniform-srt-icx36", "benchmark").var("HOST", "icx36"),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: format!(
                        "TAG case=uniformgridcpu\nTAG collision_op=srt\nMETRIC mlups={mlups}\n"
                    ),
                    exit_code: 0,
                }),
            };
            cb.execute_pipeline(&event(), false, vec![j], "lbm").unwrap()
        };
        for _ in 0..4 {
            let r = run(&mut cb, 1000.0);
            assert_eq!(r.regressions, crate::regress::IngestSummary::default());
        }
        assert!(cb.alerts.active().is_empty());

        // an 18% drop on the watched series opens an alert immediately
        let r = run(&mut cb, 820.0);
        assert_eq!(r.regressions.opened, 1);
        let open = cb.alerts.active();
        assert_eq!(open.len(), 1);
        assert!(open[0].confidence > 0.8);
        assert!(open[0].series.contains("collision_op=srt"));
        assert_eq!(open[0].suspect_commit.as_deref(), Some("abcdef12"));
        // ... and is archived as a linked datastore record
        let rec = cb.store.record_by_identifier("regress-alert-1").unwrap();
        assert_eq!(rec.record_type, "regression-alert");
        assert_eq!(rec.meta["state"], "open");

        // recovery on the next pipeline auto-resolves it
        let r = run(&mut cb, 1000.0);
        assert_eq!(r.regressions.auto_resolved, 1);
        assert!(cb.alerts.active().is_empty());
        let rec = cb.store.record_by_identifier("regress-alert-1").unwrap();
        assert_eq!(rec.meta["state"], "resolved");
    }

    #[test]
    fn failed_jobs_counted() {
        let mut cb = CbSystem::new();
        let j = PreparedJob {
            ci: CiJob::new("bad", "benchmark").var("HOST", "icx36"),
            payload: Box::new(|_n, _t| JobOutcome {
                duration: 1.0,
                stdout: "METRIC x=1\n".into(),
                exit_code: 1,
            }),
        };
        let r = cb.execute_pipeline(&event(), false, vec![j], "m").unwrap();
        assert_eq!(r.jobs_failed, 1);
        assert_eq!(r.jobs_completed, 0);
    }
}
