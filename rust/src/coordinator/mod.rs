//! The continuous-benchmarking coordinator: the paper's system
//! contribution (§3–§4), wired end to end — and, since the `sched::`
//! refactor, *overlapped*: many pipelines from many repositories share
//! one Testcluster through a single event-driven scheduler.
//!
//! On every push to a watched repository the coordinator:
//!
//! 1. creates a CI pipeline (GitLab analogue, [`crate::ci`]),
//! 2. instantiates the benchmark job matrix — node × compiler × solver ×
//!   parallelization for FE2TI, node × collision operator for waLBerla
//!   (>80 jobs per FE2TI pipeline, like the paper),
//! 3. **submit phase** ([`CbSystem::submit_pipeline`]): assembles per-job
//!   batch scripts (Listing 1) and queues them on the event-driven
//!   [`crate::sched::SimScheduler`], tagged with the pipeline id (batch),
//!   the repository (fair-share owner) and a priority — jobs of *other*
//!   in-flight pipelines interleave on the same nodes as simulated time
//!   advances,
//! 4. **collect phase** ([`CbSystem::collect_pipeline`]): consumes the
//!   pipeline's completion events, parses each job's output (likwid-style
//!   counters), uploads metric points to the *sharded* TSDB (fields)
//!   tagged with the run parameters + repository (tags) and the pipeline
//!   trigger time (timestamp), archives raw artifacts as linked records
//!   in the Kadi4Mat-like store (one collection per pipeline execution,
//!   Fig. 5), and runs the statistical regression check **incrementally**
//!   (the carried [`crate::regress::DetectorState`] ingests just the
//!   points this pipeline appended instead of re-querying the tail
//!   window; `--detect requery` restores the re-query A/B reference) —
//!   upload + detection are serialized per pipeline, which keeps alert
//!   bookkeeping and TSDB ordering deterministic even when execution
//!   overlapped,
//! 5. refreshes the Grafana-like dashboards and the roofline plots.
//!
//! **Streaming collection.** Collection is decoupled from draining the
//! cluster: a caller can collect a pipeline the instant its last job
//! finished — [`CbSystem::pipeline_done`] polled between scheduler
//! epochs ([`crate::sched::SimScheduler::step_epoch`]) is the hook, and
//! the campaign driver does exactly that by default — so results flow
//! into the TSDB and the detector *while the cluster is still busy*.
//! [`PipelineReport`] records the full latency picture (`submitted_at` →
//! `first_result_at` → `finished_at` → `collected_at`), and a detection
//! that opens alerts stamps them with the **alert SLA**: the simulated
//! cluster-time from the offending push entering the system to its alert
//! opening ([`crate::regress::Alert::sla_secs`]). Streaming collect
//! bounds that SLA by one pipeline's duration; batch collection pays the
//! whole roster's makespan.
//!
//! [`CbSystem::execute_pipeline`] remains as the submit-then-collect
//! shim (the old synchronous single-pipeline call); the multi-repo
//! campaign driver ([`campaign::run_campaign`]) keeps several pipelines
//! in flight at once and collects them in completion order, streaming
//! by default ([`campaign::CampaignConfig::streaming`]).
//!
//! Build *and detection* configuration live in the repository tree
//! (`benchmark.cfg`), so commits change both measured performance (the
//! Fig. 10b BLAS-fix story) and how suspicious their own pipelines are
//! (`regress.<policy>.<knob>` overrides, [`detector_with_config`]).

pub mod campaign;
pub mod core;
pub mod fe2ti_pipeline;
pub mod scaling_pipeline;
pub mod walberla_pipeline;

pub use self::core::{CoreHandle, IngestDetectOutcome};

use crate::ci::{CiJob, Pipeline, PipelineFactory, Runner};
use crate::cluster::machinestate::machine_state;
use crate::cluster::nodes::catalogue;
use crate::datastore::{DataStore, Id};
use crate::obs::metrics as om;
use crate::obs::trace::TraceRecorder;
use crate::regress::{Detector, Direction, IngestSummary, Policy};
use crate::sched::{JobState, Payload, SimScheduler, SubmitSpec};
use crate::select::{SelectMode, Selector, StoredRun, Touched};
use crate::slurm::JobSpec;
use crate::tsdb::{Db, Point};
use crate::vcs::{PushEvent, Repository};
use std::collections::BTreeMap;

/// Repository-side benchmark configuration (parsed from `benchmark.cfg`
/// in the commit tree). Line format: `key = value`.
#[derive(Debug, Clone, Default)]
pub struct BenchConfig {
    pub entries: BTreeMap<String, String>,
}

impl BenchConfig {
    pub fn parse(text: &str) -> BenchConfig {
        let mut entries = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                entries.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        BenchConfig { entries }
    }
    pub fn from_commit(repo: &Repository, commit_id: &str) -> BenchConfig {
        repo.get(commit_id)
            .and_then(|c| c.tree.get("benchmark.cfg"))
            .map(|t| BenchConfig::parse(t))
            .unwrap_or_default()
    }
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(|s| s.as_str())
    }
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Per-policy detection overrides from a commit's `benchmark.cfg`
/// (ROADMAP: "thresholds/windows in-repo, so commits can tune their own
/// detection"). Recognized keys, all optional, per policy name:
///
/// ```text
/// regress.<policy>.min_rel_change  = 0.12
/// regress.<policy>.alpha           = 0.01
/// regress.<policy>.min_confidence  = 0.6
/// regress.<policy>.baseline_window = 6
/// regress.<policy>.recent_window   = 2
/// regress.<policy>.changepoint     = false
/// regress.<policy>.direction       = lower-is-better
/// ```
///
/// Returns a detector cloned from `base` with the overrides applied —
/// the base stays pristine, so the next commit without overrides reverts
/// to stock sensitivity.
pub fn detector_with_config(base: &Detector, cfg: &BenchConfig) -> Detector {
    let mut det = base.clone();
    for p in &mut det.policies {
        let name = p.name.clone();
        let key = move |knob: &str| format!("regress.{name}.{knob}");
        if let Some(v) = cfg.get(&key("min_rel_change")).and_then(|s| s.parse::<f64>().ok()) {
            p.min_rel_change = v;
        }
        if let Some(v) = cfg.get(&key("alpha")).and_then(|s| s.parse::<f64>().ok()) {
            p.alpha = v;
        }
        if let Some(v) = cfg.get(&key("min_confidence")).and_then(|s| s.parse::<f64>().ok()) {
            p.min_confidence = v;
        }
        if let Some(v) = cfg.get(&key("baseline_window")).and_then(|s| s.parse::<usize>().ok()) {
            p.baseline_window = v.max(1);
        }
        if let Some(v) = cfg.get(&key("recent_window")).and_then(|s| s.parse::<usize>().ok()) {
            p.recent_window = v.max(1);
        }
        if let Some(v) = cfg.get(&key("changepoint")) {
            p.use_changepoint = matches!(v, "true" | "on" | "1");
        }
        if let Some(d) = cfg.get(&key("direction")).and_then(Direction::from_name) {
            p.direction = d;
        }
    }
    det
}

/// One executed benchmark job's parsed metrics.
#[derive(Debug, Clone)]
pub struct JobMetrics {
    pub job_name: String,
    pub node: String,
    /// Tag → value.
    pub tags: BTreeMap<String, String>,
    /// Field → value.
    pub fields: BTreeMap<String, f64>,
    pub raw_log: String,
}

/// Parse `METRIC key=value` and `TAG key=value` lines from a job log —
/// the §4.3 "collected and parsed" step.
pub fn parse_job_output(job_name: &str, node: &str, log: &str) -> JobMetrics {
    let mut tags = BTreeMap::new();
    let mut fields = BTreeMap::new();
    for line in log.lines() {
        if let Some(rest) = line.strip_prefix("METRIC ") {
            if let Some((k, v)) = rest.split_once('=') {
                if let Ok(v) = v.trim().parse::<f64>() {
                    fields.insert(k.trim().to_string(), v);
                }
            }
        } else if let Some(rest) = line.strip_prefix("TAG ") {
            if let Some((k, v)) = rest.split_once('=') {
                tags.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
    }
    JobMetrics {
        job_name: job_name.to_string(),
        node: node.to_string(),
        tags,
        fields,
        raw_log: log.to_string(),
    }
}

/// A job ready for submission: CI spec + the closure that runs it.
pub struct PreparedJob {
    pub ci: CiJob,
    pub payload: Payload,
}

/// Summary of one pipeline execution.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub pipeline_id: u64,
    /// Repository the pipeline ran for (the fair-share owner).
    pub repo: String,
    pub commit_id: String,
    pub jobs_total: usize,
    pub jobs_completed: usize,
    pub jobs_failed: usize,
    /// Jobs the scheduler backfilled into a maintenance-window gap ahead
    /// of a blocked higher-priority job (0 on an undrained cluster).
    pub jobs_backfilled: usize,
    pub points_uploaded: usize,
    pub records_created: usize,
    pub collection: Id,
    /// TSDB timestamp (ns) this pipeline's points were uploaded under.
    pub trigger_ts: i64,
    /// Simulated wall time from submission to the last job's completion —
    /// under overlap this includes time spent interleaved with other
    /// pipelines' jobs.
    pub duration: f64,
    /// What this pipeline would have taken alone on an idle cluster: the
    /// heaviest per-node sum of its own job runtimes. The back-to-back
    /// sequential baseline of a campaign is the sum of these.
    pub standalone_duration: f64,
    /// Simulated time the pipeline's jobs were submitted.
    pub submitted_at: f64,
    /// Simulated time the pipeline's *first* job started running — the
    /// end of its queue wait (equals `submitted_at` when nothing ran,
    /// e.g. every job failed validation-side before starting).
    pub first_started_at: f64,
    /// Simulated time the pipeline's *first* job finished — the earliest
    /// instant any of its results existed on the cluster.
    pub first_result_at: f64,
    /// Simulated time the pipeline's last job finished.
    pub finished_at: f64,
    /// Simulated time the results were parsed/uploaded/detected. Under
    /// streaming collect this is the pipeline's own completion instant;
    /// under batch collect it is wherever the clock stood when the caller
    /// got around to collecting (for a campaign: the roster's makespan).
    pub collected_at: f64,
    /// Outcome of the post-upload regression check (alerts opened /
    /// re-confirmed / auto-resolved by this execution).
    pub regressions: IngestSummary,
    /// Alert SLA of this execution: simulated seconds from submission to
    /// the detection that opened alerts (`Some` iff any alert opened).
    pub alert_sla: Option<f64>,
    /// Jobs change-aware selection skipped (0 under `--select full`).
    /// `jobs_total` counts the full matrix, so
    /// `jobs_total - jobs_skipped` jobs actually ran.
    pub jobs_skipped: usize,
    /// Carried-forward points synthesized for the skipped jobs.
    pub points_carried: usize,
    /// Cluster-seconds the skipped jobs would have occupied (sum of their
    /// last measured durations).
    pub saved_cluster_s: f64,
    /// Estimated standalone-makespan seconds saved: heaviest per-node
    /// load including the skipped jobs minus the actual one.
    pub saved_makespan_s: f64,
}

impl PipelineReport {
    /// Cluster-time from submission to the first result existing.
    pub fn first_result_latency(&self) -> f64 {
        (self.first_result_at - self.submitted_at).max(0.0)
    }
    /// Cluster-time from submission to upload + detection having run.
    pub fn collect_latency(&self) -> f64 {
        (self.collected_at - self.submitted_at).max(0.0)
    }
}

/// A pipeline whose jobs are on the scheduler but whose results have not
/// been collected yet (between the submit and collect phases).
pub struct PendingPipeline {
    pub pipeline_id: u64,
    pub event: PushEvent,
    pub via_trigger_api: bool,
    pub measurement: String,
    pub trigger_ts: i64,
    pub submitted_at: f64,
    /// (scheduler job id, CI job spec) per submitted job.
    pub jobs: Vec<(u64, CiJob)>,
    /// Jobs change-aware selection skipped, with the stored run each one
    /// carries forward (snapshotted at submit so the decision and its
    /// data are consistent even when pipelines overlap).
    pub skipped: Vec<(CiJob, StoredRun)>,
}

/// Everything the serial **gather** phase of a collect read off the
/// scheduler, snapshotted so the parse phase can run on a background
/// thread while the scheduler keeps advancing (overlapped campaign
/// collects) and the commit phase can replay byte-identically later.
/// Self-contained and `Send`; the one cluster-time stamp the commit
/// phase needs (`collected_at`) is captured at gather time — see
/// [`CbSystem::gather_collect`].
pub(crate) struct CollectInputs {
    pending: PendingPipeline,
    /// Per job, in submit order: (ci name, node host, terminal state,
    /// log, run duration).
    gathered: Vec<(String, String, JobState, String, f64)>,
    completed: usize,
    failed: usize,
    backfilled: usize,
    last_end: f64,
    first_end: f64,
    first_start: f64,
    node_load: BTreeMap<String, f64>,
    /// Scheduler clock at gather time — the pipeline's collect instant.
    collected_at: f64,
}

impl CollectInputs {
    /// The pipeline this gather belongs to (campaign bookkeeping).
    pub(crate) fn pipeline_id(&self) -> u64 {
        self.pending.pipeline_id
    }
}

/// The whole CB installation.
pub struct CbSystem {
    /// The shared event-driven scheduler all pipelines interleave on.
    pub scheduler: SimScheduler,
    /// The continuous-benchmarking core (TSDB + detector + carried
    /// incremental state + alert book) — the part `serve::` shares, one
    /// per project. `CbSystem` derefs to it, so `cb.db`, `cb.alerts`,
    /// `cb.detector` and `cb.det_state` keep working everywhere.
    pub core: CoreHandle,
    pub store: DataStore,
    pub runner: Runner,
    pub pipelines: PipelineFactory,
    pub executed: Vec<PipelineReport>,
    /// Pipelines submitted but not yet collected.
    in_flight: Vec<PendingPipeline>,
    root_collection: Id,
    /// Collection grouping the archived regression alerts (lazy).
    alerts_collection: Option<Id>,
    /// Simulated "trigger time" counter: advances per pipeline (ns).
    trigger_clock: i64,
    /// Cluster-time span recorder fed by every collect (see
    /// [`crate::obs::trace`]). Driven entirely by scheduler-clock values,
    /// so replays are byte-identical; `cbench trace` renders/export it.
    pub trace: TraceRecorder,
    /// When on, each collect uploads its own throughput deltas (line
    /// parse, TSDB insert, detector sync, …) to the TSDB as the
    /// `cbench_self` measurement, so the stock `self-throughput` policy
    /// watches the infrastructure like any benchmark. Off by default:
    /// self-metrics carry *host*-time rates, which would make otherwise
    /// deterministic runs emit machine-dependent points.
    self_metrics: bool,
    /// Divisor applied to uploaded self-metric rates — a CI fault
    /// injector (`--self-slowdown 100` makes the infra look 100× slower
    /// so the alerting path can be exercised end to end).
    self_slowdown: f64,
    /// Counter snapshot at the previous upload (delta basis).
    last_self: [u64; om::N_COUNTERS],
    /// Alerts the `cbench_self` detection opened (CI assertion hook).
    self_alerts_opened: usize,
    /// Benchmark-selection mode: `Full` reruns the whole matrix per push
    /// (pre-PR-9 behaviour); `ChangeAware` skips jobs whose declared
    /// components the push cannot affect and carries their results
    /// forward (`carried=1` points).
    select_mode: SelectMode,
    /// Per-(repo, job) memory of last measured runs for carry-forward.
    selector: Selector,
}

impl Default for CbSystem {
    fn default() -> Self {
        Self::new()
    }
}

/// `CbSystem` reads as its core at every field-access site: `cb.db`,
/// `cb.alerts`, `cb.det_state`, `cb.detector` resolve through this pair.
/// (Method calls that need *disjoint* mut/shared core borrows go through
/// `cb.core.…` explicitly — Deref borrows the whole system.)
impl std::ops::Deref for CbSystem {
    type Target = CoreHandle;
    fn deref(&self) -> &CoreHandle {
        &self.core
    }
}
impl std::ops::DerefMut for CbSystem {
    fn deref_mut(&mut self) -> &mut CoreHandle {
        &mut self.core
    }
}

impl CbSystem {
    pub fn new() -> CbSystem {
        let mut store = DataStore::new();
        let root_collection = store.create_collection("cb-project", "CB project-level collection");
        CbSystem {
            scheduler: SimScheduler::new(
                catalogue().into_iter().filter(|n| n.testcluster).collect(),
            ),
            core: CoreHandle::new(),
            store,
            runner: Runner::hpc(),
            pipelines: PipelineFactory::new(),
            executed: Vec::new(),
            in_flight: Vec::new(),
            root_collection,
            alerts_collection: None,
            trigger_clock: 0,
            trace: TraceRecorder::new(),
            self_metrics: false,
            self_slowdown: 1.0,
            last_self: [0; om::N_COUNTERS],
            self_alerts_opened: 0,
            select_mode: SelectMode::Full,
            selector: Selector::new(),
        }
    }

    /// Set the benchmark-selection mode (`--select change-aware|full`).
    pub fn set_select_mode(&mut self, mode: SelectMode) {
        self.select_mode = mode;
    }
    pub fn select_mode(&self) -> SelectMode {
        self.select_mode
    }
    /// The carry-forward memory (read-only; tests inspect it).
    pub fn selector(&self) -> &Selector {
        &self.selector
    }

    /// Enable uploading the coordinator's own throughput as the
    /// `cbench_self` measurement after every collect. Also turns the
    /// global [`crate::obs::metrics`] recording on — the deltas have to
    /// be measured to be uploaded.
    pub fn set_self_metrics(&mut self, on: bool) {
        self.self_metrics = on;
        if on {
            om::set_enabled(true);
            self.last_self = om::counters();
        }
    }
    pub fn self_metrics(&self) -> bool {
        self.self_metrics
    }

    /// Fault injector: divide uploaded self-metric rates by `factor`
    /// (CI uses 100.0 to prove an infra slowdown opens an alert).
    pub fn set_self_slowdown(&mut self, factor: f64) {
        self.self_slowdown = if factor > 0.0 { factor } else { 1.0 };
    }

    /// Alerts opened by `cbench_self` detections so far.
    pub fn self_alerts_opened(&self) -> usize {
        self.self_alerts_opened
    }

    /// Adopt an existing TSDB (e.g. reloaded from the store a previous
    /// `cbench pipeline` run saved) and fast-forward the trigger clock
    /// past its newest point, so this run's pipelines append strictly
    /// increasing timestamps to the carried-over history instead of
    /// overwriting it. Reads only shard metadata — a lazily-loaded
    /// manifest store stays unmaterialized. Carried detector state is
    /// validated against the adopted database at the next check (its
    /// watermarks trigger a bounded rebuild on mismatch).
    pub fn adopt_db(&mut self, db: Db) {
        let max_ts = db.newest_ts().unwrap_or(0);
        self.core.db = db;
        self.trigger_clock = self.trigger_clock.max(max_ts);
    }

    /// Run the regression detector for `measurement` against the current
    /// TSDB, fold the findings into the alert book, and archive any newly
    /// opened alerts as datastore records linked to `collection` (the
    /// pipeline execution that surfaced them). Called by
    /// [`CbSystem::collect_pipeline`] after every upload.
    ///
    /// `owner_repo` scopes the check to that repository's series (for
    /// policies grouped by `repo`): on a shared Testcluster a commit's
    /// tuned `regress.*` config judges only its own repo, and co-tenant
    /// trigger timestamps don't shrink its detection window.
    pub fn check_regressions(
        &mut self,
        measurement: &str,
        collection: Id,
        owner_repo: Option<&str>,
    ) -> IngestSummary {
        // detection + alert-book folding live on the shared core (the
        // serve:: facade runs the identical code per project)
        let now = self.trigger_clock;
        let summary = self.core.detect_and_ingest(measurement, owner_repo, now);
        // attribute exactly the alerts this execution opened to its
        // collection (the Fig. 5 provenance link)
        for id in &summary.opened_ids {
            if let Some(a) = self.core.alerts.get_mut(*id) {
                a.pipeline_collection = Some(collection);
            }
        }
        if summary.opened > 0 || summary.auto_resolved > 0 {
            let coll = match self.alerts_collection {
                Some(c) => c,
                None => {
                    let c = self
                        .store
                        .create_collection("regression-alerts", "regression alert archive");
                    self.store.add_child_collection(self.root_collection, c).ok();
                    self.alerts_collection = Some(c);
                    c
                }
            };
            self.core.alerts.archive(&mut self.store, coll);
        }
        summary
    }

    /// **Submit phase**: validate and queue a pipeline's jobs on the
    /// shared event scheduler without waiting for them. Jobs carry the
    /// pipeline id as their batch, the repository as their fair-share
    /// owner, and `priority` for inter-repository precedence. Returns the
    /// pipeline id to pass to [`CbSystem::collect_pipeline`].
    pub fn submit_pipeline(
        &mut self,
        event: &PushEvent,
        via_trigger_api: bool,
        jobs: Vec<PreparedJob>,
        measurement: &str,
        priority: i64,
    ) -> anyhow::Result<u64> {
        self.trigger_clock += 1_000_000_000; // pipelines 1 s apart
        let trigger_ts = self.trigger_clock;

        // validate the whole matrix before anything is queued: a rejected
        // job must not leave half a pipeline on the cluster
        for j in &jobs {
            anyhow::ensure!(
                self.runner.accepts(&j.ci),
                "no runner serves job `{}` tags {:?}",
                j.ci.name,
                j.ci.tags
            );
            let host = j
                .ci
                .get("HOST")
                .ok_or_else(|| anyhow::anyhow!("job `{}` missing HOST", j.ci.name))?;
            anyhow::ensure!(
                self.scheduler.has_node(host),
                "sbatch: invalid nodelist `{host}` (unknown host)"
            );
        }

        let ci_jobs: Vec<CiJob> = jobs.iter().map(|j| j.ci.clone()).collect();
        let pipeline: Pipeline = self.pipelines.create(event.clone(), via_trigger_api, ci_jobs);
        let submitted_at = self.scheduler.now();

        // change-aware selection: a job is skipped when it declares the
        // components it measures, the push cannot affect any of them, and
        // a previous measured run exists to carry forward. Full mode (and
        // pushes with unknown surface) runs everything.
        let touched = match self.select_mode {
            SelectMode::Full => Touched::All,
            SelectMode::ChangeAware => crate::select::touched(&event.changed),
        };
        let mut submitted = Vec::with_capacity(jobs.len());
        let mut skipped = Vec::new();
        for j in jobs {
            if self.selector.can_skip(&event.repo, &j.ci, &touched) {
                let run = self
                    .selector
                    .last(&event.repo, &j.ci.name)
                    .expect("can_skip checked presence")
                    .clone();
                skipped.push((j.ci, run));
                continue;
            }
            let host = j.ci.get("HOST").expect("validated above").to_string();
            let spec = SubmitSpec::new(&j.ci.name, &host)
                .timelimit(j.ci.timelimit_min())
                .priority(priority)
                .owner(&event.repo)
                .batch(pipeline.id);
            let id = self
                .scheduler
                .submit(spec, j.payload)
                .map_err(|e| anyhow::anyhow!(e))?;
            submitted.push((id, j.ci));
        }
        self.in_flight.push(PendingPipeline {
            pipeline_id: pipeline.id,
            event: event.clone(),
            via_trigger_api,
            measurement: measurement.to_string(),
            trigger_ts,
            submitted_at,
            jobs: submitted,
            skipped,
        });
        Ok(pipeline.id)
    }

    /// Pipelines submitted but not yet collected.
    pub fn in_flight(&self) -> &[PendingPipeline] {
        &self.in_flight
    }

    /// Simulated time an in-flight pipeline's last job finished (its jobs
    /// may still be queued/running: unfinished jobs don't count). `None`
    /// for ids that are not in flight.
    pub fn pipeline_finished_at(&self, pipeline_id: u64) -> Option<f64> {
        self.in_flight
            .iter()
            .find(|p| p.pipeline_id == pipeline_id)
            .map(|p| {
                p.jobs
                    .iter()
                    .filter_map(|(id, _)| self.scheduler.job(*id).and_then(|j| j.end_time))
                    .fold(p.submitted_at, f64::max)
            })
    }

    /// True when every job of an in-flight pipeline reached a terminal
    /// state — its results can be collected without advancing the clock.
    /// The streaming-collect loop polls this between scheduler epochs.
    /// `false` for ids that are not in flight.
    pub fn pipeline_done(&self, pipeline_id: u64) -> bool {
        self.in_flight
            .iter()
            .find(|p| p.pipeline_id == pipeline_id)
            .map(|p| {
                p.jobs.iter().all(|(id, _)| {
                    self.scheduler
                        .job(*id)
                        .map(|j| j.state.is_terminal())
                        .unwrap_or(true)
                })
            })
            .unwrap_or(false)
    }

    /// **Collect phase**: advance the shared scheduler until every job of
    /// this pipeline completed (other pipelines' events are processed as
    /// simulated time passes them), then parse, upload, archive and run
    /// the regression check. Upload + detection are serialized per
    /// pipeline — callers collecting several overlapped pipelines do so
    /// one at a time, in any order.
    pub fn collect_pipeline(&mut self, pipeline_id: u64) -> anyhow::Result<PipelineReport> {
        let inputs = self.gather_collect(pipeline_id)?;
        let parsed = Self::parse_collect(&inputs, true);
        self.commit_collect(inputs, parsed)
    }

    /// **Gather** (serial, scheduler-side): drain the pipeline's jobs to
    /// terminal state, snapshot everything the later phases need off the
    /// scheduler, and capture the collect instant. The returned value is
    /// self-contained (`Send`): the campaign driver hands it to a
    /// background parse while the scheduler keeps advancing epochs.
    pub(crate) fn gather_collect(&mut self, pipeline_id: u64) -> anyhow::Result<CollectInputs> {
        let pos = self
            .in_flight
            .iter()
            .position(|p| p.pipeline_id == pipeline_id)
            .ok_or_else(|| anyhow::anyhow!("pipeline #{pipeline_id} is not in flight"))?;
        let pending = self.in_flight.remove(pos);
        let ids: Vec<u64> = pending.jobs.iter().map(|(id, _)| *id).collect();
        self.scheduler.run_until_done(&ids);
        // the collect instant, captured exactly once: an overlapped
        // campaign commits this pipeline after the scheduler has moved
        // past this point, and every timestamp the commit stamps (report,
        // SLA, trace, machinestate) must be the gather-time clock for the
        // output to stay byte-identical to a serial collect
        let collected_at = self.scheduler.now();

        let mut completed = 0;
        let mut failed = 0;
        let mut backfilled = 0;
        let mut last_end = pending.submitted_at;
        let mut first_end = f64::INFINITY;
        let mut first_start = f64::INFINITY;
        let mut node_load: BTreeMap<String, f64> = BTreeMap::new();
        // read terminal job state off the scheduler and fold the
        // latency/load accounting, in job order
        let mut gathered: Vec<(String, String, JobState, String, f64)> =
            Vec::with_capacity(pending.jobs.len());
        for (sched_id, ci) in &pending.jobs {
            let job = self.scheduler.job(*sched_id).expect("job exists");
            let state = job.state;
            let log = job.log.clone();
            let node_host = job.spec.nodelist.clone();
            if job.backfilled {
                backfilled += 1;
            }
            let mut run_dur = 0.0;
            if let (Some(start), Some(end)) = (job.start_time, job.end_time) {
                last_end = last_end.max(end);
                first_end = first_end.min(end);
                first_start = first_start.min(start);
                run_dur = end - start;
                *node_load.entry(node_host.clone()).or_insert(0.0) += end - start;
            }
            if state == JobState::Completed {
                completed += 1;
            } else {
                failed += 1;
            }
            gathered.push((ci.name.clone(), node_host, state, log, run_dur));
        }
        Ok(CollectInputs {
            pending,
            gathered,
            completed,
            failed,
            backfilled,
            last_end,
            first_end,
            first_start,
            node_load,
            collected_at,
        })
    }

    /// **Parse** (the CPU-heavy middle): parse every job log. Stateless —
    /// no `&self` — so the campaign driver can run it on a background
    /// thread while the scheduler advances. `parallel` fans the logs
    /// across the par pool (the inline single-pipeline path); background
    /// callers pass `false` and stay serial, keeping total parallelism
    /// bounded by the configured thread count. Either way results come
    /// back in job order, so the commit below is byte-identical to the
    /// old serial loop for any thread count.
    pub(crate) fn parse_collect(inputs: &CollectInputs, parallel: bool) -> Vec<JobMetrics> {
        let parse_one = |(name, host, log): (&str, &str, &str)| {
            let jt = om::Timer::start();
            let metrics = parse_job_output(name, host, log);
            om::add(om::Counter::JobsParsed, 1);
            jt.stop(om::TimedOp::JobParse);
            metrics
        };
        let items: Vec<(&str, &str, &str)> = inputs
            .gathered
            .iter()
            .map(|(name, host, _, log, _)| (name.as_str(), host.as_str(), log.as_str()))
            .collect();
        if parallel {
            crate::par::map(items, parse_one)
        } else {
            items.into_iter().map(parse_one).collect()
        }
    }

    /// **Commit** (serial, in collect order): upload, archive, detect,
    /// trace, report. All mutation of shared state happens here — an
    /// overlapped campaign applies commits in (completion, pid) order, so
    /// the TSDB insert order, datastore id sequence, alert book and trace
    /// stay exactly as a serial collect would leave them. Cluster-time
    /// stamps come from `inputs.collected_at` (the gather instant), never
    /// from the scheduler's current clock.
    pub(crate) fn commit_collect(
        &mut self,
        inputs: CollectInputs,
        parsed: Vec<JobMetrics>,
    ) -> anyhow::Result<PipelineReport> {
        let CollectInputs {
            pending,
            gathered,
            completed,
            failed,
            backfilled,
            last_end,
            first_end,
            first_start,
            node_load,
            collected_at,
        } = inputs;
        let event = &pending.event;
        let trigger_ts = pending.trigger_ts;

        // per-execution collection (Fig. 5)
        let coll = self.store.create_collection(
            &format!("pipeline-{}", pending.pipeline_id),
            &format!(
                "{} pipeline #{} @ {}",
                event.repo,
                pending.pipeline_id,
                &event.commit_id[..8.min(event.commit_id.len())]
            ),
        );
        self.store
            .add_child_collection(self.root_collection, coll)
            .ok();

        let mut points = 0;
        let mut records = 0;
        // --- upload + archive (job order): the TSDB insert order and
        // record/link ids stay exactly as before ---
        let commit8 = event.commit_id[..8.min(event.commit_id.len())].to_string();
        let mut measured_runs: Vec<(String, StoredRun)> = Vec::new();
        for ((name, node_host, state, log, run_dur), metrics) in gathered.iter().zip(parsed) {
            let node = self.scheduler.node(node_host).unwrap().clone();
            if !metrics.fields.is_empty() {
                let mut p = Point::new(&pending.measurement, trigger_ts);
                p.tags.insert("node".into(), node_host.clone());
                p.tags.insert("commit".into(), commit8.clone());
                p.tags.insert("repo".into(), event.repo.clone());
                p.tags.insert("branch".into(), event.branch.clone());
                for (k, v) in &metrics.tags {
                    p.tags.insert(k.clone(), v.clone());
                }
                for (k, v) in &metrics.fields {
                    p.fields.insert(k.clone(), *v);
                }
                if *state == JobState::Completed {
                    // remember this measured run so change-aware selection
                    // can carry it forward for later unaffected pushes
                    measured_runs.push((
                        name.clone(),
                        StoredRun {
                            points: vec![p.clone()],
                            duration: *run_dur,
                            commit: commit8.clone(),
                        },
                    ));
                }
                self.core.db.insert(p);
                points += 1;
            }

            // --- archive records: job log + likwid + machinestate ---
            let rid_job = self
                .store
                .create_record(
                    &format!("p{}-job-{}", pending.pipeline_id, name),
                    &format!("job log {name}"),
                    "job-log",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            self.store.attach_file(rid_job, "slurm.log", log).ok();
            self.store.set_meta(rid_job, "node", node_host).ok();
            self.store.set_meta(rid_job, "state", &format!("{state:?}")).ok();
            let rid_perf = self
                .store
                .create_record(
                    &format!("p{}-perf-{}", pending.pipeline_id, name),
                    &format!("likwid output {name}"),
                    "likwid-output",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            self.store.attach_file(rid_perf, "perfctr.txt", &metrics.raw_log).ok();
            let rid_ms = self
                .store
                .create_record(
                    &format!("p{}-ms-{}", pending.pipeline_id, name),
                    &format!("machinestate {name}"),
                    "machinestate",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            let ms = machine_state(&node, name, collected_at);
            self.store
                .attach_file(rid_ms, "machinestate.json", &ms.to_string_pretty())
                .ok();
            for rid in [rid_job, rid_perf, rid_ms] {
                self.store.add_to_collection(coll, rid).ok();
                records += 1;
            }
            self.store.link(rid_perf, rid_job, "belongs to").ok();
            self.store.link(rid_ms, rid_job, "recorded on").ok();
        }

        for (name, run) in measured_runs {
            self.selector.record(&event.repo, &name, run);
        }

        // --- carried-forward synthesis for skipped jobs: re-upload each
        // one's last measured points under this pipeline's trigger
        // timestamp, tagged `carried=1` (+ the commit they were measured
        // at). The detector treats them as non-evidence (they can neither
        // open nor auto-resolve alerts) but they keep the skipped series
        // fresh at the stale-tenant / TAIL_SCAN_SLACK boundary. Runs
        // before the regression check so detection sees the same series
        // shape a full run would have produced. ---
        let mut carried_points = 0usize;
        let mut saved_cluster_s = 0.0;
        let mut skipped_load: BTreeMap<String, f64> = BTreeMap::new();
        for (ci, run) in &pending.skipped {
            saved_cluster_s += run.duration;
            if let Some(host) = ci.get("HOST") {
                *skipped_load.entry(host.to_string()).or_insert(0.0) += run.duration;
            }
            for stored in &run.points {
                let mut p = stored.clone();
                p.ts = trigger_ts;
                p.tags.insert("commit".into(), commit8.clone());
                p.tags.insert("branch".into(), event.branch.clone());
                p.tags
                    .insert(crate::select::CARRIED_TAG.into(), "1".into());
                p.tags
                    .insert(crate::select::CARRIED_FROM_TAG.into(), run.commit.clone());
                self.core.db.insert(p);
                carried_points += 1;
            }

            // archive the carry-forward decision with the same record
            // triple a measured job gets: the archive answers "why is
            // there no fresh log for this job?", and the datastore id
            // sequence stays identical to a full run's — alert archive
            // ids are part of the byte-identical-book contract.
            let note = format!(
                "SKIPPED by change-aware selection: carried forward from commit {}",
                run.commit
            );
            let rid_job = self
                .store
                .create_record(
                    &format!("p{}-job-{}", pending.pipeline_id, ci.name),
                    &format!("job log {} (carried)", ci.name),
                    "job-log",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            self.store.attach_file(rid_job, "slurm.log", &note).ok();
            self.store.set_meta(rid_job, "state", "Skipped").ok();
            self.store.set_meta(rid_job, "carried_from", &run.commit).ok();
            let rid_perf = self
                .store
                .create_record(
                    &format!("p{}-perf-{}", pending.pipeline_id, ci.name),
                    &format!("likwid output {} (carried)", ci.name),
                    "likwid-output",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            self.store.attach_file(rid_perf, "perfctr.txt", &note).ok();
            let rid_ms = self
                .store
                .create_record(
                    &format!("p{}-ms-{}", pending.pipeline_id, ci.name),
                    &format!("machinestate {} (carried)", ci.name),
                    "machinestate",
                )
                .map_err(|e| anyhow::anyhow!(e))?;
            self.store.attach_file(rid_ms, "machinestate.json", &note).ok();
            for rid in [rid_job, rid_perf, rid_ms] {
                self.store.add_to_collection(coll, rid).ok();
                records += 1;
            }
            self.store.link(rid_perf, rid_job, "belongs to").ok();
            self.store.link(rid_ms, rid_job, "recorded on").ok();
        }
        // estimated standalone makespan had the skipped jobs run: the
        // heaviest per-node load including their last measured durations
        let standalone_full = node_load
            .keys()
            .chain(skipped_load.keys())
            .map(|h| {
                node_load.get(h).copied().unwrap_or(0.0)
                    + skipped_load.get(h).copied().unwrap_or(0.0)
            })
            .fold(0.0, f64::max);

        // --- §4.4 closing the loop: statistical regression check,
        // scoped to the triggering repository's series ---
        let regressions =
            self.check_regressions(&pending.measurement, coll, Some(&pending.event.repo));

        // alert SLA: simulated cluster-time from the regressing push
        // entering the system to its alert opening — the latency the
        // streaming collect exists to shrink. The regression *landed*
        // with the pipeline at the alert's located change point
        // (`change_ts` is that pipeline's trigger timestamp), which may
        // be several pipelines before the one whose detection finally
        // opened the alert (e.g. a widened recent window); its submission
        // time is looked up in this process's executed reports, falling
        // back to the current pipeline's submission for change points in
        // carried-over history. Stamped per alert; the report carries the
        // worst SLA of the alerts it opened.
        // Each SLA is decomposed into where the time went — queue wait,
        // run, collect latency, detect lag (the remainder: cluster-time
        // between the offender's own collect and the later detection that
        // finally opened the alert) — components that sum to `sla_secs`
        // exactly. `cbench regress alerts` prints the breakdown.
        let first_started_at = if first_start.is_finite() {
            first_start
        } else {
            pending.submitted_at
        };
        let mut slas: Vec<(u64, f64, [f64; 4])> =
            Vec::with_capacity(regressions.opened_ids.len());
        for id in &regressions.opened_ids {
            let change_ts = self
                .alerts
                .get(*id)
                .map(|a| a.change_ts)
                .unwrap_or(trigger_ts);
            // the offending pipeline's own latency picture (fall back to
            // the current pipeline for change points in carried-over
            // history)
            let (landed_at, o_started, o_finished, o_collected) = self
                .executed
                .iter()
                .rev()
                .find(|r| r.trigger_ts == change_ts)
                .map(|r| (r.submitted_at, r.first_started_at, r.finished_at, r.collected_at))
                .unwrap_or((pending.submitted_at, first_started_at, last_end, collected_at));
            let sla = (collected_at - landed_at).max(0.0);
            let queue = o_started - landed_at;
            let run = o_finished - o_started;
            let collect = o_collected - o_finished;
            let detect = sla - queue - run - collect;
            slas.push((*id, sla, [queue, run, collect, detect]));
        }
        let alert_sla = slas
            .iter()
            .map(|&(_, s, _)| s)
            .fold(None, |acc: Option<f64>, s| Some(acc.map_or(s, |a| a.max(s))));
        for (id, s, [queue, run, collect, detect]) in slas {
            if let Some(a) = self.core.alerts.get_mut(id) {
                a.sla_secs = Some(s);
                a.sla_queue_secs = Some(queue);
                a.sla_run_secs = Some(run);
                a.sla_collect_secs = Some(collect);
                a.sla_detect_secs = Some(detect);
            }
        }

        // --- self-observability: upload this collect's own throughput
        // deltas as `cbench_self` and let the stock detector judge them ---
        if self.self_metrics {
            self.upload_self_metrics(trigger_ts, &commit8, coll);
        }

        // --- cluster-time trace: one span tree per collect, driven
        // entirely by scheduler timestamps, so replays of the same
        // roster are byte-identical (`cbench trace`) ---
        if self.trace.is_enabled() {
            let root = self.trace.root();
            let pname = format!("p{} {} @{}", pending.pipeline_id, event.repo, commit8);
            let pspan = self.trace.span_m(
                root,
                "pipeline",
                &pname,
                &event.repo,
                "",
                pending.submitted_at,
                collected_at,
                &[("commit", &commit8), ("trigger_ts", &trigger_ts.to_string())],
            );
            for (seq, (sched_id, ci)) in pending.jobs.iter().enumerate() {
                // copy the cluster-time facts out of the scheduler before
                // recording (disjoint borrows of self)
                let (start, end, node_host, was_backfilled) = {
                    let job = self.scheduler.job(*sched_id).expect("job exists");
                    (job.start_time, job.end_time, job.spec.nodelist.clone(), job.backfilled)
                };
                let (Some(start), Some(end)) = (start, end) else { continue };
                let jname = format!("p{}/j{}/{}", pending.pipeline_id, seq, ci.name);
                let jspan = self.trace.span(
                    pspan,
                    "job",
                    &jname,
                    &event.repo,
                    &node_host,
                    pending.submitted_at,
                    end,
                );
                if start > pending.submitted_at {
                    self.trace.span(
                        jspan,
                        "queue",
                        &format!("{jname}/queue"),
                        &event.repo,
                        &node_host,
                        pending.submitted_at,
                        start,
                    );
                }
                self.trace.span_m(
                    jspan,
                    "run",
                    &format!("{jname}/run"),
                    &event.repo,
                    &node_host,
                    start,
                    end,
                    &[
                        // shortest-roundtrip text: the critical-path walk
                        // reparses it to the bit-identical f64
                        ("submit", &format!("{:?}", pending.submitted_at)),
                        ("backfilled", if was_backfilled { "true" } else { "false" }),
                    ],
                );
            }
            if collected_at > last_end {
                self.trace.span(
                    pspan,
                    "collect",
                    &format!("p{}/collect", pending.pipeline_id),
                    &event.repo,
                    "",
                    last_end,
                    collected_at,
                );
            }
            self.trace.span(
                pspan,
                "detect",
                &format!("p{}/detect", pending.pipeline_id),
                &event.repo,
                "",
                collected_at,
                collected_at,
            );
            for id in &regressions.opened_ids {
                self.trace.span(
                    pspan,
                    "alert-open",
                    &format!("alert#{id}"),
                    &event.repo,
                    "",
                    collected_at,
                    collected_at,
                );
            }
        }

        let standalone_duration = node_load.values().copied().fold(0.0, f64::max);
        let report = PipelineReport {
            pipeline_id: pending.pipeline_id,
            repo: event.repo.clone(),
            commit_id: event.commit_id.clone(),
            jobs_total: pending.jobs.len() + pending.skipped.len(),
            jobs_completed: completed,
            jobs_failed: failed,
            jobs_backfilled: backfilled,
            points_uploaded: points,
            records_created: records,
            collection: coll,
            trigger_ts,
            duration: (last_end - pending.submitted_at).max(0.0),
            standalone_duration,
            submitted_at: pending.submitted_at,
            first_started_at,
            first_result_at: if first_end.is_finite() { first_end } else { pending.submitted_at },
            finished_at: last_end,
            collected_at,
            regressions,
            alert_sla,
            jobs_skipped: pending.skipped.len(),
            points_carried: carried_points,
            saved_cluster_s,
            saved_makespan_s: (standalone_full - standalone_duration).max(0.0),
        };
        self.executed.push(report.clone());
        Ok(report)
    }

    /// Upload the coordinator's own throughput since the previous upload
    /// as `cbench_self` points — one per component, rated ops/second from
    /// *host*-time deltas (see [`crate::obs::metrics`]) — then run the
    /// stock `self-throughput` detection over them: the infrastructure is
    /// watched by the same statistical machinery as the benchmarks it
    /// serves. Components with no activity this collect are skipped.
    fn upload_self_metrics(&mut self, trigger_ts: i64, commit8: &str, coll: Id) {
        let now = om::counters();
        let prev = self.last_self;
        self.last_self = now;
        let d = |c: om::Counter| now[c.idx()].saturating_sub(prev[c.idx()]);
        let components: [(&str, u64, u64); 5] = [
            ("lp_parse", d(om::Counter::LpLines), d(om::Counter::LpParseNs)),
            ("tsdb_insert", d(om::Counter::InsertPoints), d(om::Counter::InsertNs)),
            ("job_parse", d(om::Counter::JobsParsed), d(om::Counter::JobParseNs)),
            ("detector_sync", d(om::Counter::SyncPoints), d(om::Counter::SyncNs)),
            (
                "shard_load",
                d(om::Counter::ShardLoadPoints),
                d(om::Counter::ShardLoadNs),
            ),
        ];
        let mut uploaded = false;
        for (comp, ops, ns) in components {
            if ops == 0 || ns == 0 {
                continue;
            }
            let rate = om::rate_per_sec(ops, ns) / self.self_slowdown;
            let mut p = Point::new("cbench_self", trigger_ts);
            p.tags.insert("repo".into(), "cbench".into());
            p.tags.insert("component".into(), comp.into());
            p.tags.insert("commit".into(), commit8.to_string());
            p.fields.insert("points_per_sec".into(), rate);
            p.fields.insert("ops".into(), ops as f64);
            self.core.db.insert(p);
            uploaded = true;
        }
        if uploaded {
            let s = self.check_regressions("cbench_self", coll, Some("cbench"));
            self.self_alerts_opened += s.opened;
        }
    }

    /// Execute a pipeline synchronously: submit, run to completion,
    /// collect. The single-tenant path (and the pre-`sched::` API) —
    /// overlapping callers use [`CbSystem::submit_pipeline`] +
    /// [`CbSystem::collect_pipeline`] directly.
    pub fn execute_pipeline(
        &mut self,
        event: &PushEvent,
        via_trigger_api: bool,
        jobs: Vec<PreparedJob>,
        measurement: &str,
    ) -> anyhow::Result<PipelineReport> {
        let pid = self.submit_pipeline(event, via_trigger_api, jobs, measurement, 0)?;
        self.collect_pipeline(pid)
    }

    /// Current trigger timestamp (ns) of the most recent pipeline.
    pub fn last_trigger_ts(&self) -> i64 {
        self.trigger_clock
    }

    /// Execute a multi-node scaling pipeline on a *production* partition
    /// (Fritz/JUWELS node models, not part of the single-node Testcluster;
    /// paper §7 future work). Jobs run on their own scheduler domain; the
    /// parsed metrics land in the shared TSDB, and one summary record per
    /// campaign is archived.
    pub fn execute_scaling_pipeline(
        &mut self,
        event: &PushEvent,
        host: &str,
        jobs: Vec<PreparedJob>,
        measurement: &str,
    ) -> anyhow::Result<usize> {
        self.trigger_clock += 1_000_000_000;
        let trigger_ts = self.trigger_clock;
        let node = catalogue()
            .into_iter()
            .find(|n| n.host == host && !n.testcluster)
            .ok_or_else(|| anyhow::anyhow!("`{host}` is not a production partition"))?;
        let mut sched = crate::slurm::Scheduler::new(vec![node.clone()]);
        let mut ids = Vec::new();
        for j in jobs {
            let spec = JobSpec {
                name: j.ci.name.clone(),
                nodelist: host.to_string(),
                timelimit_min: j.ci.timelimit_min(),
            };
            ids.push((sched.sbatch(spec, j.payload).map_err(|e| anyhow::anyhow!(e))?, j.ci));
        }
        sched.wait_all();
        let mut points = 0;
        let mut summary = String::new();
        for (id, ci) in &ids {
            let job = sched.job(*id).expect("job exists");
            let metrics = parse_job_output(&ci.name, host, &job.log);
            if !metrics.fields.is_empty() {
                let mut p = Point::new(measurement, trigger_ts);
                p.tags.insert("node".into(), host.to_string());
                p.tags.insert(
                    "commit".into(),
                    event.commit_id[..8.min(event.commit_id.len())].to_string(),
                );
                for (k, v) in &metrics.tags {
                    p.tags.insert(k.clone(), v.clone());
                }
                for (k, v) in &metrics.fields {
                    p.fields.insert(k.clone(), *v);
                }
                self.core.db.insert(p);
                points += 1;
            }
            summary.push_str(&job.log);
            summary.push('\n');
        }
        let rid = self
            .store
            .create_record(
                &format!("scaling-{measurement}-{trigger_ts}"),
                &format!("weak-scaling campaign {measurement} on {host}"),
                "scaling-campaign",
            )
            .map_err(|e| anyhow::anyhow!(e))?;
        self.store.attach_file(rid, "campaign.log", &summary).ok();
        Ok(points)
    }
}

/// A detected performance change between consecutive pipeline executions
/// of one tagged series.
#[derive(Debug, Clone)]
pub struct PerfChange {
    pub series: String,
    pub before: f64,
    pub after: f64,
    /// Relative change of the metric ((after-before)/before).
    pub rel_change: f64,
}

/// Compare the last two points of every grouped series of
/// `measurement.field` and report changes beyond `threshold` (relative).
/// `higher_is_better` controls the sign convention for *regressions*:
/// for MLUP/s a drop is a regression; for TTS a rise is.
///
/// This is CB's raison d'être: "reveals performance degradation introduced
/// by code changes immediately" (paper §7). Since the `regress::`
/// subsystem landed this is a thin shim over
/// [`crate::regress::detector`]: a policy with a 1-point baseline window,
/// no change-point splitting and no statistical gate reproduces the
/// legacy last-vs-previous semantics exactly, while new callers should
/// use [`Detector`] with real windows.
pub fn detect_regressions(
    db: &Db,
    measurement: &str,
    field: &str,
    group_by: &[&str],
    threshold: f64,
    higher_is_better: bool,
) -> Vec<PerfChange> {
    let policy = Policy::new("legacy-last-vs-prev", measurement, field)
        .group_by(group_by)
        .direction(if higher_is_better {
            Direction::HigherIsBetter
        } else {
            Direction::LowerIsBetter
        })
        .windows(1, 1)
        .thresholds(threshold, 1.0, 0.0)
        .changepoint(false)
        // exact legacy semantics: every series' own last two points, even
        // when other tenants' trigger timestamps interleave or the series
        // went stale — so no bounded tail() pushdown here
        .full_history(true);
    crate::regress::detector::evaluate_policy(&policy, db)
        .into_iter()
        .map(|f| PerfChange {
            series: f.series,
            before: f.baseline.mean,
            after: f.current,
            rel_change: f.rel_change,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::JobOutcome;

    fn dummy_job(name: &str, host: &str, metrics: &str) -> PreparedJob {
        let out = metrics.to_string();
        PreparedJob {
            ci: CiJob::new(name, "benchmark").var("HOST", host),
            payload: Box::new(move |_n, _t| JobOutcome {
                duration: 10.0,
                stdout: out,
                exit_code: 0,
            }),
        }
    }

    fn dummy_job_dur(name: &str, host: &str, dur: f64) -> PreparedJob {
        PreparedJob {
            ci: CiJob::new(name, "benchmark").var("HOST", host),
            payload: Box::new(move |_n, _t| JobOutcome {
                duration: dur,
                stdout: format!("METRIC dur={dur}\n"),
                exit_code: 0,
            }),
        }
    }

    fn event() -> PushEvent {
        PushEvent {
            repo: "fe2ti".into(),
            branch: "master".into(),
            commit_id: "abcdef1234567890".into(),
            changed: vec![],
        }
    }

    fn event_for(repo: &str) -> PushEvent {
        PushEvent {
            repo: repo.into(),
            branch: "master".into(),
            commit_id: format!("{repo:0<16}"),
            changed: vec![],
        }
    }

    #[test]
    fn bench_config_parses() {
        let cfg = BenchConfig::parse("# comment\numfpack_blas = blis\nlbm_penalty = 0.15\n");
        assert_eq!(cfg.get("umfpack_blas"), Some("blis"));
        assert_eq!(cfg.get_f64("lbm_penalty", 0.0), 0.15);
        assert_eq!(cfg.get_f64("missing", 1.0), 1.0);
    }

    #[test]
    fn detector_config_overrides_apply_and_revert() {
        let base = Detector::with_default_policies();
        let cfg = BenchConfig::parse(
            "regress.lbm-mlups.min_rel_change = 0.5\n\
             regress.lbm-mlups.baseline_window = 3\n\
             regress.lbm-mlups.changepoint = false\n\
             regress.fe2ti-tts.direction = higher-is-better\n\
             regress.fe2ti-tts.alpha = 0.01\n",
        );
        let det = detector_with_config(&base, &cfg);
        let lbm = det.policies.iter().find(|p| p.name == "lbm-mlups").unwrap();
        assert_eq!(lbm.min_rel_change, 0.5);
        assert_eq!(lbm.baseline_window, 3);
        assert!(!lbm.use_changepoint);
        let tts = det.policies.iter().find(|p| p.name == "fe2ti-tts").unwrap();
        assert_eq!(tts.direction, Direction::HigherIsBetter);
        assert_eq!(tts.alpha, 0.01);
        // the base is untouched: the next commit reverts to stock
        let lbm0 = base.policies.iter().find(|p| p.name == "lbm-mlups").unwrap();
        assert_eq!(lbm0.min_rel_change, 0.08);
        assert!(lbm0.use_changepoint);
        // malformed values are ignored, not zeroed
        let det = detector_with_config(&base, &BenchConfig::parse("regress.lbm-mlups.alpha = abc\n"));
        assert_eq!(det.policies[0].alpha, 0.05);
    }

    #[test]
    fn parse_job_output_extracts_metrics_and_tags() {
        let log = "noise\nMETRIC tts=40.5\nMETRIC gflops=25\nTAG solver=ilu\nother\n";
        let m = parse_job_output("j", "icx36", log);
        assert_eq!(m.fields["tts"], 40.5);
        assert_eq!(m.tags["solver"], "ilu");
        assert_eq!(m.fields.len(), 2);
    }

    #[test]
    fn pipeline_executes_uploads_and_archives() {
        let mut cb = CbSystem::new();
        let jobs = vec![
            dummy_job("bench-icx36", "icx36", "METRIC tts=40\nTAG solver=ilu\n"),
            dummy_job("bench-rome1", "rome1", "METRIC tts=80\nTAG solver=ilu\n"),
        ];
        let r = cb.execute_pipeline(&event(), false, jobs, "fe2ti").unwrap();
        assert_eq!(r.jobs_total, 2);
        assert_eq!(r.jobs_completed, 2);
        assert_eq!(r.points_uploaded, 2);
        assert_eq!(r.records_created, 6); // 3 records per job
        assert_eq!(r.repo, "fe2ti");
        assert_eq!(cb.db.len(), 2);
        // points tagged with commit + node
        let pts: Vec<&Point> = cb.db.points_iter("fe2ti").collect();
        assert_eq!(pts[0].tags["commit"], "abcdef12");
        assert!(cb.store.n_links() >= 4);
    }

    #[test]
    fn submit_collect_phases_overlap_two_pipelines() {
        // two pipelines stressing different nodes, in flight at once:
        // p1 = 3 x 10 s on icx36; p2 = 1 x 25 s on rome1
        let mut cb = CbSystem::new();
        let p1 = cb
            .submit_pipeline(
                &event_for("alpha"),
                false,
                vec![
                    dummy_job_dur("a1", "icx36", 10.0),
                    dummy_job_dur("a2", "icx36", 10.0),
                    dummy_job_dur("a3", "icx36", 10.0),
                ],
                "m",
                0,
            )
            .unwrap();
        let p2 = cb
            .submit_pipeline(
                &event_for("beta"),
                false,
                vec![dummy_job_dur("b1", "rome1", 25.0)],
                "m",
                0,
            )
            .unwrap();
        assert_eq!(cb.in_flight().len(), 2);
        // nothing ran yet: submission does not advance time
        assert_eq!(cb.scheduler.now(), 0.0);

        let r2 = cb.collect_pipeline(p2).unwrap();
        // collecting p2 advanced the shared clock past p2's last job;
        // p1's same-epoch jobs progressed alongside
        assert_eq!(r2.finished_at, 25.0);
        assert_eq!(r2.duration, 25.0);
        assert_eq!(r2.standalone_duration, 25.0);
        let r1 = cb.collect_pipeline(p1).unwrap();
        assert_eq!(r1.finished_at, 30.0);
        assert_eq!(r1.standalone_duration, 30.0);
        assert_eq!(cb.in_flight().len(), 0);
        // overlapped makespan (30) beats back-to-back (55)
        assert!(cb.scheduler.now() < r1.standalone_duration + r2.standalone_duration);
        // both pipelines' points uploaded under their own repo tag
        let repos = cb.db.tag_values("m", "repo");
        assert_eq!(repos, vec!["alpha", "beta"]);
        // collecting twice is an error
        assert!(cb.collect_pipeline(p1).is_err());
    }

    #[test]
    fn pipeline_finished_at_tracks_in_flight_jobs() {
        let mut cb = CbSystem::new();
        let p1 = cb
            .submit_pipeline(
                &event_for("alpha"),
                false,
                vec![dummy_job_dur("a1", "icx36", 10.0)],
                "m",
                0,
            )
            .unwrap();
        assert_eq!(cb.pipeline_finished_at(p1), Some(0.0)); // nothing ran yet
        cb.scheduler.run_until_idle();
        assert_eq!(cb.pipeline_finished_at(p1), Some(10.0));
        cb.collect_pipeline(p1).unwrap();
        assert_eq!(cb.pipeline_finished_at(p1), None); // no longer in flight
    }

    #[test]
    fn successive_pipelines_get_increasing_timestamps() {
        let mut cb = CbSystem::new();
        let r1 = cb
            .execute_pipeline(&event(), false, vec![dummy_job("a", "icx36", "METRIC x=1\n")], "m")
            .unwrap();
        let r2 = cb
            .execute_pipeline(&event(), false, vec![dummy_job("a2", "icx36", "METRIC x=2\n")], "m")
            .unwrap();
        assert!(r2.pipeline_id > r1.pipeline_id);
        let pts: Vec<&Point> = cb.db.points_iter("m").collect();
        assert!(pts[1].ts > pts[0].ts);
    }

    #[test]
    fn job_without_host_rejected() {
        let mut cb = CbSystem::new();
        let j = PreparedJob {
            ci: CiJob::new("nohost", "benchmark"),
            payload: Box::new(|_n, _t| JobOutcome {
                duration: 1.0,
                stdout: String::new(),
                exit_code: 0,
            }),
        };
        assert!(cb.execute_pipeline(&event(), false, vec![j], "m").is_err());
        // validation happens before queueing: nothing is in flight
        assert!(cb.in_flight().is_empty());
    }

    #[test]
    fn regression_detection_flags_drops_only() {
        let mut db = Db::new();
        for (ts, op, v) in [(1, "srt", 1000.0), (2, "srt", 800.0), (1, "trt", 900.0), (2, "trt", 910.0)] {
            db.insert(
                Point::new("lbm", ts)
                    .tag("collision_op", op)
                    .field("mlups", v),
            );
        }
        let regs = detect_regressions(&db, "lbm", "mlups", &["collision_op"], 0.1, true);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].series, "collision_op=srt");
        assert!((regs[0].rel_change + 0.2).abs() < 1e-12);
        // TTS convention: a rise is a regression
        let mut db2 = Db::new();
        db2.insert(Point::new("fe2ti", 1).tag("s", "x").field("tts", 10.0));
        db2.insert(Point::new("fe2ti", 2).tag("s", "x").field("tts", 13.0));
        let regs2 = detect_regressions(&db2, "fe2ti", "tts", &["s"], 0.1, false);
        assert_eq!(regs2.len(), 1);
    }

    #[test]
    fn legacy_shim_sees_interleaved_tenant_series_exactly() {
        // co-tenant trigger timestamps interleave; the legacy shim still
        // compares each series' own last two points (it opts out of the
        // bounded tail() pushdown via Policy::full_history)
        let mut db = Db::new();
        for (ts, repo, v) in [
            (1, "a", 1000.0),
            (2, "b", 500.0),
            (3, "a", 800.0),
            (4, "b", 505.0),
        ] {
            db.insert(Point::new("lbm", ts).tag("repo", repo).field("mlups", v));
        }
        let regs = detect_regressions(&db, "lbm", "mlups", &["repo"], 0.1, true);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].series, "repo=a");
        assert!((regs[0].rel_change + 0.2).abs() < 1e-12);
    }

    #[test]
    fn install_detector_survives_commit_config() {
        let mut cb = CbSystem::new();
        let custom = Detector::with_default_policies()
            .policy(Policy::new("gflops", "fe2ti", "gflops").group_by(&["repo"]));
        cb.install_detector(custom);
        // a commit tunes the custom policy: override applies...
        cb.apply_regress_config(&BenchConfig::parse("regress.gflops.min_rel_change = 0.2\n"));
        let p = cb
            .detector
            .policies
            .iter()
            .find(|p| p.name == "gflops")
            .expect("custom policy survives per-commit config");
        assert_eq!(p.min_rel_change, 0.2);
        // ...and the next commit without overrides reverts to the
        // *installed* base, not the stock CbSystem::new() snapshot
        cb.apply_regress_config(&BenchConfig::default());
        let p = cb.detector.policies.iter().find(|p| p.name == "gflops").unwrap();
        assert_eq!(p.min_rel_change, 0.05);
    }

    #[test]
    fn pipeline_regression_check_opens_and_resolves_alerts() {
        let mut cb = CbSystem::new();
        let run = |cb: &mut CbSystem, mlups: f64| {
            let j = PreparedJob {
                ci: CiJob::new("uniform-srt-icx36", "benchmark").var("HOST", "icx36"),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: format!(
                        "TAG case=uniformgridcpu\nTAG collision_op=srt\nMETRIC mlups={mlups}\n"
                    ),
                    exit_code: 0,
                }),
            };
            cb.execute_pipeline(&event(), false, vec![j], "lbm").unwrap()
        };
        for _ in 0..4 {
            let r = run(&mut cb, 1000.0);
            assert_eq!(r.regressions, crate::regress::IngestSummary::default());
        }
        assert!(cb.alerts.active().is_empty());

        // an 18% drop on the watched series opens an alert immediately
        let r = run(&mut cb, 820.0);
        assert_eq!(r.regressions.opened, 1);
        let open = cb.alerts.active();
        assert_eq!(open.len(), 1);
        assert!(open[0].confidence > 0.8);
        assert!(open[0].series.contains("collision_op=srt"));
        assert_eq!(open[0].suspect_commit.as_deref(), Some("abcdef12"));
        // ... and is archived as a linked datastore record
        let rec = cb.store.record_by_identifier("regress-alert-1").unwrap();
        assert_eq!(rec.record_type, "regression-alert");
        assert_eq!(rec.meta["state"], "open");

        // recovery on the next pipeline auto-resolves it
        let r = run(&mut cb, 1000.0);
        assert_eq!(r.regressions.auto_resolved, 1);
        assert!(cb.alerts.active().is_empty());
        let rec = cb.store.record_by_identifier("regress-alert-1").unwrap();
        assert_eq!(rec.meta["state"], "resolved");
    }

    #[test]
    fn alert_sla_measures_from_the_offending_pipelines_submission() {
        // detection can lag the regressing push (here: a 2-point recent
        // window that still averages above the threshold when the first
        // bad pipeline lands). The SLA must span back to the pipeline at
        // the located change point, not just the detecting pipeline.
        let mut cb = CbSystem::new();
        cb.install_detector(Detector::new().policy(
            Policy::new("lag", "m", "v")
                .group_by(&["repo"])
                .windows(4, 2)
                .thresholds(0.08, 1.0, 0.0)
                .changepoint(false),
        ));
        let run = |cb: &mut CbSystem, v: f64| {
            let j = PreparedJob {
                ci: CiJob::new("j", "benchmark").var("HOST", "icx36"),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: 10.0,
                    stdout: format!("METRIC v={v}\n"),
                    exit_code: 0,
                }),
            };
            cb.execute_pipeline(&event(), false, vec![j], "m").unwrap()
        };
        for _ in 0..4 {
            assert_eq!(run(&mut cb, 1000.0).regressions.opened, 0);
        }
        // the regression LANDS here, but the recent window still averages
        // (1000 + 880) / 2 = -6% — under the 8% threshold, no alert yet
        let r5 = run(&mut cb, 880.0);
        assert_eq!(r5.regressions.opened, 0);
        // the window fills with bad points: the alert opens one pipeline
        // late...
        let r6 = run(&mut cb, 880.0);
        assert_eq!(r6.regressions.opened, 1);
        // ...and the SLA reaches back to pipeline 5's submission
        let sla = r6.alert_sla.expect("opening report carries the SLA");
        assert_eq!(sla, r6.collected_at - r5.submitted_at);
        assert!(
            sla > r6.collected_at - r6.submitted_at,
            "lagged detection must not under-report the SLA"
        );
        assert_eq!(cb.alerts.active()[0].sla_secs, Some(sla));
    }

    #[test]
    fn commit_tuned_thresholds_silence_their_own_pipeline() {
        // same 18% drop as above, but the offending commit ships a
        // benchmark.cfg raising its own min_rel_change past the drop
        let mut cb = CbSystem::new();
        let run = |cb: &mut CbSystem, mlups: f64| {
            let j = PreparedJob {
                ci: CiJob::new("uniform-srt-icx36", "benchmark").var("HOST", "icx36"),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: format!(
                        "TAG case=uniformgridcpu\nTAG collision_op=srt\nMETRIC mlups={mlups}\n"
                    ),
                    exit_code: 0,
                }),
            };
            cb.execute_pipeline(&event(), false, vec![j], "lbm").unwrap()
        };
        for _ in 0..4 {
            run(&mut cb, 1000.0);
        }
        cb.apply_regress_config(&BenchConfig::parse("regress.lbm-mlups.min_rel_change = 0.5\n"));
        let r = run(&mut cb, 820.0);
        assert_eq!(r.regressions.opened, 0, "tuned threshold must suppress the alert");
        // the next commit has no overrides: stock sensitivity is back and
        // the still-degraded series is flagged
        cb.apply_regress_config(&BenchConfig::default());
        let r = run(&mut cb, 820.0);
        assert_eq!(r.regressions.opened, 1);
    }

    #[test]
    fn co_tenant_tuned_config_cannot_mask_other_repos_alerts() {
        // repo A carries a real regression with an open alert; repo B's
        // next commit loosens ITS OWN thresholds. B's collect is scoped
        // to B's series, so A's alert must survive untouched — and B's
        // interleaved trigger timestamps must not shrink A's window.
        let mut cb = CbSystem::new();
        let run = |cb: &mut CbSystem, repo: &str, mlups: f64| {
            let j = PreparedJob {
                ci: CiJob::new("uniform-srt-icx36", "benchmark").var("HOST", "icx36"),
                payload: Box::new(move |_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: format!(
                        "TAG case=uniformgridcpu\nTAG collision_op=srt\nMETRIC mlups={mlups}\n"
                    ),
                    exit_code: 0,
                }),
            };
            cb.execute_pipeline(&event_for(repo), false, vec![j], "lbm").unwrap()
        };
        for _ in 0..4 {
            run(&mut cb, "repo-a", 1000.0);
            run(&mut cb, "repo-b", 1000.0);
        }
        let r = run(&mut cb, "repo-a", 820.0);
        assert_eq!(r.regressions.opened, 1, "repo A's drop opens an alert");
        assert_eq!(cb.alerts.active().len(), 1);

        // repo B ships a loosened config; its healthy pipeline collects
        // under it — repo A's series are out of scope and stay flagged
        cb.apply_regress_config(&BenchConfig::parse(
            "regress.lbm-mlups.min_rel_change = 0.5\n",
        ));
        let r = run(&mut cb, "repo-b", 1000.0);
        assert_eq!(r.regressions.opened, 0);
        assert_eq!(r.regressions.auto_resolved, 0, "B must not resolve A's alert");
        assert_eq!(cb.alerts.active().len(), 1);
        assert!(cb.alerts.active()[0].series.contains("repo=repo-a"));

        // A recovers under stock config: only now does the alert resolve
        cb.apply_regress_config(&BenchConfig::default());
        let r = run(&mut cb, "repo-a", 1000.0);
        assert_eq!(r.regressions.auto_resolved, 1);
        assert!(cb.alerts.active().is_empty());
    }

    #[test]
    fn change_aware_selection_skips_and_carries_forward() {
        use crate::select::COMPONENTS_VAR;
        let mut cb = CbSystem::new();
        cb.set_select_mode(SelectMode::ChangeAware);
        let jobs = || {
            vec![
                PreparedJob {
                    ci: CiJob::new("cpu-j", "benchmark")
                        .var("HOST", "icx36")
                        .var(COMPONENTS_VAR, "lbm/cpu"),
                    payload: Box::new(|_n, _t| JobOutcome {
                        duration: 10.0,
                        stdout: "TAG case=c\nMETRIC v=1\n".into(),
                        exit_code: 0,
                    }),
                },
                PreparedJob {
                    ci: CiJob::new("gpu-j", "benchmark")
                        .var("HOST", "rome1")
                        .var(COMPONENTS_VAR, "lbm/gpu"),
                    payload: Box::new(|_n, _t| JobOutcome {
                        duration: 20.0,
                        stdout: "TAG case=g\nMETRIC v=2\n".into(),
                        exit_code: 0,
                    }),
                },
            ]
        };
        let ev = |changed: &[&str]| PushEvent {
            repo: "walberla".into(),
            branch: "master".into(),
            commit_id: "0123456789abcdef".into(),
            changed: changed.iter().map(|s| s.to_string()).collect(),
        };
        // unknown surface (empty changed): conservative, everything runs
        let r1 = cb.execute_pipeline(&ev(&[]), false, jobs(), "m").unwrap();
        assert_eq!((r1.jobs_total, r1.jobs_skipped), (2, 0));
        // touches only gpu code: the cpu job is skipped + carried forward
        let r2 = cb
            .execute_pipeline(&ev(&["src/lbm/gpu/k.cu"]), false, jobs(), "m")
            .unwrap();
        assert_eq!((r2.jobs_total, r2.jobs_skipped), (2, 1));
        assert_eq!(r2.points_carried, 1);
        assert_eq!(r2.saved_cluster_s, 10.0);
        let carried: Vec<&Point> = cb
            .db
            .points_iter("m")
            .filter(|p| p.tags.get("carried").map(|v| v == "1").unwrap_or(false))
            .collect();
        assert_eq!(carried.len(), 1);
        assert_eq!(carried[0].ts, r2.trigger_ts);
        assert_eq!(carried[0].tags["case"], "c");
        assert_eq!(carried[0].fields["v"], 1.0);
        assert_eq!(carried[0].tags["carried_from"], "01234567");
        // config surface: the full matrix runs again
        let r3 = cb
            .execute_pipeline(&ev(&["benchmark.cfg"]), false, jobs(), "m")
            .unwrap();
        assert_eq!(r3.jobs_skipped, 0);
        // full mode never skips, even with history and a narrow touch
        cb.set_select_mode(SelectMode::Full);
        let r4 = cb
            .execute_pipeline(&ev(&["src/lbm/gpu/k.cu"]), false, jobs(), "m")
            .unwrap();
        assert_eq!(r4.jobs_skipped, 0);
    }

    #[test]
    fn failed_jobs_counted() {
        let mut cb = CbSystem::new();
        let j = PreparedJob {
            ci: CiJob::new("bad", "benchmark").var("HOST", "icx36"),
            payload: Box::new(|_n, _t| JobOutcome {
                duration: 1.0,
                stdout: "METRIC x=1\n".into(),
                exit_code: 1,
            }),
        };
        let r = cb.execute_pipeline(&event(), false, vec![j], "m").unwrap();
        assert_eq!(r.jobs_failed, 1);
        assert_eq!(r.jobs_completed, 0);
    }
}
