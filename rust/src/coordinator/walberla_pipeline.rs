//! waLBerla pipeline (paper §4.5.2): dynamically generated jobs for every
//! supported Testcluster node — UniformGridCPU (all collision operators)
//! plus GravityWaveFSLBM — triggered through the proxy repository.

use super::{BenchConfig, PreparedJob};
use crate::apps::walberla::collision::CollisionOp;
use crate::apps::walberla::fslbm::{gravity_wave_phases, PhaseBreakdown};
use crate::apps::walberla::uniform::{Stencil, UniformGrid};
use crate::ci::CiJob;
use crate::cluster::nodes::{catalogue, NodeModel};
use crate::cluster::WorkProfile;
use crate::mpisim::{CommModel, Geometry};
use crate::slurm::JobOutcome;
use crate::vcs::Repository;

/// All Testcluster hosts (the pipeline "dynamically generates the
/// benchmark jobs for every supported node").
pub fn walberla_nodes() -> Vec<String> {
    catalogue()
        .into_iter()
        .filter(|n| n.testcluster)
        .map(|n| n.host.to_string())
        .collect()
}

/// FSLBM currently runs on CPUs only; the paper shows these four nodes in
/// Fig. 13.
pub const FSLBM_NODES: [&str; 4] = ["skylakesp2", "icx36", "rome1", "genoa2"];

/// Build the waLBerla job matrix for one commit.
///
/// `lbm_efficiency_penalty` in `benchmark.cfg` models a performance
/// regression introduced by a commit (kernel-generation change): the
/// pipeline's whole purpose is to catch it (paper §1, §3).
pub fn walberla_job_matrix(cfg: &BenchConfig) -> Vec<PreparedJob> {
    let penalty = cfg.get_f64("lbm_efficiency_penalty", 0.0).clamp(0.0, 0.9);
    let mut jobs = Vec::new();

    // UniformGridCPU: every node × every collision operator
    for host in walberla_nodes() {
        for op in CollisionOp::all() {
            jobs.push(prepare_uniform_job(&host, op, penalty));
        }
    }
    // UniformGridGPU: one job per accelerator on GPU-carrying nodes
    // (execution is modeled — DESIGN.md §2: GPU columns are projections)
    for node in catalogue().into_iter().filter(|n| n.testcluster) {
        for (ai, _acc) in node.accelerators.iter().enumerate() {
            jobs.push(prepare_gpu_job(node.host, ai, penalty));
        }
    }
    // GravityWaveFSLBM: CPU nodes of Fig. 13
    for host in FSLBM_NODES {
        jobs.push(prepare_fslbm_job(host, penalty));
    }
    jobs
}

/// UniformGridGPU on accelerator `acc_index` of `host`: bandwidth-bound
/// projection from the device memory bandwidth, D3Q27 f32 (GPU builds use
/// single precision), SRT.
fn prepare_gpu_job(host: &str, acc_index: usize, penalty: f64) -> PreparedJob {
    let name = format!("uniformgridgpu-{host}-gpu{acc_index}");
    // GPU projections take a fixed 60 s — a 5 min limit lets these jobs
    // backfill into maintenance-window gaps the hour-scale CPU jobs
    // cannot use (the matrix annotates per-class timelimits)
    let ci = CiJob::new(&name, "benchmark")
        .var("HOST", host)
        .var("SLURM_TIMELIMIT", "5")
        .var("SCRIPT", "uniform_grid_gpu.sh")
        .var(crate::select::COMPONENTS_VAR, "lbm/gpu");
    let payload = Box::new(move |node: &NodeModel, _t: f64| {
        let Some(acc) = node.accelerators.get(acc_index) else {
            return JobOutcome {
                duration: 1.0,
                stdout: "no such accelerator\n".into(),
                exit_code: 1,
            };
        };
        // f32 PDFs: 27 reads + 27 writes × 4 B = 216 B/update; generated
        // GPU kernels reach ~85% of device bandwidth (Holzer et al.)
        let bytes_per_update = 216.0;
        let pmax = acc.mem_bw_gbs * 1e9 / bytes_per_update / 1e6;
        let mlups = pmax * 0.85 * (1.0 - penalty);
        let stdout = format!(
            "TAG case=uniformgridgpu\nTAG collision_op=srt\nTAG stencil=d3q27\nTAG gpu={}\n\
             TAG modeled=true\nMETRIC mlups={mlups:.3}\nMETRIC pmax={pmax:.3}\n\
             METRIC rel_to_pmax={:.4}\n",
            acc.name.replace(' ', "_"),
            mlups / pmax,
        );
        JobOutcome {
            duration: 60.0,
            stdout,
            exit_code: 0,
        }
    });
    PreparedJob { ci, payload }
}

fn prepare_uniform_job(host: &str, op: CollisionOp, penalty: f64) -> PreparedJob {
    let name = format!("uniformgridcpu-{}-{}", op.name(), host);
    let ci = CiJob::new(&name, "benchmark")
        .var("HOST", host)
        .var("SLURM_TIMELIMIT", "60")
        .var("SCRIPT", "uniform_grid_cpu.sh")
        .var(crate::select::COMPONENTS_VAR, "lbm/cpu");
    let payload = Box::new(move |node: &NodeModel, _t: f64| {
        let cfg = UniformGrid::new(Stencil::D3Q27, op, 32);
        let eff_scale = 1.0 - penalty;
        let mlups = cfg.projected_mlups(node) * eff_scale;
        let pmax = cfg.pmax_mlups(node);
        let cores = node.cores() as f64;
        let work = cfg.work_per_step();
        let runtime = (32f64.powi(3) * cores) / (mlups * 1e6) * 100.0; // 100 steps
        let stdout = format!(
            "TAG case=uniformgridcpu\nTAG collision_op={}\nTAG stencil=d3q27\n\
             METRIC mlups={mlups:.3}\nMETRIC mlups_per_process={:.4}\n\
             METRIC pmax={pmax:.3}\nMETRIC rel_to_pmax={:.4}\nMETRIC runtime={runtime:.4}\n\
             METRIC oi={:.5}\nMETRIC vec_ratio=0.85\nMETRIC flops_per_cell={:.1}\n",
            op.name(),
            mlups / cores,
            mlups / pmax,
            work.intensity(),
            op.flops_per_cell(27),
        );
        JobOutcome {
            duration: runtime + 20.0,
            stdout,
            exit_code: 0,
        }
    });
    PreparedJob { ci, payload }
}

fn prepare_fslbm_job(host: &str, penalty: f64) -> PreparedJob {
    let name = format!("gravitywavefslbm-{host}");
    let ci = CiJob::new(&name, "benchmark")
        .var("HOST", host)
        .var("SLURM_TIMELIMIT", "120")
        .var("SCRIPT", "gravity_wave_fslbm.sh")
        .var(crate::select::COMPONENTS_VAR, "lbm/fslbm");
    let payload = Box::new(move |node: &NodeModel, _t: f64| {
        // per-cell cost measured once from the real rust FSLBM sweep would
        // be host-dependent; the calibrated constant keeps jobs cheap
        let wpc = WorkProfile::new(550.0 / (1.0 - penalty), 500.0);
        let g = Geometry::pure_mpi(1, node.cores());
        let ph: PhaseBreakdown =
            gravity_wave_phases(node, &g, 32, &CommModel::default(), &wpc);
        let (c, s, m) = ph.shares();
        let steps = 200.0;
        let stdout = format!(
            "TAG case=gravitywavefslbm\nTAG block=32\n\
             METRIC runtime={:.4}\nMETRIC compute_share={c:.4}\nMETRIC sync_share={s:.4}\n\
             METRIC comm_share={m:.4}\nMETRIC compute_time={:.6}\nMETRIC sync_time={:.6}\n\
             METRIC comm_time={:.6}\n",
            ph.total() * steps,
            ph.compute * steps,
            ph.sync * steps,
            ph.comm * steps,
        );
        JobOutcome {
            duration: ph.total() * steps + 25.0,
            stdout,
            exit_code: 0,
        }
    });
    PreparedJob { ci, payload }
}

/// Full pipeline entry for a proxy-repo trigger.
pub fn walberla_pipeline_jobs(repo: &Repository, commit_id: &str) -> Vec<PreparedJob> {
    let cfg = BenchConfig::from_commit(repo, commit_id);
    walberla_job_matrix(&cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_covers_all_nodes_and_operators() {
        let jobs = walberla_job_matrix(&BenchConfig::default());
        // 11 nodes × 4 operators + 7 GPUs (euryale 1, genoa2 2, medusa 4)
        // + 4 fslbm = 55
        assert_eq!(jobs.len(), 55);
        assert!(jobs.iter().any(|j| j.ci.name == "uniformgridcpu-cumulant-euryale"));
        assert!(jobs.iter().any(|j| j.ci.name == "gravitywavefslbm-genoa2"));
        assert!(jobs.iter().any(|j| j.ci.name == "uniformgridgpu-medusa-gpu3"));
    }

    #[test]
    fn gpu_jobs_project_from_device_bandwidth() {
        use crate::cluster::nodes::node;
        let genoa = node("genoa2").unwrap();
        let j = prepare_gpu_job("genoa2", 1, 0.0); // L40s, 864 GB/s
        let out = (j.payload)(&genoa, 0.0);
        assert_eq!(out.exit_code, 0);
        assert!(out.stdout.contains("TAG modeled=true"));
        let mlups: f64 = out
            .stdout
            .lines()
            .find_map(|l| l.strip_prefix("METRIC mlups="))
            .unwrap()
            .parse()
            .unwrap();
        // 864e9 / 216 B * 0.85 = 3400 MLUP/s
        assert!((mlups - 3400.0).abs() < 10.0, "mlups={mlups}");
        // out-of-range accelerator index fails gracefully
        let bad = prepare_gpu_job("genoa2", 9, 0.0);
        assert_eq!((bad.payload)(&genoa, 0.0).exit_code, 1);
    }

    #[test]
    fn regression_penalty_lowers_mlups() {
        use crate::cluster::nodes::node;
        let icx = node("icx36").unwrap();
        let clean = prepare_uniform_job("icx36", CollisionOp::Srt, 0.0);
        let slow = prepare_uniform_job("icx36", CollisionOp::Srt, 0.15);
        let out_clean = (clean.payload)(&icx, 0.0);
        let out_slow = (slow.payload)(&icx, 0.0);
        let get = |s: &str, key: &str| -> f64 {
            s.lines()
                .find_map(|l| l.strip_prefix(&format!("METRIC {key}=")))
                .unwrap()
                .parse()
                .unwrap()
        };
        let m_clean = get(&out_clean.stdout, "mlups");
        let m_slow = get(&out_slow.stdout, "mlups");
        assert!((m_slow / m_clean - 0.85).abs() < 1e-6, "{m_slow} vs {m_clean}");
    }

    #[test]
    fn fslbm_job_reports_phase_shares() {
        use crate::cluster::nodes::node;
        let j = prepare_fslbm_job("icx36", 0.0);
        let out = (j.payload)(&node("icx36").unwrap(), 0.0);
        assert!(out.stdout.contains("METRIC compute_share="));
        assert!(out.stdout.contains("METRIC comm_share="));
        assert_eq!(out.exit_code, 0);
    }
}
