//! Git-like version control substrate.
//!
//! The paper's CB pipeline triggers on every commit pushed to a GitLab
//! repository (§3, §4.5). GitLab/Git are not available here, so this module
//! implements the minimal content-addressed model the pipeline contracts
//! on: commits (hash, parent, author, message, tree snapshot), branches,
//! and a "push" event stream the CI engine subscribes to. It also supports
//! the paper's *proxy repository* flow (§4.5.2): a second repository that
//! mirrors commits of an upstream one and runs its own pipeline.

use std::collections::BTreeMap;

/// FNV-1a based content hash, hex-encoded. Not cryptographic — stands in
/// for git's SHA-1 as a stable content address.
pub fn content_hash(parts: &[&str]) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut h2: u64 = 0x9e3779b97f4a7c15;
    for p in parts {
        for b in p.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
            h2 = h2.rotate_left(9) ^ h;
        }
        h ^= 0xff;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}{h2:016x}")
}

/// A tree snapshot: path → file content. Small because only benchmark-
/// relevant files are modelled (source of the hot kernels, build config).
pub type Tree = BTreeMap<String, String>;

/// One commit in a repository.
#[derive(Debug, Clone)]
pub struct Commit {
    pub id: String,
    pub parent: Option<String>,
    pub author: String,
    pub message: String,
    /// Simulated commit time (secs since campaign start).
    pub time: f64,
    pub tree: Tree,
    /// Paths touched relative to the parent tree (added, modified or
    /// removed), sorted. A root commit touches its entire tree. Not part
    /// of the content hash: derived metadata, like git's diff output.
    pub changed: Vec<String>,
}

/// A push event delivered to CI subscribers.
#[derive(Debug, Clone, PartialEq)]
pub struct PushEvent {
    pub repo: String,
    pub branch: String,
    pub commit_id: String,
    /// The commit's touched paths (see [`Commit::changed`]). Empty means
    /// "unknown surface" — consumers must treat it conservatively as
    /// affects-everything, never as affects-nothing.
    pub changed: Vec<String>,
}

/// Sorted set of paths differing between two trees (added, modified or
/// removed either way).
pub fn tree_diff(old: &Tree, new: &Tree) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for (p, c) in new {
        if old.get(p) != Some(c) {
            out.push(p.clone());
        }
    }
    for p in old.keys() {
        if !new.contains_key(p) {
            out.push(p.clone());
        }
    }
    out.sort();
    out
}

/// An in-memory repository with branches and a commit DAG.
#[derive(Debug, Clone)]
pub struct Repository {
    pub name: String,
    pub default_branch: String,
    commits: BTreeMap<String, Commit>,
    branches: BTreeMap<String, String>, // branch -> head commit id
}

impl Repository {
    pub fn new(name: &str) -> Repository {
        Repository {
            name: name.to_string(),
            default_branch: "master".to_string(),
            commits: BTreeMap::new(),
            branches: BTreeMap::new(),
        }
    }

    /// Commit `tree` onto `branch` (creating the branch if needed) and
    /// return the push event a hosting platform would emit.
    pub fn commit(
        &mut self,
        branch: &str,
        author: &str,
        message: &str,
        time: f64,
        tree: Tree,
    ) -> PushEvent {
        let parent = self.branches.get(branch).cloned();
        let tree_repr: Vec<String> = tree
            .iter()
            .map(|(p, c)| format!("{p}\0{c}"))
            .collect();
        let mut parts: Vec<&str> = vec![author, message];
        let parent_s = parent.clone().unwrap_or_default();
        parts.push(&parent_s);
        for t in &tree_repr {
            parts.push(t);
        }
        let id = content_hash(&parts);
        let changed = match parent.as_ref().and_then(|p| self.commits.get(p)) {
            Some(pc) => tree_diff(&pc.tree, &tree),
            None => tree.keys().cloned().collect(),
        };
        let c = Commit {
            id: id.clone(),
            parent,
            author: author.to_string(),
            message: message.to_string(),
            time,
            tree,
            changed: changed.clone(),
        };
        self.commits.insert(id.clone(), c);
        self.branches.insert(branch.to_string(), id.clone());
        PushEvent {
            repo: self.name.clone(),
            branch: branch.to_string(),
            commit_id: id,
            changed,
        }
    }

    /// Convenience: amend the head tree of `branch` with one file change
    /// and commit.
    pub fn commit_change(
        &mut self,
        branch: &str,
        author: &str,
        message: &str,
        time: f64,
        path: &str,
        content: &str,
    ) -> PushEvent {
        let mut tree = self
            .head(branch)
            .map(|c| c.tree.clone())
            .unwrap_or_default();
        tree.insert(path.to_string(), content.to_string());
        self.commit(branch, author, message, time, tree)
    }

    pub fn get(&self, id: &str) -> Option<&Commit> {
        self.commits.get(id)
    }

    pub fn head(&self, branch: &str) -> Option<&Commit> {
        self.branches.get(branch).and_then(|id| self.commits.get(id))
    }

    pub fn branches(&self) -> impl Iterator<Item = (&String, &String)> {
        self.branches.iter()
    }

    /// Walk history from `branch` head to root (newest first).
    pub fn log(&self, branch: &str) -> Vec<&Commit> {
        let mut out = Vec::new();
        let mut cur = self.branches.get(branch).cloned();
        while let Some(id) = cur {
            match self.commits.get(&id) {
                Some(c) => {
                    cur = c.parent.clone();
                    out.push(c);
                }
                None => break,
            }
        }
        out
    }

    /// Short (8-char) id for display.
    pub fn short(id: &str) -> &str {
        &id[..8.min(id.len())]
    }
}

/// The proxy-repository flow (§4.5.2): WALBERLA's public repo has no HPC
/// runner access, so a proxy repo pulls the upstream source and runs the CB
/// pipeline there, triggered over the platform's trigger API.
#[derive(Debug)]
pub struct ProxyRepo {
    pub proxy: Repository,
    pub upstream_name: String,
    /// Only "trusted developers with access to the credentials" may trigger
    /// for non-default branches (paper §4.5.2).
    pub trusted: Vec<String>,
}

impl ProxyRepo {
    pub fn new(upstream: &str, proxy_name: &str, trusted: &[&str]) -> ProxyRepo {
        ProxyRepo {
            proxy: Repository::new(proxy_name),
            upstream_name: upstream.to_string(),
            trusted: trusted.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Mirror `commit` of the upstream into the proxy and emit the push
    /// event that triggers the proxy's pipeline. Non-default branches
    /// require a trusted user.
    pub fn trigger(
        &mut self,
        upstream: &Repository,
        commit_id: &str,
        branch: &str,
        user: &str,
    ) -> Result<PushEvent, String> {
        if branch != upstream.default_branch && !self.trusted.iter().any(|t| t == user) {
            return Err(format!(
                "user `{user}` is not trusted to trigger branch `{branch}` on proxy `{}`",
                self.proxy.name
            ));
        }
        let c = upstream
            .get(commit_id)
            .ok_or_else(|| format!("unknown upstream commit {commit_id}"))?;
        let msg = format!("mirror {}@{}: {}", self.upstream_name, branch, c.message);
        Ok(self
            .proxy
            .commit(branch, &c.author, &msg, c.time, c.tree.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(kv: &[(&str, &str)]) -> Tree {
        kv.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn hash_is_stable_and_content_sensitive() {
        let a = content_hash(&["x", "y"]);
        assert_eq!(a, content_hash(&["x", "y"]));
        assert_ne!(a, content_hash(&["xy"])); // boundary matters
        assert_ne!(a, content_hash(&["x", "z"]));
        assert_eq!(a.len(), 32);
    }

    #[test]
    fn commit_chain_and_log() {
        let mut r = Repository::new("fe2ti");
        let e1 = r.commit("master", "alice", "init", 0.0, tree(&[("solver.c", "v1")]));
        let e2 = r.commit_change("master", "bob", "tune ilu", 10.0, "solver.c", "v2");
        assert_ne!(e1.commit_id, e2.commit_id);
        let log = r.log("master");
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].message, "tune ilu");
        assert_eq!(log[1].message, "init");
        assert_eq!(log[0].parent.as_deref(), Some(e1.commit_id.as_str()));
        assert_eq!(r.head("master").unwrap().tree["solver.c"], "v2");
    }

    #[test]
    fn identical_content_same_parent_same_id() {
        let mut r1 = Repository::new("a");
        let mut r2 = Repository::new("b");
        let t = tree(&[("f", "x")]);
        let e1 = r1.commit("master", "a", "m", 0.0, t.clone());
        let e2 = r2.commit("master", "a", "m", 5.0, t);
        // time is not part of identity; content+parent+author+msg are
        assert_eq!(e1.commit_id, e2.commit_id);
    }

    #[test]
    fn branches_are_independent() {
        let mut r = Repository::new("walberla");
        r.commit("master", "a", "base", 0.0, tree(&[("k", "1")]));
        r.commit_change("feature/gpu", "b", "gpu wip", 1.0, "k", "2");
        assert_eq!(r.head("master").unwrap().tree["k"], "1");
        assert_eq!(r.head("feature/gpu").unwrap().tree["k"], "2");
        assert_eq!(r.branches().count(), 2);
    }

    #[test]
    fn proxy_trigger_respects_trust() {
        let mut up = Repository::new("walberla");
        let e = up.commit("master", "a", "base", 0.0, tree(&[("k", "1")]));
        let mut proxy = ProxyRepo::new("walberla", "walberla-cb-proxy", &["carol"]);

        // default branch: anyone may trigger
        let ev = proxy.trigger(&up, &e.commit_id, "master", "mallory").unwrap();
        assert_eq!(ev.repo, "walberla-cb-proxy");

        // non-default branch: only trusted
        let e2 = up.commit_change("fork/x", "dev", "exp", 1.0, "k", "3");
        assert!(proxy.trigger(&up, &e2.commit_id, "fork/x", "mallory").is_err());
        assert!(proxy.trigger(&up, &e2.commit_id, "fork/x", "carol").is_ok());
    }

    #[test]
    fn changed_paths_track_the_tree_diff() {
        let mut r = Repository::new("walberla");
        let e1 = r.commit(
            "master",
            "a",
            "init",
            0.0,
            tree(&[("src/lbm/cpu/k.c", "1"), ("benchmark.cfg", "cfg")]),
        );
        // root commit: everything counts as touched
        assert_eq!(e1.changed, vec!["benchmark.cfg", "src/lbm/cpu/k.c"]);
        let e2 = r.commit_change("master", "b", "tweak", 1.0, "src/lbm/cpu/k.c", "2");
        assert_eq!(e2.changed, vec!["src/lbm/cpu/k.c"]);
        // unchanged re-commit of the same tree touches nothing new
        let head = r.head("master").unwrap().tree.clone();
        let e3 = r.commit("master", "c", "noop", 2.0, head);
        assert!(e3.changed.is_empty());
        // removal is a touch too
        let mut t = r.head("master").unwrap().tree.clone();
        t.remove("benchmark.cfg");
        let e4 = r.commit("master", "d", "rm cfg", 3.0, t);
        assert_eq!(e4.changed, vec!["benchmark.cfg"]);
    }

    #[test]
    fn proxy_trigger_unknown_commit_errors() {
        let up = Repository::new("u");
        let mut proxy = ProxyRepo::new("u", "p", &[]);
        assert!(proxy.trigger(&up, "deadbeef", "master", "x").is_err());
    }
}
