//! D3Q19 and D3Q27 lattice models (velocity sets, weights, opposites).
//!
//! Mirrors `python/compile/kernels/lattice.py`; the D3Q19 ordering is
//! byte-identical so PDF fields can round-trip through the PJRT artifacts.

/// A DdQq lattice model.
#[derive(Debug, Clone)]
pub struct Lattice {
    pub name: &'static str,
    pub q: usize,
    pub c: Vec<[i32; 3]>,
    /// Velocity components as f64 (precomputed — the collision hot loop
    /// must not pay per-cell int->float conversions, §Perf).
    pub cf: Vec<[f64; 3]>,
    pub w: Vec<f64>,
    pub opposite: Vec<usize>,
}

pub const CS2: f64 = 1.0 / 3.0;

fn build(name: &'static str, c: Vec<[i32; 3]>, w: Vec<f64>) -> Lattice {
    let q = c.len();
    let opposite = (0..q)
        .map(|i| {
            let neg = [-c[i][0], -c[i][1], -c[i][2]];
            c.iter().position(|v| *v == neg).expect("opposite exists")
        })
        .collect();
    let cf = c
        .iter()
        .map(|v| [v[0] as f64, v[1] as f64, v[2] as f64])
        .collect();
    Lattice {
        name,
        q,
        c,
        cf,
        w,
        opposite,
    }
}

/// D3Q19 — same ordering as the python kernel.
pub fn d3q19() -> Lattice {
    let c = vec![
        [0, 0, 0],
        [1, 0, 0], [-1, 0, 0],
        [0, 1, 0], [0, -1, 0],
        [0, 0, 1], [0, 0, -1],
        [1, 1, 0], [-1, -1, 0], [1, -1, 0], [-1, 1, 0],
        [1, 0, 1], [-1, 0, -1], [1, 0, -1], [-1, 0, 1],
        [0, 1, 1], [0, -1, -1], [0, 1, -1], [0, -1, 1],
    ];
    let mut w = vec![1.0 / 3.0];
    w.extend(std::iter::repeat(1.0 / 18.0).take(6));
    w.extend(std::iter::repeat(1.0 / 36.0).take(12));
    build("D3Q19", c, w)
}

/// D3Q27 — the stencil the paper's UniformGrid benchmark uses (Tab. 3).
pub fn d3q27() -> Lattice {
    let mut c = Vec::with_capacity(27);
    // ordering: rest, axis, planar diagonals, cube corners
    c.push([0, 0, 0]);
    let axis = [
        [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0], [0, 0, 1], [0, 0, -1],
    ];
    c.extend(axis);
    let planar = [
        [1, 1, 0], [-1, -1, 0], [1, -1, 0], [-1, 1, 0],
        [1, 0, 1], [-1, 0, -1], [1, 0, -1], [-1, 0, 1],
        [0, 1, 1], [0, -1, -1], [0, 1, -1], [0, -1, 1],
    ];
    c.extend(planar);
    let corners = [
        [1, 1, 1], [-1, -1, -1], [1, 1, -1], [-1, -1, 1],
        [1, -1, 1], [-1, 1, -1], [1, -1, -1], [-1, 1, 1],
    ];
    c.extend(corners);
    let mut w = vec![8.0 / 27.0];
    w.extend(std::iter::repeat(2.0 / 27.0).take(6));
    w.extend(std::iter::repeat(1.0 / 54.0).take(12));
    w.extend(std::iter::repeat(1.0 / 216.0).take(8));
    build("D3Q27", c, w)
}

impl Lattice {
    /// Second-order equilibrium (paper eq. 4) for one cell.
    pub fn equilibrium(&self, rho: f64, u: [f64; 3], out: &mut [f64]) {
        let uu = u[0] * u[0] + u[1] * u[1] + u[2] * u[2];
        let base = 1.0 - 1.5 * uu;
        for ((o, cf), w) in out[..self.q].iter_mut().zip(&self.cf).zip(&self.w) {
            let cu = cf[0] * u[0] + cf[1] * u[1] + cf[2] * u[2];
            *o = w * rho * (base + 3.0 * cu + 4.5 * cu * cu);
        }
    }

    /// Density and velocity moments of one cell's PDFs (eqs. 5–6, no force).
    pub fn moments(&self, f: &[f64]) -> (f64, [f64; 3]) {
        let mut rho = 0.0;
        let mut m = [0.0f64; 3];
        for (fq, cf) in f[..self.q].iter().zip(&self.cf) {
            rho += fq;
            m[0] += cf[0] * fq;
            m[1] += cf[1] * fq;
            m[2] += cf[2] * fq;
        }
        (rho, [m[0] / rho, m[1] / rho, m[2] / rho])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        for l in [d3q19(), d3q27()] {
            let s: f64 = l.w.iter().sum();
            assert!((s - 1.0).abs() < 1e-14, "{}: {s}", l.name);
            assert_eq!(l.c.len(), l.q);
        }
    }

    #[test]
    fn opposites_are_negations() {
        for l in [d3q19(), d3q27()] {
            for q in 0..l.q {
                let o = l.opposite[q];
                assert_eq!(l.c[o][0], -l.c[q][0]);
                assert_eq!(l.c[o][1], -l.c[q][1]);
                assert_eq!(l.c[o][2], -l.c[q][2]);
                assert_eq!(l.opposite[o], q);
            }
        }
    }

    #[test]
    fn isotropy_second_moment() {
        for l in [d3q19(), d3q27()] {
            for (i, j) in [(0, 0), (1, 1), (2, 2), (0, 1), (0, 2), (1, 2)] {
                let m: f64 = (0..l.q)
                    .map(|q| l.w[q] * l.c[q][i] as f64 * l.c[q][j] as f64)
                    .sum();
                let want = if i == j { CS2 } else { 0.0 };
                assert!((m - want).abs() < 1e-14, "{} m[{i}{j}]={m}", l.name);
            }
        }
    }

    #[test]
    fn equilibrium_moments_roundtrip() {
        let l = d3q19();
        let mut f = vec![0.0; l.q];
        l.equilibrium(1.1, [0.05, -0.02, 0.01], &mut f);
        let (rho, u) = l.moments(&f);
        assert!((rho - 1.1).abs() < 1e-12);
        assert!((u[0] - 0.05).abs() < 1e-12);
        assert!((u[1] + 0.02).abs() < 1e-12);
        assert!((u[2] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn q_counts() {
        assert_eq!(d3q19().q, 19);
        assert_eq!(d3q27().q, 27);
    }
}
