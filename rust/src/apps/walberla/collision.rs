//! Collision operators: SRT, TRT, MRT, cumulant (paper §2.2.1).
//!
//! lbmpy generates specialized kernels per operator; here each operator is
//! a per-cell update with an exact FLOP model. The *relative* costs (SRT
//! cheapest … cumulant most expensive) drive the Fig. 6/8 dashboards; all
//! operators are bandwidth-bound on the node models, so MLUP/s differences
//! come mostly from the FLOP/cell differences on low-BW machines — the
//! behaviour the paper's collision-operator filter panel shows.

use super::lattice::{Lattice, CS2};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollisionOp {
    Srt,
    Trt,
    Mrt,
    Cumulant,
}

impl CollisionOp {
    pub fn all() -> [CollisionOp; 4] {
        [
            CollisionOp::Srt,
            CollisionOp::Trt,
            CollisionOp::Mrt,
            CollisionOp::Cumulant,
        ]
    }
    pub fn name(self) -> &'static str {
        match self {
            CollisionOp::Srt => "srt",
            CollisionOp::Trt => "trt",
            CollisionOp::Mrt => "mrt",
            CollisionOp::Cumulant => "cumulant",
        }
    }
    pub fn parse(s: &str) -> Option<CollisionOp> {
        CollisionOp::all().into_iter().find(|o| o.name() == s)
    }

    /// Exact FLOPs per cell update for a given lattice (collision only;
    /// streaming adds no FLOPs). Counted from the per-cell loops below.
    pub fn flops_per_cell(self, q: usize) -> f64 {
        let moments = 7.0 * q as f64 + 5.0; // rho, momentum, divides
        let feq = 12.0 * q as f64;
        match self {
            CollisionOp::Srt => moments + feq + 3.0 * q as f64,
            CollisionOp::Trt => moments + feq + 10.0 * q as f64,
            CollisionOp::Mrt => moments + feq + 24.0 * q as f64,
            CollisionOp::Cumulant => moments + feq + 40.0 * q as f64,
        }
    }

    /// Bytes moved per cell update (one read + one write of all PDFs, f64).
    pub fn bytes_per_cell(self, q: usize) -> f64 {
        (2 * 8 * q) as f64
    }

    /// Relative roofline efficiency of the generated kernel for this
    /// operator (lbmpy kernels reach ~80% of stream on current CPUs —
    /// paper §5.2; heavier operators lose a little to register pressure).
    pub fn efficiency(self) -> f64 {
        match self {
            CollisionOp::Srt => 0.82,
            CollisionOp::Trt => 0.80,
            CollisionOp::Mrt => 0.74,
            CollisionOp::Cumulant => 0.68,
        }
    }
}

/// Collide one cell in place.
///
/// SRT/TRT are the physically-exact textbook forms. MRT and cumulant are
/// implemented as TRT-equivalent relaxation plus their genuine extra
/// arithmetic (moment transform work), so their *cost* is faithful while
/// their hydrodynamic limit matches TRT for the benchmarked flows.
pub fn collide_cell(op: CollisionOp, lat: &Lattice, tau: f64, f: &mut [f64], scratch: &mut [f64]) {
    let (rho, u) = lat.moments(f);
    lat.equilibrium(rho, u, scratch);
    match op {
        CollisionOp::Srt => {
            let omega = 1.0 / tau;
            for q in 0..lat.q {
                f[q] -= omega * (f[q] - scratch[q]);
            }
        }
        CollisionOp::Trt | CollisionOp::Mrt | CollisionOp::Cumulant => {
            let magic = 3.0 / 16.0;
            let tau_minus = magic / (tau - 0.5) + 0.5;
            let om_p = 1.0 / tau;
            let om_m = 1.0 / tau_minus;
            // extra transform work for MRT/cumulant: genuine arithmetic on
            // higher moments (kept simple: raw second moments), so the
            // FLOP model above is honest.
            if matches!(op, CollisionOp::Mrt | CollisionOp::Cumulant) {
                let mut pi = [0.0f64; 6];
                for q in 0..lat.q {
                    let c = lat.c[q];
                    let cf = f[q];
                    pi[0] += c[0] as f64 * c[0] as f64 * cf;
                    pi[1] += c[1] as f64 * c[1] as f64 * cf;
                    pi[2] += c[2] as f64 * c[2] as f64 * cf;
                    pi[3] += c[0] as f64 * c[1] as f64 * cf;
                    pi[4] += c[0] as f64 * c[2] as f64 * cf;
                    pi[5] += c[1] as f64 * c[2] as f64 * cf;
                }
                std::hint::black_box(&pi);
                if op == CollisionOp::Cumulant {
                    // cumulant transform: log/exp-free surrogate work on
                    // the same moments (third-order combinations)
                    let mut k = 0.0;
                    for v in pi {
                        k += v * v * CS2;
                    }
                    std::hint::black_box(k);
                }
            }
            // write into a separate buffer: `scratch` still holds feq and
            // must stay intact while the opposite-direction pairs read it
            let mut out = [0.0f64; 27];
            for q in 0..lat.q {
                let qb = lat.opposite[q];
                let fp = 0.5 * (f[q] + f[qb]);
                let fm = 0.5 * (f[q] - f[qb]);
                let ep = 0.5 * (scratch[q] + scratch[qb]);
                let em = 0.5 * (scratch[q] - scratch[qb]);
                out[q] = f[q] - om_p * (fp - ep) - om_m * (fm - em);
            }
            f[..lat.q].copy_from_slice(&out[..lat.q]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::walberla::lattice::{d3q19, d3q27};

    fn perturbed(lat: &Lattice, seed: u64) -> Vec<f64> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut f = vec![0.0; lat.q];
        lat.equilibrium(1.0, [0.03, -0.01, 0.02], &mut f);
        for v in f.iter_mut() {
            *v += rng.gauss(0.0, 1e-3).abs() * 0.1;
        }
        f
    }

    #[test]
    fn all_ops_conserve_mass_momentum() {
        for lat in [d3q19(), d3q27()] {
            for op in CollisionOp::all() {
                let mut f = perturbed(&lat, 7);
                let (rho0, u0) = lat.moments(&f);
                let mut scratch = vec![0.0; lat.q];
                collide_cell(op, &lat, 0.6, &mut f, &mut scratch);
                let (rho1, u1) = lat.moments(&f);
                assert!((rho0 - rho1).abs() < 1e-12, "{:?} rho", op);
                for i in 0..3 {
                    assert!(
                        (rho0 * u0[i] - rho1 * u1[i]).abs() < 1e-12,
                        "{:?} mom[{i}]",
                        op
                    );
                }
            }
        }
    }

    #[test]
    fn equilibrium_is_fixed_point_for_all_ops() {
        let lat = d3q19();
        for op in CollisionOp::all() {
            let mut f = vec![0.0; lat.q];
            lat.equilibrium(1.0, [0.02, 0.01, -0.03], &mut f);
            let before = f.clone();
            let mut scratch = vec![0.0; lat.q];
            collide_cell(op, &lat, 0.8, &mut f, &mut scratch);
            for q in 0..lat.q {
                assert!((f[q] - before[q]).abs() < 1e-12, "{:?} q={q}", op);
            }
        }
    }

    #[test]
    fn srt_relaxes_toward_equilibrium() {
        let lat = d3q19();
        let mut f = perturbed(&lat, 1);
        let (rho, u) = lat.moments(&f);
        let mut feq = vec![0.0; lat.q];
        lat.equilibrium(rho, u, &mut feq);
        let d0: f64 = f.iter().zip(&feq).map(|(a, b)| (a - b).abs()).sum();
        let mut scratch = vec![0.0; lat.q];
        collide_cell(CollisionOp::Srt, &lat, 1.0, &mut f, &mut scratch);
        // tau=1: f jumps exactly to equilibrium
        let d1: f64 = f.iter().zip(&feq).map(|(a, b)| (a - b).abs()).sum();
        assert!(d1 < 1e-12 && d0 > 1e-6);
    }

    #[test]
    fn flop_model_ordering() {
        let q = 27;
        let s = CollisionOp::Srt.flops_per_cell(q);
        let t = CollisionOp::Trt.flops_per_cell(q);
        let m = CollisionOp::Mrt.flops_per_cell(q);
        let c = CollisionOp::Cumulant.flops_per_cell(q);
        assert!(s < t && t < m && m < c);
        assert_eq!(CollisionOp::Srt.bytes_per_cell(19), 304.0);
    }

    #[test]
    fn parse_roundtrip() {
        for op in CollisionOp::all() {
            assert_eq!(CollisionOp::parse(op.name()), Some(op));
        }
        assert_eq!(CollisionOp::parse("bogus"), None);
    }
}
