//! Block-structured uniform grid with ghost layers (waLBerla's
//! fully-distributed block data structure, §2.2.3).
//!
//! One [`Block`] holds the PDF field of an (nx, ny, nz) cell box plus a
//! one-cell ghost layer, stored structure-of-arrays (q-major) for the
//! streaming sweep. Ghost exchange is periodic within a block (single-
//! block runs) or performed by the owner of the block decomposition.

use super::collision::{collide_cell, CollisionOp};
use super::lattice::Lattice;

/// One grid block with PDFs and a ghost layer.
pub struct Block {
    pub lat: Lattice,
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    /// PDFs, q-major over padded (nx+2, ny+2, nz+2) boxes.
    pub f: Vec<f64>,
    /// Double buffer for streaming.
    f_tmp: Vec<f64>,
    sx: usize,
    sy: usize,
    sz: usize,
}

impl Block {
    pub fn new(lat: Lattice, nx: usize, ny: usize, nz: usize) -> Block {
        let (sx, sy, sz) = (nx + 2, ny + 2, nz + 2);
        let len = lat.q * sx * sy * sz;
        Block {
            lat,
            nx,
            ny,
            nz,
            f: vec![0.0; len],
            f_tmp: vec![0.0; len],
            sx,
            sy,
            sz,
        }
    }

    #[inline]
    pub fn idx(&self, q: usize, x: usize, y: usize, z: usize) -> usize {
        ((q * self.sx + x) * self.sy + y) * self.sz + z
    }

    pub fn cells(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    /// Initialize every interior cell to equilibrium(rho, u).
    pub fn init_equilibrium(&mut self, rho: f64, u: [f64; 3]) {
        let mut feq = vec![0.0; self.lat.q];
        self.lat.equilibrium(rho, u, &mut feq);
        for q in 0..self.lat.q {
            for x in 1..=self.nx {
                for y in 1..=self.ny {
                    for z in 1..=self.nz {
                        let i = self.idx(q, x, y, z);
                        self.f[i] = feq[q];
                    }
                }
            }
        }
    }

    /// Collide all interior cells.
    ///
    /// Hot path (§Perf): PDFs are gathered via one base index + the
    /// q-plane stride instead of 2·q full index computations per cell, and
    /// the innermost loop runs over contiguous z-lines.
    pub fn collide(&mut self, op: CollisionOp, tau: f64) {
        let q = self.lat.q;
        let plane = self.sx * self.sy * self.sz;
        // stack buffers (max Q = 27) — no per-cell allocation or Vec
        // bounds checks in the sweep
        let mut cell = [0.0f64; 27];
        let mut scratch = [0.0f64; 27];
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                let row = (x * self.sy + y) * self.sz;
                for z in 1..=self.nz {
                    let base = row + z;
                    for (k, c) in cell[..q].iter_mut().enumerate() {
                        *c = self.f[k * plane + base];
                    }
                    collide_cell(op, &self.lat, tau, &mut cell[..q], &mut scratch[..q]);
                    for (k, c) in cell[..q].iter().enumerate() {
                        self.f[k * plane + base] = *c;
                    }
                }
            }
        }
    }

    /// Fill ghost layers from the periodic image of the interior.
    ///
    /// Hot path (§Perf): copies the six boundary slabs (z-lines /
    /// y-planes / x-planes, in that order so edges and corners pick up the
    /// already-wrapped values) instead of scanning the whole padded box.
    pub fn ghost_exchange_periodic(&mut self) {
        let (nx, ny, nz) = (self.nx, self.ny, self.nz);
        let plane = self.sx * self.sy * self.sz;
        for q in 0..self.lat.q {
            let o = q * plane;
            // z faces: per (x,y) row copy the two wrap cells
            for x in 1..=nx {
                for y in 1..=ny {
                    let row = o + (x * self.sy + y) * self.sz;
                    self.f[row] = self.f[row + nz];
                    self.f[row + nz + 1] = self.f[row + 1];
                }
            }
            // y faces: whole z-lines (contiguous) incl. freshly-set z ghosts
            for x in 1..=nx {
                let base = o + x * self.sy * self.sz;
                let (src_lo, dst_lo) = (ny * self.sz, 0);
                let (src_hi, dst_hi) = (self.sz, (ny + 1) * self.sz);
                self.f.copy_within(base + src_lo..base + src_lo + self.sz, base + dst_lo);
                self.f.copy_within(base + src_hi..base + src_hi + self.sz, base + dst_hi);
            }
            // x faces: whole (y,z) planes (contiguous)
            let ps = self.sy * self.sz;
            self.f.copy_within(o + nx * ps..o + (nx + 1) * ps, o);
            self.f.copy_within(o + ps..o + 2 * ps, o + (nx + 1) * ps);
        }
    }

    /// Pull-stream all interior cells from the (ghost-filled) field.
    ///
    /// Hot path (§Perf): each (q, x, y) destination z-line is a contiguous
    /// run whose source is the contiguous run shifted by the velocity, so
    /// the innermost loop is a `copy_from_slice` (memmove-class).
    pub fn stream(&mut self) {
        let q = self.lat.q;
        let plane = self.sx * self.sy * self.sz;
        for k in 0..q {
            let c = self.lat.c[k];
            let o = k * plane;
            for x in 1..=self.nx {
                let sx = (x as i32 - c[0]) as usize;
                for y in 1..=self.ny {
                    let sy = (y as i32 - c[1]) as usize;
                    let dst0 = o + (x * self.sy + y) * self.sz + 1;
                    let src0 = o + (sx * self.sy + sy) * self.sz + (1 - c[2]) as usize;
                    self.f_tmp[dst0..dst0 + self.nz]
                        .copy_from_slice(&self.f[src0..src0 + self.nz]);
                }
            }
        }
        std::mem::swap(&mut self.f, &mut self.f_tmp);
    }

    /// One full periodic LBM step.
    pub fn step(&mut self, op: CollisionOp, tau: f64) {
        self.collide(op, tau);
        self.ghost_exchange_periodic();
        self.stream();
    }

    /// Total interior mass.
    pub fn total_mass(&self) -> f64 {
        let mut m = 0.0;
        for q in 0..self.lat.q {
            for x in 1..=self.nx {
                for y in 1..=self.ny {
                    for z in 1..=self.nz {
                        m += self.f[self.idx(q, x, y, z)];
                    }
                }
            }
        }
        m
    }

    /// Macroscopic fields of one interior cell.
    pub fn cell_moments(&self, x: usize, y: usize, z: usize) -> (f64, [f64; 3]) {
        let mut cell = vec![0.0; self.lat.q];
        for k in 0..self.lat.q {
            cell[k] = self.f[self.idx(k, x, y, z)];
        }
        self.lat.moments(&cell)
    }

    /// Export interior PDFs in the artifact layout (q, x, y, z) as f32 —
    /// feed to `runtime::Engine::lbm_step`.
    pub fn to_artifact_layout(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.lat.q * self.cells());
        for q in 0..self.lat.q {
            for x in 1..=self.nx {
                for y in 1..=self.ny {
                    for z in 1..=self.nz {
                        out.push(self.f[self.idx(q, x, y, z)] as f32);
                    }
                }
            }
        }
        out
    }

    /// Import interior PDFs from the artifact layout.
    pub fn from_artifact_layout(&mut self, data: &[f32]) {
        assert_eq!(data.len(), self.lat.q * self.cells());
        let mut it = data.iter();
        for q in 0..self.lat.q {
            for x in 1..=self.nx {
                for y in 1..=self.ny {
                    for z in 1..=self.nz {
                        let i = self.idx(q, x, y, z);
                        self.f[i] = *it.next().unwrap() as f64;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::walberla::lattice::d3q19;

    #[test]
    fn equilibrium_is_steady_state() {
        let mut b = Block::new(d3q19(), 6, 6, 6);
        b.init_equilibrium(1.0, [0.04, 0.0, -0.01]);
        let m0 = b.total_mass();
        for _ in 0..3 {
            b.step(CollisionOp::Srt, 0.7);
        }
        assert!((b.total_mass() - m0).abs() < 1e-9);
        let (rho, u) = b.cell_moments(3, 3, 3);
        assert!((rho - 1.0).abs() < 1e-9);
        assert!((u[0] - 0.04).abs() < 1e-9);
    }

    #[test]
    fn mass_conserved_with_perturbation() {
        let mut b = Block::new(d3q19(), 5, 5, 5);
        b.init_equilibrium(1.0, [0.0, 0.0, 0.0]);
        // perturb one cell
        let i = b.idx(3, 2, 2, 2);
        b.f[i] += 0.01;
        let m0 = b.total_mass();
        for _ in 0..10 {
            b.step(CollisionOp::Trt, 0.6);
        }
        assert!((b.total_mass() - m0).abs() < 1e-9);
    }

    #[test]
    fn shear_wave_decays() {
        // viscosity test: sinusoidal shear decays at rate ~ nu k^2
        let n = 12;
        let mut b = Block::new(d3q19(), n, n, n);
        let mut feq = vec![0.0; 19];
        for x in 1..=n {
            for y in 1..=n {
                for z in 1..=n {
                    let uy = 0.01 * (2.0 * std::f64::consts::PI * (x as f64 - 1.0) / n as f64).sin();
                    b.lat.equilibrium(1.0, [0.0, uy, 0.0], &mut feq);
                    for q in 0..19 {
                        let i = b.idx(q, x, y, z);
                        b.f[i] = feq[q];
                    }
                }
            }
        }
        let amp = |b: &Block| -> f64 {
            let mut max = 0.0f64;
            for x in 1..=n {
                let (_, u) = b.cell_moments(x, 2, 2);
                max = max.max(u[1].abs());
            }
            max
        };
        let a0 = amp(&b);
        for _ in 0..40 {
            b.step(CollisionOp::Srt, 0.8);
        }
        let a1 = amp(&b);
        assert!(a1 < 0.9 * a0, "shear wave should decay: {a0} -> {a1}");
        assert!(a1 > 0.1 * a0, "but not instantly: {a0} -> {a1}");
    }

    #[test]
    fn artifact_layout_roundtrip() {
        let mut b = Block::new(d3q19(), 4, 4, 4);
        b.init_equilibrium(1.0, [0.01, 0.02, 0.03]);
        let data = b.to_artifact_layout();
        assert_eq!(data.len(), 19 * 64);
        let mut b2 = Block::new(d3q19(), 4, 4, 4);
        b2.from_artifact_layout(&data);
        let (rho, u) = b2.cell_moments(2, 2, 2);
        assert!((rho - 1.0).abs() < 1e-6);
        assert!((u[2] - 0.03).abs() < 1e-6);
    }
}
