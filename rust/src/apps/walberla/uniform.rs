//! The `UniformGridCPU` / `UniformGridGPU` benchmark (paper Tab. 3):
//! plain LBM on a uniform grid with exchangeable collision operators,
//! reporting MLUP/s and roofline-relative performance.

use super::collision::CollisionOp;
use super::grid::Block;
use super::lattice::{d3q19, d3q27, Lattice};
use crate::cluster::nodes::NodeModel;
use crate::cluster::WorkProfile;
use crate::perf::PerfMonitor;
use std::time::Instant;

/// Which lattice the benchmark uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stencil {
    D3Q19,
    D3Q27,
}

impl Stencil {
    pub fn lattice(self) -> Lattice {
        match self {
            Stencil::D3Q19 => d3q19(),
            Stencil::D3Q27 => d3q27(),
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Stencil::D3Q19 => "d3q19",
            Stencil::D3Q27 => "d3q27",
        }
    }
}

/// One benchmark configuration.
#[derive(Debug, Clone)]
pub struct UniformGrid {
    pub stencil: Stencil,
    pub op: CollisionOp,
    pub block: usize,
    pub tau: f64,
    pub steps: usize,
}

/// Result of one benchmark run.
#[derive(Debug, Clone)]
pub struct UniformGridResult {
    /// Really measured on this host.
    pub host_mlups: f64,
    pub host_secs: f64,
    /// Exact per-step work of the sweep.
    pub work_per_step: WorkProfile,
    pub cells: usize,
    pub steps: usize,
}

impl UniformGrid {
    pub fn new(stencil: Stencil, op: CollisionOp, block: usize) -> UniformGrid {
        UniformGrid {
            stencil,
            op,
            block,
            tau: 0.6,
            steps: 5,
        }
    }

    /// Exact work of one full sweep over the block.
    pub fn work_per_step(&self) -> WorkProfile {
        let lat = self.stencil.lattice();
        let cells = (self.block * self.block * self.block) as f64;
        WorkProfile::new(
            cells * self.op.flops_per_cell(lat.q),
            cells * self.op.bytes_per_cell(lat.q),
        )
        .efficiency(self.op.efficiency())
    }

    /// Run on the host (real execution, real wall time) and report.
    pub fn run(&self, mon: &mut PerfMonitor) -> UniformGridResult {
        let lat = self.stencil.lattice();
        let mut b = Block::new(lat, self.block, self.block, self.block);
        b.init_equilibrium(1.0, [0.01, 0.005, 0.0]);
        let work = self.work_per_step();
        let t0 = Instant::now();
        for _ in 0..self.steps {
            b.step(self.op, self.tau);
        }
        let secs = t0.elapsed().as_secs_f64();
        let cells = b.cells();
        let updates = (cells * self.steps) as f64;
        mon.record(
            "lbm_sweep",
            secs,
            work.flops * self.steps as f64,
            work.bytes * self.steps as f64,
            work.flops * self.steps as f64 * 0.85, // sweep is SIMD-friendly
        );
        UniformGridResult {
            host_mlups: updates / secs / 1e6,
            host_secs: secs,
            work_per_step: work,
            cells,
            steps: self.steps,
        }
    }

    /// Projected MLUP/s on a catalogue node running one block per core
    /// with the domain scaled to the core count (the paper's setup).
    pub fn projected_mlups(&self, node: &NodeModel) -> f64 {
        let lat = self.stencil.lattice();
        // bandwidth-bound projection: P = BW_eff / bytes_per_update,
        // capped by the compute roofline for heavy operators
        let bpc = self.op.bytes_per_cell(lat.q);
        let fpc = self.op.flops_per_cell(lat.q);
        let t_mem = bpc / (node.stream_bw_gbs * 1e9);
        let t_comp = fpc / (node.peak_gflops() * 1e9);
        let t = t_mem.max(t_comp) / self.op.efficiency();
        1.0 / t / 1e6
    }

    /// Roofline maximum (paper §4.5.2: `P_max = BW / bytes per update`).
    pub fn pmax_mlups(&self, node: &NodeModel) -> f64 {
        node.lbm_pmax_mlups(self.op.bytes_per_cell(self.stencil.lattice().q))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::node;

    #[test]
    fn run_reports_positive_mlups_and_counters() {
        let mut mon = PerfMonitor::new();
        let mut cfg = UniformGrid::new(Stencil::D3Q19, CollisionOp::Srt, 8);
        cfg.steps = 2;
        let r = cfg.run(&mut mon);
        assert!(r.host_mlups > 0.0);
        assert_eq!(r.cells, 512);
        let region = mon.region("lbm_sweep").unwrap();
        assert!(region.flops > 0.0 && region.bytes > 0.0);
    }

    #[test]
    fn projection_hits_about_80_percent_of_stream() {
        // paper §5.2: UniformGridCPU reaches ≈80% of stream-based P_max
        let icx = node("icx36").unwrap();
        let cfg = UniformGrid::new(Stencil::D3Q27, CollisionOp::Srt, 32);
        let frac = cfg.projected_mlups(&icx) / cfg.pmax_mlups(&icx);
        assert!((0.7..0.9).contains(&frac), "frac={frac}");
    }

    #[test]
    fn heavier_operators_slower_or_equal() {
        let icx = node("icx36").unwrap();
        let mut last = f64::MAX;
        for op in CollisionOp::all() {
            let cfg = UniformGrid::new(Stencil::D3Q27, op, 32);
            let p = cfg.projected_mlups(&icx);
            assert!(p <= last * 1.001, "{:?}: {p} vs {last}", op);
            last = p;
        }
    }

    #[test]
    fn cumulant_clearly_slower_than_srt_on_weak_nodes() {
        // ivyep1 (8 flop/cy, 85 GB/s): the cumulant operator's extra
        // arithmetic + lower kernel efficiency costs real MLUP/s there
        let ivy = node("ivyep1").unwrap();
        let srt = UniformGrid::new(Stencil::D3Q27, CollisionOp::Srt, 32).projected_mlups(&ivy);
        let cum =
            UniformGrid::new(Stencil::D3Q27, CollisionOp::Cumulant, 32).projected_mlups(&ivy);
        assert!(cum < 0.9 * srt, "cumulant {cum} vs srt {srt}");
    }

    #[test]
    fn d3q27_moves_more_bytes_than_d3q19() {
        let a = UniformGrid::new(Stencil::D3Q19, CollisionOp::Srt, 16).work_per_step();
        let b = UniformGrid::new(Stencil::D3Q27, CollisionOp::Srt, 16).work_per_step();
        assert!(b.bytes > a.bytes);
    }
}
