//! waLBerla stand-in: block-structured lattice Boltzmann framework.
//!
//! Mirrors the parts of waLBerla the paper benchmarks (§2.2): a uniform
//! block grid, D3Q19/D3Q27 stencils, exchangeable collision operators
//! (SRT/TRT/MRT/cumulant — the lbmpy-generated-kernel matrix), the
//! `UniformGridCPU` benchmark reporting MLUP/s, and the free-surface LBM
//! (volume-of-fluid fill levels, mass flux, cell conversion, curvature)
//! with the gravity-wave benchmark and its compute/sync/comm phase timers.
//!
//! The "code generation" axis of waLBerla (lbmpy) maps to our JAX/Pallas →
//! HLO artifact path: `runtime::Engine::lbm_step` executes the same
//! stream-collide update through PJRT, and `uniform::UniformGrid` can run
//! either the native rust kernels or the AOT artifact.

pub mod collision;
pub mod fslbm;
pub mod grid;
pub mod lattice;
pub mod uniform;

pub use collision::CollisionOp;
pub use grid::Block;
pub use uniform::UniformGrid;
