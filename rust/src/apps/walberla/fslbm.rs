//! Free-surface lattice Boltzmann method (paper §2.2.2) and the
//! GravityWaveFSLBM benchmark.
//!
//! Volume-of-fluid FSLBM after Schwarzmeier et al.: every cell carries a
//! fill level φ and a mass m; cells are gas (φ=0), liquid (φ=1) or
//! interface (0<φ<1). Per step: interface-curvature estimation (finite
//! differences, eq. 16–17), collision with Guo gravity forcing (eq. 8),
//! streaming with the free-surface anti-bounce-back condition for links
//! from gas cells (eq. 13), mass flux between interface and
//! liquid/interface neighbors (eq. 10), and threshold-guarded cell
//! conversion with even excess-mass redistribution (eq. 11, ε=1e-2).
//!
//! The gravity-wave initialization follows Fig. 2: fluid depth `h`, one
//! sinusoid of amplitude `a0` and wavelength = domain length; periodic in
//! x/z, no-slip (bounce-back) walls in y.

use super::collision::CollisionOp;
use super::lattice::{d3q19, Lattice, CS2};
use crate::cluster::nodes::NodeModel;
use crate::cluster::WorkProfile;
use crate::mpisim::{CommModel, Geometry};

pub const EPS_CONVERT: f64 = 1e-2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellState {
    Gas,
    Interface,
    Liquid,
    /// No-slip wall (y boundaries).
    Obstacle,
}

/// One free-surface block (single block covers the whole domain here; the
/// multi-block decomposition is handled by the scaling model below, which
/// is what the paper's CB pipeline measures too — perfectly load-balanced
/// identical blocks, §2.2.3).
pub struct FsBlock {
    pub lat: Lattice,
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    sx: usize,
    sy: usize,
    sz: usize,
    /// PDFs, q-major, padded.
    pub f: Vec<f64>,
    f_tmp: Vec<f64>,
    pub state: Vec<CellState>,
    pub fill: Vec<f64>,
    pub mass: Vec<f64>,
    pub tau: f64,
    /// Gravity (negative y).
    pub gravity: f64,
    /// Surface tension coefficient.
    pub sigma: f64,
}

impl FsBlock {
    pub fn new(nx: usize, ny: usize, nz: usize) -> FsBlock {
        let lat = d3q19();
        let (sx, sy, sz) = (nx + 2, ny + 2, nz + 2);
        let ncell = sx * sy * sz;
        FsBlock {
            f: vec![0.0; lat.q * ncell],
            f_tmp: vec![0.0; lat.q * ncell],
            state: vec![CellState::Gas; ncell],
            fill: vec![0.0; ncell],
            mass: vec![0.0; ncell],
            lat,
            nx,
            ny,
            nz,
            sx,
            sy,
            sz,
            tau: 0.6,
            gravity: 1e-5,
            sigma: 1e-3,
        }
    }

    #[inline]
    pub fn cidx(&self, x: usize, y: usize, z: usize) -> usize {
        (x * self.sy + y) * self.sz + z
    }
    #[inline]
    pub fn fidx(&self, q: usize, x: usize, y: usize, z: usize) -> usize {
        q * (self.sx * self.sy * self.sz) + self.cidx(x, y, z)
    }

    /// Periodic wrap in x/z; y is walled.
    #[inline]
    fn wrap(&self, x: i32, y: i32, z: i32) -> (usize, usize, usize) {
        let nx = self.nx as i32;
        let nz = self.nz as i32;
        let xw = if x < 1 { x + nx } else if x > nx { x - nx } else { x };
        let zw = if z < 1 { z + nz } else if z > nz { z - nz } else { z };
        (xw as usize, y.clamp(0, self.ny as i32 + 1) as usize, zw as usize)
    }

    /// Gravity-wave initialization (paper Fig. 2): depth = ny/2, amplitude
    /// `a0_frac · ny`, wavelength = nx.
    pub fn init_gravity_wave(&mut self, a0_frac: f64) {
        let h = self.ny as f64 / 2.0;
        let a0 = a0_frac * self.ny as f64;
        let k = 2.0 * std::f64::consts::PI / self.nx as f64;
        let mut feq = vec![0.0; self.lat.q];
        self.lat.equilibrium(1.0, [0.0, 0.0, 0.0], &mut feq);
        for x in 0..self.sx {
            for z in 0..self.sz {
                let surface = h + a0 * (k * (x as f64 - 1.0)).sin();
                for y in 0..self.sy {
                    let ci = self.cidx(x, y, z);
                    if y == 0 || y == self.ny + 1 {
                        self.state[ci] = CellState::Obstacle;
                        self.fill[ci] = 0.0;
                        continue;
                    }
                    let cell_bottom = (y - 1) as f64;
                    let phi = ((surface - cell_bottom).clamp(0.0, 1.0)).min(1.0);
                    let st = if phi >= 1.0 {
                        CellState::Liquid
                    } else if phi <= 0.0 {
                        CellState::Gas
                    } else {
                        CellState::Interface
                    };
                    self.state[ci] = st;
                    self.fill[ci] = phi;
                    self.mass[ci] = phi; // rho = 1
                    if st != CellState::Gas {
                        for q in 0..self.lat.q {
                            let i = self.fidx(q, x, y, z);
                            self.f[i] = feq[q];
                        }
                    }
                }
            }
        }
    }

    fn is_fluid(&self, ci: usize) -> bool {
        matches!(self.state[ci], CellState::Liquid | CellState::Interface)
    }

    /// Interface curvature from central differences of the fill level
    /// (eqs. 16–17, simplified: unsmoothed φ).
    fn curvature(&self, x: usize, y: usize, z: usize) -> f64 {
        let phi = |dx: i32, dy: i32, dz: i32| -> f64 {
            let (xx, yy, zz) = self.wrap(x as i32 + dx, y as i32 + dy, z as i32 + dz);
            let ci = self.cidx(xx, yy, zz);
            match self.state[ci] {
                CellState::Obstacle => self.fill[self.cidx(x, y, z)],
                _ => self.fill[ci],
            }
        };
        // -div( grad phi / |grad phi| ) via second differences
        let dxx = phi(1, 0, 0) - 2.0 * phi(0, 0, 0) + phi(-1, 0, 0);
        let dyy = phi(0, 1, 0) - 2.0 * phi(0, 0, 0) + phi(0, -1, 0);
        let dzz = phi(0, 0, 1) - 2.0 * phi(0, 0, 0) + phi(0, 0, -1);
        let gx = 0.5 * (phi(1, 0, 0) - phi(-1, 0, 0));
        let gy = 0.5 * (phi(0, 1, 0) - phi(0, -1, 0));
        let gz = 0.5 * (phi(0, 0, 1) - phi(0, 0, -1));
        let gnorm = (gx * gx + gy * gy + gz * gz).sqrt().max(1e-9);
        -(dxx + dyy + dzz) / gnorm * 0.5
    }

    /// One FSLBM step. Returns exact per-phase work (for the projections).
    pub fn step(&mut self, op: CollisionOp) -> FsWork {
        let q = self.lat.q;
        let ncell = self.sx * self.sy * self.sz;
        let mut work = FsWork::default();

        // ---- phase 1: curvature of interface cells ----
        let mut kappa = vec![0.0f64; ncell];
        let mut n_interface = 0usize;
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    if self.state[ci] == CellState::Interface {
                        kappa[ci] = self.curvature(x, y, z);
                        n_interface += 1;
                    }
                }
            }
        }
        work.curvature = WorkProfile::new(40.0 * n_interface as f64, 60.0 * n_interface as f64);

        // ---- phase 2: collision with gravity forcing on fluid cells ----
        let mut cell = vec![0.0f64; q];
        let mut scratch = vec![0.0f64; q];
        let mut n_fluid = 0usize;
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    if !self.is_fluid(ci) {
                        continue;
                    }
                    n_fluid += 1;
                    for k in 0..q {
                        cell[k] = self.f[self.fidx(k, x, y, z)];
                    }
                    let (rho, u) = self.lat.moments(&cell);
                    // velocity shift for forcing (eq. 6): u += F dt / (2 rho)
                    let fy = -self.gravity * rho;
                    let u_sh = [u[0], u[1] + fy / (2.0 * rho), u[2]];
                    self.lat.equilibrium(rho, u_sh, &mut scratch);
                    let omega = 1.0 / self.tau;
                    let pref = 1.0 - 0.5 * omega;
                    for k in 0..q {
                        // Guo forcing term (eq. 8), only the y component of F
                        let c = self.lat.c[k];
                        let cu = c[0] as f64 * u_sh[0] + c[1] as f64 * u_sh[1] + c[2] as f64 * u_sh[2];
                        let fi = pref
                            * self.lat.w[k]
                            * ((c[1] as f64 - u_sh[1]) / CS2 + cu * c[1] as f64 / (CS2 * CS2))
                            * fy;
                        cell[k] = cell[k] - omega * (cell[k] - scratch[k]) + fi;
                    }
                    for k in 0..q {
                        let i = self.fidx(k, x, y, z);
                        self.f[i] = cell[k];
                    }
                }
            }
        }
        let fpc = op.flops_per_cell(q) + 30.0; // + forcing
        work.collision = WorkProfile::new(fpc * n_fluid as f64, op.bytes_per_cell(q) * n_fluid as f64);

        // ---- phase 3: streaming with free-surface + bounce-back BCs ----
        self.f_tmp.copy_from_slice(&self.f);
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    if !self.is_fluid(ci) {
                        continue;
                    }
                    for k in 0..q {
                        let c = self.lat.c[k];
                        let (sxx, syy, szz) =
                            self.wrap(x as i32 - c[0], y as i32 - c[1], z as i32 - c[2]);
                        let si = self.cidx(sxx, syy, szz);
                        let dst = self.fidx(k, x, y, z);
                        match self.state[si] {
                            CellState::Liquid | CellState::Interface => {
                                self.f_tmp[dst] = self.f[self.fidx(k, sxx, syy, szz)];
                            }
                            CellState::Obstacle => {
                                // bounce-back: reflected population from this cell
                                let kb = self.lat.opposite[k];
                                self.f_tmp[dst] = self.f[self.fidx(kb, x, y, z)];
                            }
                            CellState::Gas => {
                                // free-surface anti-bounce-back (eq. 13)
                                let kb = self.lat.opposite[k];
                                let (rho, u) = {
                                    let mut cc = vec![0.0; q];
                                    for kk in 0..q {
                                        cc[kk] = self.f[self.fidx(kk, x, y, z)];
                                    }
                                    self.lat.moments(&cc)
                                };
                                let _ = rho;
                                let rho_g = 1.0 + 2.0 * self.sigma * kappa[ci] / CS2;
                                let mut feq_g = vec![0.0; q];
                                self.lat.equilibrium(rho_g, u, &mut feq_g);
                                self.f_tmp[dst] =
                                    feq_g[k] + feq_g[kb] - self.f[self.fidx(kb, x, y, z)];
                            }
                        }
                    }
                }
            }
        }
        std::mem::swap(&mut self.f, &mut self.f_tmp);
        work.streaming = WorkProfile::new(
            8.0 * (n_fluid * q) as f64,
            16.0 * (n_fluid * q) as f64,
        );

        // ---- phase 4: mass flux for interface cells (eq. 10) ----
        let mut dmass = vec![0.0f64; ncell];
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    if self.state[ci] != CellState::Interface {
                        continue;
                    }
                    for k in 1..q {
                        let c = self.lat.c[k];
                        let (nxx, nyy, nzz) =
                            self.wrap(x as i32 + c[0], y as i32 + c[1], z as i32 + c[2]);
                        let ni = self.cidx(nxx, nyy, nzz);
                        let kb = self.lat.opposite[k];
                        // incoming from neighbor along -c_k minus outgoing
                        let f_in = self.f[self.fidx(kb, nxx, nyy, nzz)];
                        let f_out = self.f[self.fidx(k, x, y, z)];
                        match self.state[ni] {
                            CellState::Liquid => {
                                dmass[ci] += f_in - f_out;
                            }
                            CellState::Interface => {
                                let avg =
                                    0.5 * (self.fill[ci] + self.fill[ni]);
                                dmass[ci] += avg * (f_in - f_out);
                            }
                            _ => {}
                        }
                    }
                }
            }
        }
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    match self.state[ci] {
                        CellState::Interface => self.mass[ci] += dmass[ci],
                        CellState::Liquid => {
                            // liquid cells stay at m = rho
                            let mut cc = vec![0.0; q];
                            for kk in 0..q {
                                cc[kk] = self.f[self.fidx(kk, x, y, z)];
                            }
                            let (rho, _) = self.lat.moments(&cc);
                            self.mass[ci] = rho;
                        }
                        _ => {}
                    }
                }
            }
        }
        work.mass_flux = WorkProfile::new(
            6.0 * (n_interface * q) as f64,
            24.0 * (n_interface * q) as f64,
        );

        // ---- phase 5: conversions (eq. 11) + fill update ----
        let mut excess_total = 0.0;
        let mut converted = 0usize;
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    if self.state[ci] != CellState::Interface {
                        continue;
                    }
                    let mut cc = vec![0.0; q];
                    for kk in 0..q {
                        cc[kk] = self.f[self.fidx(kk, x, y, z)];
                    }
                    let (rho, _) = self.lat.moments(&cc);
                    let phi = self.mass[ci] / rho.max(1e-12);
                    self.fill[ci] = phi;
                    if phi > 1.0 + EPS_CONVERT {
                        // -> liquid; excess mass (phi - 1) rho
                        self.state[ci] = CellState::Liquid;
                        excess_total += (phi - 1.0) * rho;
                        self.mass[ci] = rho;
                        self.fill[ci] = 1.0;
                        converted += 1;
                    } else if phi < -EPS_CONVERT {
                        // -> gas; negative excess
                        self.state[ci] = CellState::Gas;
                        excess_total += phi * rho;
                        self.mass[ci] = 0.0;
                        self.fill[ci] = 0.0;
                        converted += 1;
                    }
                }
            }
        }
        // keep the interface closed: gas cells adjacent to liquid become
        // interface (initialized from equilibrium of neighbors, eq. 4)
        let mut to_interface = Vec::new();
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    if self.state[ci] != CellState::Gas {
                        continue;
                    }
                    let mut has_liquid = false;
                    for k in 1..q {
                        let c = self.lat.c[k];
                        let (nx2, ny2, nz2) =
                            self.wrap(x as i32 + c[0], y as i32 + c[1], z as i32 + c[2]);
                        if self.state[self.cidx(nx2, ny2, nz2)] == CellState::Liquid {
                            has_liquid = true;
                            break;
                        }
                    }
                    if has_liquid {
                        to_interface.push((x, y, z));
                    }
                }
            }
        }
        for (x, y, z) in to_interface {
            let ci = self.cidx(x, y, z);
            self.state[ci] = CellState::Interface;
            let mut feq = vec![0.0; q];
            self.lat.equilibrium(1.0, [0.0, 0.0, 0.0], &mut feq);
            for k in 0..q {
                let i = self.fidx(k, x, y, z);
                self.f[i] = feq[k];
            }
            // seeded with zero mass; it fills from the excess pool
        }
        // distribute excess mass evenly over interface cells
        let mut interface_cells = Vec::new();
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    let ci = self.cidx(x, y, z);
                    if self.state[ci] == CellState::Interface {
                        interface_cells.push(ci);
                    }
                }
            }
        }
        if !interface_cells.is_empty() {
            let share = excess_total / interface_cells.len() as f64;
            for ci in interface_cells {
                self.mass[ci] += share;
            }
        }
        work.conversion = WorkProfile::new(
            20.0 * n_interface as f64 + 50.0 * converted as f64,
            40.0 * n_interface as f64,
        );
        work.n_interface = n_interface;
        work.n_fluid = n_fluid;
        work
    }

    /// Total liquid mass (interface + liquid cells).
    pub fn total_mass(&self) -> f64 {
        let mut m = 0.0;
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    m += self.mass[self.cidx(x, y, z)];
                }
            }
        }
        m
    }

    /// Counts of (gas, interface, liquid) interior cells.
    pub fn state_counts(&self) -> (usize, usize, usize) {
        let (mut g, mut i, mut l) = (0, 0, 0);
        for x in 1..=self.nx {
            for y in 1..=self.ny {
                for z in 1..=self.nz {
                    match self.state[self.cidx(x, y, z)] {
                        CellState::Gas => g += 1,
                        CellState::Interface => i += 1,
                        CellState::Liquid => l += 1,
                        CellState::Obstacle => {}
                    }
                }
            }
        }
        (g, i, l)
    }

    /// Mean surface height at column x (for wave-dynamics checks).
    pub fn surface_height(&self, x: usize) -> f64 {
        let mut h = 0.0;
        for y in 1..=self.ny {
            for z in 1..=self.nz {
                h += self.fill[self.cidx(x, y, z)];
            }
        }
        h / self.nz as f64
    }
}

/// Exact per-phase work of one FSLBM step.
#[derive(Debug, Clone, Default)]
pub struct FsWork {
    pub curvature: WorkProfile,
    pub collision: WorkProfile,
    pub streaming: WorkProfile,
    pub mass_flux: WorkProfile,
    pub conversion: WorkProfile,
    pub n_interface: usize,
    pub n_fluid: usize,
}

impl FsWork {
    pub fn compute_total(&self) -> WorkProfile {
        let mut w = WorkProfile::new(0.0, 0.0);
        for p in [
            &self.curvature,
            &self.collision,
            &self.streaming,
            &self.mass_flux,
            &self.conversion,
        ] {
            w.add(p);
        }
        w
    }
}

/// Phase breakdown of a GravityWaveFSLBM run (the Fig. 13/14 quantities).
#[derive(Debug, Clone, Copy)]
pub struct PhaseBreakdown {
    pub compute: f64,
    pub sync: f64,
    pub comm: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.sync + self.comm
    }
    pub fn shares(&self) -> (f64, f64, f64) {
        let t = self.total();
        (self.compute / t, self.sync / t, self.comm / t)
    }
}

/// Per-step phase times of the gravity-wave benchmark on `node` with one
/// `block_edge`³ block per core (the paper's setup: domain scaled with
/// cores, 2-D x/z decomposition, artificial sync barrier after each
/// computation step, §5.2).
pub fn gravity_wave_phases(
    node: &NodeModel,
    geometry: &Geometry,
    block_edge: usize,
    comm: &CommModel,
    work_per_cell: &WorkProfile,
) -> PhaseBreakdown {
    let cells = (block_edge * block_edge * block_edge) as f64;
    let cores = geometry.cores_per_node();
    // compute: every core sweeps its own block; node BW shared
    let w = WorkProfile::new(work_per_cell.flops * cells, work_per_cell.bytes * cells)
        .efficiency(0.75);
    let t_block_node = node.exec_time(&w, node.cores()); // one block with full node
    let t_compute = t_block_node * (cores as f64 * cells)
        / (cells * node.cores() as f64 / node.cores() as f64)
        * (1.0 / cores as f64)
        * cores as f64;
    // simpler: all cores sweep concurrently; aggregate work = cores×cells,
    // executed at full-node throughput:
    let w_all = WorkProfile::new(
        work_per_cell.flops * cells * cores as f64,
        work_per_cell.bytes * cells * cores as f64,
    )
    .efficiency(0.75);
    let t_compute = {
        let _ = t_compute;
        node.exec_time(&w_all, node.cores())
    };

    // sync: the paper enforces a barrier after each of the 5 computation
    // steps; stragglers follow extreme-value scaling with participant
    // count (OS noise, per-barrier jitter on that barrier's compute
    // share), plus a per-barrier base cost.
    let participants = geometry.total_ranks().max(cores) as f64;
    let barriers = 5.0;
    let noise_sigma = 0.09 * t_compute / barriers; // 9% per-phase jitter
    let t_sync = barriers
        * (2.0e-6 * participants.log2().max(1.0)
            + noise_sigma * (2.0 * participants.ln().max(1.0)).sqrt());

    // comm: 2-D x/z decomposition → 8 neighbors (4 faces + 4 edges); a
    // face carries the full PDF ghost layer (19) + fill + mass + state
    // (≈ 22 values/cell of 8 B); the paper communicates after each of
    // the 5 steps.
    let face_cells = (block_edge * block_edge) as f64;
    let bytes_face = face_cells * 22.0 * 8.0;
    let off_node = if geometry.nodes > 1 {
        // 2-D decomposition over all cores: roughly the node-boundary share
        (4.0 / (geometry.cores_per_node() as f64).sqrt()).min(1.0)
    } else {
        0.0
    };
    // intra-node exchange rides the same memory system as the sweep:
    // scale by the node's bandwidth (relative to the skylake reference)
    // and by rank contention
    let bw_scale = 180.0 / node.stream_bw_gbs;
    let contention = (cores as f64 / 40.0).sqrt();
    let t_comm = 5.0
        * comm.halo_exchange(geometry, bytes_face, 8, off_node)
        * contention
        * bw_scale.max(0.5)
        + 5.0 * comm.omp_overhead(geometry, 1);

    PhaseBreakdown {
        compute: t_compute,
        sync: t_sync,
        comm: t_comm,
    }
}

/// The FSLBM per-cell work, measured from a real block sweep.
pub fn measured_work_per_cell(block_edge: usize, steps: usize) -> WorkProfile {
    let mut b = FsBlock::new(block_edge, block_edge, block_edge);
    b.init_gravity_wave(0.1);
    let mut total = WorkProfile::new(0.0, 0.0);
    for _ in 0..steps {
        let w = b.step(CollisionOp::Srt);
        total.add(&w.compute_total());
    }
    let cells = (block_edge * block_edge * block_edge * steps) as f64;
    WorkProfile::new(total.flops / cells, total.bytes / cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::node;

    #[test]
    fn gravity_wave_init_has_all_states() {
        let mut b = FsBlock::new(16, 16, 4);
        b.init_gravity_wave(0.15);
        let (g, i, l) = b.state_counts();
        assert!(g > 0 && i > 0 && l > 0, "g={g} i={i} l={l}");
        // roughly half the domain is liquid
        let frac = l as f64 / (16 * 16 * 4) as f64;
        assert!((0.3..0.7).contains(&frac), "liquid frac {frac}");
    }

    #[test]
    fn mass_approximately_conserved() {
        let mut b = FsBlock::new(12, 12, 4);
        b.init_gravity_wave(0.1);
        let m0 = b.total_mass();
        for _ in 0..20 {
            b.step(CollisionOp::Srt);
        }
        let m1 = b.total_mass();
        assert!(
            (m1 - m0).abs() / m0 < 0.02,
            "mass drift {m0} -> {m1} ({:+.3}%)",
            100.0 * (m1 - m0) / m0
        );
    }

    #[test]
    fn wave_relaxes_under_gravity() {
        let mut b = FsBlock::new(16, 16, 4);
        b.gravity = 5e-4;
        b.init_gravity_wave(0.2);
        // surface height difference between crest and trough columns
        let spread = |b: &FsBlock| {
            let mut lo = f64::MAX;
            let mut hi = f64::MIN;
            for x in 1..=b.nx {
                let h = b.surface_height(x);
                lo = lo.min(h);
                hi = hi.max(h);
            }
            hi - lo
        };
        let s0 = spread(&b);
        for _ in 0..60 {
            b.step(CollisionOp::Srt);
        }
        let s1 = spread(&b);
        assert!(s1 < s0, "wave should flatten: {s0} -> {s1}");
        assert!(s1.is_finite());
    }

    #[test]
    fn pdfs_stay_finite() {
        let mut b = FsBlock::new(10, 10, 4);
        b.init_gravity_wave(0.1);
        for _ in 0..30 {
            b.step(CollisionOp::Srt);
        }
        assert!(b.f.iter().all(|v| v.is_finite()));
        assert!(b.fill.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn phase_shares_match_paper_ranges_single_node() {
        // Fig. 13: compute 45-55%, sync 12-18%, comm 30-38% at 32³/core,
        // "depending on the architecture". Per-node we allow a wider band;
        // the 4-node average must land in the paper's ranges.
        let wpc = WorkProfile::new(550.0, 500.0); // calibrated per-cell cost
        let (mut ac, mut as_, mut am) = (0.0, 0.0, 0.0);
        for host in ["skylakesp2", "icx36", "rome1", "genoa2"] {
            let n = node(host).unwrap();
            let g = Geometry::pure_mpi(1, n.cores());
            let ph = gravity_wave_phases(&n, &g, 32, &CommModel::default(), &wpc);
            let (c, s, m) = ph.shares();
            assert!(
                (0.40..0.65).contains(&c),
                "{host}: compute share {c:.3} (sync {s:.3} comm {m:.3})"
            );
            assert!((0.08..0.20).contains(&s), "{host}: sync share {s:.3}");
            assert!((0.20..0.45).contains(&m), "{host}: comm share {m:.3}");
            assert!(m > s, "{host}: comm should dominate sync");
            ac += c / 4.0;
            as_ += s / 4.0;
            am += m / 4.0;
        }
        assert!((0.45..0.60).contains(&ac), "avg compute {ac:.3}");
        assert!((0.10..0.18).contains(&as_), "avg sync {as_:.3}");
        assert!((0.25..0.40).contains(&am), "avg comm {am:.3}");
    }

    #[test]
    fn comm_jumps_beyond_topology_threshold() {
        // Fig. 14b: comm time jumps between 4 and 8 nodes
        let n = node("fritz").unwrap();
        let wpc = WorkProfile::new(550.0, 500.0);
        let comm = CommModel::default();
        let t4 = gravity_wave_phases(&n, &Geometry::pure_mpi(4, 72), 64, &comm, &wpc).comm;
        let t8 = gravity_wave_phases(&n, &Geometry::pure_mpi(8, 72), 64, &comm, &wpc).comm;
        assert!(t8 > 1.15 * t4, "comm t8={t8} t4={t4}");
    }

    #[test]
    fn sync_grows_with_scale() {
        // Fig. 14b: sync keeps growing with node count
        let n = node("fritz").unwrap();
        let wpc = WorkProfile::new(550.0, 500.0);
        let comm = CommModel::default();
        let s: Vec<f64> = [1usize, 8, 64]
            .iter()
            .map(|&nodes| {
                gravity_wave_phases(&n, &Geometry::pure_mpi(nodes, 72), 64, &comm, &wpc).sync
            })
            .collect();
        assert!(s[0] < s[1] && s[1] < s[2], "{s:?}");
    }

    #[test]
    fn measured_work_is_reasonable() {
        let wpc = measured_work_per_cell(8, 2);
        assert!(wpc.flops > 100.0, "flops/cell = {}", wpc.flops);
        assert!(wpc.bytes > 100.0, "bytes/cell = {}", wpc.bytes);
    }
}
