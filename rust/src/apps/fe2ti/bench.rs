//! FE2TI benchmark driver: the fe2ti216 / fe2ti1728 cases (paper Tab. 3)
//! and the weak-scaling campaigns (Figs. 11–12).
//!
//! The solves are real (small RVE grids, exact work counters); wall times
//! are projected onto the target node model through the roofline execution
//! model — DESIGN.md §2 explains why that preserves the paper's findings
//! (they are all about *relative* solver/parallelization behaviour).

use super::macroscale::{macro_solve, micro_phase, MacroMesh, MacroSolver};
use super::rve::Material;
use super::solvers::SolverConfig;
use crate::cluster::nodes::NodeModel;
use crate::cluster::WorkProfile;
use crate::mpisim::{CommModel, Geometry};
use crate::sparse::Work;

/// Benchmark case (Tab. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fe2tiCase {
    /// 2×2×2 macro elements, 216 RVEs, full simulation, 2 load steps.
    Fe2ti216,
    /// 8×8×1 macro elements, 1728 RVEs; benchmark mode: macro solve is
    /// precomputed (read from file), only 216 RVEs are solved.
    Fe2ti1728,
}

impl Fe2tiCase {
    pub fn name(self) -> &'static str {
        match self {
            Fe2tiCase::Fe2ti216 => "fe2ti216",
            Fe2tiCase::Fe2ti1728 => "fe2ti1728",
        }
    }
    pub fn mesh(self) -> MacroMesh {
        match self {
            Fe2tiCase::Fe2ti216 => MacroMesh::fe2ti216(),
            Fe2tiCase::Fe2ti1728 => MacroMesh::fe2ti1728(),
        }
    }
    /// RVEs actually solved per macro iteration.
    pub fn rves_to_solve(self) -> usize {
        216
    }
    pub fn skips_macro_solve(self) -> bool {
        matches!(self, Fe2tiCase::Fe2ti1728)
    }
}

/// Parallelization mode (the three Fig. 9 variants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelization {
    MpiOnly,
    OmpOnly,
    Hybrid,
}

impl Parallelization {
    pub fn name(self) -> &'static str {
        match self {
            Parallelization::MpiOnly => "mpi",
            Parallelization::OmpOnly => "omp",
            Parallelization::Hybrid => "hybrid",
        }
    }
    pub fn geometry(self, nodes: usize, cores_per_node: usize) -> Geometry {
        match self {
            Parallelization::MpiOnly => Geometry::pure_mpi(nodes, cores_per_node),
            Parallelization::OmpOnly => Geometry {
                nodes,
                ranks_per_node: 1,
                threads_per_rank: cores_per_node,
            },
            Parallelization::Hybrid => Geometry::hybrid(nodes, cores_per_node),
        }
    }
}

/// A fully-specified benchmark run.
#[derive(Debug, Clone)]
pub struct Fe2tiRun {
    pub case: Fe2tiCase,
    pub solver: SolverConfig,
    pub par: Parallelization,
    /// RVE grid edge (cells). Paper RVEs are 6.5k–28k DoF; ours are small
    /// but structurally identical.
    pub rve_n: usize,
    pub load_steps: usize,
    /// RVEs actually solved (sampled) per micro phase for work counting.
    pub sample_rves: usize,
    pub macro_solver: MacroSolver,
}

impl Fe2tiRun {
    pub fn new(case: Fe2tiCase, solver: SolverConfig, par: Parallelization) -> Fe2tiRun {
        Fe2tiRun {
            case,
            solver,
            par,
            rve_n: 8,
            load_steps: 2,
            sample_rves: 2,
            macro_solver: MacroSolver::SequentialDirect,
        }
    }
}

/// Result of one benchmark run (everything the pipeline uploads).
#[derive(Debug, Clone)]
pub struct Fe2tiRunResult {
    /// Time to solution (projected on the node model), seconds.
    pub tts: f64,
    pub micro_time: f64,
    pub macro_time: f64,
    pub comm_time: f64,
    pub omp_overhead: f64,
    /// Exact totals.
    pub work: Work,
    /// Achieved GFLOP/s (work.flops / tts).
    pub gflops: f64,
    /// Operational intensity FLOP/byte.
    pub oi: f64,
    pub vector_ratio: f64,
    /// Macro Newton iterations summed over load steps.
    pub newton_iters: usize,
    /// |stress − reference| / |reference| of the final state (the
    /// numerical-verification panel, §4.5.1).
    pub verification_error: f64,
    pub mean_stress: f64,
}

/// Reference stress for verification: strict direct solve, tiny tolerance.
pub fn reference_stress(rve_n: usize, strain: f64) -> f64 {
    use super::solvers::{Compiler, SolverKind};
    let cfg = SolverConfig::new(SolverKind::Pardiso, Compiler::Intel);
    let mut rve = super::rve::Rve::new(rve_n, Material::default());
    rve.solve(strain, &cfg, 1e-10).stress
}

/// Run one FE2TI benchmark on `nodes` nodes of type `node`.
pub fn run_fe2ti_benchmark(run: &Fe2tiRun, node: &NodeModel, nodes: usize) -> Fe2tiRunResult {
    let comm = CommModel::default();
    let geometry = run.par.geometry(nodes, node.cores());
    let mesh = run.case.mesh();
    let mat = Material::default();
    let total_strain = 0.25;

    let mut micro_time = 0.0;
    let mut macro_time = 0.0;
    let mut comm_time = 0.0;
    let mut omp_overhead = 0.0;
    let mut work = Work::default();
    let mut newton_iters = 0usize;
    let mut mean_stress = 0.0;
    let mut micro_newton_total = 0usize;

    for step in 1..=run.load_steps {
        let strain = total_strain * step as f64 / run.load_steps as f64;
        // macro Newton: iterate until the homogenized response is consistent
        let macro_iters = 3;
        for _ in 0..macro_iters {
            newton_iters += 1;
            // ---- micro phase: all RVEs in parallel ----
            let micro = micro_phase(
                &mesh,
                run.rve_n,
                mat,
                strain,
                &run.solver,
                1e-7,
                run.sample_rves,
            );
            mean_stress = micro.mean_stress;
            micro_newton_total += micro.stats.iter().map(|s| s.newton_iters).sum::<usize>();
            // scale from "total mesh RVEs" to "RVEs actually solved"
            let solve_frac = run.case.rves_to_solve() as f64 / micro.rves_total as f64;
            let mut w = micro.total_work;
            w.flops *= solve_frac * nodes as f64;
            w.bytes *= solve_frac * nodes as f64;
            work.merge(w);
            // project: all ranks across nodes work concurrently; each
            // node executes its share on its own cores
            let per_node = WorkProfile::new(w.flops / nodes as f64, w.bytes / nodes as f64)
                .efficiency(run.solver.efficiency());
            micro_time += node.exec_time(&per_node, geometry.cores_per_node());
            // hybrid runs pay OpenMP region overhead per RVE Newton iter
            let regions = micro.stats.iter().map(|s| s.newton_iters).sum::<usize>()
                * run.case.rves_to_solve()
                / micro.rves_solved.max(1);
            omp_overhead += comm.omp_overhead(&geometry, regions);
            // gather the stresses to the macro problem
            comm_time += comm.gather(&geometry, 8.0);

            // ---- macro phase ----
            if !run.case.skips_macro_solve() {
                let m = macro_solve(&mesh, mean_stress.max(0.1), run.macro_solver, &geometry, &comm)
                    .expect("macro solve");
                work.merge(m.serial_work);
                work.merge(m.parallel_work);
                let serial =
                    WorkProfile::new(m.serial_work.flops, m.serial_work.bytes).parallel(0.0);
                let par = WorkProfile::new(m.parallel_work.flops, m.parallel_work.bytes)
                    .efficiency(0.4);
                macro_time += node.exec_time(&serial, 1) + node.exec_time(&par, geometry.cores_per_node());
                comm_time += m.comm_time;
            }
        }
    }
    let _ = micro_newton_total;

    let tts = micro_time + macro_time + comm_time + omp_overhead;
    let reference = reference_stress(run.rve_n, total_strain);
    Fe2tiRunResult {
        tts,
        micro_time,
        macro_time,
        comm_time,
        omp_overhead,
        gflops: work.flops / tts / 1e9,
        oi: work.flops / work.bytes.max(1.0),
        vector_ratio: run.solver.vector_ratio(),
        work,
        newton_iters,
        verification_error: (mean_stress - reference).abs() / reference.abs().max(1e-12),
        mean_stress,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::fe2ti::solvers::{Compiler, SolverKind};
    use crate::cluster::nodes::node;

    fn run_on_icx(kind: SolverKind, compiler: Compiler) -> Fe2tiRunResult {
        let cfg = SolverConfig::new(kind, compiler);
        let mut run = Fe2tiRun::new(Fe2tiCase::Fe2ti216, cfg, Parallelization::MpiOnly);
        run.rve_n = 8;
        run.sample_rves = 1;
        let icx = node("icx36").unwrap();
        run_fe2ti_benchmark(&run, &icx, 1)
    }

    #[test]
    fn fig9_solver_ordering_holds() {
        // ILU(1e-4) < ILU(1e-8) < PARDISO < UMFPACK(intel) < UMFPACK(gcc)
        let ilu_relaxed = run_on_icx(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel);
        let ilu_strict = run_on_icx(SolverKind::Ilu { tol: 1e-8 }, Compiler::Intel);
        let pardiso = run_on_icx(SolverKind::Pardiso, Compiler::Intel);
        let umf_intel = run_on_icx(SolverKind::Umfpack, Compiler::Intel);
        let umf_gcc = run_on_icx(SolverKind::Umfpack, Compiler::Gcc);
        assert!(
            ilu_relaxed.tts < ilu_strict.tts,
            "relaxed {} vs strict {}",
            ilu_relaxed.tts,
            ilu_strict.tts
        );
        assert!(ilu_strict.tts < pardiso.tts, "{} vs {}", ilu_strict.tts, pardiso.tts);
        assert!(pardiso.tts < umf_intel.tts, "{} vs {}", pardiso.tts, umf_intel.tts);
        assert!(umf_intel.tts < umf_gcc.tts, "{} vs {}", umf_intel.tts, umf_gcc.tts);
    }

    #[test]
    fn fig10a_pardiso_highest_flops_rate() {
        let pardiso = run_on_icx(SolverKind::Pardiso, Compiler::Intel);
        let ilu = run_on_icx(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel);
        let umf_gcc = run_on_icx(SolverKind::Umfpack, Compiler::Gcc);
        assert!(pardiso.gflops > ilu.gflops);
        assert!(pardiso.gflops > umf_gcc.gflops);
    }

    #[test]
    fn verification_error_small_for_all_solvers() {
        for kind in SolverKind::paper_set() {
            let r = run_on_icx(kind, Compiler::Intel);
            assert!(
                r.verification_error < 0.05,
                "{:?}: verr={}",
                kind,
                r.verification_error
            );
        }
    }

    #[test]
    fn fe2ti1728_skips_macro_and_is_micro_dominated() {
        let cfg = SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel);
        let mut run = Fe2tiRun::new(Fe2tiCase::Fe2ti1728, cfg, Parallelization::Hybrid);
        run.rve_n = 5;
        run.sample_rves = 2;
        let icx = node("icx36").unwrap();
        let r = run_fe2ti_benchmark(&run, &icx, 1);
        assert_eq!(r.macro_time, 0.0);
        assert!(r.micro_time > 0.9 * (r.tts - r.comm_time - r.omp_overhead));
    }

    #[test]
    fn hybrid_slightly_slower_on_one_node() {
        // Fig. 11's micro-solve observation: pure MPI beats hybrid slightly
        let cfg = SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel);
        let icx = node("icx36").unwrap();
        let mut mpi_run = Fe2tiRun::new(Fe2tiCase::Fe2ti216, cfg, Parallelization::MpiOnly);
        mpi_run.rve_n = 5;
        mpi_run.sample_rves = 2;
        let mut hyb_run = mpi_run.clone();
        hyb_run.par = Parallelization::Hybrid;
        let t_mpi = run_fe2ti_benchmark(&mpi_run, &icx, 1);
        let t_hyb = run_fe2ti_benchmark(&hyb_run, &icx, 1);
        assert!(
            t_hyb.micro_time + t_hyb.omp_overhead > t_mpi.micro_time + t_mpi.omp_overhead,
            "hybrid {} vs mpi {}",
            t_hyb.micro_time + t_hyb.omp_overhead,
            t_mpi.micro_time + t_mpi.omp_overhead
        );
    }

    #[test]
    fn results_stable_across_repeats() {
        // paper: "over the different runs, the results remain stable"
        let a = run_on_icx(SolverKind::Pardiso, Compiler::Intel);
        let b = run_on_icx(SolverKind::Pardiso, Compiler::Intel);
        assert!((a.tts - b.tts).abs() < 1e-12);
    }
}
