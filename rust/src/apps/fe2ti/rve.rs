//! The RVE problem: nonlinear two-phase microstructure solve (paper §2.1).
//!
//! Structured stand-in for the paper's tetrahedral RVE FEM: a 3-D
//! structured grid with a spherical martensite inclusion in a ferrite
//! matrix and a J2-plasticity-like *secant softening* nonlinearity — the
//! effective stiffness decreases as the local solution gradient grows,
//! which produces the genuine nested-Newton structure (macro Newton
//! around many micro Newton solves) the paper benchmarks. The grid matches
//! `python/compile/kernels/ref.py::rve_apply_ref` (flux form, SPD), so the
//! PJRT `rve_cg` artifact can serve as an accelerated linear solve.

use super::solvers::{SolveOutcome, SolverConfig};
use crate::sparse::{Csr, Work};

/// Two-phase material parameters (paper: dual-phase steel, §2.1.3).
#[derive(Debug, Clone, Copy)]
pub struct Material {
    pub k_ferrite: f64,
    pub k_martensite: f64,
    /// inclusion radius as a fraction of the RVE edge
    pub radius_frac: f64,
    /// J2-like softening coefficient: k_eff = k / (1 + beta |grad u|^2)
    pub beta: f64,
}

impl Default for Material {
    fn default() -> Material {
        Material {
            k_ferrite: 1.0,
            k_martensite: 10.0,
            radius_frac: 0.3,
            beta: 5.0,
        }
    }
}

/// One representative volume element.
#[derive(Debug, Clone)]
pub struct Rve {
    /// Cells per edge.
    pub n: usize,
    pub mat: Material,
    /// Per-cell base stiffness (two-phase geometry).
    pub kappa: Vec<f64>,
    /// Current solution (cell-centered scalar displacement-like field).
    pub u: Vec<f64>,
}

/// Statistics of one RVE solve.
#[derive(Debug, Clone, Default)]
pub struct RveSolveStats {
    pub newton_iters: usize,
    pub inner_iters: usize,
    pub work: Work,
    pub residual: f64,
    pub converged: bool,
    /// Homogenized stress (volume-averaged flux), the P̄ the macro scale
    /// consumes.
    pub stress: f64,
}

impl Rve {
    pub fn new(n: usize, mat: Material) -> Rve {
        let mut kappa = vec![0.0; n * n * n];
        let c = (n as f64 - 1.0) / 2.0;
        let r2 = (mat.radius_frac * n as f64).powi(2);
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    let d2 = (x as f64 - c).powi(2) + (y as f64 - c).powi(2) + (z as f64 - c).powi(2);
                    kappa[(x * n + y) * n + z] = if d2 <= r2 {
                        mat.k_martensite
                    } else {
                        mat.k_ferrite
                    };
                }
            }
        }
        Rve {
            n,
            mat,
            kappa,
            u: vec![0.0; n * n * n],
        }
    }

    #[inline]
    fn idx(&self, x: usize, y: usize, z: usize) -> usize {
        (x * self.n + y) * self.n + z
    }

    pub fn dofs(&self) -> usize {
        self.n * self.n * self.n
    }

    /// Effective per-cell stiffness at the current state (secant softening
    /// on the local gradient magnitude).
    fn kappa_eff(&self) -> Vec<f64> {
        let n = self.n;
        let mut ke = vec![0.0; self.dofs()];
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    let i = self.idx(x, y, z);
                    let gx = if x + 1 < n {
                        self.u[self.idx(x + 1, y, z)] - self.u[i]
                    } else {
                        0.0
                    };
                    let gy = if y + 1 < n {
                        self.u[self.idx(x, y + 1, z)] - self.u[i]
                    } else {
                        0.0
                    };
                    let gz = if z + 1 < n {
                        self.u[self.idx(x, y, z + 1)] - self.u[i]
                    } else {
                        0.0
                    };
                    let g2 = gx * gx + gy * gy + gz * gz;
                    ke[i] = self.kappa[i] / (1.0 + self.mat.beta * g2);
                }
            }
        }
        ke
    }

    /// Assemble the flux-form operator on the *effective* stiffness plus
    /// the Dirichlet boundary load from the macroscopic strain: ghost
    /// values follow the affine field `strain · x` (periodic-BC stand-in,
    /// paper §2.1.1). Returns (A, b).
    pub fn assemble(&self, strain: f64) -> (Csr, Vec<f64>) {
        let n = self.n;
        let ke = self.kappa_eff();
        let mut t = Vec::with_capacity(7 * self.dofs());
        let mut b = vec![0.0; self.dofs()];
        let face = |a: f64, bk: f64| 0.5 * (a + bk);
        for x in 0..n {
            for y in 0..n {
                for z in 0..n {
                    let i = self.idx(x, y, z);
                    let mut diag = 0.0;
                    let mut neigh = |t: &mut Vec<(usize, usize, f64)>,
                                     b: &mut Vec<f64>,
                                     inside: Option<usize>,
                                     kf: f64,
                                     ghost: f64| {
                        diag += kf;
                        match inside {
                            Some(j) => t.push((i, j, -kf)),
                            None => b[i] += kf * ghost,
                        }
                    };
                    // x faces: Dirichlet ghost = strain * x_ghost
                    let kf = if x + 1 < n {
                        face(ke[i], ke[self.idx(x + 1, y, z)])
                    } else {
                        ke[i]
                    };
                    neigh(
                        &mut t,
                        &mut b,
                        (x + 1 < n).then(|| self.idx(x + 1, y, z)),
                        kf,
                        strain * (n as f64),
                    );
                    let kf = if x > 0 {
                        face(ke[i], ke[self.idx(x - 1, y, z)])
                    } else {
                        ke[i]
                    };
                    neigh(
                        &mut t,
                        &mut b,
                        (x > 0).then(|| self.idx(x - 1, y, z)),
                        kf,
                        0.0,
                    );
                    // y, z faces: homogeneous Dirichlet walls
                    let kf = if y + 1 < n {
                        face(ke[i], ke[self.idx(x, y + 1, z)])
                    } else {
                        ke[i]
                    };
                    neigh(&mut t, &mut b, (y + 1 < n).then(|| self.idx(x, y + 1, z)), kf, 0.0);
                    let kf = if y > 0 {
                        face(ke[i], ke[self.idx(x, y - 1, z)])
                    } else {
                        ke[i]
                    };
                    neigh(&mut t, &mut b, (y > 0).then(|| self.idx(x, y - 1, z)), kf, 0.0);
                    let kf = if z + 1 < n {
                        face(ke[i], ke[self.idx(x, y, z + 1)])
                    } else {
                        ke[i]
                    };
                    neigh(&mut t, &mut b, (z + 1 < n).then(|| self.idx(x, y, z + 1)), kf, 0.0);
                    let kf = if z > 0 {
                        face(ke[i], ke[self.idx(x, y, z - 1)])
                    } else {
                        ke[i]
                    };
                    neigh(&mut t, &mut b, (z > 0).then(|| self.idx(x, y, z - 1)), kf, 0.0);
                    t.push((i, i, diag));
                }
            }
        }
        (Csr::from_triplets(self.dofs(), &t), b)
    }

    /// Residual norm ||A(u)·u − b|| at the current state.
    pub fn residual(&self, strain: f64) -> f64 {
        let (a, b) = self.assemble(strain);
        a.residual_norm(&self.u, &b)
    }

    /// Homogenized stress: volume-averaged x-flux at the current state.
    pub fn homogenized_stress(&self) -> f64 {
        let n = self.n;
        let ke = self.kappa_eff();
        let mut flux = 0.0;
        let mut count = 0usize;
        for x in 0..n - 1 {
            for y in 0..n {
                for z in 0..n {
                    let i = self.idx(x, y, z);
                    let j = self.idx(x + 1, y, z);
                    flux += 0.5 * (ke[i] + ke[j]) * (self.u[j] - self.u[i]);
                    count += 1;
                }
            }
        }
        flux / count as f64
    }

    /// Nonlinear RVE solve: damped Newton(-secant) iteration driven by the
    /// chosen solver package. This is the paper's innermost loop.
    pub fn solve(&mut self, strain: f64, cfg: &SolverConfig, newton_tol: f64) -> RveSolveStats {
        let mut stats = RveSolveStats::default();
        let b_scale = (self.dofs() as f64).sqrt() * strain.abs().max(1e-12);
        // adaptive damping stabilizes the secant (Picard-type) iteration
        // under strong softening: back off when the residual grows
        let mut damping = 1.0f64;
        let mut prev_res = f64::MAX;
        for _ in 0..80 {
            let (a, b) = self.assemble(strain);
            // account assembly traffic
            stats.work.add(10.0 * a.nnz() as f64, 20.0 * a.nnz() as f64);
            let mut r = vec![0.0; self.dofs()];
            a.matvec(&self.u, &mut r, &mut stats.work);
            for (ri, bi) in r.iter_mut().zip(&b) {
                *ri = bi - *ri;
            }
            let rnorm = r.iter().map(|v| v * v).sum::<f64>().sqrt();
            stats.residual = rnorm / b_scale;
            if stats.residual < newton_tol {
                stats.converged = true;
                break;
            }
            if stats.residual > prev_res {
                damping = (damping * 0.5).max(0.05);
            } else {
                damping = (damping * 1.3).min(1.0);
            }
            prev_res = stats.residual;
            stats.newton_iters += 1;
            let out: SolveOutcome = match cfg.solve(&a, &r) {
                Ok(o) => o,
                Err(_) => break,
            };
            stats.inner_iters += out.inner_iters;
            stats.work.merge(out.work);
            for (ui, di) in self.u.iter_mut().zip(&out.x) {
                *ui += damping * di;
            }
        }
        stats.stress = self.homogenized_stress();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::fe2ti::solvers::{Compiler, SolverKind};

    fn cfg(kind: SolverKind) -> SolverConfig {
        SolverConfig::new(kind, Compiler::Intel)
    }

    #[test]
    fn linear_limit_solves_in_one_newton() {
        // beta = 0 -> problem is linear; Newton converges in 1 iteration
        let mat = Material {
            beta: 0.0,
            ..Material::default()
        };
        let mut rve = Rve::new(6, mat);
        let stats = rve.solve(0.01, &cfg(SolverKind::Pardiso), 1e-10);
        assert!(stats.converged, "res={}", stats.residual);
        assert_eq!(stats.newton_iters, 1);
    }

    #[test]
    fn nonlinear_needs_multiple_newton_iters() {
        let mut rve = Rve::new(6, Material::default());
        let stats = rve.solve(0.25, &cfg(SolverKind::Pardiso), 1e-8);
        assert!(stats.converged);
        assert!(stats.newton_iters >= 2, "iters={}", stats.newton_iters);
    }

    #[test]
    fn all_solvers_reach_same_state() {
        let strain = 0.2;
        let mut stress = Vec::new();
        for kind in SolverKind::paper_set() {
            let mut rve = Rve::new(5, Material::default());
            let s = rve.solve(strain, &cfg(kind), 1e-7);
            assert!(s.converged, "{:?}", kind);
            stress.push(s.stress);
        }
        for s in &stress[1..] {
            assert!(
                (s - stress[0]).abs() < 1e-4 * stress[0].abs().max(1e-12),
                "stress mismatch {stress:?}"
            );
        }
    }

    #[test]
    fn relaxed_tolerance_still_converges_newton() {
        // the paper's point: inexact micro solves suffice for Newton
        let mut strict = Rve::new(6, Material::default());
        let mut relaxed = Rve::new(6, Material::default());
        let s1 = strict.solve(0.3, &cfg(SolverKind::Ilu { tol: 1e-8 }), 1e-7);
        let s2 = relaxed.solve(0.3, &cfg(SolverKind::Ilu { tol: 1e-4 }), 1e-7);
        assert!(s1.converged && s2.converged);
        assert!(s2.newton_iters <= s1.newton_iters + 2);
        assert!(s2.work.flops < s1.work.flops, "relaxed must be cheaper");
        assert!((s1.stress - s2.stress).abs() < 1e-4 * s1.stress.abs());
    }

    #[test]
    fn stress_increases_with_strain() {
        let mut stress = Vec::new();
        for strain in [0.05, 0.1, 0.2] {
            let mut rve = Rve::new(5, Material::default());
            let s = rve.solve(strain, &cfg(SolverKind::Pardiso), 1e-8);
            stress.push(s.stress);
        }
        assert!(stress[0] < stress[1] && stress[1] < stress[2], "{stress:?}");
    }

    #[test]
    fn softening_reduces_secant_stiffness() {
        // at larger strain the effective stress/strain ratio drops
        let ratio = |strain: f64| {
            let mut rve = Rve::new(5, Material::default());
            let s = rve.solve(strain, &cfg(SolverKind::Pardiso), 1e-8);
            s.stress / strain
        };
        assert!(ratio(0.5) < ratio(0.05), "secant stiffness should soften");
    }

    #[test]
    fn inclusion_geometry() {
        let rve = Rve::new(8, Material::default());
        let mid = rve.idx(4, 4, 4);
        let corner = rve.idx(0, 0, 0);
        assert_eq!(rve.kappa[mid], 10.0);
        assert_eq!(rve.kappa[corner], 1.0);
    }
}
