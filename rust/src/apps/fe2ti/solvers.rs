//! RVE solver registry: the paper's solver packages as personalities over
//! our from-scratch sparse kernels.
//!
//! Numerically, PARDISO and UMFPACK are both sparse LU here (`sparse::lu`).
//! What the paper actually measures between them is *kernel efficiency*:
//! MKL-PARDISO uses tuned supernodal BLAS-3 kernels; UMFPACK's multifrontal
//! kernels go through whatever BLAS PETSc was linked against — the Intel
//! build got MKL, the gcc build silently got PETSc's reference BLAS, and
//! the jump in Fig. 10 is the commit that switched the gcc build to BLIS
//! (§5.1). The personality table encodes exactly that.

use crate::sparse::{gmres, Csr, Ilu0, SparseLu, Work};

/// Compiler toolchain of the build (Fig. 9's dashed vs solid lines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Compiler {
    Gcc,
    Intel,
}

impl Compiler {
    pub fn name(self) -> &'static str {
        match self {
            Compiler::Gcc => "gcc",
            Compiler::Intel => "intel",
        }
    }
    /// MPI library that comes with the toolchain in the paper's setup.
    pub fn mpi(self) -> &'static str {
        match self {
            Compiler::Gcc => "OpenMPI",
            Compiler::Intel => "IntelMPI",
        }
    }
    /// Small general code-gen factor (non-BLAS parts).
    pub fn codegen_factor(self) -> f64 {
        match self {
            Compiler::Gcc => 0.95,
            Compiler::Intel => 1.0,
        }
    }
}

/// BLAS the UMFPACK/gcc build links against. The `blis` state is what the
/// fix commit switches to (Fig. 10b's drop in TTS).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BlasLib {
    Reference,
    Blis,
    Mkl,
}

impl BlasLib {
    pub fn name(self) -> &'static str {
        match self {
            BlasLib::Reference => "reference",
            BlasLib::Blis => "blis",
            BlasLib::Mkl => "mkl",
        }
    }
}

/// Solver selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SolverKind {
    Pardiso,
    Umfpack,
    /// GMRES + ILU(0) with the given relative tolerance.
    Ilu { tol: f64 },
}

impl SolverKind {
    pub fn name(self) -> String {
        match self {
            SolverKind::Pardiso => "pardiso".to_string(),
            SolverKind::Umfpack => "umfpack".to_string(),
            SolverKind::Ilu { tol } => format!("ilu{:.0e}", tol),
        }
    }

    /// The paper's four Fig. 9 configurations.
    pub fn paper_set() -> Vec<SolverKind> {
        vec![
            SolverKind::Pardiso,
            SolverKind::Umfpack,
            SolverKind::Ilu { tol: 1e-8 },
            SolverKind::Ilu { tol: 1e-4 },
        ]
    }
}

/// A fully-specified solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct SolverConfig {
    pub kind: SolverKind,
    pub compiler: Compiler,
    /// BLAS the UMFPACK path resolves (depends on build; see module doc).
    pub umfpack_blas: BlasLib,
}

impl SolverConfig {
    pub fn new(kind: SolverKind, compiler: Compiler) -> SolverConfig {
        SolverConfig {
            kind,
            compiler,
            // historical default: intel builds linked MKL, gcc builds the
            // reference routines (the paper's pre-fix state)
            umfpack_blas: match compiler {
                Compiler::Intel => BlasLib::Mkl,
                Compiler::Gcc => BlasLib::Reference,
            },
        }
    }

    pub fn with_blas(mut self, blas: BlasLib) -> SolverConfig {
        self.umfpack_blas = blas;
        self
    }

    pub fn label(&self) -> String {
        format!("{}-{}", self.kind.name(), self.compiler.name())
    }

    /// Roofline efficiency of the solver's hot kernels on a node
    /// (fraction of the machine limit the package reaches).
    pub fn efficiency(&self) -> f64 {
        let base = match self.kind {
            // tuned supernodal BLAS-3 kernels (node-level utilization of a
            // many-small-fronts sparse factorization)
            SolverKind::Pardiso => 0.31,
            SolverKind::Umfpack => match self.umfpack_blas {
                BlasLib::Mkl => 0.20,
                BlasLib::Blis => 0.18,
                BlasLib::Reference => 0.03,
            },
            // streaming triangular sweeps; bandwidth-bound anyway
            SolverKind::Ilu { .. } => 0.75,
        };
        base * self.compiler.codegen_factor()
    }

    /// Operational intensity of the package's hot kernels (FLOP/byte).
    /// Supernodal/multifrontal direct solvers run BLAS-3 on dense fronts
    /// (cache-blocked, OI ≈ 2); our from-scratch row LU counts raw sparse
    /// traffic, so the direct personalities override the byte count.
    pub fn kernel_oi(&self) -> Option<f64> {
        match self.kind {
            SolverKind::Pardiso | SolverKind::Umfpack => Some(2.0),
            SolverKind::Ilu { .. } => None, // honest counted traffic
        }
    }

    /// Fraction of FLOPs issued through SIMD units (the Fig. 6 panel).
    pub fn vector_ratio(&self) -> f64 {
        match self.kind {
            SolverKind::Pardiso => 0.92,
            SolverKind::Umfpack => match self.umfpack_blas {
                BlasLib::Mkl => 0.88,
                BlasLib::Blis => 0.85,
                BlasLib::Reference => 0.06,
            },
            SolverKind::Ilu { .. } => 0.55,
        }
    }

    /// Solve A·x = b, really. Returns the solution, the exact work, and
    /// the inner-iteration count (0 for direct solvers).
    pub fn solve(&self, a: &Csr, b: &[f64]) -> Result<SolveOutcome, String> {
        match self.kind {
            SolverKind::Pardiso | SolverKind::Umfpack => {
                let lu = SparseLu::factor(a)?;
                let mut w = lu.factor_work;
                let x = lu.solve(b, &mut w);
                // traffic personality: BLAS-3 dense-front kernels
                if let Some(oi) = self.kernel_oi() {
                    w.bytes = w.flops / oi;
                }
                Ok(SolveOutcome {
                    x,
                    work: w,
                    inner_iters: 0,
                    converged: true,
                })
            }
            SolverKind::Ilu { tol } => {
                let ilu = Ilu0::factor(a)?;
                let r = gmres(a, b, Some(&ilu), tol, 40, 4000);
                let mut w = ilu.factor_work;
                w.merge(r.work);
                Ok(SolveOutcome {
                    x: r.x,
                    work: w,
                    inner_iters: r.iters,
                    converged: r.converged,
                })
            }
        }
    }
}

/// Result of one linear solve.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    pub x: Vec<f64>,
    pub work: Work,
    pub inner_iters: usize,
    pub converged: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::testmat::laplacian2d;

    #[test]
    fn all_solvers_solve_the_same_system() {
        let a = laplacian2d(10);
        let b = vec![1.0; a.n];
        for kind in SolverKind::paper_set() {
            let cfg = SolverConfig::new(kind, Compiler::Intel);
            let out = cfg.solve(&a, &b).unwrap();
            assert!(out.converged, "{:?}", kind);
            let res = a.residual_norm(&out.x, &b);
            let tol = match kind {
                SolverKind::Ilu { tol } => tol * 100.0 * (a.n as f64).sqrt(),
                _ => 1e-8,
            };
            assert!(res < tol, "{:?}: res={res}", kind);
        }
    }

    #[test]
    fn direct_solvers_do_more_flops_than_relaxed_ilu() {
        // Fig. 10a's mechanism: "the iterative solver is doing less work".
        // Needs a system large enough that factorization fill dominates.
        let a = laplacian2d(40);
        let b = vec![1.0; a.n];
        let direct = SolverConfig::new(SolverKind::Pardiso, Compiler::Intel)
            .solve(&a, &b)
            .unwrap();
        let ilu = SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel)
            .solve(&a, &b)
            .unwrap();
        assert!(
            direct.work.flops > ilu.work.flops,
            "direct {} vs ilu {}",
            direct.work.flops,
            ilu.work.flops
        );
    }

    #[test]
    fn efficiency_personalities_ordering() {
        let pardiso = SolverConfig::new(SolverKind::Pardiso, Compiler::Intel);
        let umf_intel = SolverConfig::new(SolverKind::Umfpack, Compiler::Intel);
        let umf_gcc = SolverConfig::new(SolverKind::Umfpack, Compiler::Gcc);
        let umf_gcc_blis = umf_gcc.with_blas(BlasLib::Blis);
        assert!(pardiso.efficiency() > umf_intel.efficiency());
        assert!(umf_intel.efficiency() > umf_gcc_blis.efficiency());
        // the paper's headline UMFPACK gap: reference BLAS is ~6x slower
        assert!(umf_gcc_blis.efficiency() > 4.0 * umf_gcc.efficiency());
        // vectorization panel
        assert!(umf_gcc.vector_ratio() < 0.1);
        assert!(pardiso.vector_ratio() > 0.9);
    }

    #[test]
    fn default_blas_follows_compiler() {
        assert_eq!(
            SolverConfig::new(SolverKind::Umfpack, Compiler::Intel).umfpack_blas,
            BlasLib::Mkl
        );
        assert_eq!(
            SolverConfig::new(SolverKind::Umfpack, Compiler::Gcc).umfpack_blas,
            BlasLib::Reference
        );
    }

    #[test]
    fn relaxed_ilu_cheaper_than_strict() {
        let a = laplacian2d(12);
        let b = vec![1.0; a.n];
        let strict = SolverConfig::new(SolverKind::Ilu { tol: 1e-8 }, Compiler::Intel)
            .solve(&a, &b)
            .unwrap();
        let relaxed = SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel)
            .solve(&a, &b)
            .unwrap();
        assert!(relaxed.work.flops < strict.work.flops);
        assert!(relaxed.inner_iters < strict.inner_iters);
    }

    #[test]
    fn solver_names() {
        assert_eq!(SolverKind::Pardiso.name(), "pardiso");
        assert_eq!(SolverKind::Ilu { tol: 1e-4 }.name(), "ilu1e-4");
        assert_eq!(
            SolverConfig::new(SolverKind::Umfpack, Compiler::Gcc).label(),
            "umfpack-gcc"
        );
    }
}
