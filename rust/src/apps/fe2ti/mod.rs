//! FE2TI stand-in: FE² computational homogenization (paper §2.1).
//!
//! Scale-bridging solver: a macroscopic finite-element problem whose
//! constitutive response at every integration point comes from solving a
//! representative-volume-element (RVE) problem with a two-phase
//! microstructure (spherical martensite inclusion in a ferrite matrix).
//! The algorithmic structure is the paper's three nested loops: pseudo-time
//! load stepping → macroscopic Newton → parallel RVE Newton solves.
//!
//! Solver options mirror the paper's packages: MKL-PARDISO and UMFPACK
//! (sparse direct; same numerics here, different kernel-efficiency
//! personalities — the paper's UMFPACK finding is purely about the linked
//! BLAS), and GMRES+ILU(0) with strict/relaxed tolerances (the "inexact
//! option").

pub mod bench;
pub mod macroscale;
pub mod rve;
pub mod solvers;

pub use bench::{run_fe2ti_benchmark, Fe2tiCase, Fe2tiRunResult};
pub use rve::{Rve, RveSolveStats};
pub use solvers::{Compiler, SolverKind};
