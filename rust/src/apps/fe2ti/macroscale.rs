//! The macroscopic scale: tri-quadratic hex elements with 27 integration
//! points each, one RVE attached to every integration point (paper §2.1.1,
//! Fig. 1), plus the two macro solver options: a sequential sparse direct
//! solve (MKL-PARDISO) and the parallel BDDC domain-decomposition model
//! (§5.1, Fig. 12).

use super::rve::{Material, Rve, RveSolveStats};
use super::solvers::SolverConfig;
use crate::mpisim::{CommModel, Geometry};
use crate::sparse::{Csr, SparseLu, Work};

/// Macro mesh: ex × ey × ez tri-quadratic hexahedra.
#[derive(Debug, Clone, Copy)]
pub struct MacroMesh {
    pub ex: usize,
    pub ey: usize,
    pub ez: usize,
}

pub const INT_POINTS_PER_ELEMENT: usize = 27;

impl MacroMesh {
    /// The fe2ti216 mesh: 2×2×2 elements → 216 RVEs.
    pub fn fe2ti216() -> MacroMesh {
        MacroMesh { ex: 2, ey: 2, ez: 2 }
    }
    /// The fe2ti1728 mesh: 8×8×1 elements → 1728 RVEs.
    pub fn fe2ti1728() -> MacroMesh {
        MacroMesh { ex: 8, ey: 8, ez: 1 }
    }
    pub fn elements(&self) -> usize {
        self.ex * self.ey * self.ez
    }
    pub fn rves(&self) -> usize {
        self.elements() * INT_POINTS_PER_ELEMENT
    }
    /// Tri-quadratic nodes per direction: 2e+1.
    pub fn nodes(&self) -> usize {
        (2 * self.ex + 1) * (2 * self.ey + 1) * (2 * self.ez + 1)
    }

    /// Assemble the macroscopic tangent as a structured second-order
    /// stencil on the node grid, scaled by the homogenized secant
    /// stiffness from the RVEs.
    pub fn assemble_tangent(&self, stiffness: f64) -> Csr {
        let (nx, ny, nz) = (2 * self.ex + 1, 2 * self.ey + 1, 2 * self.ez + 1);
        let idx = |x: usize, y: usize, z: usize| (x * ny + y) * nz + z;
        let n = nx * ny * nz;
        let mut t = Vec::with_capacity(7 * n);
        for x in 0..nx {
            for y in 0..ny {
                for z in 0..nz {
                    let i = idx(x, y, z);
                    let mut diag = 1e-6; // tiny regularization (free faces)
                    let mut push = |j: Option<usize>, t: &mut Vec<(usize, usize, f64)>| {
                        diag += stiffness;
                        if let Some(j) = j {
                            t.push((i, j, -stiffness));
                        }
                    };
                    push((x + 1 < nx).then(|| idx(x + 1, y, z)), &mut t);
                    push((x > 0).then(|| idx(x - 1, y, z)), &mut t);
                    push((y + 1 < ny).then(|| idx(x, y + 1, z)), &mut t);
                    push((y > 0).then(|| idx(x, y - 1, z)), &mut t);
                    push((z + 1 < nz).then(|| idx(x, y, z + 1)), &mut t);
                    push((z > 0).then(|| idx(x, y, z - 1)), &mut t);
                    t.push((i, i, diag));
                }
            }
        }
        Csr::from_triplets(n, &t)
    }
}

/// Macro solver options (Fig. 12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacroSolver {
    /// Sequential sparse direct solve on rank 0 (MKL-PARDISO).
    SequentialDirect,
    /// Parallel BDDC domain decomposition on a subset of ranks.
    Bddc,
}

/// Outcome of one macro linear solve (work split serial vs parallel).
#[derive(Debug, Clone, Default)]
pub struct MacroSolveOutcome {
    /// Work executed sequentially on one rank.
    pub serial_work: Work,
    /// Work executed across ranks in parallel.
    pub parallel_work: Work,
    /// Collective communication time contribution (alpha-beta model).
    pub comm_time: f64,
}

/// Solve the macro tangent system with the chosen option, really running
/// the factorization and counting work.
pub fn macro_solve(
    mesh: &MacroMesh,
    stiffness: f64,
    solver: MacroSolver,
    geometry: &Geometry,
    comm: &CommModel,
) -> Result<MacroSolveOutcome, String> {
    let a = mesh.assemble_tangent(stiffness);
    let rhs = vec![1.0; a.n];
    match solver {
        MacroSolver::SequentialDirect => {
            let lu = SparseLu::factor(&a)?;
            let mut w = lu.factor_work;
            let _x = lu.solve(&rhs, &mut w);
            // every rank must receive the macro state afterwards
            let bcast = comm.allreduce(geometry, 8.0 * a.n as f64);
            Ok(MacroSolveOutcome {
                serial_work: w,
                parallel_work: Work::default(),
                comm_time: bcast,
            })
        }
        MacroSolver::Bddc => {
            // BDDC: subdomain solves in parallel + a coarse problem whose
            // size grows with the number of subdomains.
            let subdomains = geometry.total_ranks().min(mesh.elements().max(1));
            let sub_n = (a.n / subdomains).max(8);
            // subdomain solve: factor a local block (done per rank, in parallel)
            let sub_mesh = MacroMesh { ex: 1, ey: 1, ez: 1 };
            let sub_a = sub_mesh.assemble_tangent(stiffness);
            let _ = sub_n;
            let sub_lu = SparseLu::factor(&sub_a)?;
            let mut pw = sub_lu.factor_work;
            let _ = sub_lu.solve(&vec![1.0; sub_a.n], &mut pw);
            // coarse problem: one dof per subdomain vertex region
            // (~ O(subdomains)), solved sparsely — FE2TI's three-level /
            // AMG-preconditioned coarse options keep this from becoming a
            // dense bottleneck (paper ref. [17])
            let coarse_n = (subdomains as f64).max(2.0);
            let mut sw = Work::default();
            sw.add(100.0 * coarse_n.powf(1.5), 12.0 * 8.0 * coarse_n);
            // two collectives per BDDC application (gather + scatter of
            // coarse dofs) times a few Krylov iterations
            let iters = 10.0;
            let comm_time = iters
                * (comm.allreduce(geometry, 8.0 * coarse_n)
                    + comm.gather(geometry, 8.0 * coarse_n / geometry.total_ranks().max(1) as f64));
            Ok(MacroSolveOutcome {
                serial_work: sw,
                parallel_work: pw,
                comm_time,
            })
        }
    }
}

/// One macroscopic Newton iteration's micro phase: solve every RVE (really
/// solving `sample` of them and scaling the counted work — the paper's own
/// fe2ti1728 benchmark mode does exactly this trick, solving 216 of 1728).
pub struct MicroPhaseResult {
    pub stats: Vec<RveSolveStats>,
    /// Mean homogenized stress fed back to the macro residual.
    pub mean_stress: f64,
    /// Exact work of ALL RVEs (sampled × scale).
    pub total_work: Work,
    pub rves_solved: usize,
    pub rves_total: usize,
}

pub fn micro_phase(
    mesh: &MacroMesh,
    rve_n: usize,
    mat: Material,
    strain: f64,
    cfg: &SolverConfig,
    newton_tol: f64,
    sample: usize,
) -> MicroPhaseResult {
    let total = mesh.rves();
    let solve_count = sample.min(total).max(1);
    let mut stats = Vec::with_capacity(solve_count);
    let mut work = Work::default();
    let mut stress_sum = 0.0;
    for k in 0..solve_count {
        // vary the strain slightly per integration point (realistic spread)
        let local_strain = strain * (1.0 + 0.05 * (k as f64 / solve_count as f64 - 0.5));
        let mut rve = Rve::new(rve_n, mat);
        let s = rve.solve(local_strain, cfg, newton_tol);
        stress_sum += s.stress;
        work.merge(s.work);
        stats.push(s);
    }
    let scale = total as f64 / solve_count as f64;
    work.flops *= scale;
    work.bytes *= scale;
    MicroPhaseResult {
        mean_stress: stress_sum / solve_count as f64,
        total_work: work,
        rves_solved: solve_count,
        rves_total: total,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::fe2ti::solvers::{Compiler, SolverKind};

    #[test]
    fn mesh_rve_counts_match_paper() {
        assert_eq!(MacroMesh::fe2ti216().rves(), 216);
        assert_eq!(MacroMesh::fe2ti1728().rves(), 1728);
        assert_eq!(MacroMesh::fe2ti216().nodes(), 125);
    }

    #[test]
    fn tangent_is_solvable() {
        let mesh = MacroMesh::fe2ti216();
        let a = mesh.assemble_tangent(2.5);
        assert_eq!(a.n, 125);
        let lu = SparseLu::factor(&a).unwrap();
        let mut w = Work::default();
        let x = lu.solve(&vec![1.0; a.n], &mut w);
        assert!(a.residual_norm(&x, &vec![1.0; a.n]) < 1e-8);
    }

    #[test]
    fn sequential_macro_solve_work_grows_with_mesh() {
        let g = Geometry::pure_mpi(1, 72);
        let comm = CommModel::default();
        let small = macro_solve(&MacroMesh::fe2ti216(), 1.0, MacroSolver::SequentialDirect, &g, &comm)
            .unwrap();
        let big = macro_solve(&MacroMesh::fe2ti1728(), 1.0, MacroSolver::SequentialDirect, &g, &comm)
            .unwrap();
        assert!(big.serial_work.flops > 5.0 * small.serial_work.flops);
    }

    #[test]
    fn bddc_shifts_work_to_parallel() {
        let g = Geometry::pure_mpi(16, 48);
        let comm = CommModel::default();
        let seq = macro_solve(&MacroMesh::fe2ti1728(), 1.0, MacroSolver::SequentialDirect, &g, &comm)
            .unwrap();
        let bddc = macro_solve(&MacroMesh::fe2ti1728(), 1.0, MacroSolver::Bddc, &g, &comm).unwrap();
        assert!(bddc.serial_work.flops < seq.serial_work.flops);
        assert!(bddc.parallel_work.flops > 0.0);
    }

    #[test]
    fn micro_phase_sampling_scales_work() {
        let mesh = MacroMesh::fe2ti216();
        let cfg = SolverConfig::new(SolverKind::Ilu { tol: 1e-4 }, Compiler::Intel);
        let full = micro_phase(&mesh, 4, Material::default(), 0.1, &cfg, 1e-6, 8);
        assert_eq!(full.rves_solved, 8);
        assert_eq!(full.rves_total, 216);
        let per_rve = full.total_work.flops / 216.0;
        // sampled-and-scaled work should be close to a directly-solved RVE
        let mut rve = Rve::new(4, Material::default());
        let direct = rve.solve(0.1, &cfg, 1e-6);
        assert!(
            (per_rve - direct.work.flops).abs() / direct.work.flops < 0.5,
            "per_rve={per_rve} direct={}",
            direct.work.flops
        );
        assert!(full.mean_stress > 0.0);
    }
}
