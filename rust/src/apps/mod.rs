//! The two instrumented HPC applications the paper benchmarks.
//!
//! * [`fe2ti`] — FE² computational homogenization (implicit, PETSc-style
//!   solver stack): nested Newton, per-integration-point RVE solves,
//!   pluggable direct/iterative solvers.
//! * [`walberla`] — block-structured LBM framework (explicit, generated
//!   kernels): uniform-grid benchmarks with several collision operators
//!   and the free-surface LBM gravity-wave case.
//!
//! Both report exact likwid-style counters (`perf::`) and workload
//! profiles that the cluster node models project to per-architecture
//! timings (DESIGN.md §2).

pub mod fe2ti;
pub mod walberla;
