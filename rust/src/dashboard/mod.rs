//! Grafana stand-in: programmatic dashboards over the TSDB (paper §4.4).
//!
//! Dashboards are specified in code (the grafanalib analogue), carry
//! template variables (the interactive filter dropdowns, e.g. the
//! "collision Setup menu" of Fig. 6), and render to text/CSV for the
//! terminal and to a simple SVG for files. Panels query the TSDB with
//! group-by-tags, exactly how the paper's dashboards connect data points
//! with equal parameter values.

use crate::regress::{Alert, AlertState};
use crate::tsdb::{Aggregate, Db, Query};
use crate::util::table::{bar_chart, Table};

/// Panel flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PanelKind {
    /// Time series per group (runtime-over-commits panels).
    TimeSeries,
    /// Latest value per group as bars (Fig. 8's per-node latest results).
    LatestBars,
    /// Single aggregated number.
    Stat,
}

/// One dashboard panel.
#[derive(Debug, Clone)]
pub struct Panel {
    pub title: String,
    pub kind: PanelKind,
    pub measurement: String,
    pub field: String,
    pub group_by: Vec<String>,
    pub unit: String,
}

impl Panel {
    pub fn new(title: &str, kind: PanelKind, measurement: &str, field: &str) -> Panel {
        Panel {
            title: title.to_string(),
            kind,
            measurement: measurement.to_string(),
            field: field.to_string(),
            group_by: Vec::new(),
            unit: String::new(),
        }
    }
    pub fn group_by(mut self, tags: &[&str]) -> Panel {
        self.group_by = tags.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn unit(mut self, u: &str) -> Panel {
        self.unit = u.to_string();
        self
    }
}

/// A template variable: an interactive filter over a tag.
#[derive(Debug, Clone)]
pub struct TemplateVar {
    pub tag: String,
    /// Selected values; empty = all.
    pub selected: Vec<String>,
}

/// A dashboard: panels + filters.
#[derive(Debug, Clone)]
pub struct Dashboard {
    pub title: String,
    pub panels: Vec<Panel>,
    pub variables: Vec<TemplateVar>,
}

impl Dashboard {
    pub fn new(title: &str) -> Dashboard {
        Dashboard {
            title: title.to_string(),
            panels: Vec::new(),
            variables: Vec::new(),
        }
    }
    pub fn panel(mut self, p: Panel) -> Dashboard {
        self.panels.push(p);
        self
    }
    pub fn variable(mut self, tag: &str) -> Dashboard {
        self.variables.push(TemplateVar {
            tag: tag.to_string(),
            selected: Vec::new(),
        });
        self
    }

    /// Set a filter (like picking entries in a Grafana dropdown).
    pub fn select(&mut self, tag: &str, values: &[&str]) {
        for v in &mut self.variables {
            if v.tag == tag {
                v.selected = values.iter().map(|s| s.to_string()).collect();
            }
        }
    }

    fn apply_filters(&self, mut q: Query) -> Query {
        for v in &self.variables {
            if !v.selected.is_empty() {
                let refs: Vec<&str> = v.selected.iter().map(|s| s.as_str()).collect();
                q = q.where_tag_in(&v.tag, &refs);
            }
        }
        q
    }

    /// Render the dashboard against a TSDB as terminal text.
    pub fn render_text(&self, db: &Db) -> String {
        self.render_text_with_alerts(db, &[])
    }

    /// Render with regression-alert annotations: every panel whose
    /// measurement/field carries an unresolved alert gets a `!!` line —
    /// the Grafana alert-banner analogue (paper §4.4's "track how each
    /// code change affects the performance", surfaced where people look).
    pub fn render_text_with_alerts(&self, db: &Db, alerts: &[&Alert]) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        for v in &self.variables {
            let opts = db_options(db, &self.panels, &v.tag);
            let sel = if v.selected.is_empty() {
                "all".to_string()
            } else {
                v.selected.join(",")
            };
            out.push_str(&format!("filter {}: [{}] selected: {}\n", v.tag, opts.join(" "), sel));
        }
        for p in &self.panels {
            out.push('\n');
            out.push_str(&format!("-- {} ({}) --\n", p.title, p.unit));
            let q = self.apply_filters(
                Query::new(&p.measurement, &p.field)
                    .group_by(&p.group_by.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
            );
            match p.kind {
                PanelKind::TimeSeries => {
                    let mut t = Table::new(&["series", "points", "first", "last", "mean"]);
                    for s in q.run(db) {
                        let mean = s.aggregate(Aggregate::Mean);
                        t.row(&[
                            s.label(),
                            s.points.len().to_string(),
                            format!("{:.4}", s.points.first().map(|p| p.1).unwrap_or(f64::NAN)),
                            format!("{:.4}", s.points.last().map(|p| p.1).unwrap_or(f64::NAN)),
                            format!("{mean:.4}"),
                        ]);
                    }
                    out.push_str(&t.render());
                }
                PanelKind::LatestBars => {
                    let entries = q.run_agg(db, Aggregate::Last);
                    out.push_str(&bar_chart(&entries, 40));
                }
                PanelKind::Stat => {
                    let entries = q.run_agg(db, Aggregate::Last);
                    for (label, v) in entries {
                        out.push_str(&format!("{label}: {v:.4} {}\n", p.unit));
                    }
                }
            }
            for a in alerts
                .iter()
                .filter(|a| {
                    a.state != AlertState::Resolved
                        && a.measurement == p.measurement
                        && a.field == p.field
                })
            {
                out.push_str(&format!(
                    "  !! {} alert #{}: {} {:+.1}% vs baseline {:.3} (confidence {:.2}{})\n",
                    a.state.name(),
                    a.id,
                    a.series,
                    100.0 * a.rel_change,
                    a.baseline_mean,
                    a.confidence,
                    a.suspect_commit
                        .as_deref()
                        .map(|c| format!(", suspect commit {c}"))
                        .unwrap_or_default(),
                ));
            }
        }
        out
    }

    /// CSV export of every panel (one header line per panel block).
    pub fn render_csv(&self, db: &Db) -> String {
        let mut out = String::new();
        for p in &self.panels {
            out.push_str(&format!("# panel: {}\n", p.title));
            let q = self.apply_filters(
                Query::new(&p.measurement, &p.field)
                    .group_by(&p.group_by.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
            );
            out.push_str("series,ts,value\n");
            for s in q.run(db) {
                for (ts, v) in &s.points {
                    out.push_str(&format!("{},{ts},{v}\n", s.label()));
                }
            }
        }
        out
    }
}

fn db_options(db: &Db, panels: &[Panel], tag: &str) -> Vec<String> {
    let mut opts = Vec::new();
    for p in panels {
        for v in db.tag_values(&p.measurement, tag) {
            if !opts.contains(&v) {
                opts.push(v);
            }
        }
    }
    opts.sort();
    opts
}

/// The paper's two project dashboards, specified programmatically
/// (the grafanalib step of §4.5).
pub fn fe2ti_dashboard() -> Dashboard {
    Dashboard::new("FE2TI benchmarks")
        .variable("solver")
        .variable("node")
        .variable("parallelization")
        .variable("compiler")
        .panel(
            Panel::new("Time to solution", PanelKind::TimeSeries, "fe2ti", "tts")
                .group_by(&["solver", "compiler"])
                .unit("s"),
        )
        .panel(
            Panel::new("FLOP rate", PanelKind::TimeSeries, "fe2ti", "gflops")
                .group_by(&["solver", "compiler"])
                .unit("GFLOP/s"),
        )
        .panel(
            Panel::new("Operational intensity", PanelKind::TimeSeries, "fe2ti", "oi")
                .group_by(&["solver"])
                .unit("FLOP/byte"),
        )
        .panel(
            Panel::new("Verification error", PanelKind::Stat, "fe2ti", "verification_error")
                .group_by(&["solver"])
                .unit("rel"),
        )
}

/// The multi-repo campaign dashboard: one `campaign` point per collected
/// pipeline (see [`crate::coordinator::campaign::run_campaign`]) renders
/// into per-repository wall-time series — the at-a-glance view of how
/// the shared Testcluster splits between projects, and how much the
/// overlapped wall time diverges from each pipeline's idle-cluster
/// standalone duration.
pub fn campaign_dashboard() -> Dashboard {
    Dashboard::new("Campaign — multi-repo CB on one Testcluster")
        .variable("repo")
        .variable("kind")
        .panel(
            Panel::new("Pipeline wall time (overlapped)", PanelKind::TimeSeries, "campaign", "duration")
                .group_by(&["repo"])
                .unit("s"),
        )
        .panel(
            Panel::new("Standalone duration (idle cluster)", PanelKind::TimeSeries, "campaign", "standalone")
                .group_by(&["repo"])
                .unit("s"),
        )
        .panel(
            Panel::new("Jobs per pipeline", PanelKind::LatestBars, "campaign", "jobs")
                .group_by(&["repo"])
                .unit("jobs"),
        )
        // utilization split under maintenance windows: how many of a
        // pipeline's job starts were conservative backfills into a
        // drain-window gap vs ordinary head-of-line dispatches (both 0 /
        // all-head-of-line on an undrained cluster)
        .panel(
            Panel::new("Utilization: backfilled starts", PanelKind::LatestBars, "campaign", "backfilled")
                .group_by(&["repo"])
                .unit("jobs"),
        )
        .panel(
            Panel::new("Utilization: head-of-line starts", PanelKind::LatestBars, "campaign", "head_of_line")
                .group_by(&["repo"])
                .unit("jobs"),
        )
        // streaming-collect latencies: cluster-time from a pipeline's
        // submission to its first finished job and to its results being
        // uploaded + detection having run. Under streaming collect the
        // collect latency tracks the pipeline's own completion; under
        // batch collect it balloons to the roster makespan — this panel
        // is the A/B view of `cbench campaign --collect streaming|batch`.
        .panel(
            Panel::new("Latency: first result", PanelKind::TimeSeries, "campaign", "first_result_latency")
                .group_by(&["repo"])
                .unit("s"),
        )
        .panel(
            Panel::new("Latency: upload + detect", PanelKind::TimeSeries, "campaign", "collect_latency")
                .group_by(&["repo"])
                .unit("s"),
        )
        // alert SLA: how long a landed regression sat on the cluster
        // before its alert opened (only pipelines that opened alerts
        // upload this field)
        .panel(
            Panel::new("Alert SLA", PanelKind::Stat, "campaign", "alert_sla")
                .group_by(&["repo"])
                .unit("s"),
        )
        .panel(
            Panel::new("Failed jobs", PanelKind::Stat, "campaign", "failed")
                .group_by(&["repo"])
                .unit("jobs"),
        )
}

/// The self-observability dashboard: the benchmarker benchmarked. Renders
/// the `cbench_self` series the coordinator uploads when self-metrics are
/// on (`obs::metrics` counter deltas per collect — line-protocol parse,
/// TSDB insert, job-output parse, detector-state sync, shard loads) plus
/// the campaign-level latency/SLA series, so an infrastructure slowdown
/// shows up here exactly like a workload regression shows up on the
/// project dashboards — and the stock `self-throughput` policy alerts on
/// the same series.
pub fn self_observability_dashboard() -> Dashboard {
    Dashboard::new("cbench self-observability — infrastructure throughput")
        .variable("component")
        .variable("repo")
        .panel(
            // the series the stock self-throughput policy watches, so its
            // alerts annotate here
            Panel::new("Ingest/parse/sync throughput", PanelKind::TimeSeries, "cbench_self", "points_per_sec")
                .group_by(&["component"])
                .unit("points/s"),
        )
        .panel(
            Panel::new("Latest throughput by component", PanelKind::LatestBars, "cbench_self", "points_per_sec")
                .group_by(&["component"])
                .unit("points/s"),
        )
        .panel(
            Panel::new("Ops per collect", PanelKind::TimeSeries, "cbench_self", "ops")
                .group_by(&["component"])
                .unit("ops"),
        )
        .panel(
            Panel::new("Latency: upload + detect", PanelKind::TimeSeries, "campaign", "collect_latency")
                .group_by(&["repo"])
                .unit("s"),
        )
        .panel(
            Panel::new("Alert SLA", PanelKind::TimeSeries, "campaign", "alert_sla")
                .group_by(&["repo"])
                .unit("s"),
        )
}

pub fn walberla_dashboard() -> Dashboard {
    Dashboard::new("waLBerla benchmarks")
        .variable("case")
        .variable("collision_op")
        .variable("node")
        .variable("repo")
        .variable("branch")
        .panel(
            Panel::new("Runtime", PanelKind::TimeSeries, "lbm", "runtime")
                .group_by(&["collision_op", "node"])
                .unit("s"),
        )
        .panel(
            // total node throughput — the series the stock lbm-mlups
            // regression policy watches, so its alerts annotate here
            Panel::new("MLUP/s", PanelKind::TimeSeries, "lbm", "mlups")
                .group_by(&["collision_op", "node"])
                .unit("MLUP/s"),
        )
        .panel(
            Panel::new("MLUP/s per process", PanelKind::TimeSeries, "lbm", "mlups_per_process")
                .group_by(&["collision_op", "node"])
                .unit("MLUP/s"),
        )
        .panel(
            Panel::new("Relative to P_max", PanelKind::LatestBars, "lbm", "rel_to_pmax")
                .group_by(&["node"])
                .unit("fraction"),
        )
        .panel(
            Panel::new("Vectorized FLOP ratio", PanelKind::LatestBars, "lbm", "vec_ratio")
                .group_by(&["collision_op"])
                .unit("fraction"),
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tsdb::Point;

    fn db() -> Db {
        let mut db = Db::new();
        for (ts, op, node, mlups) in [
            (1, "srt", "icx36", 1500.0),
            (2, "srt", "icx36", 1510.0),
            (1, "trt", "icx36", 1400.0),
            (1, "srt", "rome1", 600.0),
        ] {
            db.insert(
                Point::new("lbm", ts)
                    .tag("collision_op", op)
                    .tag("node", node)
                    .field("mlups", mlups * 36.0)
                    .field("mlups_per_process", mlups)
                    .field("runtime", 1000.0 / mlups)
                    .field("rel_to_pmax", 0.8)
                    .field("vec_ratio", 0.9),
            );
        }
        db
    }

    #[test]
    fn stock_mlups_alert_annotates_walberla_dashboard() {
        // the default lbm-mlups policy's alerts must land on a real panel
        use crate::regress::{Detector, Direction};
        let d = walberla_dashboard();
        let det = Detector::with_default_policies();
        let p = det
            .policies
            .iter()
            .find(|p| p.measurement == "lbm" && p.direction == Direction::HigherIsBetter)
            .expect("stock lbm policy");
        assert!(
            d.panels.iter().any(|panel| panel.measurement == p.measurement
                && panel.field == p.field),
            "no waLBerla panel shows `{}.{}`",
            p.measurement,
            p.field
        );
    }

    #[test]
    fn stock_self_throughput_alert_annotates_self_dashboard() {
        // the self-throughput policy's alerts must land on a real panel
        use crate::regress::Detector;
        let d = self_observability_dashboard();
        let det = Detector::with_default_policies();
        let p = det
            .policies
            .iter()
            .find(|p| p.measurement == "cbench_self")
            .expect("stock self-throughput policy");
        assert!(
            d.panels.iter().any(|panel| panel.measurement == p.measurement
                && panel.field == p.field),
            "no self-observability panel shows `{}.{}`",
            p.measurement,
            p.field
        );
    }

    #[test]
    fn render_text_contains_all_panels() {
        let d = walberla_dashboard();
        let txt = d.render_text(&db());
        assert!(txt.contains("MLUP/s per process"));
        assert!(txt.contains("Relative to P_max"));
        assert!(txt.contains("collision_op=srt,node=icx36"));
        assert!(txt.contains("filter collision_op: [srt trt]"));
    }

    #[test]
    fn template_filter_limits_series() {
        let mut d = walberla_dashboard();
        d.select("collision_op", &["srt"]);
        let txt = d.render_text(&db());
        assert!(txt.contains("collision_op=srt"));
        assert!(!txt.contains("collision_op=trt"));
    }

    #[test]
    fn csv_export_parses_back() {
        let d = walberla_dashboard();
        let csv = d.render_csv(&db());
        assert!(csv.contains("# panel: Runtime"));
        assert!(csv.lines().any(|l| l.starts_with("collision_op=srt,node=icx36,")));
    }

    #[test]
    fn alerts_annotate_matching_panels_only() {
        use crate::regress::Direction;
        let alert = Alert {
            id: 3,
            fingerprint: "p/collision_op=srt,node=icx36".into(),
            policy: "p".into(),
            measurement: "lbm".into(),
            field: "runtime".into(),
            series: "collision_op=srt,node=icx36".into(),
            group: Default::default(),
            direction: Direction::LowerIsBetter,
            state: AlertState::Open,
            opened_ts: 1,
            last_seen_ts: 2,
            resolved_ts: None,
            times_seen: 2,
            confidence: 0.91,
            baseline_mean: 1.0,
            baseline_sd: 0.0,
            current: 1.2,
            rel_change: 0.2,
            change_ts: 2,
            sla_secs: None,
            sla_queue_secs: None,
            sla_run_secs: None,
            sla_collect_secs: None,
            sla_detect_secs: None,
            suspect_commit: Some("deadbeef".into()),
            first_bad_commit: None,
            archive_record: None,
            pipeline_collection: None,
        };
        let txt = walberla_dashboard().render_text_with_alerts(&db(), &[&alert]);
        assert!(txt.contains("!! open alert #3"));
        assert!(txt.contains("suspect commit deadbeef"));
        // annotated under the Runtime panel only
        assert_eq!(txt.matches("!!").count(), 1);
        // resolved alerts no longer annotate
        let mut resolved = alert.clone();
        resolved.state = AlertState::Resolved;
        let txt = walberla_dashboard().render_text_with_alerts(&db(), &[&resolved]);
        assert_eq!(txt.matches("!!").count(), 0);
    }

    #[test]
    fn campaign_dashboard_renders_per_repo_series() {
        let mut db = Db::new();
        for (ts, repo, dur, standalone) in [
            (1_000_000_000i64, "walberla-0", 320.0, 320.0),
            (2_000_000_000, "fe2ti-1", 3300.0, 3200.0),
            (3_000_000_000, "walberla-0", 330.0, 321.0),
        ] {
            db.insert(
                Point::new("campaign", ts)
                    .tag("repo", repo)
                    .tag("kind", repo.split('-').next().unwrap())
                    .field("duration", dur)
                    .field("standalone", standalone)
                    .field("jobs", 55.0)
                    .field("backfilled", 4.0)
                    .field("head_of_line", 51.0)
                    .field("failed", 0.0)
                    .field("first_result_latency", 60.0)
                    .field("collect_latency", dur),
            );
        }
        // only the alert-opening pipeline uploads an alert_sla field
        db.insert(
            Point::new("campaign", 4_000_000_000)
                .tag("repo", "walberla-0")
                .tag("kind", "walberla")
                .field("duration", 320.0)
                .field("standalone", 320.0)
                .field("jobs", 55.0)
                .field("backfilled", 0.0)
                .field("head_of_line", 55.0)
                .field("failed", 0.0)
                .field("first_result_latency", 58.0)
                .field("collect_latency", 320.0)
                .field("alert_sla", 320.0),
        );
        let d = campaign_dashboard();
        let txt = d.render_text(&db);
        assert!(txt.contains("Pipeline wall time (overlapped)"));
        assert!(txt.contains("repo=walberla-0"));
        assert!(txt.contains("repo=fe2ti-1"));
        assert!(txt.contains("filter repo:"));
        // the maintenance-utilization split renders per repository
        assert!(txt.contains("Utilization: backfilled starts"));
        assert!(txt.contains("Utilization: head-of-line starts"));
        // the streaming-collect latency + alert SLA panels render
        assert!(txt.contains("Latency: first result"));
        assert!(txt.contains("Latency: upload + detect"));
        assert!(txt.contains("Alert SLA"));
        // repo filter narrows to one project
        let mut d = campaign_dashboard();
        d.select("repo", &["fe2ti-1"]);
        let txt = d.render_text(&db);
        assert!(txt.contains("repo=fe2ti-1"));
        assert!(!txt.contains("repo=walberla-0"));
    }

    #[test]
    fn fe2ti_dashboard_has_verification_panel() {
        let d = fe2ti_dashboard();
        assert!(d.panels.iter().any(|p| p.title.contains("Verification")));
        assert_eq!(d.variables.len(), 4);
    }
}
