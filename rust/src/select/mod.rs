//! Change-aware benchmark selection (the exaCB idea, PAPERS.md arxiv
//! 2603.22251): re-run only the benchmark subset a change can affect and
//! carry the rest forward from the component's last measured commit.
//!
//! The model has three pieces:
//!
//! * **Touched surface** — a push's changed paths (tracked by
//!   [`crate::vcs::PushEvent::changed`]) classify to *components*:
//!   `src/lbm/cpu/**` → `lbm/cpu`, `src/fe2ti/pardiso/**` →
//!   `fe2ti/pardiso`, and so on. Build/config/CI surface
//!   (`benchmark.cfg`, YAML, Makefiles, `ci/`, `scripts/`) classifies to
//!   *affects-everything*, as does any path the classifier does not
//!   recognise — unknown must never mean "safe to skip".
//! * **Job declarations** — a pipeline job declares the components its
//!   measurement depends on in the `CB_COMPONENTS` CI variable
//!   (comma-separated). Jobs with no declaration are conservatively
//!   treated as affected by every change.
//! * **The [`Selector`]** — remembers, per `(repo, job)`, the points and
//!   duration of the job's last *measured* run so a skipped job can be
//!   carried forward and the saved cluster time can be reported.
//!
//! Safety contract (property-tested in `rust/tests/select_prop.rs`):
//! because job payloads are pure functions of the benchmark config, a
//! correctly-declared skipped job would have reproduced its previous
//! value bit for bit — so carried-forward points are tagged `carried=1`
//! and the detector treats them as *non-evidence*: they keep a series
//! fresh at the stale-tenant boundary and keep open alerts' bookkeeping
//! identical to a full run, but can neither open nor auto-resolve
//! alerts. A regression committed to an untouched component is caught on
//! the next commit that touches it (deferred, never lost).

use crate::ci::CiJob;
use crate::tsdb::Point;
use std::collections::{BTreeMap, BTreeSet};

/// CI variable a job uses to declare the components it measures.
pub const COMPONENTS_VAR: &str = "CB_COMPONENTS";

/// Tag carried-forward points are stamped with (value `"1"`).
pub const CARRIED_TAG: &str = "carried";

/// Tag recording which measured commit a carried point was copied from.
pub const CARRIED_FROM_TAG: &str = "carried_from";

/// Selection mode for pipeline submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SelectMode {
    /// Run the full matrix on every push (the pre-PR-9 behaviour).
    #[default]
    Full,
    /// Skip jobs whose declared components the push cannot affect.
    ChangeAware,
}

impl SelectMode {
    pub fn parse(s: &str) -> Option<SelectMode> {
        match s {
            "full" => Some(SelectMode::Full),
            "change-aware" | "changeaware" | "change_aware" => Some(SelectMode::ChangeAware),
            _ => None,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            SelectMode::Full => "full",
            SelectMode::ChangeAware => "change-aware",
        }
    }
}

/// The component surface a push touches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Touched {
    /// Build/config/CI or unclassifiable paths: every job is affected.
    All,
    /// Only jobs declaring one of these components are affected.
    Components(BTreeSet<String>),
}

impl Touched {
    /// Is a job declaring `declared` affected by this touched surface?
    /// Matching is exact or at a `/` group boundary in either direction:
    /// touched `fe2ti` affects declared `fe2ti/pardiso` and vice versa.
    pub fn affects(&self, declared: &[String]) -> bool {
        match self {
            Touched::All => true,
            Touched::Components(set) => declared.iter().any(|d| {
                set.iter().any(|t| {
                    t == d
                        || d.starts_with(&format!("{t}/"))
                        || t.starts_with(&format!("{d}/"))
                })
            }),
        }
    }
}

/// Classify one changed path to the components it belongs to. `None`
/// means the path affects everything (config/build/CI surface, or a path
/// the classifier does not model).
pub fn classify_path(path: &str) -> Option<Vec<String>> {
    let base = path.rsplit('/').next().unwrap_or(path);
    let config_surface = base == "benchmark.cfg"
        || base.ends_with(".yml")
        || base.ends_with(".yaml")
        || base == "Makefile"
        || base == "CMakeLists.txt"
        || base.ends_with(".cmake")
        || path.starts_with("ci/")
        || path.starts_with(".github/")
        || path.starts_with("scripts/");
    if config_surface {
        return None;
    }
    if let Some(rest) = path.strip_prefix("src/lbm/") {
        return Some(match rest.split('/').next() {
            Some("cpu") => vec!["lbm/cpu".to_string()],
            Some("gpu") => vec!["lbm/gpu".to_string()],
            Some("fslbm") => vec!["lbm/fslbm".to_string()],
            // shared lbm source: every backend rebuilds
            _ => vec!["lbm".to_string()],
        });
    }
    if let Some(rest) = path.strip_prefix("src/fe2ti/") {
        return Some(match rest.split('/').next() {
            // a solver-stage subdirectory names its component
            Some(stage) if rest.contains('/') => vec![format!("fe2ti/{stage}")],
            // shared fe2ti source: every solver stage rebuilds
            _ => vec!["fe2ti".to_string()],
        });
    }
    if path.starts_with("src/scaling/") {
        return Some(vec!["scaling".to_string()]);
    }
    None
}

/// Fold a push's changed paths into its touched surface. An *empty*
/// change list means the surface is unknown (hand-built events, root
/// pushes from before tracking) and is conservatively affects-everything.
pub fn touched(changed: &[String]) -> Touched {
    if changed.is_empty() {
        return Touched::All;
    }
    let mut set = BTreeSet::new();
    for path in changed {
        match classify_path(path) {
            None => return Touched::All,
            Some(cs) => set.extend(cs),
        }
    }
    Touched::Components(set)
}

/// The components a job declares via [`COMPONENTS_VAR`]. `None` when the
/// job declares nothing — such jobs are always run.
pub fn components_of(job: &CiJob) -> Option<Vec<String>> {
    job.get(COMPONENTS_VAR).map(|v| {
        v.split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    })
}

/// The last measured run of one job: the points it uploaded (before
/// retagging) and its simulated duration, for carry-forward and savings
/// accounting.
#[derive(Debug, Clone, Default)]
pub struct StoredRun {
    pub points: Vec<Point>,
    pub duration: f64,
    /// Short commit tag the run measured.
    pub commit: String,
}

/// Per-`(repo, job)` memory of last measured runs. Lives on the
/// coordinator; deterministic (BTreeMap order, no timestamps of its own).
#[derive(Debug, Default)]
pub struct Selector {
    runs: BTreeMap<(String, String), StoredRun>,
}

impl Selector {
    pub fn new() -> Selector {
        Selector::default()
    }

    pub fn record(&mut self, repo: &str, job: &str, run: StoredRun) {
        self.runs.insert((repo.to_string(), job.to_string()), run);
    }

    pub fn last(&self, repo: &str, job: &str) -> Option<&StoredRun> {
        self.runs.get(&(repo.to_string(), job.to_string()))
    }

    /// Can `job` be skipped for a push with this touched surface? True
    /// only when the job declares components, none of them is affected,
    /// and a previous measured run exists to carry forward.
    pub fn can_skip(&self, repo: &str, job: &CiJob, touched: &Touched) -> bool {
        if matches!(touched, Touched::All) {
            return false;
        }
        match components_of(job) {
            Some(cs) if !cs.is_empty() => {
                !touched.affects(&cs) && self.last(repo, &job.name).is_some()
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn config_surface_affects_everything() {
        for p in [
            "benchmark.cfg",
            "app/benchmark.cfg",
            ".gitlab-ci.yml",
            "ci/pipeline.sh",
            "CMakeLists.txt",
            "cmake/toolchain.cmake",
            "scripts/run.sh",
            "totally/unknown/path.c",
        ] {
            assert_eq!(classify_path(p), None, "{p}");
        }
        assert_eq!(touched(&strs(&["src/lbm/cpu/k.c", "benchmark.cfg"])), Touched::All);
        assert_eq!(touched(&[]), Touched::All);
    }

    #[test]
    fn backend_paths_classify_to_their_component() {
        assert_eq!(classify_path("src/lbm/cpu/stream.c"), Some(strs(&["lbm/cpu"])));
        assert_eq!(classify_path("src/lbm/gpu/stream.cu"), Some(strs(&["lbm/gpu"])));
        assert_eq!(classify_path("src/lbm/fslbm/surface.c"), Some(strs(&["lbm/fslbm"])));
        assert_eq!(classify_path("src/lbm/lattice.h"), Some(strs(&["lbm"])));
        assert_eq!(classify_path("src/fe2ti/pardiso/factor.c"), Some(strs(&["fe2ti/pardiso"])));
        assert_eq!(classify_path("src/fe2ti/common.c"), Some(strs(&["fe2ti"])));
    }

    #[test]
    fn group_prefix_matching_is_symmetric() {
        let t = touched(&strs(&["src/lbm/lattice.h"]));
        assert!(t.affects(&strs(&["lbm/cpu"])), "group touch hits member");
        assert!(!t.affects(&strs(&["fe2ti/pardiso"])));
        let t = touched(&strs(&["src/lbm/gpu/k.cu"]));
        assert!(t.affects(&strs(&["lbm/gpu"])));
        assert!(!t.affects(&strs(&["lbm/cpu"])));
        // declared group, touched member
        assert!(t.affects(&strs(&["lbm"])));
    }

    #[test]
    fn selector_skips_only_declared_unaffected_jobs_with_history() {
        let mut sel = Selector::new();
        let declared = CiJob::new("cpu-bench", "benchmark").var(COMPONENTS_VAR, "lbm/cpu");
        let undeclared = CiJob::new("misc", "benchmark");
        let gpu_touch = touched(&strs(&["src/lbm/gpu/k.cu"]));

        // no stored run yet: must run even though unaffected
        assert!(!sel.can_skip("r", &declared, &gpu_touch));
        sel.record("r", "cpu-bench", StoredRun::default());
        assert!(sel.can_skip("r", &declared, &gpu_touch));
        // affected component: run
        let cpu_touch = touched(&strs(&["src/lbm/cpu/k.c"]));
        assert!(!sel.can_skip("r", &declared, &cpu_touch));
        // All surface: run
        assert!(!sel.can_skip("r", &declared, &Touched::All));
        // undeclared job: always run
        assert!(!sel.can_skip("r", &undeclared, &gpu_touch));
        // different repo: no history there
        assert!(!sel.can_skip("other", &declared, &gpu_touch));
    }

    #[test]
    fn select_mode_parses_cli_spellings() {
        assert_eq!(SelectMode::parse("full"), Some(SelectMode::Full));
        assert_eq!(SelectMode::parse("change-aware"), Some(SelectMode::ChangeAware));
        assert_eq!(SelectMode::parse("nope"), None);
        assert_eq!(SelectMode::default(), SelectMode::Full);
        assert_eq!(SelectMode::ChangeAware.name(), "change-aware");
    }
}
