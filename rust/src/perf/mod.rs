//! likwid-perfctr stand-in: exact operation/traffic counting with region
//! markers and derived metrics.
//!
//! The paper gathers FLOP counts, data-traffic volumes and timings with the
//! likwid tool-suite (§4.2) and derives operational intensity / GFLOP/s for
//! the roofline dashboards (§4.4). Our applications are instrumented at the
//! source level: every kernel reports the FLOPs it executed and the bytes
//! it moved, so the "counters" here are exact by construction rather than
//! sampled from PMU registers. The same `Region` API shape as
//! `LIKWID_MARKER_START/STOP` is kept so application code reads naturally.

use crate::cluster::WorkProfile;
use std::collections::BTreeMap;

/// Counter state for one marker region.
#[derive(Debug, Clone, Default)]
pub struct RegionStats {
    /// Number of start/stop visits.
    pub calls: usize,
    /// Accumulated (simulated) runtime in seconds.
    pub time: f64,
    /// Exact DP FLOP count.
    pub flops: f64,
    /// Exact main-memory traffic in bytes.
    pub bytes: f64,
    /// FLOPs executed through vector (SIMD) units — the paper's dashboard
    /// has a "ratio of vectorized to total FLOP count" panel (Fig. 6).
    pub vector_flops: f64,
}

impl RegionStats {
    /// Operational intensity (FLOP/byte).
    pub fn intensity(&self) -> f64 {
        if self.bytes <= 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }
    /// Achieved GFLOP/s over the accumulated time.
    pub fn gflops(&self) -> f64 {
        if self.time <= 0.0 {
            0.0
        } else {
            self.flops / self.time / 1e9
        }
    }
    /// Achieved memory bandwidth in GB/s.
    pub fn bandwidth_gbs(&self) -> f64 {
        if self.time <= 0.0 {
            0.0
        } else {
            self.bytes / self.time / 1e9
        }
    }
    /// Fraction of FLOPs that were vectorized.
    pub fn vector_ratio(&self) -> f64 {
        if self.flops <= 0.0 {
            0.0
        } else {
            self.vector_flops / self.flops
        }
    }
    pub fn as_profile(&self) -> WorkProfile {
        WorkProfile::new(self.flops, self.bytes)
    }
}

/// A likwid-like measurement context: named regions with exact counters.
#[derive(Debug, Default, Clone)]
pub struct PerfMonitor {
    regions: BTreeMap<String, RegionStats>,
    open: BTreeMap<String, f64>, // region -> start time
    clock: f64,
}

impl PerfMonitor {
    pub fn new() -> PerfMonitor {
        PerfMonitor::default()
    }

    /// Advance the monitor's clock (simulated seconds).
    pub fn tick(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.clock += dt;
    }
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// `LIKWID_MARKER_START`.
    pub fn start(&mut self, region: &str) {
        self.open.insert(region.to_string(), self.clock);
        self.regions.entry(region.to_string()).or_default().calls += 1;
    }

    /// `LIKWID_MARKER_STOP` — accounts elapsed simulated time since start.
    pub fn stop(&mut self, region: &str) {
        if let Some(t0) = self.open.remove(region) {
            let r = self.regions.entry(region.to_string()).or_default();
            r.time += self.clock - t0;
        }
    }

    /// Count work inside the currently-open (or any) region.
    pub fn count(&mut self, region: &str, flops: f64, bytes: f64, vector_flops: f64) {
        let r = self.regions.entry(region.to_string()).or_default();
        r.flops += flops;
        r.bytes += bytes;
        r.vector_flops += vector_flops;
    }

    /// Convenience: run a region of `dur` seconds with the given counts.
    pub fn record(&mut self, region: &str, dur: f64, flops: f64, bytes: f64, vector_flops: f64) {
        self.start(region);
        self.tick(dur);
        self.count(region, flops, bytes, vector_flops);
        self.stop(region);
    }

    pub fn region(&self, name: &str) -> Option<&RegionStats> {
        self.regions.get(name)
    }

    pub fn regions(&self) -> impl Iterator<Item = (&String, &RegionStats)> {
        self.regions.iter()
    }

    /// Total over all regions.
    pub fn total(&self) -> RegionStats {
        let mut t = RegionStats::default();
        for r in self.regions.values() {
            t.calls += r.calls;
            t.time += r.time;
            t.flops += r.flops;
            t.bytes += r.bytes;
            t.vector_flops += r.vector_flops;
        }
        t
    }

    /// Render the likwid-style text report the pipeline parses and uploads.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str("--- perfctr report (likwid-sim) ---\n");
        for (name, r) in &self.regions {
            out.push_str(&format!(
                "REGION {name} calls={} time={:.6} flops={:.6e} bytes={:.6e} oi={:.4} gflops={:.3} bw_gbs={:.3} vec_ratio={:.3}\n",
                r.calls,
                r.time,
                r.flops,
                r.bytes,
                r.intensity(),
                r.gflops(),
                r.bandwidth_gbs(),
                r.vector_ratio(),
            ));
        }
        out
    }

    /// Parse a report produced by [`PerfMonitor::report`] back into region
    /// stats — the pipeline's output-parsing step (§4.3).
    pub fn parse_report(text: &str) -> BTreeMap<String, RegionStats> {
        let mut out = BTreeMap::new();
        for line in text.lines() {
            let Some(rest) = line.strip_prefix("REGION ") else {
                continue;
            };
            let mut name = String::new();
            let mut stats = RegionStats::default();
            for (i, tok) in rest.split_whitespace().enumerate() {
                if i == 0 {
                    name = tok.to_string();
                    continue;
                }
                if let Some((k, v)) = tok.split_once('=') {
                    let v: f64 = v.parse().unwrap_or(0.0);
                    match k {
                        "calls" => stats.calls = v as usize,
                        "time" => stats.time = v,
                        "flops" => stats.flops = v,
                        "bytes" => stats.bytes = v,
                        "vec_ratio" => stats.vector_flops = v, // fixed up below
                        _ => {}
                    }
                }
            }
            stats.vector_flops *= stats.flops; // vec_ratio -> absolute
            out.insert(name, stats);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_accumulates_time_and_counts() {
        let mut m = PerfMonitor::new();
        m.start("rve_solve");
        m.tick(2.0);
        m.count("rve_solve", 4e9, 1e9, 3e9);
        m.stop("rve_solve");
        m.record("rve_solve", 2.0, 4e9, 1e9, 3e9);

        let r = m.region("rve_solve").unwrap();
        assert_eq!(r.calls, 2);
        assert_eq!(r.time, 4.0);
        assert_eq!(r.flops, 8e9);
        assert_eq!(r.intensity(), 4.0);
        assert_eq!(r.gflops(), 2.0);
        assert!((r.vector_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn nested_regions_dont_interfere() {
        let mut m = PerfMonitor::new();
        m.start("outer");
        m.tick(1.0);
        m.start("inner");
        m.tick(2.0);
        m.stop("inner");
        m.tick(1.0);
        m.stop("outer");
        assert_eq!(m.region("outer").unwrap().time, 4.0);
        assert_eq!(m.region("inner").unwrap().time, 2.0);
    }

    #[test]
    fn report_roundtrips_through_parser() {
        let mut m = PerfMonitor::new();
        m.record("collide", 0.5, 1e9, 2e9, 0.8e9);
        m.record("stream", 0.25, 0.0, 3e9, 0.0);
        let text = m.report();
        let parsed = PerfMonitor::parse_report(&text);
        let c = &parsed["collide"];
        assert_eq!(c.calls, 1);
        assert!((c.time - 0.5).abs() < 1e-9);
        assert!((c.flops - 1e9).abs() / 1e9 < 1e-5);
        assert!((c.vector_flops - 0.8e9).abs() / 1e9 < 1e-3);
        assert!(parsed.contains_key("stream"));
    }

    #[test]
    fn total_sums_regions() {
        let mut m = PerfMonitor::new();
        m.record("a", 1.0, 1e9, 1e9, 0.0);
        m.record("b", 2.0, 3e9, 1e9, 0.0);
        let t = m.total();
        assert_eq!(t.time, 3.0);
        assert_eq!(t.flops, 4e9);
    }

    #[test]
    fn zero_time_region_has_zero_rates() {
        let r = RegionStats::default();
        assert_eq!(r.gflops(), 0.0);
        assert_eq!(r.bandwidth_gbs(), 0.0);
        assert!(r.intensity().is_infinite());
    }
}
