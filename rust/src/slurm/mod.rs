//! Slurm stand-in: single-node batch scheduling over the simulated
//! Testcluster.
//!
//! The paper's pipeline assembles job scripts and submits them with
//! `sbatch --parsable --wait --nodelist=$HOST` (Listing 1); the Testcluster
//! partition only allows single-node jobs (§4.1). This module implements
//! exactly that contract in simulated time:
//!
//! * [`Scheduler::sbatch`] queues a job targeting one node (FIFO per node),
//! * job payloads are closures that "run" on the node model and return
//!   their stdout plus the simulated duration,
//! * `SLURM_TIMELIMIT` (minutes) kills overrunning jobs (`Timeout` state),
//! * [`Scheduler::wait_all`] advances simulated time until the queue
//!   drains (the `--wait` behaviour),
//! * completed jobs leave a log file content (`$CI_JOB_NAME.o$JOBID.log`).

use crate::cluster::nodes::NodeModel;
use std::collections::BTreeMap;

/// Outcome a job payload reports back.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Simulated runtime in seconds.
    pub duration: f64,
    /// Captured stdout (the benchmark's output the pipeline parses).
    pub stdout: String,
    /// Nonzero = job failed.
    pub exit_code: i32,
}

/// The payload executed when the job starts: gets the node model and the
/// simulated start time.
pub type Payload = Box<dyn FnOnce(&NodeModel, f64) -> JobOutcome + Send>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Failed,
    Timeout,
    Cancelled,
}

/// Submission parameters (the `sbatch` flags the pipeline uses).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// `--nodelist`: the single target host (Testcluster is single-node).
    pub nodelist: String,
    /// `SLURM_TIMELIMIT` in minutes.
    pub timelimit_min: f64,
}

/// Scheduler-side job record.
pub struct Job {
    pub id: u64,
    pub spec: JobSpec,
    pub state: JobState,
    pub submit_time: f64,
    pub start_time: Option<f64>,
    pub end_time: Option<f64>,
    pub log: String,
    payload: Option<Payload>,
}

impl std::fmt::Debug for Job {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Job")
            .field("id", &self.id)
            .field("name", &self.spec.name)
            .field("node", &self.spec.nodelist)
            .field("state", &self.state)
            .finish()
    }
}

/// The cluster scheduler: one FIFO queue per node, simulated clock.
pub struct Scheduler {
    nodes: BTreeMap<String, NodeModel>,
    jobs: Vec<Job>,
    /// Per-node: sim time at which the node becomes free.
    node_free_at: BTreeMap<String, f64>,
    clock: f64,
    next_id: u64,
}

impl Scheduler {
    /// Build a scheduler over the given nodes.
    pub fn new(nodes: Vec<NodeModel>) -> Scheduler {
        let node_free_at = nodes.iter().map(|n| (n.host.to_string(), 0.0)).collect();
        Scheduler {
            nodes: nodes.into_iter().map(|n| (n.host.to_string(), n)).collect(),
            jobs: Vec::new(),
            node_free_at,
            clock: 0.0,
            next_id: 1000,
        }
    }

    pub fn now(&self) -> f64 {
        self.clock
    }
    pub fn nodes(&self) -> impl Iterator<Item = &NodeModel> {
        self.nodes.values()
    }
    pub fn node(&self, host: &str) -> Option<&NodeModel> {
        self.nodes.get(host)
    }

    /// `sbatch --parsable`: queue a job, return its id. Errors if the
    /// nodelist names an unknown host (sbatch would reject it).
    pub fn sbatch(&mut self, spec: JobSpec, payload: Payload) -> Result<u64, String> {
        if !self.nodes.contains_key(&spec.nodelist) {
            return Err(format!(
                "sbatch: invalid nodelist `{}` (unknown host)",
                spec.nodelist
            ));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.push(Job {
            id,
            spec,
            state: JobState::Pending,
            submit_time: self.clock,
            start_time: None,
            end_time: None,
            log: String::new(),
            payload: Some(payload),
        });
        Ok(id)
    }

    /// `squeue`: all jobs in the given state.
    pub fn squeue(&self, state: JobState) -> Vec<&Job> {
        self.jobs.iter().filter(|j| j.state == state).collect()
    }

    pub fn job(&self, id: u64) -> Option<&Job> {
        self.jobs.iter().find(|j| j.id == id)
    }

    /// `scancel`.
    pub fn scancel(&mut self, id: u64) -> bool {
        for j in &mut self.jobs {
            if j.id == id && j.state == JobState::Pending {
                j.state = JobState::Cancelled;
                j.payload = None;
                return true;
            }
        }
        false
    }

    /// Run every pending job to completion in FIFO order per node,
    /// advancing the simulated clock (the `--wait` semantics the pipeline
    /// relies on). Returns ids of jobs executed this call.
    pub fn wait_all(&mut self) -> Vec<u64> {
        let mut executed = Vec::new();
        // FIFO per node: process in submission order
        let order: Vec<usize> = (0..self.jobs.len())
            .filter(|&i| self.jobs[i].state == JobState::Pending)
            .collect();
        for i in order {
            let node_host = self.jobs[i].spec.nodelist.clone();
            let node = self.nodes[&node_host].clone();
            let free_at = self.node_free_at[&node_host].max(self.jobs[i].submit_time);
            let start = free_at;
            let payload = self.jobs[i].payload.take().expect("pending job has payload");
            self.jobs[i].state = JobState::Running;
            self.jobs[i].start_time = Some(start);

            let outcome = payload(&node, start);
            let limit = self.jobs[i].spec.timelimit_min * 60.0;
            let (dur, state) = if outcome.duration > limit {
                (limit, JobState::Timeout)
            } else if outcome.exit_code != 0 {
                (outcome.duration, JobState::Failed)
            } else {
                (outcome.duration, JobState::Completed)
            };
            let end = start + dur;
            self.node_free_at.insert(node_host.clone(), end);
            self.clock = self.clock.max(end);

            let j = &mut self.jobs[i];
            j.end_time = Some(end);
            j.state = state;
            j.log = format!(
                "== slurm job {} ({}) on {} ==\nsubmit={:.3} start={:.3} end={:.3} state={:?}\n{}{}",
                j.id,
                j.spec.name,
                j.spec.nodelist,
                j.submit_time,
                start,
                end,
                state,
                outcome.stdout,
                if state == JobState::Timeout {
                    format!("\nslurmstepd: *** JOB {} CANCELLED DUE TO TIME LIMIT ***\n", j.id)
                } else {
                    String::new()
                }
            );
            executed.push(j.id);
        }
        executed
    }

    /// The log-file content the CI job `cat`s after `--wait` returns
    /// (`${CI_JOB_NAME}.o${job_id}.log` in Listing 1).
    pub fn job_log(&self, id: u64) -> Option<&str> {
        self.job(id).map(|j| j.log.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::catalogue;

    fn sched() -> Scheduler {
        Scheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect())
    }

    fn ok_payload(dur: f64, out: &str) -> Payload {
        let out = out.to_string();
        Box::new(move |_n, _t| JobOutcome {
            duration: dur,
            stdout: out,
            exit_code: 0,
        })
    }

    #[test]
    fn sbatch_queues_and_wait_completes() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec {
                    name: "fe2ti216-icx36".into(),
                    nodelist: "icx36".into(),
                    timelimit_min: 120.0,
                },
                ok_payload(40.0, "TTS=40.0\n"),
            )
            .unwrap();
        assert_eq!(s.squeue(JobState::Pending).len(), 1);
        s.wait_all();
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.end_time, Some(40.0));
        assert!(s.job_log(id).unwrap().contains("TTS=40.0"));
    }

    #[test]
    fn unknown_node_rejected() {
        let mut s = sched();
        let r = s.sbatch(
            JobSpec {
                name: "x".into(),
                nodelist: "nonexistent".into(),
                timelimit_min: 1.0,
            },
            ok_payload(1.0, ""),
        );
        assert!(r.is_err());
    }

    #[test]
    fn fifo_per_node_serializes_same_node_jobs() {
        let mut s = sched();
        let a = s
            .sbatch(
                JobSpec { name: "a".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                ok_payload(10.0, ""),
            )
            .unwrap();
        let b = s
            .sbatch(
                JobSpec { name: "b".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                ok_payload(5.0, ""),
            )
            .unwrap();
        // different node runs in parallel (starts at t=0)
        let c = s
            .sbatch(
                JobSpec { name: "c".into(), nodelist: "rome1".into(), timelimit_min: 10.0 },
                ok_payload(7.0, ""),
            )
            .unwrap();
        s.wait_all();
        assert_eq!(s.job(a).unwrap().end_time, Some(10.0));
        assert_eq!(s.job(b).unwrap().start_time, Some(10.0));
        assert_eq!(s.job(b).unwrap().end_time, Some(15.0));
        assert_eq!(s.job(c).unwrap().start_time, Some(0.0));
        assert_eq!(s.job(c).unwrap().end_time, Some(7.0));
    }

    #[test]
    fn timelimit_kills_job() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "slow".into(), nodelist: "icx36".into(), timelimit_min: 1.0 },
                ok_payload(3600.0, "partial output\n"),
            )
            .unwrap();
        s.wait_all();
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Timeout);
        assert_eq!(j.end_time, Some(60.0));
        assert!(j.log.contains("CANCELLED DUE TO TIME LIMIT"));
    }

    #[test]
    fn failing_job_marked_failed() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "bad".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                Box::new(|_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: "segfault".into(),
                    exit_code: 139,
                }),
            )
            .unwrap();
        s.wait_all();
        assert_eq!(s.job(id).unwrap().state, JobState::Failed);
    }

    #[test]
    fn scancel_pending_only() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "x".into(), nodelist: "icx36".into(), timelimit_min: 1.0 },
                ok_payload(1.0, ""),
            )
            .unwrap();
        assert!(s.scancel(id));
        assert!(!s.scancel(id)); // already cancelled
        s.wait_all();
        assert_eq!(s.job(id).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn payload_sees_node_model() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "probe".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                Box::new(|n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: format!("cores={}", n.cores()),
                    exit_code: 0,
                }),
            )
            .unwrap();
        s.wait_all();
        assert!(s.job_log(id).unwrap().contains("cores=72"));
    }
}
