//! Slurm stand-in: the `sbatch --parsable --wait` contract over the
//! simulated Testcluster.
//!
//! The paper's pipeline assembles job scripts and submits them with
//! `sbatch --parsable --wait --nodelist=$HOST` (Listing 1); the Testcluster
//! partition only allows single-node jobs (§4.1). This module preserves
//! exactly that contract — but since the `sched::` refactor it is a thin
//! veneer over the event-driven [`crate::sched::SimScheduler`]:
//!
//! * [`Scheduler::sbatch`] queues a job targeting one node,
//! * job payloads are closures that "run" on the node model and return
//!   their stdout plus the simulated duration,
//! * `SLURM_TIMELIMIT` (minutes) kills overrunning jobs (`Timeout` state),
//! * [`Scheduler::wait_all`] drains the event queue (the `--wait`
//!   behaviour); phase-split callers use the engine's completion events
//!   directly instead (see [`crate::coordinator::CbSystem::submit_pipeline`]),
//! * completed jobs leave a log file content (`$CI_JOB_NAME.o$JOBID.log`).
//!
//! Jobs submitted through this wrapper run as owner `default` with
//! priority 0 — single-tenant FIFO, which is what `sbatch --wait` scripts
//! expect. Multi-repo fair-share and priorities live in [`crate::sched`].
//!
//! Since the backfill/maintenance refactor the veneer also exposes
//! * [`parse_time`] — the sbatch `--time` grammar (`M`, `M:S`, `H:M:S`,
//!   `D-H[:M[:S]]`) so CI `SLURM_TIMELIMIT` variables can use real Slurm
//!   time strings, and
//! * [`Scheduler::scontrol_drain`] / [`Scheduler::scontrol_resume`] —
//!   the `scontrol update nodename=... state=drain|resume` analogue over
//!   the engine's maintenance windows (no new job starts on a draining
//!   node; running jobs finish).

use crate::cluster::nodes::NodeModel;
use crate::sched::{SimScheduler, SubmitSpec};

pub use crate::sched::{JobOutcome, JobState, Payload};

/// Parse an sbatch `--time` specification into **minutes**. Accepted
/// forms (the Slurm grammar subset the pipelines use): `M`, `M:S`,
/// `H:M:S`, `D-H`, `D-H:M`, `D-H:M:S`. Returns `None` for anything else.
pub fn parse_time(spec: &str) -> Option<f64> {
    let spec = spec.trim();
    if spec.is_empty() {
        return None;
    }
    let (days, rest, dayform) = match spec.split_once('-') {
        Some((d, r)) => (d.parse::<f64>().ok().filter(|d| *d >= 0.0)?, r, true),
        None => (0.0, spec, false),
    };
    let nums: Vec<f64> = rest
        .split(':')
        .map(|p| p.parse::<f64>().ok().filter(|v| *v >= 0.0))
        .collect::<Option<_>>()?;
    let minutes = match (dayform, nums.as_slice()) {
        (true, [h]) => h * 60.0,
        (true, [h, m]) => h * 60.0 + m,
        (true, [h, m, s]) => h * 60.0 + m + s / 60.0,
        (false, [m]) => *m,
        (false, [m, s]) => m + s / 60.0,
        (false, [h, m, s]) => h * 60.0 + m + s / 60.0,
        _ => return None,
    };
    Some(days * 24.0 * 60.0 + minutes)
}

/// Scheduler-side job record (the event engine's).
pub type Job = crate::sched::SimJob;

/// Submission parameters (the `sbatch` flags the pipeline uses).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// `--nodelist`: the single target host (Testcluster is single-node).
    pub nodelist: String,
    /// `SLURM_TIMELIMIT` in minutes.
    pub timelimit_min: f64,
}

/// The `sbatch --wait` front end over the shared event engine.
pub struct Scheduler {
    core: SimScheduler,
}

impl Scheduler {
    /// Build a scheduler over the given nodes (one run slot per node).
    pub fn new(nodes: Vec<NodeModel>) -> Scheduler {
        Scheduler {
            core: SimScheduler::new(nodes),
        }
    }

    pub fn now(&self) -> f64 {
        self.core.now()
    }
    pub fn nodes(&self) -> impl Iterator<Item = &NodeModel> {
        self.core.nodes()
    }
    pub fn node(&self, host: &str) -> Option<&NodeModel> {
        self.core.node(host)
    }

    /// Direct access to the underlying event engine.
    pub fn core(&self) -> &SimScheduler {
        &self.core
    }
    pub fn core_mut(&mut self) -> &mut SimScheduler {
        &mut self.core
    }

    /// `sbatch --parsable`: queue a job, return its id. Errors if the
    /// nodelist names an unknown host (sbatch would reject it).
    pub fn sbatch(&mut self, spec: JobSpec, payload: Payload) -> Result<u64, String> {
        self.core.submit(
            SubmitSpec::new(&spec.name, &spec.nodelist).timelimit(spec.timelimit_min),
            payload,
        )
    }

    /// `squeue`: all jobs in the given state.
    pub fn squeue(&self, state: JobState) -> Vec<&Job> {
        self.core.squeue(state)
    }

    pub fn job(&self, id: u64) -> Option<&Job> {
        self.core.job(id)
    }

    /// `scancel`.
    pub fn scancel(&mut self, id: u64) -> bool {
        self.core.scancel(id)
    }

    /// `scontrol update nodename=HOST state=drain`: from simulated time
    /// `at` no new job starts on `host`; running jobs finish. Open-ended
    /// until [`Scheduler::scontrol_resume`].
    pub fn scontrol_drain(&mut self, host: &str, at: f64) -> Result<(), String> {
        self.core.drain(host, at)
    }

    /// `scontrol update nodename=HOST state=resume`: close the node's
    /// open drain window at time `at`.
    pub fn scontrol_resume(&mut self, host: &str, at: f64) -> Result<(), String> {
        self.core.resume(host, at)
    }

    /// Drain the event queue (the `--wait` semantics the pipeline relies
    /// on): every queued job runs to completion, FIFO per node at equal
    /// priority. Returns ids of jobs that finished during this call.
    pub fn wait_all(&mut self) -> Vec<u64> {
        self.core.run_until_idle()
    }

    /// The log-file content the CI job `cat`s after `--wait` returns
    /// (`${CI_JOB_NAME}.o${job_id}.log` in Listing 1).
    pub fn job_log(&self, id: u64) -> Option<&str> {
        self.core.job_log(id)
    }

    /// `scontrol show node HOST` maintenance view: the node's windows,
    /// `[from, until)` sorted by start (`until` may be `INFINITY` for an
    /// open drain).
    pub fn maintenance_windows(&self, host: &str) -> &[(f64, f64)] {
        self.core.maintenance_windows(host)
    }

    /// The deterministic event log (`sacct`-style): submissions, starts,
    /// finishes with simulated times — the replay/trace ground truth.
    pub fn timeline(&self) -> String {
        self.core.timeline()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::nodes::catalogue;

    fn sched() -> Scheduler {
        Scheduler::new(catalogue().into_iter().filter(|n| n.testcluster).collect())
    }

    fn ok_payload(dur: f64, out: &str) -> Payload {
        let out = out.to_string();
        Box::new(move |_n, _t| JobOutcome {
            duration: dur,
            stdout: out,
            exit_code: 0,
        })
    }

    #[test]
    fn sbatch_queues_and_wait_completes() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec {
                    name: "fe2ti216-icx36".into(),
                    nodelist: "icx36".into(),
                    timelimit_min: 120.0,
                },
                ok_payload(40.0, "TTS=40.0\n"),
            )
            .unwrap();
        assert_eq!(s.squeue(JobState::Pending).len(), 1);
        s.wait_all();
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.end_time, Some(40.0));
        assert!(s.job_log(id).unwrap().contains("TTS=40.0"));
    }

    #[test]
    fn unknown_node_rejected() {
        let mut s = sched();
        let r = s.sbatch(
            JobSpec {
                name: "x".into(),
                nodelist: "nonexistent".into(),
                timelimit_min: 1.0,
            },
            ok_payload(1.0, ""),
        );
        assert!(r.is_err());
    }

    #[test]
    fn fifo_per_node_serializes_same_node_jobs() {
        let mut s = sched();
        let a = s
            .sbatch(
                JobSpec { name: "a".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                ok_payload(10.0, ""),
            )
            .unwrap();
        let b = s
            .sbatch(
                JobSpec { name: "b".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                ok_payload(5.0, ""),
            )
            .unwrap();
        // different node runs in parallel (starts at t=0)
        let c = s
            .sbatch(
                JobSpec { name: "c".into(), nodelist: "rome1".into(), timelimit_min: 10.0 },
                ok_payload(7.0, ""),
            )
            .unwrap();
        s.wait_all();
        assert_eq!(s.job(a).unwrap().end_time, Some(10.0));
        assert_eq!(s.job(b).unwrap().start_time, Some(10.0));
        assert_eq!(s.job(b).unwrap().end_time, Some(15.0));
        assert_eq!(s.job(c).unwrap().start_time, Some(0.0));
        assert_eq!(s.job(c).unwrap().end_time, Some(7.0));
    }

    #[test]
    fn timelimit_kills_job() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "slow".into(), nodelist: "icx36".into(), timelimit_min: 1.0 },
                ok_payload(3600.0, "partial output\n"),
            )
            .unwrap();
        s.wait_all();
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Timeout);
        assert_eq!(j.end_time, Some(60.0));
        assert!(j.log.contains("CANCELLED DUE TO TIME LIMIT"));
    }

    #[test]
    fn failing_job_marked_failed() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "bad".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                Box::new(|_n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: "segfault".into(),
                    exit_code: 139,
                }),
            )
            .unwrap();
        s.wait_all();
        assert_eq!(s.job(id).unwrap().state, JobState::Failed);
    }

    #[test]
    fn scancel_pending_only() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "x".into(), nodelist: "icx36".into(), timelimit_min: 1.0 },
                ok_payload(1.0, ""),
            )
            .unwrap();
        assert!(s.scancel(id));
        assert!(!s.scancel(id)); // already cancelled
        s.wait_all();
        assert_eq!(s.job(id).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn parse_time_slurm_grammar() {
        assert_eq!(parse_time("60"), Some(60.0));
        assert_eq!(parse_time("90:30"), Some(90.5));
        assert_eq!(parse_time("2:30:00"), Some(150.0));
        assert_eq!(parse_time("1-0"), Some(1440.0));
        assert_eq!(parse_time("1-2:30"), Some(1590.0));
        assert_eq!(parse_time("1-0:0:30"), Some(1440.5));
        assert_eq!(parse_time(" 15 "), Some(15.0));
        for bad in ["", "abc", "1:2:3:4", "-5", "1-", "1-2-3", "1:-2"] {
            assert_eq!(parse_time(bad), None, "{bad:?} must be rejected");
        }
    }

    #[test]
    fn scontrol_drain_resume_gates_job_starts() {
        let mut s = sched();
        s.scontrol_drain("icx36", 0.0).unwrap();
        let id = s
            .sbatch(
                JobSpec { name: "j".into(), nodelist: "icx36".into(), timelimit_min: 1.0 },
                ok_payload(5.0, ""),
            )
            .unwrap();
        s.wait_all();
        assert_eq!(s.job(id).unwrap().state, JobState::Pending, "node is draining");
        s.scontrol_resume("icx36", 25.0).unwrap();
        s.wait_all();
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.start_time, Some(25.0));
        // unknown host is rejected like sbatch rejects bad nodelists
        assert!(s.scontrol_drain("cray-1", 0.0).is_err());
    }

    #[test]
    fn payload_sees_node_model() {
        let mut s = sched();
        let id = s
            .sbatch(
                JobSpec { name: "probe".into(), nodelist: "icx36".into(), timelimit_min: 10.0 },
                Box::new(|n, _t| JobOutcome {
                    duration: 1.0,
                    stdout: format!("cores={}", n.cores()),
                    exit_code: 0,
                }),
            )
            .unwrap();
        s.wait_all();
        assert!(s.job_log(id).unwrap().contains("cores=72"));
    }
}
