//! Load generator for the serve:: facade — the client half of the
//! benchmark-as-a-service story. Drives concurrent line-protocol ingest
//! and tail queries over real HTTP connections, optionally injects a
//! performance regression into the generated series, and reports
//! sustained QPS with p50/p99 request latency (`cbench loadgen`,
//! `bench_serve.rs`).
//!
//! Generated traffic is shaped to trip the stock `lbm-mlups` policy:
//! `lbm` points carrying the `case/node/collision_op/gpu/repo` tags with
//! a `mlups` field, strictly increasing timestamps, a stable baseline
//! and (with [`LoadgenConfig::inject_regression`]) a >30% drop for the
//! final batches — enough for the CUSUM + Welch-t gates to open an
//! alert, which the serve-smoke CI job then reads back over
//! `GET /v0/projects/{p}/alerts`.

use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Project name prefix; client `i` writes to `{project}-{i}`.
    pub project: String,
    /// Concurrent client threads (each owns a disjoint project).
    pub clients: usize,
    /// Ingest requests (batches) per client.
    pub batches: usize,
    /// Points per ingest batch.
    pub batch_points: usize,
    /// Query requests per client (after the ingest phase).
    pub queries: usize,
    /// After the healthy batches, send a few *single-point* batches that
    /// regress ~35% — single-point so the detector's recent window (1)
    /// sees the drop against a still-healthy baseline window (8); a
    /// whole regressed batch would shift the baseline along with it.
    pub inject_regression: bool,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            addr: "127.0.0.1:8080".to_string(),
            project: "loadgen".to_string(),
            clients: 2,
            batches: 20,
            batch_points: 50,
            queries: 50,
            inject_regression: false,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    pub ingest_requests: usize,
    pub query_requests: usize,
    pub points_sent: usize,
    pub http_errors: usize,
    pub ingest_qps: f64,
    pub query_qps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Open alerts summed over the driven projects after the run.
    pub alerts_open: usize,
}

impl LoadgenReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("ingest_requests", self.ingest_requests)
            .set("query_requests", self.query_requests)
            .set("points_sent", self.points_sent)
            .set("http_errors", self.http_errors)
            .set("ingest_qps", round2(self.ingest_qps))
            .set("query_qps", round2(self.query_qps))
            .set("p50_ms", round3(self.p50_ms))
            .set("p99_ms", round3(self.p99_ms))
            .set("alerts_open", self.alerts_open)
    }
}

fn round2(v: f64) -> f64 {
    (v * 100.0).round() / 100.0
}
fn round3(v: f64) -> f64 {
    (v * 1000.0).round() / 1000.0
}

/// One blocking HTTP/1.1 exchange (connection per request — the server
/// always answers `Connection: close`). Returns `(status, body)`.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<(u16, Vec<u8>), String> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .ok();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body))
        .map_err(|e| format!("send: {e}"))?;
    let mut resp = Vec::new();
    stream
        .read_to_end(&mut resp)
        .map_err(|e| format!("recv: {e}"))?;
    let head_end = resp
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or("malformed response: no header terminator")?;
    let head_txt = std::str::from_utf8(&resp[..head_end])
        .map_err(|_| "malformed response head".to_string())?;
    let status: u16 = head_txt
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or("malformed status line")?;
    Ok((status, resp[head_end + 4..].to_vec()))
}

/// Line-protocol batch for one client: `batch_points` points shaped for
/// the stock `lbm-mlups` policy. Timestamps are strictly increasing
/// across batches (1 s apart — one "pipeline trigger" per point), values
/// hold a jittered baseline until `regress_from`, then drop ~35%.
pub fn lp_batch(
    repo: &str,
    batch_idx: usize,
    batch_points: usize,
    regress: bool,
) -> (String, usize) {
    let mut out = String::with_capacity(batch_points * 96);
    for j in 0..batch_points {
        let i = batch_idx * batch_points + j;
        let base = if regress { 520.0 } else { 800.0 };
        // deterministic ±2 jitter so the baseline has variance for the
        // Welch-t gate without drifting
        let v = base + (i % 5) as f64;
        let ts = (i as i64 + 1) * 1_000_000_000;
        out.push_str(&format!(
            "lbm,case=uniform,node=icx36,collision_op=srt,gpu=false,repo={repo} mlups={v} {ts}\n"
        ));
    }
    (out, batch_points)
}

/// Run the configured load against a serve:: instance. Wall-clock is
/// used only to *measure* (QPS/latency) — the stored state the server
/// ends up with is a pure function of the requests sent.
pub fn run(cfg: &LoadgenConfig) -> LoadgenReport {
    let start = Instant::now();
    let handles: Vec<std::thread::JoinHandle<(usize, usize, usize, Vec<f64>, Vec<f64>)>> = (0
        ..cfg.clients.max(1))
        .map(|c| {
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                let project = format!("{}-{c}", cfg.project);
                let mut ingest_lat = Vec::with_capacity(cfg.batches);
                let mut query_lat = Vec::with_capacity(cfg.queries);
                let mut points = 0usize;
                let mut errors = 0usize;
                let mut ingest_reqs = 0usize;
                let ingest_path = format!("/v0/projects/{project}/ingest");
                let mut send = |body: &str, n: usize, points: &mut usize, errors: &mut usize,
                                lat: &mut Vec<f64>| {
                    let t = Instant::now();
                    match http_request(&cfg.addr, "POST", &ingest_path, body.as_bytes()) {
                        Ok((200, _)) => *points += n,
                        _ => *errors += 1,
                    }
                    lat.push(t.elapsed().as_secs_f64() * 1000.0);
                };
                for b in 0..cfg.batches {
                    let (body, n) = lp_batch(&project, b, cfg.batch_points, false);
                    send(&body, n, &mut points, &mut errors, &mut ingest_lat);
                    ingest_reqs += 1;
                }
                if cfg.inject_regression {
                    // single-point regressed batches continuing the series
                    let next = cfg.batches * cfg.batch_points;
                    for k in 0..3 {
                        let i = next + k;
                        let v = 520.0 + (i % 5) as f64;
                        let ts = (i as i64 + 1) * 1_000_000_000;
                        let body = format!(
                            "lbm,case=uniform,node=icx36,collision_op=srt,gpu=false,repo={project} mlups={v} {ts}\n"
                        );
                        send(&body, 1, &mut points, &mut errors, &mut ingest_lat);
                        ingest_reqs += 1;
                    }
                }
                let q = format!(
                    "/v0/projects/{project}/query?measurement=lbm&field=mlups&tail=8&tag.repo={project}"
                );
                for _ in 0..cfg.queries {
                    let t = Instant::now();
                    match http_request(&cfg.addr, "GET", &q, b"") {
                        Ok((200, _)) => {}
                        _ => errors += 1,
                    }
                    query_lat.push(t.elapsed().as_secs_f64() * 1000.0);
                }
                (points, errors, ingest_reqs, ingest_lat, query_lat)
            })
        })
        .collect();

    let mut rep = LoadgenReport::default();
    let mut all_lat: Vec<f64> = Vec::new();
    let mut ingest_time = 0.0f64;
    for h in handles {
        if let Ok((points, errors, ingest_reqs, ingest_lat, query_lat)) = h.join() {
            rep.points_sent += points;
            rep.http_errors += errors;
            rep.ingest_requests += ingest_reqs;
            rep.query_requests += query_lat.len();
            ingest_time = ingest_time.max(ingest_lat.iter().sum::<f64>() / 1000.0);
            all_lat.extend(ingest_lat);
            all_lat.extend(query_lat);
        }
    }
    let total = start.elapsed().as_secs_f64().max(1e-9);
    let query_time = (total - ingest_time).max(1e-9);
    rep.ingest_qps = rep.ingest_requests as f64 / ingest_time.max(1e-9);
    rep.query_qps = rep.query_requests as f64 / query_time;
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rep.p50_ms = percentile(&all_lat, 0.50);
    rep.p99_ms = percentile(&all_lat, 0.99);

    // read back open alerts per driven project over the API
    for c in 0..cfg.clients.max(1) {
        let project = format!("{}-{c}", cfg.project);
        if let Ok((200, body)) =
            http_request(&cfg.addr, "GET", &format!("/v0/projects/{project}/alerts"), b"")
        {
            if let Ok(json) = Json::parse(&String::from_utf8_lossy(&body)) {
                rep.alerts_open += json.as_arr().map(|a| a.len()).unwrap_or(0);
            }
        }
    }
    rep
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}
