//! Minimal HTTP/1.1 request/response layer over `std::net` — no new
//! dependencies, exactly the subset the benchmark-as-a-service facade
//! needs: request-line + headers + `Content-Length` bodies in, status +
//! headers + body out, one request per connection (`Connection: close`).
//!
//! Robustness knobs live here so every endpoint inherits them: a
//! per-connection read timeout (socket-level, set by the accept loop),
//! a bounded request head and a bounded body size with the standard
//! error mapping (408 timeout, 413 too large, 400 malformed). Handlers
//! speak [`HttpError`]; the worker turns it into a response.

use std::io::{Read, Write};
use std::net::TcpStream;

/// Cap on the request line + headers. Generous for the v0 API (the
/// longest legal request is a query with a dozen tag filters).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// A parsed request. Header names are lowercased; the query string is
/// split into decoded key/value pairs preserving order.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path without the query string, percent-decoded.
    pub path: String,
    pub query: Vec<(String, String)>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First query value for `key`, if present.
    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
    /// `/`-separated path segments (empty segments dropped).
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
    pub fn body_utf8(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::new(400, "request body is not valid UTF-8"))
    }
}

/// An error that maps directly onto an HTTP status. Handlers return it;
/// the connection worker renders it as a JSON body.
#[derive(Debug, Clone)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError {
            status,
            message: message.into(),
        }
    }
}

pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// Read one request from the stream. `Ok(None)` means the client closed
/// the connection before sending anything (a clean no-op, not an error).
/// The socket read timeout (set by the accept loop) surfaces as 408.
pub fn read_request(stream: &mut TcpStream, max_body: usize) -> Result<Option<Request>, HttpError> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    // read until the blank line terminating the head
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(HttpError::new(400, "request head too large"));
        }
        let n = stream.read(&mut chunk).map_err(io_to_http)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(HttpError::new(400, "connection closed mid-request"));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| HttpError::new(400, "request head is not valid UTF-8"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::new(400, "malformed request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| HttpError::new(400, "malformed request line"))?;
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(400, "unsupported HTTP version"));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, "malformed header line"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let content_length: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| {
            v.parse()
                .map_err(|_| HttpError::new(400, "bad Content-Length"))
        })
        .transpose()?
        .unwrap_or(0);
    if content_length > max_body {
        return Err(HttpError::new(
            413,
            format!("request body {content_length} B exceeds the {max_body} B limit"),
        ));
    }
    // body: whatever arrived with the head, then read the remainder
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk).map_err(io_to_http)?;
        if n == 0 {
            return Err(HttpError::new(400, "connection closed mid-body"));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let (path, query) = parse_target(target);
    Ok(Some(Request {
        method,
        path,
        query,
        headers,
        body,
    }))
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn io_to_http(e: std::io::Error) -> HttpError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            HttpError::new(408, "timed out reading request")
        }
        _ => HttpError::new(400, format!("read error: {e}")),
    }
}

/// Split a request target into its decoded path and query pairs.
fn parse_target(target: &str) -> (String, Vec<(String, String)>) {
    let (path, qs) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    let query = qs
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (percent_decode(k), percent_decode(v)),
            None => (percent_decode(kv), String::new()),
        })
        .collect();
    (percent_decode(path), query)
}

/// Minimal `%XX` + `+` decoding (the only encodings the v0 clients emit).
/// Invalid escapes pass through literally rather than failing the request.
pub fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => match (hex_val(bytes.get(i + 1)), hex_val(bytes.get(i + 2))) {
                (Some(h), Some(l)) => {
                    out.push(h * 16 + l);
                    i += 3;
                }
                _ => {
                    out.push(b'%');
                    i += 1;
                }
            },
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn hex_val(b: Option<&u8>) -> Option<u8> {
    match b {
        Some(c @ b'0'..=b'9') => Some(c - b'0'),
        Some(c @ b'a'..=b'f') => Some(c - b'a' + 10),
        Some(c @ b'A'..=b'F') => Some(c - b'A' + 10),
        _ => None,
    }
}

/// Percent-encode a path segment or query value for a request line.
pub fn percent_encode(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' | b'/' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// Write a full response and flush. Always `Connection: close` — the
/// facade trades keep-alive for a trivially correct lifecycle (drain =
/// finish the queued connections).
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status,
        status_text(status),
        content_type,
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_parsing_decodes_path_and_query() {
        let (path, q) = parse_target("/v0/projects/my%2Dproj/query?measurement=lbm&tag.a=x+y");
        assert_eq!(path, "/v0/projects/my-proj/query");
        assert_eq!(q[0], ("measurement".to_string(), "lbm".to_string()));
        assert_eq!(q[1], ("tag.a".to_string(), "x y".to_string()));
    }

    #[test]
    fn percent_roundtrip() {
        let s = "a b/c-d_e.f~g%h&i=j";
        assert_eq!(percent_decode(&percent_encode(s)), s);
    }

    #[test]
    fn invalid_escapes_pass_through() {
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }
}
