//! `serve::` — the benchmark-as-a-service facade (ROADMAP: "the path
//! from one operator's CLI to millions of users").
//!
//! Exposes the continuous-benchmarking core ([`CoreHandle`]) as a
//! multi-tenant HTTP/1.1 service over `std::net` — no new dependencies.
//! Each *project* (tenant) owns a full, independent core: sharded TSDB,
//! detector + carried incremental state, alert book, and its own
//! `regress.*` threshold overrides. The v0 surface:
//!
//! | Method | Path | Body | Semantics |
//! |---|---|---|---|
//! | `POST` | `/v0/projects/{p}/ingest` | line protocol | batched ingest → scoped detection → alert book |
//! | `GET`  | `/v0/projects/{p}/query` | — | range/`tail(n)` pushdown query (`measurement`, `field`, `tag.K=V`, `group_by`, `tail`, `t_min`, `t_max`) |
//! | `GET`  | `/v0/projects/{p}/alerts` | — | alert list (`state=open` default, `state=all`) |
//! | `POST` | `/v0/projects/{p}/alerts/{id}/resolve` | — | manual resolve (409 if already resolved) |
//! | `PUT`  | `/v0/projects/{p}/thresholds` | `regress.*` cfg | detector rebuild via fingerprint invalidation |
//! | `GET`  | `/healthz` | — | liveness + project/request counts |
//! | `GET`  | `/metrics` | — | `obs::metrics` counters + serve counters, text exposition |
//!
//! **Locking model.** A registry `RwLock` guards the project map (held
//! only to look up / create entries); each project is its own
//! `Arc<RwLock<ProjectStore>>`. Reads (`query`, `alerts`) take the
//! project read lock and ride the `Sync` `Db` (PR 7: `OnceLock` shard
//! bodies, atomic LRU bookkeeping) — concurrent readers of one project
//! proceed in parallel. Writes (`ingest`, `resolve`, `thresholds`) take
//! the project write lock. Two different projects never share a lock, so
//! tenants scale without contention and cannot observe each other's
//! state — the cross-tenant isolation the pipeline path gets from
//! detection scoping, the service gets from ownership.
//!
//! **Shutdown/drain.** [`ServerHandle::stop`] (or SIGTERM via `cbench
//! serve`) flips the shutdown flag; the accept loop stops accepting,
//! workers drain every already-accepted connection, then each project is
//! saved through the PR-5 manifest commit protocol (crash-atomic: shard
//! files first, manifest rename last). The returned [`ServeReport`]
//! counts `dirty_after_save` — zero on a clean drain, which the
//! serve-smoke CI job asserts.
//!
//! **Determinism.** Per-project state transitions are deterministic in
//! the request order that project observed (same core code as the
//! simulated pipeline path; detection timestamps come from the data's
//! own trigger clock, `Db::newest_ts`). Wall-clock enters only in
//! latency *measurements* (loadgen, bench_serve) — never in stored
//! state.

pub mod http;
pub mod loadgen;

use crate::coordinator::{BenchConfig, CoreHandle};
use crate::obs::metrics as om;
use crate::regress::{alert_to_json, detector_fingerprint, AlertBook, AlertState, DetectorState};
use crate::tsdb::{Db, Query};
use crate::util::json::Json;
use http::{read_request, write_response, HttpError, Request};
use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

/// On-disk layout of one project under the serve data dir.
const TSDB_DIR: &str = "tsdb";
const ALERTS_FILE: &str = "alerts.json";
const STATE_FILE: &str = "state.json";
const THRESHOLDS_FILE: &str = "thresholds.cfg";

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks an ephemeral
    /// port, reported in [`ServerHandle::addr`]).
    pub addr: String,
    /// Per-project persistence root; `None` = in-memory only.
    pub data_dir: Option<PathBuf>,
    /// Worker threads handling connections.
    pub threads: usize,
    /// Request body cap (413 beyond it).
    pub max_body: usize,
    /// Socket read timeout per connection (408 on expiry).
    pub read_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8080".to_string(),
            data_dir: None,
            threads: 4,
            max_body: 8 * 1024 * 1024,
            read_timeout_ms: 5000,
        }
    }
}

/// One tenant: a full CB core plus the raw threshold config it was last
/// given (persisted verbatim so a restart re-applies it).
pub struct ProjectStore {
    pub core: CoreHandle,
    /// Raw `regress.*` config text from the last `PUT …/thresholds`.
    pub thresholds: Option<String>,
}

impl ProjectStore {
    fn new() -> ProjectStore {
        ProjectStore {
            core: CoreHandle::new(),
            thresholds: None,
        }
    }

    /// Load a project from `dir` (its subtree of the serve data dir).
    fn load(dir: &Path) -> Result<ProjectStore, String> {
        let mut p = ProjectStore::new();
        let tsdb = dir.join(TSDB_DIR);
        if tsdb.exists() {
            p.core.db = Db::load(&tsdb).map_err(|e| format!("load {}: {e}", tsdb.display()))?;
        }
        p.core.alerts = AlertBook::load(&dir.join(ALERTS_FILE))
            .map_err(|e| format!("load {}: {e}", dir.join(ALERTS_FILE).display()))?;
        p.core.alerts.detach_store();
        p.core.det_state = DetectorState::load(&dir.join(STATE_FILE))
            .map_err(|e| format!("load {}: {e}", dir.join(STATE_FILE).display()))?;
        if let Ok(text) = std::fs::read_to_string(dir.join(THRESHOLDS_FILE)) {
            p.core.apply_regress_config(&BenchConfig::parse(&text));
            p.thresholds = Some(text);
        }
        Ok(p)
    }

    /// Persist via the PR-5 manifest commit protocol (crash-atomic) and
    /// report what was written. Returns `(written, kept, dirty_after)`.
    fn save(&mut self, dir: &Path) -> Result<(usize, usize, usize), String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
        let rep = self
            .core
            .db
            .save_report(&dir.join(TSDB_DIR))
            .map_err(|e| format!("save tsdb: {e}"))?;
        self.core
            .alerts
            .save(&dir.join(ALERTS_FILE))
            .map_err(|e| format!("save alerts: {e}"))?;
        self.core
            .det_state
            .save(&dir.join(STATE_FILE))
            .map_err(|e| format!("save state: {e}"))?;
        if let Some(t) = &self.thresholds {
            std::fs::write(dir.join(THRESHOLDS_FILE), t)
                .map_err(|e| format!("save thresholds: {e}"))?;
        }
        Ok((rep.shards_written, rep.shards_kept, self.core.db.dirty_shards()))
    }
}

/// State shared between the accept loop, the workers and the handle.
struct Shared {
    cfg: ServeConfig,
    projects: RwLock<BTreeMap<String, Arc<RwLock<ProjectStore>>>>,
    /// Accepted connections awaiting a worker.
    queue: Mutex<VecDeque<TcpStream>>,
    queue_cv: Condvar,
    shutdown: AtomicBool,
    requests: AtomicU64,
    errors: AtomicU64,
}

/// Final accounting returned by [`ServerHandle::stop`].
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    pub requests: u64,
    pub errors: u64,
    pub projects_saved: usize,
    pub shards_written: usize,
    pub shards_kept: usize,
    /// Dirty shards remaining after the drain save — 0 on a clean stop.
    pub dirty_after_save: usize,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("requests", self.requests as i64)
            .set("errors", self.errors as i64)
            .set("projects_saved", self.projects_saved)
            .set("shards_written", self.shards_written)
            .set("shards_kept", self.shards_kept)
            .set("dirty_after_save", self.dirty_after_save)
    }
}

/// A running server. Dropping the handle does NOT stop the server; call
/// [`ServerHandle::stop`] (or let the process exit).
pub struct ServerHandle {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    accept_join: std::thread::JoinHandle<()>,
    worker_joins: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Worker thread count the server was started with.
    pub fn threads(&self) -> usize {
        self.worker_joins.len()
    }

    /// Persistence root, if any.
    pub fn data_dir(&self) -> Option<&Path> {
        self.shared.cfg.data_dir.as_deref()
    }

    /// Request shutdown without waiting (signal-handler safe side:
    /// `cbench serve` flips this from its SIGTERM handler loop).
    pub fn request_stop(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
    }

    /// Graceful shutdown: stop accepting, drain every already-accepted
    /// connection, join all threads, then save every project store.
    pub fn stop(self) -> ServeReport {
        self.request_stop();
        self.accept_join.join().ok();
        for j in self.worker_joins {
            j.join().ok();
        }
        let mut rep = ServeReport {
            requests: self.shared.requests.load(Ordering::Relaxed),
            errors: self.shared.errors.load(Ordering::Relaxed),
            ..ServeReport::default()
        };
        let projects = self.shared.projects.read().unwrap();
        for (name, store) in projects.iter() {
            let mut st = store.write().unwrap();
            if let Some(root) = &self.shared.cfg.data_dir {
                match st.save(&root.join(name)) {
                    Ok((w, k, dirty)) => {
                        rep.projects_saved += 1;
                        rep.shards_written += w;
                        rep.shards_kept += k;
                        rep.dirty_after_save += dirty;
                    }
                    Err(e) => {
                        eprintln!("serve: failed to save project {name}: {e}");
                        rep.dirty_after_save += st.core.db.dirty_shards();
                    }
                }
            } else {
                rep.dirty_after_save += st.core.db.dirty_shards();
            }
        }
        rep
    }
}

/// Bind and start the service: one accept thread + `threads` workers.
pub fn start(cfg: ServeConfig) -> Result<ServerHandle, String> {
    // the /metrics endpoint is part of the service contract — turn the
    // (zero-cost-when-disabled) self-metrics recording on
    om::set_enabled(true);
    let listener =
        TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("local_addr: {e}"))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("set_nonblocking: {e}"))?;
    let shared = Arc::new(Shared {
        cfg,
        projects: RwLock::new(BTreeMap::new()),
        queue: Mutex::new(VecDeque::new()),
        queue_cv: Condvar::new(),
        shutdown: AtomicBool::new(false),
        requests: AtomicU64::new(0),
        errors: AtomicU64::new(0),
    });

    let accept_shared = Arc::clone(&shared);
    let accept_join = std::thread::Builder::new()
        .name("serve-accept".into())
        .spawn(move || accept_loop(listener, accept_shared))
        .map_err(|e| format!("spawn accept thread: {e}"))?;

    let mut worker_joins = Vec::new();
    for i in 0..shared.cfg.threads.max(1) {
        let w = Arc::clone(&shared);
        worker_joins.push(
            std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || worker_loop(w))
                .map_err(|e| format!("spawn worker: {e}"))?,
        );
    }
    Ok(ServerHandle {
        addr,
        shared,
        accept_join,
        worker_joins,
    })
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let t = Duration::from_millis(shared.cfg.read_timeout_ms.max(1));
                stream.set_read_timeout(Some(t)).ok();
                stream.set_write_timeout(Some(t)).ok();
                shared.queue.lock().unwrap().push_back(stream);
                shared.queue_cv.notify_one();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // nonblocking accept doubles as the shutdown poll point
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    // wake every worker so they can observe shutdown + drain the queue
    shared.queue_cv.notify_all();
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let stream = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    // queue empty + shutdown: fully drained
                    break None;
                }
                let (guard, _) = shared
                    .queue_cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap();
                q = guard;
            }
        };
        let Some(mut stream) = stream else { return };
        handle_connection(&mut stream, &shared);
    }
}

fn handle_connection(stream: &mut TcpStream, shared: &Shared) {
    match read_request(stream, shared.cfg.max_body) {
        Ok(None) => {} // client connected and left
        Ok(Some(req)) => {
            shared.requests.fetch_add(1, Ordering::Relaxed);
            match route(&req, shared) {
                Ok((content_type, body)) => {
                    write_response(stream, 200, content_type, body.as_bytes()).ok();
                }
                Err(e) => {
                    shared.errors.fetch_add(1, Ordering::Relaxed);
                    let body = Json::obj()
                        .set("error", e.message.clone())
                        .to_string_compact();
                    write_response(stream, e.status, "application/json", body.as_bytes()).ok();
                }
            }
        }
        Err(e) => {
            shared.requests.fetch_add(1, Ordering::Relaxed);
            shared.errors.fetch_add(1, Ordering::Relaxed);
            let body = Json::obj()
                .set("error", e.message.clone())
                .to_string_compact();
            write_response(stream, e.status, "application/json", body.as_bytes()).ok();
        }
    }
    // Connection: close — drop the stream
}

/// Dispatch one parsed request. Returns `(content_type, body)`.
fn route(req: &Request, shared: &Shared) -> Result<(&'static str, String), HttpError> {
    let segs = req.segments();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => {
            let projects = shared.projects.read().unwrap().len();
            Ok((
                "application/json",
                Json::obj()
                    .set("status", "ok")
                    .set("projects", projects)
                    .set("requests", shared.requests.load(Ordering::Relaxed) as i64)
                    .to_string_compact(),
            ))
        }
        ("GET", ["metrics"]) => Ok(("text/plain; version=0.0.4", render_metrics(shared))),
        (_, ["healthz" | "metrics"]) => Err(HttpError::new(405, "method not allowed")),
        (method, ["v0", "projects", project, rest @ ..]) => {
            let project = validate_project(project)?;
            match (method, rest) {
                ("POST", ["ingest"]) => {
                    let store = open_project(shared, &project, true)?;
                    let text = req.body_utf8()?.to_string();
                    let mut st = store.write().unwrap();
                    let out = st
                        .core
                        .ingest_and_detect(&text)
                        .map_err(|e| HttpError::new(400, e))?;
                    Ok((
                        "application/json",
                        Json::obj()
                            .set("points", out.points)
                            .set("scopes", out.scopes)
                            .set("alerts_opened", out.summary.opened)
                            .set("alerts_auto_resolved", out.summary.auto_resolved)
                            .to_string_compact(),
                    ))
                }
                ("GET", ["query"]) => {
                    let store = open_project(shared, &project, false)?;
                    let q = build_query(req)?;
                    let st = store.read().unwrap();
                    let series = q.run(&st.core.db);
                    let arr: Vec<Json> = series
                        .iter()
                        .map(|s| {
                            let mut group = Json::obj();
                            for (k, v) in &s.group {
                                group = group.set(k, v.as_str());
                            }
                            let pts: Vec<Json> = s
                                .points
                                .iter()
                                .map(|(ts, v)| Json::Arr(vec![Json::from(*ts), Json::from(*v)]))
                                .collect();
                            Json::obj().set("group", group).set("points", pts)
                        })
                        .collect();
                    Ok(("application/json", Json::Arr(arr).to_string_compact()))
                }
                ("GET", ["alerts"]) => {
                    let store = open_project(shared, &project, false)?;
                    let all = req.query_get("state") == Some("all");
                    let st = store.read().unwrap();
                    let arr: Vec<Json> = st
                        .core
                        .alerts
                        .alerts
                        .iter()
                        .filter(|a| all || a.state != AlertState::Resolved)
                        .map(alert_to_json)
                        .collect();
                    Ok(("application/json", Json::Arr(arr).to_string_compact()))
                }
                ("POST", ["alerts", id, "resolve"]) => {
                    let id: u64 = id
                        .parse()
                        .map_err(|_| HttpError::new(400, "alert id must be an integer"))?;
                    let store = open_project(shared, &project, false)?;
                    let mut st = store.write().unwrap();
                    match st.core.alerts.get(id) {
                        None => return Err(HttpError::new(404, format!("no alert #{id}"))),
                        Some(a) if a.state == AlertState::Resolved => {
                            return Err(HttpError::new(409, format!("alert #{id} already resolved")))
                        }
                        Some(_) => {}
                    }
                    let now = st.core.db.newest_ts().unwrap_or(0);
                    st.core
                        .alerts
                        .resolve(id, now)
                        .map_err(|e| HttpError::new(400, e))?;
                    Ok((
                        "application/json",
                        Json::obj().set("resolved", id as i64).to_string_compact(),
                    ))
                }
                ("PUT", ["thresholds"]) => {
                    let store = open_project(shared, &project, true)?;
                    let text = req.body_utf8()?.to_string();
                    let cfg = BenchConfig::parse(&text);
                    let mut st = store.write().unwrap();
                    // fingerprint change invalidates the carried
                    // detector state at its next sync (bounded rebuild)
                    st.core.apply_regress_config(&cfg);
                    let fp = detector_fingerprint(&st.core.detector);
                    st.thresholds = Some(text);
                    if let Some(root) = &shared.cfg.data_dir {
                        let dir = root.join(&project);
                        std::fs::create_dir_all(&dir).ok();
                        std::fs::write(dir.join(THRESHOLDS_FILE), st.thresholds.as_deref().unwrap())
                            .ok();
                    }
                    Ok((
                        "application/json",
                        Json::obj()
                            .set("applied", true)
                            .set("fingerprint", fp)
                            .to_string_compact(),
                    ))
                }
                _ => Err(HttpError::new(404, format!("no route for {} {}", req.method, req.path))),
            }
        }
        _ => Err(HttpError::new(404, format!("no route for {} {}", req.method, req.path))),
    }
}

/// Project names are path components on disk — restrict them hard
/// (no traversal, no separators, bounded length).
fn validate_project(name: &str) -> Result<String, HttpError> {
    let ok = !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_');
    if ok {
        Ok(name.to_string())
    } else {
        Err(HttpError::new(
            400,
            "project names are [A-Za-z0-9_-]{1,64}",
        ))
    }
}

/// Look up a project. `create` (ingest/thresholds) makes missing
/// projects spring into existence — loading from the data dir if a
/// previous run persisted them; read endpoints 404 instead.
fn open_project(
    shared: &Shared,
    name: &str,
    create: bool,
) -> Result<Arc<RwLock<ProjectStore>>, HttpError> {
    if let Some(p) = shared.projects.read().unwrap().get(name) {
        return Ok(Arc::clone(p));
    }
    let on_disk = shared
        .cfg
        .data_dir
        .as_ref()
        .map(|root| root.join(name))
        .filter(|d| d.exists());
    if !create && on_disk.is_none() {
        return Err(HttpError::new(404, format!("no project '{name}'")));
    }
    let mut projects = shared.projects.write().unwrap();
    // double-checked: another worker may have created it meanwhile
    if let Some(p) = projects.get(name) {
        return Ok(Arc::clone(p));
    }
    let store = match on_disk {
        Some(dir) => ProjectStore::load(&dir).map_err(|e| HttpError::new(500, e))?,
        None => ProjectStore::new(),
    };
    let arc = Arc::new(RwLock::new(store));
    projects.insert(name.to_string(), Arc::clone(&arc));
    Ok(arc)
}

/// Translate query parameters into a [`Query`]: `measurement` + `field`
/// required; `tag.K=V` exact filters, `group_by=a,b`, `tail=n`,
/// `t_min`/`t_max` in ns.
fn build_query(req: &Request) -> Result<Query, HttpError> {
    let measurement = req
        .query_get("measurement")
        .ok_or_else(|| HttpError::new(400, "missing query parameter 'measurement'"))?;
    let field = req
        .query_get("field")
        .ok_or_else(|| HttpError::new(400, "missing query parameter 'field'"))?;
    let mut q = Query::new(measurement, field);
    for (k, v) in &req.query {
        if let Some(tag) = k.strip_prefix("tag.") {
            q.where_tags.insert(tag.to_string(), v.clone());
        }
    }
    if let Some(g) = req.query_get("group_by") {
        q.group_by = g
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect();
    }
    if let Some(t) = req.query_get("tail") {
        q.tail = Some(
            t.parse()
                .map_err(|_| HttpError::new(400, "'tail' must be an integer"))?,
        );
    }
    if let Some(t) = req.query_get("t_min") {
        q.t_min = Some(
            t.parse()
                .map_err(|_| HttpError::new(400, "'t_min' must be an integer (ns)"))?,
        );
    }
    if let Some(t) = req.query_get("t_max") {
        q.t_max = Some(
            t.parse()
                .map_err(|_| HttpError::new(400, "'t_max' must be an integer (ns)"))?,
        );
    }
    Ok(q)
}

/// Prometheus-style text exposition of the `obs::metrics` counters plus
/// the serve-layer request counters.
fn render_metrics(shared: &Shared) -> String {
    let mut out = String::new();
    let counters = om::counters();
    for (i, c) in om::Counter::ALL.iter().enumerate() {
        out.push_str(&format!("cbench_{} {}\n", c.name(), counters[i]));
    }
    out.push_str(&format!(
        "cbench_serve_requests {}\n",
        shared.requests.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "cbench_serve_errors {}\n",
        shared.errors.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "cbench_serve_projects {}\n",
        shared.projects.read().unwrap().len()
    ));
    out
}
