//! GitLab-CI stand-in: pipeline specifications, job templating and the
//! custom HPC runner contract.
//!
//! The paper specifies CI jobs in YAML (Listing 1): a job carries runner
//! `tags` (must include `testcluster` to reach the HPC runner), `variables`
//! (`HOST`, `SCRIPT`, `SLURM_TIMELIMIT`, `NO_SLURM_SUBMIT`) and a script
//! that assembles a batch job file from a cluster-specific base part
//! (`base_config.sh`) plus a benchmark-specific part, then submits it via
//! `sbatch --parsable --wait`. This module models that structure:
//!
//! * [`CiJob`] — one job spec (the `.submit_job` template, instantiated
//!   per host × benchmark),
//! * [`Pipeline`] — ordered stages of jobs, triggered by a VCS push event,
//! * [`assemble_job_script`] — the Listing-1 concatenation,
//! * [`Runner`] — the custom GitLab-runner: picks up jobs whose tags it
//!   serves and hands them to the Slurm scheduler (done by the
//!   coordinator, which owns both ends).

use crate::vcs::PushEvent;
use std::collections::BTreeMap;

/// One CI job, i.e. an instantiated `.submit_job` template.
#[derive(Debug, Clone)]
pub struct CiJob {
    pub name: String,
    pub stage: String,
    /// Runner tags; the HPC runner serves `testcluster`.
    pub tags: Vec<String>,
    /// CI variables (HOST, SCRIPT, SLURM_TIMELIMIT, ...).
    pub variables: BTreeMap<String, String>,
}

impl CiJob {
    pub fn new(name: &str, stage: &str) -> CiJob {
        CiJob {
            name: name.to_string(),
            stage: stage.to_string(),
            tags: vec!["testcluster".to_string()],
            variables: BTreeMap::new(),
        }
    }
    pub fn var(mut self, k: &str, v: &str) -> CiJob {
        self.variables.insert(k.to_string(), v.to_string());
        self
    }
    pub fn get(&self, k: &str) -> Option<&str> {
        self.variables.get(k).map(|s| s.as_str())
    }
    /// `SLURM_TIMELIMIT` in minutes (default 120, as in Listing 1).
    /// Accepts plain minutes or any sbatch `--time` form (`H:M:S`,
    /// `D-H:M:S`, ... — see [`crate::slurm::parse_time`]).
    pub fn timelimit_min(&self) -> f64 {
        self.get("SLURM_TIMELIMIT")
            .and_then(crate::slurm::parse_time)
            .unwrap_or(120.0)
    }
}

/// A pipeline: the set of jobs generated for one commit.
#[derive(Debug, Clone)]
pub struct Pipeline {
    pub id: u64,
    /// The push event that triggered it.
    pub trigger: PushEvent,
    /// Whether it came through the trigger API (proxy-repo flow) rather
    /// than a direct push.
    pub via_trigger_api: bool,
    pub jobs: Vec<CiJob>,
}

impl Pipeline {
    /// Stages in declaration order (deduplicated).
    pub fn stages(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for j in &self.jobs {
            if !out.contains(&j.stage.as_str()) {
                out.push(&j.stage);
            }
        }
        out
    }
    pub fn jobs_in_stage(&self, stage: &str) -> Vec<&CiJob> {
        self.jobs.iter().filter(|j| j.stage == stage).collect()
    }
}

/// The Listing-1 job-script assembly: cluster-specific `base_config.sh`
/// prologue + benchmark-specific script body, with `${VAR}` substitution
/// from the job's CI variables.
pub fn assemble_job_script(base_config: &str, benchmark_script: &str, job: &CiJob) -> String {
    let mut script = String::new();
    script.push_str("#!/bin/bash\n");
    script.push_str(&format!("#SBATCH --job-name {}\n", job.name));
    if let Some(host) = job.get("HOST") {
        script.push_str(&format!("#SBATCH --nodelist={host}\n"));
    }
    script.push_str(&format!("#SBATCH --time={}\n", job.timelimit_min() as u64));
    script.push_str(base_config);
    if !base_config.ends_with('\n') {
        script.push('\n');
    }
    script.push_str(benchmark_script);
    if !benchmark_script.ends_with('\n') {
        script.push('\n');
    }
    substitute_vars(&script, &job.variables)
}

/// `${NAME}` substitution (unknown variables are left untouched, like a
/// shell with `set +u` would under templating).
pub fn substitute_vars(text: &str, vars: &BTreeMap<String, String>) -> String {
    let mut out = String::with_capacity(text.len());
    let bytes = text.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'$' && i + 1 < bytes.len() && bytes[i + 1] == b'{' {
            if let Some(end) = text[i + 2..].find('}') {
                let name = &text[i + 2..i + 2 + end];
                if let Some(v) = vars.get(name) {
                    out.push_str(v);
                } else {
                    out.push_str(&text[i..i + 3 + end]);
                }
                i += 3 + end;
                continue;
            }
        }
        // advance one UTF-8 scalar
        let c = text[i..].chars().next().unwrap();
        out.push(c);
        i += c.len_utf8();
    }
    out
}

/// The custom GitLab runner: serves jobs whose tags it covers.
#[derive(Debug, Clone)]
pub struct Runner {
    pub name: String,
    pub serves_tags: Vec<String>,
}

impl Runner {
    pub fn hpc() -> Runner {
        Runner {
            name: "nhr-testcluster-runner".to_string(),
            serves_tags: vec!["testcluster".to_string()],
        }
    }
    /// Can this runner pick up the job? (All job tags must be served.)
    pub fn accepts(&self, job: &CiJob) -> bool {
        job.tags.iter().all(|t| self.serves_tags.contains(t))
    }
}

/// Counter for pipeline ids.
#[derive(Debug, Default)]
pub struct PipelineFactory {
    next_id: u64,
}

impl PipelineFactory {
    pub fn new() -> PipelineFactory {
        PipelineFactory { next_id: 1 }
    }
    pub fn create(&mut self, trigger: PushEvent, via_trigger_api: bool, jobs: Vec<CiJob>) -> Pipeline {
        let id = self.next_id;
        self.next_id += 1;
        Pipeline {
            id,
            trigger,
            via_trigger_api,
            jobs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event() -> PushEvent {
        PushEvent {
            repo: "fe2ti".into(),
            branch: "master".into(),
            commit_id: "abc123".into(),
            changed: vec![],
        }
    }

    #[test]
    fn job_variables_and_timelimit() {
        let j = CiJob::new("bench-icx36", "benchmark")
            .var("HOST", "icx36")
            .var("SLURM_TIMELIMIT", "60");
        assert_eq!(j.get("HOST"), Some("icx36"));
        assert_eq!(j.timelimit_min(), 60.0);
        assert_eq!(CiJob::new("x", "s").timelimit_min(), 120.0);
        // sbatch --time grammar is accepted too; garbage falls back
        let j = CiJob::new("y", "s").var("SLURM_TIMELIMIT", "2:30:00");
        assert_eq!(j.timelimit_min(), 150.0);
        let j = CiJob::new("z", "s").var("SLURM_TIMELIMIT", "soon");
        assert_eq!(j.timelimit_min(), 120.0);
    }

    #[test]
    fn assemble_concatenates_and_substitutes() {
        let j = CiJob::new("fe2ti216-icx36-mpi", "benchmark")
            .var("HOST", "icx36")
            .var("SOLVER", "ilu");
        let script = assemble_job_script(
            "module load petsc\nexport OMP_NUM_THREADS=1\n",
            "./fe2ti --solver ${SOLVER} --host ${HOST}\n",
            &j,
        );
        assert!(script.starts_with("#!/bin/bash\n"));
        assert!(script.contains("#SBATCH --nodelist=icx36"));
        assert!(script.contains("module load petsc"));
        assert!(script.contains("./fe2ti --solver ilu --host icx36"));
    }

    #[test]
    fn unknown_vars_left_intact() {
        let vars: BTreeMap<String, String> = BTreeMap::new();
        assert_eq!(substitute_vars("echo ${UNSET} done", &vars), "echo ${UNSET} done");
        let mut vars = BTreeMap::new();
        vars.insert("A".to_string(), "x".to_string());
        assert_eq!(substitute_vars("${A}${A}", &vars), "xx");
        assert_eq!(substitute_vars("tail ${", &vars), "tail ${");
    }

    #[test]
    fn runner_tag_matching() {
        let r = Runner::hpc();
        assert!(r.accepts(&CiJob::new("a", "s")));
        let mut gpu_job = CiJob::new("b", "s");
        gpu_job.tags.push("gpu-farm".to_string());
        assert!(!r.accepts(&gpu_job));
    }

    #[test]
    fn pipeline_stages_ordered_dedup() {
        let mut f = PipelineFactory::new();
        let p = f.create(
            event(),
            false,
            vec![
                CiJob::new("build", "build"),
                CiJob::new("b1", "benchmark"),
                CiJob::new("b2", "benchmark"),
                CiJob::new("plot", "visualize"),
            ],
        );
        assert_eq!(p.stages(), vec!["build", "benchmark", "visualize"]);
        assert_eq!(p.jobs_in_stage("benchmark").len(), 2);
        assert_eq!(p.id, 1);
        assert_eq!(f.create(event(), true, vec![]).id, 2);
    }
}
