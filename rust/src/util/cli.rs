//! Tiny argv parser (clap stand-in) for the `cbench` launcher.
//!
//! Grammar: `cbench <command> [<subcommand>] [--flag] [--key value] [positional...]`

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order (after the command words).
    pub positional: Vec<String>,
    /// `--key value` pairs; bare `--flag` maps to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse a raw argv tail (everything after the command words).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn parses_positional_and_options() {
        let a = args("fig9 --node icx36 --ranks 72 extra");
        assert_eq!(a.positional, vec!["fig9", "extra"]);
        assert_eq!(a.get("node"), Some("icx36"));
        assert_eq!(a.get_usize("ranks", 1), 72);
    }

    #[test]
    fn parses_flags_and_eq_syntax() {
        let a = args("--verbose --out=/tmp/x --n 3");
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert_eq!(a.get_usize("n", 0), 3);
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = args("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5), 1.5);
    }
}
