//! Minimal JSON value model, writer and parser.
//!
//! Used by the datastore (Kadi4Mat-like records), machine-state snapshots,
//! dashboard specs and report output. Supports the full JSON grammar except
//! `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable —
/// important for content-hashing records in the datastore.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns an error with byte offset on failure.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}
impl From<Vec<String>> for Json {
    fn from(a: Vec<String>) -> Json {
        Json::Arr(a.into_iter().map(Json::Str).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }
    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj()
            .set("name", "fe2ti216")
            .set("rves", 216i64)
            .set("tts", 40.25)
            .set("ok", true)
            .set("tags", vec!["a".to_string(), "b".to_string()]);
        let s = j.to_string_compact();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested_and_escapes() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x\ny\"z"}],"n":null,"e":-1.5e3}"#).unwrap();
        assert_eq!(j.get("e").unwrap().as_f64(), Some(-1500.0));
        let inner = &j.get("a").unwrap().as_arr().unwrap()[2];
        assert_eq!(inner.get("b").unwrap().as_str(), Some("x\ny\"z"));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""grün""#).unwrap();
        assert_eq!(j.as_str(), Some("grün"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn pretty_is_reparseable() {
        let j = Json::obj().set("k", Json::Arr(vec![Json::Num(1.0), Json::obj().set("x", 2i64)]));
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(216.0).to_string_compact(), "216");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
