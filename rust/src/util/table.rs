//! Aligned text tables and ASCII charts for terminal reports.
//!
//! The report generators (`cbench report figN`) print the same rows/series
//! the paper's figures show; these helpers render them readably.

/// A simple text table with a header row and auto-sized columns.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(c);
                for _ in c.chars().count()..width[i] {
                    out.push(' ');
                }
                out.push(' ');
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.header);
        for (i, w) in width.iter().enumerate() {
            out.push_str(if i == 0 { "|" } else { "|" });
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("|\n");
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    /// CSV export (comma-separated; cells containing commas get quoted).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Horizontal ASCII bar chart: one labelled bar per entry, scaled to `width`.
pub fn bar_chart(entries: &[(String, f64)], width: usize) -> String {
    if entries.is_empty() {
        return String::new();
    }
    let maxv = entries.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = entries.iter().map(|(l, _)| l.chars().count()).max().unwrap();
    let mut out = String::new();
    for (label, v) in entries {
        let n = if maxv > 0.0 {
            ((v / maxv) * width as f64).round() as usize
        } else {
            0
        };
        out.push_str(&format!(
            "{:<label_w$}  {:<width$}  {v:.4}\n",
            label,
            "#".repeat(n),
        ));
    }
    out
}

/// Stacked 100%-bar (Fig. 13 style): segments as (name, share) where shares
/// sum to ~1. Each bar is `width` chars of the segment letters.
pub fn stacked_bar(label: &str, segments: &[(&str, f64)], width: usize) -> String {
    let mut bar = String::new();
    let total: f64 = segments.iter().map(|(_, s)| s).sum();
    for (name, share) in segments {
        let n = ((share / total) * width as f64).round() as usize;
        let c = name.chars().next().unwrap_or('?').to_ascii_uppercase();
        for _ in 0..n {
            bar.push(c);
        }
    }
    bar.truncate(width);
    while bar.chars().count() < width {
        bar.push(' ');
    }
    let pct: Vec<String> = segments
        .iter()
        .map(|(n, s)| format!("{n}={:.1}%", 100.0 * s / total))
        .collect();
    format!("{label:<14} [{bar}]  {}", pct.join(" "))
}

/// Simple x/y ASCII scatter-line for scaling plots (log-ish x handled by
/// caller passing already-spaced points).
pub fn series_plot(series: &[(String, Vec<(f64, f64)>)], height: usize, width: usize) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, pts)| pts.iter().copied()).collect();
    if all.is_empty() {
        return String::new();
    }
    let (xmin, xmax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), (x, _)| (lo.min(*x), hi.max(*x)));
    let (ymin, ymax) = all
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), (_, y)| (lo.min(*y), hi.max(*y)));
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', 'o', '+', 'x', '@', '%'];
    for (si, (_, pts)) in series.iter().enumerate() {
        for (x, y) in pts {
            let cx = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("y: {ymin:.3} .. {ymax:.3}\n"));
    for row in &grid {
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("x: {xmin:.3} .. {xmax:.3}   "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("[{}]={} ", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["solver", "tts"]);
        t.row_str(&["PARDISO", "60.1"]);
        t.row_str(&["ILU", "40.0"]);
        let r = t.render();
        assert!(r.contains("| solver  | tts  |"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn bar_chart_scales() {
        let c = bar_chart(
            &[("a".into(), 1.0), ("b".into(), 2.0)],
            10,
        );
        let lines: Vec<&str> = c.lines().collect();
        assert!(lines[0].matches('#').count() == 5);
        assert!(lines[1].matches('#').count() == 10);
    }

    #[test]
    fn stacked_bar_shares() {
        let s = stacked_bar("icx36", &[("compute", 0.5), ("sync", 0.15), ("comm", 0.35)], 20);
        assert!(s.contains("compute=50.0%"));
        // 10 compute chars, 3 sync chars, 7 comm chars in order
        assert!(s.contains("[CCCCCCCCCCSSSCCCCCCC]"));
        assert!(s.starts_with("icx36"));
    }

    #[test]
    fn series_plot_renders() {
        let p = series_plot(
            &[("ilu".into(), vec![(1.0, 40.0), (64.0, 45.0)])],
            8,
            40,
        );
        assert!(p.contains("[*]=ilu"));
    }
}
