//! Hand-rolled substrates.
//!
//! The build environment vendors only the `xla` crate's dependency tree, so
//! everything a normal project would pull from crates.io (serde, rand, clap,
//! criterion, a table printer) is implemented here from scratch.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Simulated-time clock used across the stack.
///
/// The coordinator, scheduler and TSDB all share one notion of time: the
/// *simulated* wall clock in seconds since campaign start. Real host wall
/// time is only used by the bench harness (`stats::Bench`).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimTime(pub f64);

impl SimTime {
    pub fn secs(self) -> f64 {
        self.0
    }
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "time cannot go backwards (dt={dt})");
        self.0 += dt;
    }
    /// Nanoseconds since epoch — the TSDB timestamp unit (influx-style).
    pub fn nanos(self) -> i64 {
        (self.0 * 1e9) as i64
    }
    pub fn from_nanos(n: i64) -> Self {
        SimTime(n as f64 / 1e9)
    }
}

/// Format seconds human-readably (`1h02m`, `3.2s`, `450ms`).
pub fn fmt_secs(s: f64) -> String {
    if s >= 3600.0 {
        format!("{}h{:02}m", (s / 3600.0) as u64, ((s % 3600.0) / 60.0) as u64)
    } else if s >= 60.0 {
        format!("{}m{:02}s", (s / 60.0) as u64, (s % 60.0) as u64)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}us", s * 1e6)
    }
}

/// Format a byte count (`1.5 GB`, `320 MB`).
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_advances_and_converts() {
        let mut t = SimTime::default();
        t.advance(1.5);
        assert_eq!(t.secs(), 1.5);
        assert_eq!(t.nanos(), 1_500_000_000);
        assert_eq!(SimTime::from_nanos(t.nanos()).secs(), 1.5);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(3725.0), "1h02m");
        assert_eq!(fmt_secs(62.0), "1m02s");
        assert_eq!(fmt_secs(1.25), "1.25s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-6), "2.50us");
    }

    #[test]
    fn fmt_bytes_ranges() {
        assert_eq!(fmt_bytes(512.0), "512.00 B");
        assert_eq!(fmt_bytes(1.5e9), "1.50 GB");
    }
}
