//! Deterministic PRNG (splitmix64 + xoshiro256**).
//!
//! No `rand` crate is available in the vendored set; all stochastic parts of
//! the simulation (noise on timings, workload generators, property tests)
//! use this generator so runs are reproducible from a seed.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 to spread the seed over the state
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/stddev.
    pub fn gauss(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Multiplicative log-normal-ish noise around 1.0 with relative spread
    /// `rel` — used to perturb simulated timings the way real machines
    /// jitter (always positive).
    pub fn jitter(&mut self, rel: f64) -> f64 {
        (self.gauss(0.0, rel)).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn jitter_positive_centered() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let j = r.jitter(0.03);
            assert!(j > 0.0 && (0.7..1.4).contains(&j), "j={j}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
