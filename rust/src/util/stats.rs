//! Descriptive statistics + a small measurement harness.
//!
//! `criterion` is not available in the vendored crate set, so the
//! `rust/benches/*` targets (built with `harness = false`) use
//! [`Bench`] for warmup / timed iterations / outlier-robust reporting.

use std::time::Instant;

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            sd: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            p50: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
        }
    }
}

/// Percentile (linear interpolation) of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Least-squares slope+intercept for (x, y) pairs — used by report code to
/// check scaling trends ("time grows with nodes").
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-300 {
        return (0.0, sy / n);
    }
    let slope = (n * sxy - sx * sy) / denom;
    (slope, (sy - slope * sx) / n)
}

/// Wall-clock measurement harness (criterion stand-in).
///
/// Usage (in a `harness = false` bench binary):
/// ```ignore
/// let mut b = Bench::new("lbm_srt_32");
/// let r = b.run(|| lattice.step());
/// println!("{}", r.report());
/// ```
pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once total measured time exceeds this many seconds.
    pub budget_secs: f64,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs_per_iter: Summary,
}

impl BenchResult {
    /// One-line criterion-style report.
    pub fn report(&self) -> String {
        format!(
            "{:<40} time: [{} {} {}]  n={}",
            self.name,
            crate::util::fmt_secs(self.secs_per_iter.min),
            crate::util::fmt_secs(self.secs_per_iter.p50),
            crate::util::fmt_secs(self.secs_per_iter.max),
            self.iters,
        )
    }
    /// Throughput report when each iteration processes `units` items.
    pub fn report_throughput(&self, units: f64, unit_name: &str) -> String {
        let per_sec = units / self.secs_per_iter.p50;
        format!("{}  thrpt: {:.3e} {unit_name}/s", self.report(), per_sec)
    }
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 500,
            budget_secs: 2.0,
        }
    }

    pub fn quick(name: &str) -> Bench {
        Bench {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            budget_secs: 0.5,
            ..Bench::new(name)
        }
    }

    pub fn run<T>(&mut self, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && start.elapsed().as_secs_f64() < self.budget_secs)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        BenchResult {
            name: self.name.clone(),
            iters: samples.len(),
            secs_per_iter: Summary::of(&samples),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.sd - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&s, 0.0), 10.0);
        assert_eq!(percentile_sorted(&s, 100.0), 40.0);
        assert!((percentile_sorted(&s, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 1.0).collect();
        let (m, b) = linear_fit(&xs, &ys);
        assert!((m - 3.0).abs() < 1e-9 && (b - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bench::quick("noop");
        let r = b.run(|| 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.report().contains("noop"));
        assert!(r.report_throughput(100.0, "elem").contains("elem/s"));
    }
}
