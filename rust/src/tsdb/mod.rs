//! InfluxDB stand-in: a time-series database with tags, fields, a
//! line-protocol wire format — and, since the multi-year-history work,
//! **time-partitioned shards with a compaction pass**.
//!
//! The paper stores every benchmark result in InfluxDB (§4.3): *fields*
//! carry the runtime metrics (TTS, FLOP count, traffic), *tags* carry the
//! metadata (domain size, solver, compute node), and the pipeline trigger
//! time is the timestamp. Grafana then queries grouped-by-tag series.
//! This module implements that data model from scratch:
//!
//! * [`Point`] — measurement + tags + fields + nanosecond timestamp,
//! * line protocol encode/parse ([`Point::to_line`], [`Point::parse_line`]),
//! * [`Db`] — the storage engine (shard layout below),
//! * [`Query`] — tag filters, time range, field selection, group-by-tags,
//!   and the aggregations the dashboards use (last/mean/min/max).
//!
//! # Shard layout
//!
//! Every measurement is split into **time-partitioned shards**: shard `k`
//! owns the points with `ts ∈ [k·span, (k+1)·span)` where `span` is the
//! database's shard span ([`Db::with_shard_span`]; default
//! [`DEFAULT_SHARD_SPAN_NS`] = 4096 simulated seconds ≈ 4096 pipeline
//! triggers). Shards are kept in partition order and each shard keeps its
//! points time-sorted, so the concatenation of shards *is* the
//! time-sorted measurement. Because points are sorted, a shard's first
//! and last timestamps double as its **min/max-ts index**:
//! [`Db::points_in_range`] binary-searches the shard list for the
//! overlapping run and then clamps only inside the edge shards, and the
//! reverse walks behind `tail(n)` ([`Db::tail_start_ts`], the filtered
//! bound scan in [`Query::run`]) stream shard-by-shard from the newest —
//! a query over the trailing window never touches the years of shards in
//! front of it, no matter how deep the history grows.
//!
//! # Compaction / retention
//!
//! [`Db::compact`] implements the retention policy for multi-year
//! histories: shards entirely older than `newest_ts − retain_raw_ns`
//! have their raw points replaced by **downsampled rollup summaries** —
//! one point per series (distinct tag set) per shard, carrying the
//! per-field mean over the shard, the raw point count in the
//! `rollup_n` field, the series' last in-shard timestamp, and a
//! `rollup=mean` marker tag. Queries over the *retained raw* range are
//! byte-for-byte unchanged; queries reaching into compacted shards see
//! the coarse summaries (good enough for the dashboards' long-range
//! panels, and exactly what keeps the store bounded). The pass is
//! idempotent — compacted shards (including ones reloaded from a saved
//! file, recognized by the marker tag) are skipped — and is exposed as
//! `cbench tsdb compact`.
//!
//! # Streaming uploads
//!
//! `coordinator::collect_pipeline` uploads each pipeline's points at the
//! pipeline's **completion event** on the simulated clock (streaming
//! collect), so inserts arrive in nearly trigger-time order and hit the
//! append fast path of the newest shard; late/out-of-order points are
//! routed to their partition by binary search.

pub mod query;

pub use query::{Aggregate, GroupedSeries, Query};

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// Default shard span: 4096 simulated seconds. Campaign trigger clocks
/// advance 1 s per pipeline, so a shard holds ~4096 pipeline triggers.
pub const DEFAULT_SHARD_SPAN_NS: i64 = 4096 * 1_000_000_000;

/// Marker tag carried by compaction rollup summaries (`rollup=mean`).
pub const ROLLUP_TAG: &str = "rollup";

/// One data point.
#[derive(Debug, Clone, PartialEq)]
pub struct Point {
    pub measurement: String,
    pub tags: BTreeMap<String, String>,
    pub fields: BTreeMap<String, f64>,
    /// Nanoseconds since campaign epoch.
    pub ts: i64,
}

impl Point {
    pub fn new(measurement: &str, ts: i64) -> Point {
        Point {
            measurement: measurement.to_string(),
            tags: BTreeMap::new(),
            fields: BTreeMap::new(),
            ts,
        }
    }
    pub fn tag(mut self, k: &str, v: &str) -> Point {
        self.tags.insert(k.to_string(), v.to_string());
        self
    }
    pub fn field(mut self, k: &str, v: f64) -> Point {
        self.fields.insert(k.to_string(), v);
        self
    }

    /// Influx line protocol: `measurement,tag=v,... field=v,... ts`.
    /// Spaces/commas in tag values are escaped with `\`.
    pub fn to_line(&self) -> String {
        let esc = |s: &str| s.replace('\\', "\\\\").replace(',', "\\,").replace(' ', "\\ ").replace('=', "\\=");
        let mut line = esc(&self.measurement);
        for (k, v) in &self.tags {
            line.push(',');
            line.push_str(&esc(k));
            line.push('=');
            line.push_str(&esc(v));
        }
        line.push(' ');
        let fields: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("{}={v}", esc(k)))
            .collect();
        line.push_str(&fields.join(","));
        line.push(' ');
        line.push_str(&self.ts.to_string());
        line
    }

    /// Parse one line-protocol line.
    pub fn parse_line(line: &str) -> Result<Point, String> {
        // split into 3 sections on unescaped spaces
        let mut sections: Vec<String> = Vec::new();
        let mut cur = String::new();
        let mut esc = false;
        for c in line.chars() {
            if esc {
                cur.push(c);
                esc = false;
            } else if c == '\\' {
                cur.push(c);
                esc = true;
            } else if c == ' ' && sections.len() < 2 {
                sections.push(std::mem::take(&mut cur));
            } else {
                cur.push(c);
            }
        }
        sections.push(cur);
        if sections.len() != 3 {
            return Err(format!("expected 3 sections, got {}", sections.len()));
        }
        let unesc = |s: &str| -> String {
            let mut out = String::new();
            let mut esc = false;
            for c in s.chars() {
                if esc {
                    out.push(c);
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else {
                    out.push(c);
                }
            }
            out
        };
        // measurement + tags: split on unescaped commas
        let split_unescaped = |s: &str, sep: char| -> Vec<String> {
            let mut parts = Vec::new();
            let mut cur = String::new();
            let mut esc = false;
            for c in s.chars() {
                if esc {
                    cur.push(c);
                    esc = false;
                } else if c == '\\' {
                    cur.push(c);
                    esc = true;
                } else if c == sep {
                    parts.push(std::mem::take(&mut cur));
                } else {
                    cur.push(c);
                }
            }
            parts.push(cur);
            parts
        };
        let head = split_unescaped(&sections[0], ',');
        let mut p = Point::new(&unesc(&head[0]), 0);
        for t in &head[1..] {
            let kv = split_unescaped(t, '=');
            if kv.len() != 2 {
                return Err(format!("bad tag `{t}`"));
            }
            p.tags.insert(unesc(&kv[0]), unesc(&kv[1]));
        }
        for f in split_unescaped(&sections[1], ',') {
            let kv = split_unescaped(&f, '=');
            if kv.len() != 2 {
                return Err(format!("bad field `{f}`"));
            }
            let v: f64 = kv[1].parse().map_err(|_| format!("bad field value `{}`", kv[1]))?;
            p.fields.insert(unesc(&kv[0]), v);
        }
        p.ts = sections[2]
            .trim()
            .parse()
            .map_err(|_| format!("bad timestamp `{}`", sections[2]))?;
        if p.fields.is_empty() {
            return Err("point has no fields".into());
        }
        Ok(p)
    }
}

/// One time partition of a measurement: the points with
/// `ts ∈ [key·span, (key+1)·span)`, kept time-sorted. The first/last
/// timestamps of the sorted storage are the shard's min/max-ts index.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Partition index: this shard covers `[key·span, (key+1)·span)`.
    key: i64,
    points: Vec<Point>,
    /// Raw points replaced by rollup summaries (see [`Db::compact`]).
    compacted: bool,
}

impl Shard {
    pub fn key(&self) -> i64 {
        self.key
    }
    pub fn points(&self) -> &[Point] {
        &self.points
    }
    pub fn len(&self) -> usize {
        self.points.len()
    }
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
    /// Oldest timestamp in the shard (the min side of the index).
    pub fn min_ts(&self) -> Option<i64> {
        self.points.first().map(|p| p.ts)
    }
    /// Newest timestamp in the shard (the max side of the index).
    pub fn max_ts(&self) -> Option<i64> {
        self.points.last().map(|p| p.ts)
    }
    /// True once this shard holds rollup summaries instead of raw points
    /// (set by [`Db::compact`], re-detected on reload via [`ROLLUP_TAG`]).
    pub fn is_compacted(&self) -> bool {
        self.compacted
    }
}

/// Outcome of one [`Db::compact`] pass.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CompactionReport {
    /// Shards inspected across all measurements.
    pub shards_seen: usize,
    /// Shards whose raw points were replaced by rollup summaries.
    pub shards_compacted: usize,
    /// Total points before / after the pass.
    pub points_before: usize,
    pub points_after: usize,
}

/// The storage engine: time-partitioned shards per measurement (see the
/// module docs for the layout and the compaction/retention model).
#[derive(Debug)]
pub struct Db {
    measurements: BTreeMap<String, Vec<Shard>>,
    shard_span_ns: i64,
}

impl Default for Db {
    fn default() -> Db {
        Db::new()
    }
}

impl Db {
    pub fn new() -> Db {
        Db::with_shard_span(DEFAULT_SHARD_SPAN_NS)
    }

    /// Build a database with a custom shard span (ns per partition).
    /// The span is fixed for the database's lifetime — partition keys are
    /// derived from it.
    pub fn with_shard_span(span_ns: i64) -> Db {
        Db {
            measurements: BTreeMap::new(),
            shard_span_ns: span_ns.max(1),
        }
    }

    pub fn shard_span(&self) -> i64 {
        self.shard_span_ns
    }

    /// The shard list of `measurement`, in partition (= time) order.
    pub fn shards(&self, measurement: &str) -> &[Shard] {
        self.measurements
            .get(measurement)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Insert one point into its time partition (keeps the shard sorted).
    /// Streaming uploads arrive in near trigger-time order, so the common
    /// case is an append to the newest shard. A raw point landing in an
    /// already-compacted shard (late import into rolled-up history)
    /// reopens that shard for the next [`Db::compact`] pass, which merges
    /// raw points and existing rollups weight-correctly.
    pub fn insert(&mut self, p: Point) {
        let key = p.ts.div_euclid(self.shard_span_ns);
        let raw = !p.tags.contains_key(ROLLUP_TAG);
        let shards = self.measurements.entry(p.measurement.clone()).or_default();
        let si = match shards.binary_search_by(|s| s.key.cmp(&key)) {
            Ok(i) => i,
            Err(i) => {
                shards.insert(
                    i,
                    Shard { key, points: Vec::new(), compacted: false },
                );
                i
            }
        };
        if raw {
            shards[si].compacted = false;
        }
        let v = &mut shards[si].points;
        if v.last().map(|l| l.ts <= p.ts).unwrap_or(true) {
            v.push(p);
        } else {
            let idx = v.partition_point(|q| q.ts <= p.ts);
            v.insert(idx, p);
        }
    }

    /// Ingest a batch of line-protocol text (the pipeline's upload step).
    pub fn ingest_lines(&mut self, text: &str) -> Result<usize, String> {
        let mut n = 0;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            self.insert(Point::parse_line(line)?);
            n += 1;
        }
        Ok(n)
    }

    pub fn measurements(&self) -> impl Iterator<Item = &String> {
        self.measurements.keys()
    }

    pub fn len(&self) -> usize {
        self.measurements
            .values()
            .map(|shards| shards.iter().map(|s| s.points.len()).sum::<usize>())
            .sum()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of points of one measurement (across all its shards).
    pub fn n_points(&self, measurement: &str) -> usize {
        self.shards(measurement).iter().map(|s| s.points.len()).sum()
    }

    /// All points of `measurement` in time order, streamed shard by shard.
    /// Double-ended: `.rev()` walks newest-first without touching old
    /// shards until reached (the bound scans behind `tail(n)` rely on it).
    pub fn points_iter<'a>(
        &'a self,
        measurement: &str,
    ) -> impl DoubleEndedIterator<Item = &'a Point> + 'a {
        self.shards(measurement).iter().flat_map(|s| s.points.iter())
    }

    /// The newest point of `measurement` (last point of the last shard).
    pub fn last_point(&self, measurement: &str) -> Option<&Point> {
        self.shards(measurement).last().and_then(|s| s.points.last())
    }

    /// Points of `measurement` within the inclusive `[t_min, t_max]`
    /// window. The shard list is binary-searched by its min/max-ts index
    /// for the overlapping run, and only the two edge shards are clamped
    /// by an inner binary search — shards outside the window are never
    /// touched, O(log shards + log shard_size + hits).
    pub fn points_in_range<'a>(
        &'a self,
        measurement: &str,
        t_min: Option<i64>,
        t_max: Option<i64>,
    ) -> impl Iterator<Item = &'a Point> + 'a {
        let shards = self.shards(measurement);
        let lo = t_min
            .map(|t0| shards.partition_point(|s| s.max_ts().map(|m| m < t0).unwrap_or(true)))
            .unwrap_or(0);
        let hi = t_max
            .map(|t1| shards.partition_point(|s| s.min_ts().map(|m| m <= t1).unwrap_or(false)))
            .unwrap_or(shards.len());
        shards[lo..hi.max(lo)].iter().flat_map(move |s| {
            let pts = &s.points;
            let a = t_min.map(|t| pts.partition_point(|p| p.ts < t)).unwrap_or(0);
            let b = t_max
                .map(|t| pts.partition_point(|p| p.ts <= t))
                .unwrap_or(pts.len());
            pts[a..b.max(a)].iter()
        })
    }

    /// Timestamp at which the trailing `n` *distinct* timestamps of
    /// `measurement` begin — the pushdown bound behind [`Query::tail`].
    /// CB uploads one point per live series per pipeline trigger, so the
    /// walk from the end touches O(n × series) points — and, shard-wise,
    /// only the newest shard(s) — regardless of how many years of history
    /// sit in front. Returns `None` for an empty measurement or `n == 0`;
    /// with fewer than `n` distinct timestamps it returns the earliest one.
    pub fn tail_start_ts(&self, measurement: &str, n: usize) -> Option<i64> {
        if n == 0 {
            return None;
        }
        let mut distinct = 0usize;
        let mut last: Option<i64> = None;
        for p in self.points_iter(measurement).rev() {
            if last != Some(p.ts) {
                distinct += 1;
                last = Some(p.ts);
                if distinct == n {
                    return last;
                }
            }
        }
        last
    }

    /// All distinct values of `tag` within a measurement — powers the
    /// dashboard template-variable dropdowns (the "collision Setup menu").
    pub fn tag_values(&self, measurement: &str, tag: &str) -> Vec<String> {
        let mut vals: Vec<String> = self
            .points_iter(measurement)
            .filter_map(|p| p.tags.get(tag).cloned())
            .collect();
        vals.sort();
        vals.dedup();
        vals
    }

    /// Retention pass: replace the raw points of every shard entirely
    /// older than `newest_ts − retain_raw_ns` with per-series rollup
    /// summaries (per-field mean over the shard, raw count in `rollup_n`,
    /// `rollup=mean` marker tag, timestamp = the series' last in-shard
    /// point). Shards overlapping the retained window are untouched, so
    /// queries over the raw range are unchanged. Idempotent: already
    /// compacted shards — including ones reloaded from a saved file,
    /// recognized by the marker tag — are skipped, and a shard that mixes
    /// existing rollups with late-arriving raw points (see [`Db::insert`])
    /// is merged **weight-correctly**: a rollup contributes its stored
    /// per-field means at weight `rollup_n`, so re-compaction never
    /// degrades means into means-of-means or resets raw counts.
    pub fn compact(&mut self, retain_raw_ns: i64) -> CompactionReport {
        let mut rep = CompactionReport {
            points_before: self.len(),
            ..CompactionReport::default()
        };
        let newest = self
            .measurements
            .values()
            .filter_map(|shards| shards.last().and_then(|s| s.max_ts()))
            .max();
        let Some(newest) = newest else {
            return rep;
        };
        let watermark = newest.saturating_sub(retain_raw_ns.max(0));
        for shards in self.measurements.values_mut() {
            for s in shards.iter_mut() {
                rep.shards_seen += 1;
                if s.compacted || s.points.is_empty() {
                    continue;
                }
                if s.max_ts().unwrap_or(i64::MAX) >= watermark {
                    continue; // overlaps the retained raw window
                }
                if s.points.iter().all(|p| p.tags.contains_key(ROLLUP_TAG)) {
                    s.compacted = true; // reloaded pre-compacted shard
                    continue;
                }
                // one rollup per series — keyed by the tags WITHOUT the
                // rollup marker, so late raw points merge into the series'
                // existing rollup. Accumulator: (last ts, per-field
                // (weighted sum, weight), total weight); a raw point
                // weighs 1, a rollup weighs its stored `rollup_n`.
                type Acc = (i64, BTreeMap<String, (f64, f64)>, f64);
                let mut groups: BTreeMap<BTreeMap<String, String>, Acc> = BTreeMap::new();
                for p in &s.points {
                    let is_rollup = p.tags.contains_key(ROLLUP_TAG);
                    let w = if is_rollup {
                        p.fields.get("rollup_n").copied().unwrap_or(1.0).max(1.0)
                    } else {
                        1.0
                    };
                    let mut key = p.tags.clone();
                    key.remove(ROLLUP_TAG);
                    let e = groups
                        .entry(key)
                        .or_insert_with(|| (p.ts, BTreeMap::new(), 0.0));
                    e.0 = e.0.max(p.ts);
                    e.2 += w;
                    for (k, v) in &p.fields {
                        if is_rollup && k == "rollup_n" {
                            continue; // the count is the weight, not a metric
                        }
                        let f = e.1.entry(k.clone()).or_insert((0.0, 0.0));
                        f.0 += v * w;
                        f.1 += w;
                    }
                }
                let measurement = s.points[0].measurement.clone();
                let mut summaries: Vec<Point> = groups
                    .into_iter()
                    .map(|(mut tags, (ts, fields, n))| {
                        tags.insert(ROLLUP_TAG.to_string(), "mean".to_string());
                        let mut fmap: BTreeMap<String, f64> = fields
                            .into_iter()
                            .map(|(k, (sum, weight))| (k, sum / weight))
                            .collect();
                        fmap.insert("rollup_n".to_string(), n);
                        Point { measurement: measurement.clone(), tags, fields: fmap, ts }
                    })
                    .collect();
                // deterministic order: time-sorted, BTreeMap tie order
                summaries.sort_by_key(|p| p.ts);
                s.points = summaries;
                s.compacted = true;
                rep.shards_compacted += 1;
            }
        }
        rep.points_after = self.len();
        rep
    }

    /// Persist as line protocol (shards stream out in time order).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for shards in self.measurements.values() {
            for s in shards {
                for p in &s.points {
                    writeln!(f, "{}", p.to_line())?;
                }
            }
        }
        Ok(())
    }

    /// Load from a line-protocol file (default shard span).
    pub fn load(path: &Path) -> std::io::Result<Db> {
        Db::load_with_shard_span(path, DEFAULT_SHARD_SPAN_NS)
    }

    /// Load with a custom shard span (`cbench tsdb compact --shard-span`).
    pub fn load_with_shard_span(path: &Path, span_ns: i64) -> std::io::Result<Db> {
        let text = std::fs::read_to_string(path)?;
        let mut db = Db::with_shard_span(span_ns);
        db.ingest_lines(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        Ok(db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Point {
        Point::new("fe2ti", 1_000_000_000)
            .tag("node", "icx36")
            .tag("solver", "ilu")
            .field("tts", 40.5)
            .field("gflops", 25.0)
    }

    #[test]
    fn line_protocol_roundtrip() {
        let p = sample();
        let line = p.to_line();
        assert!(line.starts_with("fe2ti,node=icx36,solver=ilu "));
        let q = Point::parse_line(&line).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_escapes_specials() {
        let p = Point::new("m x", 5)
            .tag("k,1", "v 2=3")
            .field("f", 1.0);
        let q = Point::parse_line(&p.to_line()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_negative_timestamp_roundtrip() {
        // timestamps are ns relative to the campaign epoch; pre-epoch
        // imports (e.g. backfilled history) are legitimately negative
        let p = Point::new("m", -1_500_000_000).field("v", 1.0);
        let line = p.to_line();
        assert!(line.ends_with(" -1500000000"));
        assert_eq!(Point::parse_line(&line).unwrap(), p);
        assert_eq!(Point::parse_line("m v=1 -1").unwrap().ts, -1);
    }

    #[test]
    fn line_protocol_escaped_commas_spaces_equals_everywhere() {
        // every syntactic position that the wire format delimits:
        // measurement, tag key, tag value, field key — with every special
        let p = Point::new("mea,su re=ment", 7)
            .tag("tag,key with=all", "va,l ue=x")
            .tag("plain", "v")
            .field("fie,ld key=f", -2.5)
            .field("g", 1e-7);
        let q = Point::parse_line(&p.to_line())
            .unwrap_or_else(|e| panic!("{e}: {}", p.to_line()));
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_backslash_tails_roundtrip() {
        // trailing and doubled backslashes must survive the escape layer
        let p = Point::new("m\\", 1)
            .tag("k\\\\", "v\\")
            .field("f\\", 3.0);
        let q = Point::parse_line(&p.to_line()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_extreme_field_values_roundtrip() {
        // Rust's f64 Display prints the shortest representation that
        // parses back exactly, so numeric round-trips must be lossless
        for v in [
            0.1,
            -0.30000000000000004,
            1.7976931348623157e308,
            5e-324,
            -1234567890.123456,
            0.0,
        ] {
            let p = Point::new("m", 9).field("v", v);
            let q = Point::parse_line(&p.to_line()).unwrap();
            assert_eq!(p, q, "value {v:e}");
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Point::parse_line("nofields 123").is_err());
        assert!(Point::parse_line("m f=1 notanumber").is_err());
        assert!(Point::parse_line("m f=x 1").is_err());
        assert!(Point::parse_line("m").is_err());
    }

    #[test]
    fn db_keeps_time_order() {
        let mut db = Db::new();
        for ts in [5, 1, 3, 2, 4] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let ts: Vec<i64> = db.points_iter("m").map(|p| p.ts).collect();
        assert_eq!(ts, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn db_keeps_time_order_across_shard_boundaries() {
        // span 10: keys ..., -1 => [-10, 0), 0 => [0, 10), 1 => [10, 20)
        let mut db = Db::with_shard_span(10);
        for ts in [25, 3, -7, 14, 9, 10, -10, 19, 0] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let ts: Vec<i64> = db.points_iter("m").map(|p| p.ts).collect();
        assert_eq!(ts, vec![-10, -7, 0, 3, 9, 10, 14, 19, 25]);
        assert_eq!(db.shards("m").len(), 4);
        let keys: Vec<i64> = db.shards("m").iter().map(|s| s.key()).collect();
        assert_eq!(keys, vec![-1, 0, 1, 2]);
        // min/max index of the middle shard
        let s = &db.shards("m")[1];
        assert_eq!((s.min_ts(), s.max_ts()), (Some(0), Some(9)));
        assert_eq!(db.last_point("m").unwrap().ts, 25);
        assert_eq!(db.n_points("m"), 9);
        // reverse iteration streams newest-first across shards
        let rev: Vec<i64> = db.points_iter("m").rev().map(|p| p.ts).collect();
        assert_eq!(rev, vec![25, 19, 14, 10, 9, 3, 0, -7, -10]);
    }

    #[test]
    fn ingest_and_tag_values() {
        let mut db = Db::new();
        let text = "\
# comment
lbm,node=icx36,op=srt mlups=1200 1
lbm,node=icx36,op=trt mlups=1100 2

lbm,node=rome1,op=srt mlups=400 3
";
        assert_eq!(db.ingest_lines(text).unwrap(), 3);
        assert_eq!(db.len(), 3);
        assert_eq!(db.tag_values("lbm", "op"), vec!["srt", "trt"]);
        assert_eq!(db.tag_values("lbm", "node"), vec!["icx36", "rome1"]);
        assert!(db.tag_values("lbm", "missing").is_empty());
    }

    #[test]
    fn points_in_range_binary_search_matches_scan() {
        let mut db = Db::new();
        for ts in [1, 2, 2, 3, 5, 8, 8, 9] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let slice: Vec<&Point> = db.points_in_range("m", Some(2), Some(8)).collect();
        assert_eq!(slice.len(), 6);
        assert_eq!(slice.first().unwrap().ts, 2);
        assert_eq!(slice.last().unwrap().ts, 8);
        assert_eq!(db.points_in_range("m", None, Some(1)).count(), 1);
        assert_eq!(db.points_in_range("m", Some(9), None).count(), 1);
        assert_eq!(db.points_in_range("m", Some(6), Some(7)).count(), 0);
        assert_eq!(db.points_in_range("m", Some(10), None).count(), 0);
        assert_eq!(db.points_in_range("m", None, None).count(), 8);
        assert_eq!(db.points_in_range("nosuch", None, None).count(), 0);
    }

    #[test]
    fn points_in_range_touches_only_overlapping_shards() {
        // spans of 10 over [0, 50): ranges land inside / across shards,
        // and exactly on shard edges — all equivalent to a linear filter
        let mut sharded = Db::with_shard_span(10);
        let mut single = Db::with_shard_span(i64::MAX / 4);
        for ts in 0..50 {
            let p = Point::new("m", ts).field("v", ts as f64);
            sharded.insert(p.clone());
            single.insert(p);
        }
        assert!(sharded.shards("m").len() > 1);
        assert_eq!(single.shards("m").len(), 1);
        for (a, b) in [(0, 49), (5, 25), (10, 19), (9, 10), (19, 20), (30, 30), (48, 200), (-5, 3)] {
            let s1: Vec<i64> = sharded
                .points_in_range("m", Some(a), Some(b))
                .map(|p| p.ts)
                .collect();
            let s2: Vec<i64> = single
                .points_in_range("m", Some(a), Some(b))
                .map(|p| p.ts)
                .collect();
            assert_eq!(s1, s2, "range [{a}, {b}]");
        }
    }

    #[test]
    fn tail_start_ts_counts_distinct_timestamps() {
        let mut db = Db::new();
        // two series reporting at each of 4 pipeline triggers
        for ts in [10, 20, 30, 40] {
            db.insert(Point::new("m", ts).tag("s", "a").field("v", 1.0));
            db.insert(Point::new("m", ts).tag("s", "b").field("v", 2.0));
        }
        assert_eq!(db.tail_start_ts("m", 1), Some(40));
        assert_eq!(db.tail_start_ts("m", 2), Some(30));
        assert_eq!(db.tail_start_ts("m", 4), Some(10));
        // fewer distinct timestamps than requested: earliest
        assert_eq!(db.tail_start_ts("m", 99), Some(10));
        assert_eq!(db.tail_start_ts("m", 0), None);
        assert_eq!(db.tail_start_ts("nosuch", 3), None);
    }

    #[test]
    fn tail_start_ts_crosses_shard_boundaries() {
        let mut db = Db::with_shard_span(10);
        for ts in [5, 15, 25] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        assert_eq!(db.shards("m").len(), 3);
        assert_eq!(db.tail_start_ts("m", 1), Some(25));
        assert_eq!(db.tail_start_ts("m", 2), Some(15));
        assert_eq!(db.tail_start_ts("m", 3), Some(5));
    }

    #[test]
    fn compaction_rolls_up_old_shards_and_keeps_raw_recent() {
        // span 10, points over [0, 35): shards [0,10) [10,20) [20,30)
        // [30,40). retain_raw 10 => watermark 24: shards 0 and 1 compact,
        // shard [20,30) contains ts 24..29 >= watermark side — max_ts 29
        // >= 24 so it stays raw, as does [30,40)
        let mut db = Db::with_shard_span(10);
        for ts in 0..35 {
            for s in ["a", "b"] {
                db.insert(
                    Point::new("m", ts)
                        .tag("s", s)
                        .field("v", ts as f64)
                        .field("w", 2.0 * ts as f64),
                );
            }
        }
        let before = db.len();
        let rep = db.compact(10);
        assert_eq!(rep.points_before, before);
        assert_eq!(rep.shards_compacted, 2);
        // each compacted shard: 2 series => 2 rollup points (was 20)
        assert_eq!(rep.points_after, before - 2 * 20 + 2 * 2);
        assert_eq!(db.len(), rep.points_after);
        let s0 = &db.shards("m")[0];
        assert!(s0.is_compacted());
        assert_eq!(s0.len(), 2);
        let p = &s0.points()[0];
        assert_eq!(p.tags[ROLLUP_TAG], "mean");
        assert_eq!(p.ts, 9, "rollup carries the series' last in-shard ts");
        assert_eq!(p.fields["v"], 4.5, "mean of 0..=9");
        assert_eq!(p.fields["rollup_n"], 10.0);
        // the retained raw window is untouched
        let recent: Vec<i64> = db
            .points_in_range("m", Some(25), Some(34))
            .map(|p| p.ts)
            .collect();
        assert_eq!(recent.len(), 20);
        assert!(db.shards("m")[2].points().iter().all(|p| !p.tags.contains_key(ROLLUP_TAG)));
        // idempotent: a second pass changes nothing
        let rep2 = db.compact(10);
        assert_eq!(rep2.shards_compacted, 0);
        assert_eq!(rep2.points_after, rep2.points_before);
    }

    #[test]
    fn compaction_survives_save_load_roundtrip() {
        let mut db = Db::with_shard_span(10);
        for ts in 0..30 {
            db.insert(Point::new("m", ts).tag("s", "a").field("v", ts as f64));
        }
        db.compact(5);
        let dump_before: Vec<String> = db.points_iter("m").map(|p| p.to_line()).collect();
        let path = std::env::temp_dir().join("cbench_tsdb_compact_roundtrip.lp");
        db.save(&path).unwrap();
        let mut back = Db::load_with_shard_span(&path, 10).unwrap();
        let dump_after: Vec<String> = back.points_iter("m").map(|p| p.to_line()).collect();
        assert_eq!(dump_before, dump_after);
        // reloaded rollup shards are recognized and not re-compacted
        let rep = back.compact(5);
        assert_eq!(rep.shards_compacted, 0);
        assert_eq!(rep.points_after, rep.points_before);
        assert!(back.shards("m")[0].is_compacted());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn late_insert_reopens_compacted_shard_and_recompaction_merges_weights() {
        // a raw point landing in rolled-up history must reopen the shard,
        // and the next pass must merge it into the existing rollup
        // weight-correctly (no mean-of-means, no reset raw count)
        let mut db = Db::with_shard_span(10);
        for ts in 0..30 {
            db.insert(Point::new("m", ts).tag("s", "a").field("v", 1.0));
        }
        db.compact(5); // shards [0,10) and [10,20) -> rollups of 10 points
        assert!(db.shards("m")[0].is_compacted());
        assert_eq!(db.shards("m")[0].points()[0].fields["rollup_n"], 10.0);

        // late import: one raw point with a different value into shard 0
        db.insert(Point::new("m", 5).tag("s", "a").field("v", 12.0));
        assert!(!db.shards("m")[0].is_compacted(), "raw insert reopens the shard");
        assert_eq!(db.shards("m")[0].len(), 2);

        let rep = db.compact(5);
        assert_eq!(rep.shards_compacted, 1, "only the reopened shard recompacts");
        let s0 = &db.shards("m")[0];
        assert!(s0.is_compacted());
        assert_eq!(s0.len(), 1, "rollup and late point merge into one series");
        let p = &s0.points()[0];
        assert_eq!(p.fields["rollup_n"], 11.0, "raw count accumulates, not resets");
        // weighted mean: (10 x 1.0 + 1 x 12.0) / 11
        assert!((p.fields["v"] - 2.0).abs() < 1e-12, "got {}", p.fields["v"]);
        assert_eq!(p.ts, 9, "rollup keeps the series' last in-shard ts");
    }

    #[test]
    fn compaction_on_empty_db_is_a_noop() {
        let mut db = Db::new();
        let rep = db.compact(100);
        assert_eq!(rep, CompactionReport::default());
    }

    #[test]
    fn save_load_roundtrip() {
        let mut db = Db::new();
        db.insert(sample());
        db.insert(Point::new("lbm", 7).tag("op", "srt").field("mlups", 900.0));
        let path = std::env::temp_dir().join("cbench_tsdb_test.lp");
        db.save(&path).unwrap();
        let back = Db::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.points_iter("fe2ti").next().unwrap(), &sample());
        std::fs::remove_file(&path).ok();
    }
}
