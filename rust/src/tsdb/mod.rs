//! InfluxDB stand-in: a time-series database with tags, fields, a
//! line-protocol wire format — and, since the multi-year-history work,
//! **time-partitioned shards with a compaction pass**.
//!
//! The paper stores every benchmark result in InfluxDB (§4.3): *fields*
//! carry the runtime metrics (TTS, FLOP count, traffic), *tags* carry the
//! metadata (domain size, solver, compute node), and the pipeline trigger
//! time is the timestamp. Grafana then queries grouped-by-tag series.
//! This module implements that data model from scratch:
//!
//! * [`Point`] — measurement + tags + fields + nanosecond timestamp,
//! * line protocol encode/parse ([`Point::to_line`], [`Point::parse_line`]),
//! * [`Db`] — the storage engine (shard layout below),
//! * [`Query`] — tag filters, time range, field selection, group-by-tags,
//!   and the aggregations the dashboards use (last/mean/min/max).
//!
//! # Shard layout
//!
//! Every measurement is split into **time-partitioned shards**: shard `k`
//! owns the points with `ts ∈ [k·span, (k+1)·span)` where `span` is the
//! database's shard span ([`Db::with_shard_span`]; default
//! [`DEFAULT_SHARD_SPAN_NS`] = 4096 simulated seconds ≈ 4096 pipeline
//! triggers). Shards are kept in partition order and each shard keeps its
//! points time-sorted, so the concatenation of shards *is* the
//! time-sorted measurement. Because points are sorted, a shard's first
//! and last timestamps double as its **min/max-ts index**:
//! [`Db::points_in_range`] binary-searches the shard list for the
//! overlapping run and then clamps only inside the edge shards, and the
//! reverse walks behind `tail(n)` ([`Db::tail_start_ts`], the filtered
//! bound scan in [`Query::run`]) stream shard-by-shard from the newest —
//! a query over the trailing window never touches the years of shards in
//! front of it, no matter how deep the history grows.
//!
//! # Compaction / retention
//!
//! [`Db::compact`] implements the retention policy for multi-year
//! histories: shards entirely older than `newest_ts − retain_raw_ns`
//! have their raw points replaced by **downsampled rollup summaries** —
//! one point per series (distinct tag set) per shard, carrying the
//! per-field mean over the shard, the raw point count in the
//! `rollup_n` field, the series' last in-shard timestamp, and a
//! `rollup=mean` marker tag. Queries over the *retained raw* range are
//! byte-for-byte unchanged; queries reaching into compacted shards see
//! the coarse summaries (good enough for the dashboards' long-range
//! panels, and exactly what keeps the store bounded). The pass is
//! idempotent — compacted shards (including ones reloaded from a saved
//! file, recognized by the marker tag) are skipped — and is exposed as
//! `cbench tsdb compact`.
//!
//! # Streaming uploads
//!
//! `coordinator::collect_pipeline` uploads each pipeline's points at the
//! pipeline's **completion event** on the simulated clock (streaming
//! collect), so inserts arrive in nearly trigger-time order and hit the
//! append fast path of the newest shard; late/out-of-order points are
//! routed to their partition by binary search.
//!
//! # Persistence: the manifest layout
//!
//! Since the shard-aware-persistence work a [`Db`] persists as a
//! **directory**, not a single line-protocol file:
//!
//! ```text
//! cbench_tsdb.lp/
//!   manifest.json      shard index: per measurement, per shard the
//!                      partition key, backing file, point count,
//!                      min/max-ts index and compaction state
//!   lbm-k0.lp          one line-protocol file per shard
//!   lbm-k1.lp
//!   campaign-k0.lp
//! ```
//!
//! Two contracts fall out of the layout, both on the "don't redo old
//! work" axis the whole system is built around:
//!
//! * **Loads parse the manifest eagerly but shard bodies lazily.**
//!   [`Db::load`] materializes only the index; a shard's points are
//!   parsed the first time a query actually reaches into it
//!   ([`Shard::points`]). The range/tail pushdowns select shards by the
//!   manifest's min/max-ts index, so a detector-style trailing-window
//!   query over a multi-year compacted history parses the newest
//!   shard(s) only — cold-load cost is flat in history depth (the
//!   `bench_regress` PERSIST_JSON section pins it). The shard span is
//!   the materialization granularity: a query touching one point pays
//!   for that point's whole shard, never for its neighbours.
//! * **Saves rewrite only mutated shards.** Every shard carries a dirty
//!   flag ([`Shard::is_dirty`]); [`Db::save`] onto the directory the
//!   store was loaded from (its *home*) rewrites dirty shards plus the
//!   manifest and leaves everything else untouched on disk
//!   ([`PersistReport`] counts both). Appending one pipeline to a
//!   multi-year store costs one shard file + the manifest.
//!
//! All writes are **atomic around the manifest rename**: rewritten
//! shards land in *fresh* file names (never over a file the committed
//! manifest references), shard files and the manifest go through a
//! `.tmp` sibling + rename with the manifest renamed last, superseded
//! files are dropped only after that commit, and the in-memory
//! dirty/home bookkeeping is updated only on success — a crash at any
//! instant leaves the previous manifest pointing at intact files, a
//! failed save leaves the store retryable, and stray `.tmp` leftovers
//! are ignored and cleaned on the next load.
//!
//! Legacy single-file stores (the pre-manifest `cbench_tsdb.lp` format)
//! are still read transparently: [`Db::load`] on a file parses it whole
//! (compacted shards re-detected via the [`ROLLUP_TAG`] marker), leaves
//! the file untouched, and the first [`Db::save`] migrates the layout to
//! a manifest directory in place — the original file is parked as a
//! `.legacy.bak` sibling until the migration commits (and loads recover
//! from it if a crash strands a half-built directory). [`Db::export_lp`]
//! writes the legacy single-file format back out (stable dump order —
//! CI uses it to assert byte-identical reloads).

//! # Memory layout (the "raw speed, round 2" rewrite)
//!
//! Since the interned-columnar work a shard body is **not** a
//! `Vec<Point>`: it is a [`col::Columns`] structure-of-arrays (ts
//! column, interned tag-set id column, flat field plane) resolved
//! against the database's single [`col::Interner`]. Ingest parses
//! line protocol straight into interned columns
//! ([`Db::ingest_lines`]), saves/exports render columns straight back
//! to lp text through the byte-identical [`codec`] fast paths, and the
//! owned [`Point`] form is materialized lazily — once per shard, cached
//! until the shard is mutated — only where the public API hands out
//! `&Point`. The wire format, every error string, and the manifest
//! layout are unchanged: the lp codec is the compatibility boundary,
//! and the whole test envelope (round-trips, byte-identical
//! export/reload, replay equivalence) runs against it unchanged.

pub mod codec;
pub mod col;
pub mod lp;
pub mod query;

pub use col::{Columns, Interner, InternerStats};
pub use query::{Aggregate, GroupedSeries, Query, TAIL_SCAN_SLACK};

use crate::obs::metrics as om;
use crate::par;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Process-global monotone stamp for shard-body touch order (the LRU key
/// behind [`Db::evict_cold_bodies`]). Global rather than per-`Db` because
/// `Shard::points` takes `&self`; within one process a deterministic
/// access sequence still yields a deterministic eviction order.
static TOUCH: AtomicU64 = AtomicU64::new(1);

/// Default shard span: 4096 simulated seconds. Campaign trigger clocks
/// advance 1 s per pipeline, so a shard holds ~4096 pipeline triggers.
pub const DEFAULT_SHARD_SPAN_NS: i64 = 4096 * 1_000_000_000;

/// Marker tag carried by compaction rollup summaries (`rollup=mean`).
pub const ROLLUP_TAG: &str = "rollup";

/// Index file of the manifest persistence layout (see the module docs).
pub const MANIFEST_FILE: &str = "manifest.json";

/// On-disk manifest schema version.
const MANIFEST_VERSION: i64 = 1;

/// One data point.
#[derive(Debug, Clone, PartialEq)]
pub struct Point {
    pub measurement: String,
    pub tags: BTreeMap<String, String>,
    pub fields: BTreeMap<String, f64>,
    /// Nanoseconds since campaign epoch.
    pub ts: i64,
}

impl Point {
    pub fn new(measurement: &str, ts: i64) -> Point {
        Point {
            measurement: measurement.to_string(),
            tags: BTreeMap::new(),
            fields: BTreeMap::new(),
            ts,
        }
    }
    pub fn tag(mut self, k: &str, v: &str) -> Point {
        self.tags.insert(k.to_string(), v.to_string());
        self
    }
    pub fn field(mut self, k: &str, v: f64) -> Point {
        self.fields.insert(k.to_string(), v);
        self
    }

    /// Influx line protocol: `measurement,tag=v,... field=v,... ts`.
    /// Spaces/commas in tag values are escaped with `\`. Rendering goes
    /// through [`lp::escape_into`] and the [`codec`] float/int fast
    /// paths — byte-identical to the original `replace`+`format!`
    /// implementation, without its per-token allocations.
    pub fn to_line(&self) -> String {
        let mut line = String::with_capacity(64);
        lp::escape_into(&self.measurement, &mut line);
        for (k, v) in &self.tags {
            line.push(',');
            lp::escape_into(k, &mut line);
            line.push('=');
            lp::escape_into(v, &mut line);
        }
        line.push(' ');
        let mut first = true;
        for (k, v) in &self.fields {
            if !first {
                line.push(',');
            }
            first = false;
            lp::escape_into(k, &mut line);
            line.push('=');
            codec::fmt_f64(*v, &mut line);
        }
        line.push(' ');
        codec::fmt_i64(self.ts, &mut line);
        line
    }

    /// Parse one line-protocol line (the zero-copy parser in
    /// [`lp`] — slices borrowed from the input, allocations only on
    /// escaped tokens).
    pub fn parse_line(line: &str) -> Result<Point, String> {
        lp::parse_line(line)
    }
}

/// One time partition of a measurement: the points with
/// `ts ∈ [key·span, (key+1)·span)`, kept time-sorted. The min/max-ts
/// index and point count live in shard *metadata* (carried by the
/// manifest), so a shard loaded from a manifest directory answers every
/// index question without its body in memory — the points are parsed
/// lazily on first access ([`Shard::points`]).
/// `Sync` by construction — the lazy body is an [`OnceLock`] and the LRU
/// bookkeeping is atomics, so `&Shard` (and therefore `&Db`) can be
/// shared across the [`crate::par`] pool for parallel materialization
/// and range scans.
#[derive(Debug)]
pub struct Shard {
    /// Partition index: this shard covers `[key·span, (key+1)·span)`.
    key: i64,
    /// Raw points replaced by rollup summaries (see [`Db::compact`]).
    compacted: bool,
    /// Mutated since the last save into the bound manifest directory —
    /// the next [`Db::save`] must rewrite this shard's file.
    dirty: bool,
    /// Point count (authoritative; body may be unloaded).
    n: usize,
    /// Min/max-ts index (valid when `n > 0`).
    min_ts: i64,
    max_ts: i64,
    /// Backing file in the manifest layout; `None` for in-memory shards.
    file: Option<PathBuf>,
    /// Measurement name, shared with the database interner's pool —
    /// column materialization and rendering never re-allocate it.
    meas: Arc<str>,
    /// The owning database's interner (shards resolve their columns
    /// through it; clones share it, keeping symbols self-consistent).
    intern: Arc<Interner>,
    /// Lazily materialized columnar body. Pre-set for in-memory shards,
    /// parsed from `file` on first access for manifest-loaded ones.
    body: OnceLock<ShardBody>,
    /// Touch stamp of the last body access (LRU recency; see [`TOUCH`]).
    touch: AtomicU64,
    /// Body was evicted at least once — the next materialization counts
    /// as a re-materialization in the self-metrics.
    evicted: AtomicBool,
}

/// A materialized shard body: the columnar rows plus the lazily built
/// (and cached) owned-`Point` view the `&[Point]` APIs hand out. The
/// cache is kept coherent by point inserts and dropped by bulk merges.
#[derive(Debug, Clone)]
struct ShardBody {
    cols: Columns,
    rows: OnceLock<Vec<Point>>,
}

impl Clone for Shard {
    fn clone(&self) -> Shard {
        Shard {
            key: self.key,
            compacted: self.compacted,
            dirty: self.dirty,
            n: self.n,
            min_ts: self.min_ts,
            max_ts: self.max_ts,
            file: self.file.clone(),
            meas: self.meas.clone(),
            intern: self.intern.clone(),
            body: self.body.clone(),
            touch: AtomicU64::new(self.touch.load(Ordering::Relaxed)),
            evicted: AtomicBool::new(self.evicted.load(Ordering::Relaxed)),
        }
    }
}

impl Shard {
    /// A fresh, mutable, unbacked shard (the insert path). The row cache
    /// starts present (and empty): per-point inserts keep it coherent,
    /// so pure point-insert workloads never pay a materialization.
    fn in_memory(key: i64, meas: Arc<str>, intern: Arc<Interner>) -> Shard {
        let body = OnceLock::new();
        let rows = OnceLock::new();
        let _ = rows.set(Vec::new());
        let _ = body.set(ShardBody { cols: Columns::default(), rows });
        Shard {
            key,
            compacted: false,
            dirty: true,
            n: 0,
            min_ts: 0,
            max_ts: 0,
            file: None,
            meas,
            intern,
            body,
            touch: AtomicU64::new(TOUCH.fetch_add(1, Ordering::Relaxed)),
            evicted: AtomicBool::new(false),
        }
    }

    pub fn key(&self) -> i64 {
        self.key
    }
    pub fn len(&self) -> usize {
        self.n
    }
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
    /// Oldest timestamp in the shard (the min side of the index).
    pub fn min_ts(&self) -> Option<i64> {
        (self.n > 0).then_some(self.min_ts)
    }
    /// Newest timestamp in the shard (the max side of the index).
    pub fn max_ts(&self) -> Option<i64> {
        (self.n > 0).then_some(self.max_ts)
    }
    /// True once this shard holds rollup summaries instead of raw points
    /// (set by [`Db::compact`], recorded in the manifest, re-detected via
    /// [`ROLLUP_TAG`] on legacy single-file loads).
    pub fn is_compacted(&self) -> bool {
        self.compacted
    }
    /// True when this shard must be rewritten by the next [`Db::save`].
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }
    /// True once the body is materialized in memory. Manifest loads start
    /// with metadata only — queries that never reach into this shard
    /// never pay for parsing it.
    pub fn is_loaded(&self) -> bool {
        self.body.get().is_some()
    }
    /// Backing file name within the manifest directory, once bound.
    pub fn file_name(&self) -> Option<&str> {
        self.file
            .as_deref()
            .and_then(|p| p.file_name())
            .and_then(|n| n.to_str())
    }

    /// The shard body, materialized on first access. Panics if the
    /// backing file vanished or was modified behind the manifest — the
    /// manifest is authoritative for a bound store; rebuild via
    /// [`Db::export_lp`] + reload if a store was edited by hand.
    /// Thread-safe: concurrent callers race through [`OnceLock`] and
    /// exactly one materializes (losers' parses are dropped — the
    /// shard-load counters record attempts, which is what the cache
    /// metrics mean).
    pub fn points(&self) -> &[Point] {
        self.try_points().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible body materialization: like [`Shard::points`] but a
    /// missing, truncated or otherwise corrupt backing file surfaces as
    /// an `Err` naming the shard key and file path instead of a panic
    /// deep inside a query. `tsdb info` and [`Db::verify_bodies`] use
    /// this to flag unreadable shards without tearing the process down.
    /// The owned-`Point` view is built from the columns on first demand
    /// and cached until the shard is mutated.
    pub fn try_points(&self) -> Result<&[Point], String> {
        let body = self.try_body()?;
        Ok(body
            .rows
            .get_or_init(|| {
                om::add(om::Counter::ColMaterializations, 1);
                body.cols.to_points(&self.meas, &self.intern)
            })
            .as_slice())
    }

    /// Columnar body access, loading from the backing file on first
    /// touch (columns only — no `Point` materialization).
    fn try_body(&self) -> Result<&ShardBody, String> {
        if self.body.get().is_none() {
            let t = om::Timer::start();
            let path = self
                .file
                .as_deref()
                .expect("unloaded shard always has a backing file");
            let cols = read_shard_cols(path, self.key, self.n, &self.intern)?;
            om::add(om::Counter::ShardLoads, 1);
            om::add(om::Counter::ShardLoadPoints, cols.len() as u64);
            if self.evicted.load(Ordering::Relaxed) {
                om::add(om::Counter::ShardRemats, 1);
            }
            t.stop(om::TimedOp::ShardLoad);
            // a concurrent materializer may have won the race — its body
            // is identical (the file is the source of truth); ours drops
            let _ = self.body.set(ShardBody { cols, rows: OnceLock::new() });
        }
        self.touch.store(TOUCH.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
        Ok(self.body.get().expect("body just materialized"))
    }

    /// Validate that this shard's body is readable without retaining it:
    /// already-loaded (or unbacked) bodies are trivially fine; cold ones
    /// get their file read and parsed, and the parse result is dropped.
    pub fn check_body(&self) -> Result<(), String> {
        if self.body.get().is_some() {
            return Ok(());
        }
        let path = self
            .file
            .as_deref()
            .expect("unloaded shard always has a backing file");
        read_shard_cols(path, self.key, self.n, &self.intern).map(|_| ())
    }

    /// Mutable body access (materializes the columns first).
    fn body_mut(&mut self) -> &mut ShardBody {
        if let Err(e) = self.try_body() {
            panic!("{e}");
        }
        self.body.get_mut().expect("body just materialized")
    }

    /// Replace the body wholesale (compaction), refreshing the meta index
    /// and marking the shard for rewrite. The given points pre-fill the
    /// row cache — they are exactly what materializing the fresh columns
    /// would rebuild.
    fn set_points(&mut self, pts: Vec<Point>) {
        self.n = pts.len();
        self.min_ts = pts.first().map(|p| p.ts).unwrap_or(0);
        self.max_ts = pts.last().map(|p| p.ts).unwrap_or(0);
        let cols = Columns::from_points(&pts, &self.intern);
        let rows = OnceLock::new();
        let _ = rows.set(pts);
        let _ = self.body.take();
        let _ = self.body.set(ShardBody { cols, rows });
        self.dirty = true;
    }
}

/// Materialize the cold, file-backed bodies of a range-scan window in
/// parallel before the scan walks them serially — the walk (and so the
/// result order) is untouched; only the disk/parse latency overlaps.
/// `Shard: Sync` makes the shared `&Shard` access sound; `OnceLock`
/// arbitrates the (impossible here — shards are distinct) set race.
/// [`Db::points_iter`] deliberately does NOT prefetch: its cold-load
/// cost must stay flat in history depth (the PERSIST bench contract) —
/// full scans pay only for the shards actually reached.
fn prefetch_shards(shards: &[Shard]) {
    if par::threads() <= 1 || par::in_worker() {
        return;
    }
    let cold: Vec<&Shard> = shards
        .iter()
        .filter(|s| !s.is_loaded() && s.file.is_some())
        .collect();
    if cold.len() > 1 {
        par::map(cold, |s| {
            s.points();
        });
    }
}

/// The per-point insert body shared by [`Db::insert`] (serial) and
/// [`Db::insert_batch`] (parallel, one worker per shard): sorted insert,
/// meta-index refresh, dirty + compaction-reopen bookkeeping. Keeping it
/// a single function is what makes "batch == replayed serial inserts"
/// true by construction.
fn insert_point_into(s: &mut Shard, p: Point) {
    let timer = om::Timer::start();
    let ts = p.ts;
    if !p.tags.contains_key(ROLLUP_TAG) {
        s.compacted = false;
    }
    {
        let (tagset, syms, vals) = col::intern_point(&s.intern, &p);
        // a late insert into a cold shard materializes just that shard
        let body = s.body_mut();
        let c = &mut body.cols;
        let idx = if c.ts.last().map(|&l| l <= ts).unwrap_or(true) {
            c.len()
        } else {
            c.ts.partition_point(|&q| q <= ts)
        };
        c.insert_row(idx, ts, tagset, &syms, &vals);
        // a live row cache stays coherent instead of being tossed — the
        // campaign upload path interleaves inserts with detector reads,
        // and re-materializing the whole shard per insert would be a
        // step backwards from the old Vec<Point> body
        if let Some(rows) = body.rows.get_mut() {
            rows.insert(idx, p);
        }
    }
    s.n += 1;
    if s.n == 1 {
        s.min_ts = ts;
        s.max_ts = ts;
    } else {
        s.min_ts = s.min_ts.min(ts);
        s.max_ts = s.max_ts.max(ts);
    }
    s.dirty = true;
    om::add(om::Counter::InsertPoints, 1);
    timer.stop(om::TimedOp::Insert);
}

/// Merge one in-order columnar group into a shard — the batch-ingest
/// equivalent of replaying [`insert_point_into`] per row: identical
/// sorted-insert placement, meta-index refresh, dirty + compaction-
/// reopen bookkeeping. A time-sorted group landing at/after the shard's
/// max timestamp (the streaming-upload common case) appends wholesale.
fn merge_columns_into(s: &mut Shard, cols: &Columns, rollup_sym: Option<u32>) {
    if cols.is_empty() {
        return;
    }
    if s.compacted {
        // any raw (non-rollup) row reopens the shard for re-compaction
        let has_raw = match rollup_sym {
            None => true,
            Some(rsym) => {
                let view = s.intern.view();
                (0..cols.len())
                    .any(|i| !view.pairs(cols.tagset[i]).iter().any(|&(k, _)| k == rsym))
            }
        };
        if has_raw {
            s.compacted = false;
        }
    }
    let lo = cols.ts.iter().copied().min().expect("non-empty");
    let hi = cols.ts.iter().copied().max().expect("non-empty");
    {
        let body = s.body_mut();
        if cols.is_time_sorted() && body.cols.ts.last().map(|&l| l <= cols.ts[0]).unwrap_or(true) {
            body.cols.append_all(cols);
        } else {
            for i in 0..cols.len() {
                let ts = cols.ts[i];
                let (syms, vals) = cols.row_fields(i);
                let c = &mut body.cols;
                let idx = if c.ts.last().map(|&l| l <= ts).unwrap_or(true) {
                    c.len()
                } else {
                    c.ts.partition_point(|&q| q <= ts)
                };
                c.insert_row(idx, ts, cols.tagset[i], syms, vals);
            }
        }
        // bulk merges have no owned Points to mirror — drop the cache
        body.rows = OnceLock::new();
    }
    if s.n == 0 {
        s.min_ts = lo;
        s.max_ts = hi;
    } else {
        s.min_ts = s.min_ts.min(lo);
        s.max_ts = s.max_ts.max(hi);
    }
    s.n += cols.len();
    s.dirty = true;
}

/// Parse one shard file straight into columns, enforcing the manifest's
/// point count. Large bodies parse in chunks across the [`crate::par`]
/// pool (order-preserving appends), like the old `lp::parse_lines` path.
fn read_shard_cols(
    path: &Path,
    key: i64,
    expect: usize,
    intern: &Interner,
) -> Result<Columns, String> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        format!(
            "tsdb: cannot materialize shard key={key} from {}: {e} \
             (store directory modified behind the manifest?)",
            path.display()
        )
    })?;
    let corrupt = |e: String| format!("tsdb: corrupt shard key={key} at {}: {e}", path.display());
    let lines: Vec<&str> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .collect();
    let cols = if lines.len() < lp::PAR_MIN_LINES || par::threads() <= 1 || par::in_worker() {
        col::parse_lines_to_cols(&lines, intern).map_err(corrupt)?
    } else {
        let chunk = (lines.len() / (par::threads() * 4)).max(lp::PAR_MIN_LINES / 4);
        let slices: Vec<&[&str]> = lines.chunks(chunk).collect();
        let parts = par::try_map(slices, |c| col::parse_lines_to_cols(c, intern))
            .map_err(corrupt)?;
        let mut all = Columns::default();
        for p in &parts {
            all.append_all(p);
        }
        all
    };
    if cols.len() != expect {
        return Err(format!(
            "tsdb: shard key={key} at {} holds {} points but the manifest says {expect} — \
             the store was modified behind the manifest",
            path.display(),
            cols.len()
        ));
    }
    Ok(cols)
}

/// Outcome of one [`Db::compact`] pass.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CompactionReport {
    /// Shards inspected across all measurements.
    pub shards_seen: usize,
    /// Shards whose raw points were replaced by rollup summaries.
    pub shards_compacted: usize,
    /// Total points before / after the pass.
    pub points_before: usize,
    pub points_after: usize,
}

/// Outcome of one [`Db::save_report`]: how many shard files were
/// rewritten vs kept on disk untouched — the dirty-shard contract.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PersistReport {
    pub shards_written: usize,
    pub shards_kept: usize,
}

/// The storage engine: time-partitioned shards per measurement (see the
/// module docs for the layout, the compaction/retention model and the
/// manifest persistence contract).
#[derive(Debug)]
pub struct Db {
    measurements: BTreeMap<String, Vec<Shard>>,
    shard_span_ns: i64,
    /// Manifest directory this store is bound to (set by load/save).
    /// Saves onto the home rewrite only dirty shards; saving elsewhere
    /// copies everything and rebinds.
    home: Option<PathBuf>,
    /// Cap on concurrently materialized shard bodies (LRU eviction of
    /// clean, cold bodies; `None` = unbounded). See [`Db::set_body_cap`].
    body_cap: Option<usize>,
    /// Store-wide symbol table: every shard of this `Db` resolves its
    /// column symbols here. Shared (`Arc`) so shards stay independently
    /// materializable and parse workers can intern concurrently.
    intern: Arc<Interner>,
}

impl Default for Db {
    fn default() -> Db {
        Db::new()
    }
}

impl Db {
    pub fn new() -> Db {
        Db::with_shard_span(DEFAULT_SHARD_SPAN_NS)
    }

    /// Build a database with a custom shard span (ns per partition).
    /// The span is fixed for the database's lifetime — partition keys are
    /// derived from it.
    pub fn with_shard_span(span_ns: i64) -> Db {
        Db {
            measurements: BTreeMap::new(),
            shard_span_ns: span_ns.max(1),
            home: None,
            body_cap: None,
            intern: Arc::new(Interner::default()),
        }
    }

    pub fn shard_span(&self) -> i64 {
        self.shard_span_ns
    }

    /// Size of the store-wide symbol table (strings, tag sets, approx
    /// retained bytes) — surfaced by `bench_regress`'s MEMORY_JSON.
    pub fn interner_stats(&self) -> InternerStats {
        self.intern.stats()
    }

    /// The shard list of `measurement`, in partition (= time) order.
    pub fn shards(&self, measurement: &str) -> &[Shard] {
        self.measurements
            .get(measurement)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Insert one point into its time partition (keeps the shard sorted).
    /// Streaming uploads arrive in near trigger-time order, so the common
    /// case is an append to the newest shard. A raw point landing in an
    /// already-compacted shard (late import into rolled-up history)
    /// reopens that shard for the next [`Db::compact`] pass, which merges
    /// raw points and existing rollups weight-correctly.
    pub fn insert(&mut self, p: Point) {
        let key = p.ts.div_euclid(self.shard_span_ns);
        let meas = self.intern.intern_arc(&p.measurement);
        let intern = self.intern.clone();
        let shards = self.measurements.entry(p.measurement.clone()).or_default();
        let si = match shards.binary_search_by(|s| s.key.cmp(&key)) {
            Ok(i) => i,
            Err(i) => {
                shards.insert(i, Shard::in_memory(key, meas, intern));
                i
            }
        };
        insert_point_into(&mut shards[si], p);
        if self.body_cap.is_some() {
            self.maybe_evict();
        }
    }

    /// Insert a whole batch of points. The final store is byte-identical
    /// to inserting every point in order with [`Db::insert`]: points are
    /// grouped by destination shard *preserving input order within each
    /// group*, and the per-shard insert replays exactly the serial body.
    /// Large batches fan the disjoint per-shard work across the
    /// [`crate::par`] pool. The grouped path is taken by batch size alone
    /// (with one worker it runs inline) so the store — including LRU
    /// eviction timing — never depends on the thread count.
    pub fn insert_batch(&mut self, pts: Vec<Point>) {
        const PAR_MIN_BATCH: usize = 256;
        if pts.len() < PAR_MIN_BATCH {
            for p in pts {
                self.insert(p);
            }
            return;
        }
        // group by (measurement, shard key); BTreeMap iteration gives a
        // deterministic job order, Vec pushes keep input order per group
        let mut groups: BTreeMap<(String, i64), Vec<Point>> = BTreeMap::new();
        for p in pts {
            let key = p.ts.div_euclid(self.shard_span_ns);
            groups.entry((p.measurement.clone(), key)).or_default().push(p);
        }
        // pass A (serial): create every missing destination shard
        for (m, key) in groups.keys() {
            let meas = self.intern.intern_arc(m);
            let intern = self.intern.clone();
            let shards = self.measurements.entry(m.clone()).or_default();
            if let Err(i) = shards.binary_search_by(|s| s.key.cmp(key)) {
                shards.insert(i, Shard::in_memory(*key, meas, intern));
            }
        }
        // pass B: one job per target shard — each worker gets exclusive
        // `&mut` access to its shard, so the fan-out is data-race-free by
        // construction (no locks on the insert path)
        let mut jobs: Vec<(&mut Shard, Vec<Point>)> = Vec::new();
        for (m, shards) in self.measurements.iter_mut() {
            for s in shards.iter_mut() {
                if let Some(pts) = groups.remove(&(m.clone(), s.key)) {
                    jobs.push((s, pts));
                }
            }
        }
        par::map(jobs, |(s, pts)| {
            for p in pts {
                insert_point_into(s, p);
            }
        });
        // LRU once per batch, not per point
        if self.body_cap.is_some() {
            self.maybe_evict();
        }
    }

    /// Ingest a batch of line-protocol text (the pipeline's upload step).
    /// Lines parse straight into interned columns ([`col::parse_chunk`] —
    /// parallel for large batches) and merge into their destination
    /// shards columnar, without ever materializing an owned [`Point`].
    /// Atomic: a malformed line fails the whole batch and nothing is
    /// ingested (symbols interned before the error are harmless — they
    /// change no stored rows). The resulting store is byte-identical to
    /// parsing every line into a `Point` and replaying [`Db::insert`] in
    /// input order, for any thread count. The `LpParse` timer covers the
    /// parse, one batch-wide `Insert` timer covers the merge.
    pub fn ingest_lines(&mut self, text: &str) -> Result<usize, String> {
        self.ingest_cols(text).map(|(n, _)| n)
    }

    /// [`Db::ingest_lines`] plus the distinct `(measurement, scope-tag
    /// value)` combinations the batch touched, resolved to owned strings
    /// in sorted order — what a scoped post-ingest detection pass needs,
    /// computed from the interned tag sets instead of a second walk over
    /// owned `Point`s.
    pub fn ingest_lines_scoped(
        &mut self,
        text: &str,
        scope_tag: &str,
    ) -> Result<(usize, BTreeSet<(String, Option<String>)>), String> {
        let (n, seen) = self.ingest_cols(text)?;
        // resolve before taking the view: interning under a held view
        // would deadlock (read -> write upgrade)
        let tag_sym = self.intern.lookup(scope_tag);
        let view = self.intern.view();
        let mut scopes = BTreeSet::new();
        for (msym, tagset) in seen {
            let repo = tag_sym.and_then(|t| {
                view.pairs(tagset)
                    .iter()
                    .find(|&&(k, _)| k == t)
                    .map(|&(_, v)| view.string(v).to_string())
            });
            scopes.insert((view.string(msym).to_string(), repo));
        }
        Ok((n, scopes))
    }

    /// Shared columnar-ingest body: parse (serial or chunked across the
    /// [`crate::par`] pool), re-key the chunk groups by measurement
    /// *string* (symbol ids are assigned in parse order and therefore
    /// nondeterministic across runs/thread counts — shard creation order
    /// must not depend on them), create missing shards serially, then fan
    /// disjoint per-shard merges across the pool.
    fn ingest_cols(&mut self, text: &str) -> Result<(usize, Vec<(u32, u32)>), String> {
        let timer = om::Timer::start();
        let lines: Vec<&str> = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .collect();
        let span = self.shard_span_ns;
        let it = &self.intern;
        let chunks: Vec<col::Chunk> =
            if lines.len() < lp::PAR_MIN_LINES || par::threads() <= 1 || par::in_worker() {
                vec![col::parse_chunk(&lines, it, span)?]
            } else {
                let chunk = (lines.len() / (par::threads() * 4)).max(lp::PAR_MIN_LINES / 4);
                let slices: Vec<&[&str]> = lines.chunks(chunk).collect();
                par::try_map(slices, |c| col::parse_chunk(c, it, span))?
            };
        let n = lines.len();
        om::add(om::Counter::LpLines, n as u64);
        timer.stop(om::TimedOp::LpParse);

        let mut merged: BTreeMap<(Arc<str>, i64), Vec<Columns>> = BTreeMap::new();
        let mut seen: BTreeSet<(u32, u32)> = BTreeSet::new();
        for ch in chunks {
            for ((msym, key), cols) in ch.groups {
                merged.entry((self.intern.get(msym), key)).or_default().push(cols);
            }
            seen.extend(ch.seen);
        }

        let timer = om::Timer::start();
        // pass A (serial): create every missing destination shard, in
        // (measurement, key) order — the same creation/touch order the
        // old per-point insert replay produced
        for (m, key) in merged.keys() {
            let meas = self.intern.intern_arc(m);
            let intern = self.intern.clone();
            let shards = self.measurements.entry(m.to_string()).or_default();
            if let Err(i) = shards.binary_search_by(|s| s.key.cmp(key)) {
                shards.insert(i, Shard::in_memory(*key, meas, intern));
            }
        }
        let rollup_sym = self.intern.lookup(ROLLUP_TAG);
        // pass B: one job per target shard — disjoint `&mut` access, so
        // the fan-out is data-race-free by construction
        let mut jobs: Vec<(&mut Shard, Vec<Columns>)> = Vec::new();
        for shards in self.measurements.values_mut() {
            for s in shards.iter_mut() {
                if let Some(groups) = merged.remove(&(s.meas.clone(), s.key)) {
                    jobs.push((s, groups));
                }
            }
        }
        par::map(jobs, |(s, groups)| {
            for cols in &groups {
                merge_columns_into(s, cols, rollup_sym);
            }
        });
        om::add(om::Counter::InsertPoints, n as u64);
        timer.stop(om::TimedOp::Insert);
        if self.body_cap.is_some() {
            self.maybe_evict();
        }
        Ok((n, seen.into_iter().collect()))
    }

    /// Cap the number of concurrently materialized shard bodies. The
    /// mutating entry points ([`Db::insert`], and everything built on it)
    /// enforce the cap by evicting clean, cold, file-backed bodies in LRU
    /// order; dirty or unbacked bodies are never evicted (they cannot be
    /// reloaded), so the cap is best-effort while many shards are mutated
    /// between saves. `None` (the default) disables eviction.
    pub fn set_body_cap(&mut self, cap: Option<usize>) {
        self.body_cap = cap;
        self.maybe_evict();
    }

    pub fn body_cap(&self) -> Option<usize> {
        self.body_cap
    }

    /// Number of shard bodies currently materialized in memory.
    pub fn loaded_bodies(&self) -> usize {
        self.measurements
            .values()
            .flatten()
            .filter(|s| s.is_loaded())
            .count()
    }

    /// Evict clean, cold shard bodies — least recently touched first —
    /// until at most `keep` bodies remain materialized. Only clean,
    /// file-backed bodies are candidates; the freed body reloads lazily
    /// (and byte-identically — the file is the body's source of truth)
    /// on its next touch. Returns the eviction count; each eviction bumps
    /// the `shard_evictions` self-metric and the eventual reload counts
    /// as a `shard_remats`.
    pub fn evict_cold_bodies(&mut self, keep: usize) -> usize {
        let loaded = self.loaded_bodies();
        if loaded <= keep {
            return 0;
        }
        // (touch stamp, measurement, shard index) of every candidate
        let mut cands: Vec<(u64, String, usize)> = Vec::new();
        for (m, shards) in &self.measurements {
            for (i, s) in shards.iter().enumerate() {
                if s.is_loaded() && !s.dirty && s.file.is_some() {
                    cands.push((s.touch.load(Ordering::Relaxed), m.clone(), i));
                }
            }
        }
        cands.sort();
        let mut over = loaded - keep;
        let mut evicted = 0;
        for (_, m, i) in cands {
            if over == 0 {
                break;
            }
            let s = &mut self.measurements.get_mut(&m).expect("candidate exists")[i];
            let _ = s.body.take();
            s.evicted.store(true, Ordering::Relaxed);
            om::add(om::Counter::ShardEvictions, 1);
            evicted += 1;
            over -= 1;
        }
        evicted
    }

    fn maybe_evict(&mut self) {
        if let Some(cap) = self.body_cap {
            if self.loaded_bodies() > cap {
                self.evict_cold_bodies(cap);
            }
        }
    }

    pub fn measurements(&self) -> impl Iterator<Item = &String> {
        self.measurements.keys()
    }

    /// Shards mutated since the last save into the bound manifest
    /// directory — the count the next [`Db::save`] would rewrite. Zero
    /// right after a save: the serve-smoke "clean shutdown" assertion.
    pub fn dirty_shards(&self) -> usize {
        self.measurements
            .values()
            .flatten()
            .filter(|s| s.is_dirty())
            .count()
    }

    /// Validate every cold shard body without retaining any of them
    /// ([`Shard::check_body`]): returns one `(measurement, shard key,
    /// file name, error)` tuple per unreadable body. A valid manifest
    /// over truncated/corrupt shard files is detected *here*, at
    /// materialization-check time, instead of deep inside the first
    /// query that happens to touch the bad shard — `tsdb info` calls
    /// this to flag broken stores.
    pub fn verify_bodies(&self) -> Vec<(String, i64, String, String)> {
        let mut bad = Vec::new();
        for (m, shards) in &self.measurements {
            for s in shards {
                if let Err(e) = s.check_body() {
                    bad.push((
                        m.clone(),
                        s.key(),
                        s.file_name().unwrap_or("<unbound>").to_string(),
                        e,
                    ));
                }
            }
        }
        bad
    }

    pub fn len(&self) -> usize {
        self.measurements
            .values()
            .map(|shards| shards.iter().map(|s| s.n).sum::<usize>())
            .sum()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of points of one measurement (across all its shards) —
    /// answered from shard metadata, no bodies are materialized.
    pub fn n_points(&self, measurement: &str) -> usize {
        self.shards(measurement).iter().map(|s| s.n).sum()
    }

    /// Newest timestamp across every measurement, from shard metadata
    /// (no bodies are materialized) — the "now" for trigger clocks and
    /// the compaction watermark.
    pub fn newest_ts(&self) -> Option<i64> {
        self.measurements
            .values()
            .filter_map(|shards| shards.last().and_then(|s| s.max_ts()))
            .max()
    }

    /// All points of `measurement` in time order, streamed shard by shard
    /// — shard bodies materialize as the walk reaches them. Double-ended:
    /// `.rev()` walks newest-first without touching (or parsing) old
    /// shards until reached (the bound scans behind `tail(n)` rely on it).
    pub fn points_iter<'a>(
        &'a self,
        measurement: &str,
    ) -> impl DoubleEndedIterator<Item = &'a Point> + 'a {
        self.shards(measurement).iter().flat_map(|s| s.points().iter())
    }

    /// The newest point of `measurement` (last point of the last shard —
    /// materializes that shard).
    pub fn last_point(&self, measurement: &str) -> Option<&Point> {
        self.shards(measurement).last().and_then(|s| s.points().last())
    }

    /// Points of `measurement` within the inclusive `[t_min, t_max]`
    /// window. The shard list is binary-searched by its min/max-ts index
    /// for the overlapping run, and only the two edge shards are clamped
    /// by an inner binary search — shards outside the window are never
    /// touched, O(log shards + log shard_size + hits).
    pub fn points_in_range<'a>(
        &'a self,
        measurement: &str,
        t_min: Option<i64>,
        t_max: Option<i64>,
    ) -> impl Iterator<Item = &'a Point> + 'a {
        let shards = self.shards(measurement);
        let lo = t_min
            .map(|t0| shards.partition_point(|s| s.max_ts().map(|m| m < t0).unwrap_or(true)))
            .unwrap_or(0);
        let hi = t_max
            .map(|t1| shards.partition_point(|s| s.min_ts().map(|m| m <= t1).unwrap_or(false)))
            .unwrap_or(shards.len());
        prefetch_shards(&shards[lo..hi.max(lo)]);
        shards[lo..hi.max(lo)].iter().flat_map(move |s| {
            let pts = s.points();
            let a = t_min.map(|t| pts.partition_point(|p| p.ts < t)).unwrap_or(0);
            let b = t_max
                .map(|t| pts.partition_point(|p| p.ts <= t))
                .unwrap_or(pts.len());
            pts[a..b.max(a)].iter()
        })
    }

    /// Timestamp at which the trailing `n` *distinct* timestamps of
    /// `measurement` begin — the pushdown bound behind [`Query::tail`].
    /// CB uploads one point per live series per pipeline trigger, so the
    /// walk from the end touches O(n × series) points — and, shard-wise,
    /// only the newest shard(s) — regardless of how many years of history
    /// sit in front. Returns `None` for an empty measurement or `n == 0`;
    /// with fewer than `n` distinct timestamps it returns the earliest one.
    pub fn tail_start_ts(&self, measurement: &str, n: usize) -> Option<i64> {
        if n == 0 {
            return None;
        }
        let mut distinct = 0usize;
        let mut last: Option<i64> = None;
        for p in self.points_iter(measurement).rev() {
            if last != Some(p.ts) {
                distinct += 1;
                last = Some(p.ts);
                if distinct == n {
                    return last;
                }
            }
        }
        last
    }

    /// All distinct values of `tag` within a measurement — powers the
    /// dashboard template-variable dropdowns (the "collision Setup menu").
    pub fn tag_values(&self, measurement: &str, tag: &str) -> Vec<String> {
        let mut vals: Vec<String> = self
            .points_iter(measurement)
            .filter_map(|p| p.tags.get(tag).cloned())
            .collect();
        vals.sort();
        vals.dedup();
        vals
    }

    /// Retention pass: replace the raw points of every shard entirely
    /// older than `newest_ts − retain_raw_ns` with per-series rollup
    /// summaries (per-field mean over the shard, raw count in `rollup_n`,
    /// `rollup=mean` marker tag, timestamp = the series' last in-shard
    /// point). Shards overlapping the retained window are untouched, so
    /// queries over the raw range are unchanged. Idempotent: already
    /// compacted shards — including ones reloaded from a saved file,
    /// recognized by the marker tag — are skipped, and a shard that mixes
    /// existing rollups with late-arriving raw points (see [`Db::insert`])
    /// is merged **weight-correctly**: a rollup contributes its stored
    /// per-field means at weight `rollup_n`, so re-compaction never
    /// degrades means into means-of-means or resets raw counts.
    pub fn compact(&mut self, retain_raw_ns: i64) -> CompactionReport {
        let mut rep = CompactionReport {
            points_before: self.len(),
            ..CompactionReport::default()
        };
        let Some(newest) = self.newest_ts() else {
            return rep;
        };
        let watermark = newest.saturating_sub(retain_raw_ns.max(0));
        for shards in self.measurements.values_mut() {
            for s in shards.iter_mut() {
                rep.shards_seen += 1;
                // the compacted flag and the min/max-ts index live in
                // shard metadata — shards that are already rolled up or
                // inside the retained raw window are skipped without
                // materializing their bodies
                if s.compacted || s.n == 0 {
                    continue;
                }
                if s.max_ts >= watermark {
                    continue; // overlaps the retained raw window
                }
                if s.points().iter().all(|p| p.tags.contains_key(ROLLUP_TAG)) {
                    s.compacted = true; // pre-compacted legacy-file shard
                    continue;
                }
                // one rollup per series — keyed by the tags WITHOUT the
                // rollup marker, so late raw points merge into the series'
                // existing rollup. Accumulator: (last ts, per-field
                // (weighted sum, weight), total weight); a raw point
                // weighs 1, a rollup weighs its stored `rollup_n`.
                type Acc = (i64, BTreeMap<String, (f64, f64)>, f64);
                let mut groups: BTreeMap<BTreeMap<String, String>, Acc> = BTreeMap::new();
                for p in s.points() {
                    let is_rollup = p.tags.contains_key(ROLLUP_TAG);
                    let w = if is_rollup {
                        p.fields.get("rollup_n").copied().unwrap_or(1.0).max(1.0)
                    } else {
                        1.0
                    };
                    let mut key = p.tags.clone();
                    key.remove(ROLLUP_TAG);
                    let e = groups
                        .entry(key)
                        .or_insert_with(|| (p.ts, BTreeMap::new(), 0.0));
                    e.0 = e.0.max(p.ts);
                    e.2 += w;
                    for (k, v) in &p.fields {
                        if is_rollup && k == "rollup_n" {
                            continue; // the count is the weight, not a metric
                        }
                        let f = e.1.entry(k.clone()).or_insert((0.0, 0.0));
                        f.0 += v * w;
                        f.1 += w;
                    }
                }
                let measurement = s.points()[0].measurement.clone();
                let mut summaries: Vec<Point> = groups
                    .into_iter()
                    .map(|(mut tags, (ts, fields, n))| {
                        tags.insert(ROLLUP_TAG.to_string(), "mean".to_string());
                        let mut fmap: BTreeMap<String, f64> = fields
                            .into_iter()
                            .map(|(k, (sum, weight))| (k, sum / weight))
                            .collect();
                        fmap.insert("rollup_n".to_string(), n);
                        Point { measurement: measurement.clone(), tags, fields: fmap, ts }
                    })
                    .collect();
                // deterministic order: time-sorted, BTreeMap tie order
                summaries.sort_by_key(|p| p.ts);
                s.set_points(summaries);
                s.compacted = true;
                rep.shards_compacted += 1;
            }
        }
        rep.points_after = self.len();
        rep
    }

    /// Persist as a manifest directory (see the module docs): one
    /// line-protocol file per shard plus `manifest.json`. Saving onto the
    /// directory the store was loaded from rewrites only dirty shards; a
    /// legacy single-file store at `path` is replaced by the directory
    /// layout on this first save (the old file is kept as a
    /// `.legacy.bak` sibling until the migration committed).
    ///
    /// The save is **crash-atomic around the manifest rename**: rewritten
    /// shards go to *fresh* file names (never over a file the current
    /// manifest references), the manifest is renamed into place last, and
    /// only then are the superseded files removed and the in-memory
    /// dirty/file bookkeeping updated — a crash at any earlier instant
    /// leaves the previous manifest pointing at intact files, and a
    /// failed save leaves this store's state unchanged so a retry
    /// rewrites everything it must.
    pub fn save(&mut self, path: &Path) -> std::io::Result<()> {
        self.save_report(path).map(|_| ())
    }

    /// [`Db::save`] returning the written/kept shard split.
    pub fn save_report(&mut self, path: &Path) -> std::io::Result<PersistReport> {
        let timer = om::Timer::start();
        // legacy single-file store: move it aside (atomic rename) instead
        // of deleting it — the history's only on-disk copy must survive
        // until the manifest layout has fully committed. The `.bak` is
        // removed after the manifest rename; `Db::load` knows to fall
        // back to it if a crash strands a half-built directory.
        if path.is_file() {
            std::fs::rename(path, &legacy_bak_path(path))?;
        }
        std::fs::create_dir_all(path)?;
        let bound = self.home.as_deref() == Some(path);

        // --- plan phase (no mutation, no I/O): decide which shards keep
        // their file and which get a FRESH name. On a bound store every
        // live file name is reserved, so a rewrite can never land on a
        // file the committed manifest still references.
        let mut used: BTreeSet<String> = BTreeSet::new();
        if bound {
            for shards in self.measurements.values() {
                for s in shards {
                    if let Some(n) = s.file_name() {
                        used.insert(n.to_string());
                    }
                }
            }
        }
        let mut rep = PersistReport::default();
        // (measurement, shard key) -> manifest file name
        let mut names: BTreeMap<(String, i64), String> = BTreeMap::new();
        // shards that need their file written: (measurement, key, name)
        let mut writes: Vec<(String, i64, String)> = Vec::new();
        for (m, shards) in &self.measurements {
            for s in shards {
                if bound && !s.dirty && s.file_name().is_some() {
                    names.insert((m.clone(), s.key), s.file_name().unwrap().to_string());
                    rep.shards_kept += 1;
                    continue;
                }
                let name = match s.file_name() {
                    Some(n) if !used.contains(n) => n.to_string(),
                    _ => alloc_shard_name(m, s.key, &used),
                };
                used.insert(name.clone());
                names.insert((m.clone(), s.key), name.clone());
                writes.push((m.clone(), s.key, name));
                rep.shards_written += 1;
            }
        }

        // --- write phase: shard files via .tmp + rename, manifest last.
        // Nothing in-memory has been touched yet — an Err return leaves
        // the store exactly as it was (still dirty, still bound to the
        // old home), so a retried save rewrites everything it must.
        // Per-shard writes are independent (distinct files, each .tmp +
        // rename atomic on its own) and fan out across the par pool; the
        // manifest write below stays the single serial commit point, so
        // a crash mid-fan-out still leaves the old manifest authoritative.
        {
            let jobs: Vec<(PathBuf, &Shard)> = writes
                .iter()
                .map(|(m, key, name)| {
                    let shards = &self.measurements[m];
                    let i = shards
                        .binary_search_by(|s| s.key.cmp(key))
                        .expect("planned shard exists");
                    (path.join(name), &shards[i])
                })
                .collect();
            par::try_map(jobs, |(p, s)| write_shard_file(&p, s))?;
        }
        let tmp = path.join(format!("{MANIFEST_FILE}.tmp"));
        std::fs::write(&tmp, self.manifest_json(&names).to_string_pretty())?;
        std::fs::rename(&tmp, path.join(MANIFEST_FILE))?;

        // --- commit phase: the manifest is on disk; now update the
        // in-memory bookkeeping and drop superseded files.
        for (m, key, name) in writes {
            let shards = self.measurements.get_mut(&m).expect("exists");
            let i = shards
                .binary_search_by(|s| s.key.cmp(&key))
                .expect("exists");
            shards[i].file = Some(path.join(&name));
            shards[i].dirty = false;
        }
        let referenced: BTreeSet<&str> = names.values().map(|s| s.as_str()).collect();
        if let Ok(rd) = std::fs::read_dir(path) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().to_string();
                let stray_tmp = name.ends_with(".tmp");
                // inside a bound store the manifest is authoritative:
                // files it no longer references (superseded rewrites,
                // orphans) are dropped. An unbound target directory may
                // hold foreign files — those are left alone.
                let orphan = bound && name.ends_with(".lp") && !referenced.contains(name.as_str());
                if stray_tmp || orphan {
                    std::fs::remove_file(e.path()).ok();
                }
            }
        }
        // the manifest committed: any parked legacy original — from this
        // save's migration or a crashed earlier one — is superseded
        std::fs::remove_file(legacy_bak_path(path)).ok();
        self.home = Some(path.to_path_buf());
        om::add(om::Counter::SaveShardsWritten, rep.shards_written as u64);
        timer.stop(om::TimedOp::Save);
        Ok(rep)
    }

    fn manifest_json(&self, names: &BTreeMap<(String, i64), String>) -> Json {
        let mut meas = Json::obj();
        for (m, shards) in &self.measurements {
            let arr: Vec<Json> = shards
                .iter()
                .map(|s| {
                    let file = names
                        .get(&(m.clone(), s.key))
                        .map(|n| n.as_str())
                        .expect("every shard was planned a file name");
                    Json::obj()
                        .set("key", s.key)
                        .set("file", file)
                        .set("points", s.n)
                        // timestamps as strings: i64 round-trips exactly,
                        // beyond f64's 2^53 integer range
                        .set("min_ts", s.min_ts.to_string())
                        .set("max_ts", s.max_ts.to_string())
                        .set("compacted", s.compacted)
                })
                .collect();
            meas = meas.set(m, Json::Arr(arr));
        }
        Json::obj()
            .set("version", MANIFEST_VERSION)
            .set("shard_span_ns", self.shard_span_ns.to_string())
            .set("points", self.len())
            .set("measurements", meas)
    }

    /// Load a store: a manifest directory loads its index eagerly and
    /// shard bodies lazily; a legacy single-file store is parsed whole
    /// (and migrates to the manifest layout on the first save). A
    /// directory without a manifest is an error — unless a `.legacy.bak`
    /// sibling exists (a migration crashed mid-way), in which case the
    /// preserved legacy file is loaded instead.
    pub fn load(path: &Path) -> std::io::Result<Db> {
        Db::load_impl(path, None)
    }

    /// Load with a custom shard span (`cbench tsdb --shard-span`). A
    /// manifest store whose recorded span differs is **re-partitioned**,
    /// which materializes every shard — re-sharding is a full-copy
    /// operation by nature; matching spans stay lazy.
    pub fn load_with_shard_span(path: &Path, span_ns: i64) -> std::io::Result<Db> {
        Db::load_impl(path, Some(span_ns))
    }

    fn load_impl(path: &Path, span_ns: Option<i64>) -> std::io::Result<Db> {
        if path.join(MANIFEST_FILE).is_file() {
            let db = Db::load_manifest_dir(path)?;
            return Ok(match span_ns {
                Some(span) if db.shard_span_ns != span.max(1) => db.reshard(span),
                _ => db,
            });
        }
        let legacy_span = span_ns.unwrap_or(DEFAULT_SHARD_SPAN_NS);
        if path.is_dir() {
            // a crash between the legacy-file rename-aside and the
            // manifest commit leaves a half-built directory plus the
            // preserved original — recover from the original
            let bak = legacy_bak_path(path);
            if bak.is_file() {
                return Db::load_legacy_file(&bak, legacy_span);
            }
            return Err(invalid_data(format!(
                "{} is a directory without a {MANIFEST_FILE}",
                path.display()
            )));
        }
        Db::load_legacy_file(path, legacy_span)
    }

    fn load_legacy_file(path: &Path, span_ns: i64) -> std::io::Result<Db> {
        let text = std::fs::read_to_string(path)?;
        let mut db = Db::with_shard_span(span_ns);
        db.ingest_lines(&text)
            .map_err(|e| invalid_data(e))?;
        // compaction state survives the legacy format via the marker tag
        // (probed on the tag-set ids — no Point materialization; lookup,
        // not intern, so a rollup-free store leaves the symbol unmade)
        if let Some(rsym) = db.intern.lookup(ROLLUP_TAG) {
            let intern = db.intern.clone();
            for shards in db.measurements.values_mut() {
                for s in shards.iter_mut() {
                    if s.n == 0 {
                        continue;
                    }
                    let all_rollup = {
                        let body = s.try_body().map_err(invalid_data)?;
                        let view = intern.view();
                        (0..body.cols.len()).all(|i| {
                            view.pairs(body.cols.tagset[i]).iter().any(|&(k, _)| k == rsym)
                        })
                    };
                    if all_rollup {
                        s.compacted = true;
                    }
                }
            }
        }
        // home stays None: the first save migrates to the manifest layout
        Ok(db)
    }

    fn load_manifest_dir(dir: &Path) -> std::io::Result<Db> {
        let text = std::fs::read_to_string(dir.join(MANIFEST_FILE))?;
        let j = Json::parse(&text).map_err(|e| invalid_data(format!("bad manifest: {e}")))?;
        let version = j.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0) as i64;
        if version != MANIFEST_VERSION {
            return Err(invalid_data(format!(
                "unsupported tsdb manifest version {version} (this build reads {MANIFEST_VERSION})"
            )));
        }
        let span = j
            .get("shard_span_ns")
            .and_then(|v| v.as_str())
            .and_then(|s| s.parse::<i64>().ok())
            .ok_or_else(|| invalid_data("manifest missing shard_span_ns"))?;
        let mut db = Db::with_shard_span(span);
        if let Some(meas) = j.get("measurements").and_then(|v| v.as_obj()) {
            for (m, arr) in meas {
                let mut shards: Vec<Shard> = Vec::new();
                for e in arr.as_arr().unwrap_or(&[]) {
                    let key = e
                        .get("key")
                        .and_then(|v| v.as_f64())
                        .ok_or_else(|| invalid_data("manifest shard missing key"))?
                        as i64;
                    let file = e
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| invalid_data("manifest shard missing file"))?;
                    let n = e.get("points").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
                    let min_ts = manifest_ts(e, "min_ts")?;
                    let max_ts = manifest_ts(e, "max_ts")?;
                    let compacted = e.get("compacted").and_then(|v| v.as_bool()).unwrap_or(false);
                    let path = dir.join(file);
                    if !path.is_file() {
                        return Err(invalid_data(format!(
                            "manifest references missing shard file {file}"
                        )));
                    }
                    shards.push(Shard {
                        key,
                        compacted,
                        dirty: false,
                        n,
                        min_ts,
                        max_ts,
                        file: Some(path),
                        meas: db.intern.intern_arc(m),
                        intern: db.intern.clone(),
                        body: OnceLock::new(),
                        touch: AtomicU64::new(0),
                        evicted: AtomicBool::new(false),
                    });
                }
                shards.sort_by_key(|s| s.key);
                db.measurements.insert(m.clone(), shards);
            }
        }
        // a crash between the shard and manifest renames can strand .tmp
        // siblings; nothing references them — clean them up
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.flatten() {
                if e.file_name().to_string_lossy().ends_with(".tmp") {
                    std::fs::remove_file(e.path()).ok();
                }
            }
        }
        db.home = Some(dir.to_path_buf());
        Ok(db)
    }

    /// Re-partition into a fresh store with a different span. The result
    /// is unbound (`home` cleared): its first save is a full rewrite.
    fn reshard(self, span_ns: i64) -> Db {
        let mut out = Db::with_shard_span(span_ns);
        for shards in self.measurements.values() {
            for s in shards {
                for p in s.points() {
                    out.insert(p.clone());
                }
            }
        }
        out
    }

    /// Write the whole store as one legacy line-protocol file — the
    /// pre-manifest format, measurements in name order, shards in time
    /// order. The inverse of the legacy auto-migration, and the stable
    /// dump CI diffs to assert byte-identical reloads.
    pub fn export_lp(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        let mut line = String::with_capacity(128);
        for shards in self.measurements.values() {
            for s in shards {
                // materialize the body before taking the view (loading
                // interns; rendering only resolves)
                let body = s.try_body().map_err(invalid_data)?;
                let view = self.intern.view();
                for i in 0..body.cols.len() {
                    line.clear();
                    body.cols.render_row(i, &s.meas, &view, &mut line);
                    line.push('\n');
                    f.write_all(line.as_bytes())?;
                }
            }
        }
        Ok(())
    }
}

fn invalid_data(msg: impl Into<String>) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.into())
}

/// Sibling path a legacy single-file store is parked at while its
/// first manifest save commits (`cbench_tsdb.lp` →
/// `cbench_tsdb.lp.legacy.bak`).
fn legacy_bak_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".legacy.bak");
    PathBuf::from(os)
}

fn manifest_ts(e: &Json, key: &str) -> std::io::Result<i64> {
    e.get(key)
        .and_then(|v| v.as_str())
        .and_then(|s| s.parse::<i64>().ok())
        .ok_or_else(|| invalid_data(format!("manifest shard missing {key}")))
}

/// Shard file names are manifest-internal: derived from the measurement
/// for readability, uniqued within the directory, and resolved only
/// through the manifest on load.
fn alloc_shard_name(measurement: &str, key: i64, used: &BTreeSet<String>) -> String {
    let sanitized: String = measurement
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.') { c } else { '_' })
        .collect();
    let base = if sanitized.is_empty() {
        format!("m-k{key}")
    } else {
        format!("{sanitized}-k{key}")
    };
    let cand = format!("{base}.lp");
    if !used.contains(&cand) {
        return cand;
    }
    let mut i = 2usize;
    loop {
        let cand = format!("{base}-{i}.lp");
        if !used.contains(&cand) {
            return cand;
        }
        i += 1;
    }
}

/// Atomic shard write: `.tmp` sibling + rename. Rows render straight
/// from the columnar body ([`Columns::render_row`] — byte-identical to
/// `Point::to_line`) through one reused line buffer; no `Point` is ever
/// materialized on the save path.
fn write_shard_file(path: &Path, s: &Shard) -> std::io::Result<()> {
    let body = s.try_body().map_err(invalid_data)?;
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    let tmp = PathBuf::from(os);
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        // the body is materialized above; rendering only resolves
        // symbols, so holding the view across the write is safe
        let view = s.intern.view();
        let mut line = String::with_capacity(128);
        for i in 0..body.cols.len() {
            line.clear();
            body.cols.render_row(i, &s.meas, &view, &mut line);
            line.push('\n');
            f.write_all(line.as_bytes())?;
        }
        f.into_inner().map_err(|e| e.into_error())?;
    }
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Point {
        Point::new("fe2ti", 1_000_000_000)
            .tag("node", "icx36")
            .tag("solver", "ilu")
            .field("tts", 40.5)
            .field("gflops", 25.0)
    }

    #[test]
    fn line_protocol_roundtrip() {
        let p = sample();
        let line = p.to_line();
        assert!(line.starts_with("fe2ti,node=icx36,solver=ilu "));
        let q = Point::parse_line(&line).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_escapes_specials() {
        let p = Point::new("m x", 5)
            .tag("k,1", "v 2=3")
            .field("f", 1.0);
        let q = Point::parse_line(&p.to_line()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_negative_timestamp_roundtrip() {
        // timestamps are ns relative to the campaign epoch; pre-epoch
        // imports (e.g. backfilled history) are legitimately negative
        let p = Point::new("m", -1_500_000_000).field("v", 1.0);
        let line = p.to_line();
        assert!(line.ends_with(" -1500000000"));
        assert_eq!(Point::parse_line(&line).unwrap(), p);
        assert_eq!(Point::parse_line("m v=1 -1").unwrap().ts, -1);
    }

    #[test]
    fn line_protocol_escaped_commas_spaces_equals_everywhere() {
        // every syntactic position that the wire format delimits:
        // measurement, tag key, tag value, field key — with every special
        let p = Point::new("mea,su re=ment", 7)
            .tag("tag,key with=all", "va,l ue=x")
            .tag("plain", "v")
            .field("fie,ld key=f", -2.5)
            .field("g", 1e-7);
        let q = Point::parse_line(&p.to_line())
            .unwrap_or_else(|e| panic!("{e}: {}", p.to_line()));
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_backslash_tails_roundtrip() {
        // trailing and doubled backslashes must survive the escape layer
        let p = Point::new("m\\", 1)
            .tag("k\\\\", "v\\")
            .field("f\\", 3.0);
        let q = Point::parse_line(&p.to_line()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_extreme_field_values_roundtrip() {
        // Rust's f64 Display prints the shortest representation that
        // parses back exactly, so numeric round-trips must be lossless
        for v in [
            0.1,
            -0.30000000000000004,
            1.7976931348623157e308,
            5e-324,
            -1234567890.123456,
            0.0,
        ] {
            let p = Point::new("m", 9).field("v", v);
            let q = Point::parse_line(&p.to_line()).unwrap();
            assert_eq!(p, q, "value {v:e}");
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Point::parse_line("nofields 123").is_err());
        assert!(Point::parse_line("m f=1 notanumber").is_err());
        assert!(Point::parse_line("m f=x 1").is_err());
        assert!(Point::parse_line("m").is_err());
    }

    #[test]
    fn db_keeps_time_order() {
        let mut db = Db::new();
        for ts in [5, 1, 3, 2, 4] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let ts: Vec<i64> = db.points_iter("m").map(|p| p.ts).collect();
        assert_eq!(ts, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn db_keeps_time_order_across_shard_boundaries() {
        // span 10: keys ..., -1 => [-10, 0), 0 => [0, 10), 1 => [10, 20)
        let mut db = Db::with_shard_span(10);
        for ts in [25, 3, -7, 14, 9, 10, -10, 19, 0] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let ts: Vec<i64> = db.points_iter("m").map(|p| p.ts).collect();
        assert_eq!(ts, vec![-10, -7, 0, 3, 9, 10, 14, 19, 25]);
        assert_eq!(db.shards("m").len(), 4);
        let keys: Vec<i64> = db.shards("m").iter().map(|s| s.key()).collect();
        assert_eq!(keys, vec![-1, 0, 1, 2]);
        // min/max index of the middle shard
        let s = &db.shards("m")[1];
        assert_eq!((s.min_ts(), s.max_ts()), (Some(0), Some(9)));
        assert_eq!(db.last_point("m").unwrap().ts, 25);
        assert_eq!(db.n_points("m"), 9);
        // reverse iteration streams newest-first across shards
        let rev: Vec<i64> = db.points_iter("m").rev().map(|p| p.ts).collect();
        assert_eq!(rev, vec![25, 19, 14, 10, 9, 3, 0, -7, -10]);
    }

    #[test]
    fn ingest_and_tag_values() {
        let mut db = Db::new();
        let text = "\
# comment
lbm,node=icx36,op=srt mlups=1200 1
lbm,node=icx36,op=trt mlups=1100 2

lbm,node=rome1,op=srt mlups=400 3
";
        assert_eq!(db.ingest_lines(text).unwrap(), 3);
        assert_eq!(db.len(), 3);
        assert_eq!(db.tag_values("lbm", "op"), vec!["srt", "trt"]);
        assert_eq!(db.tag_values("lbm", "node"), vec!["icx36", "rome1"]);
        assert!(db.tag_values("lbm", "missing").is_empty());
    }

    #[test]
    fn points_in_range_binary_search_matches_scan() {
        let mut db = Db::new();
        for ts in [1, 2, 2, 3, 5, 8, 8, 9] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let slice: Vec<&Point> = db.points_in_range("m", Some(2), Some(8)).collect();
        assert_eq!(slice.len(), 6);
        assert_eq!(slice.first().unwrap().ts, 2);
        assert_eq!(slice.last().unwrap().ts, 8);
        assert_eq!(db.points_in_range("m", None, Some(1)).count(), 1);
        assert_eq!(db.points_in_range("m", Some(9), None).count(), 1);
        assert_eq!(db.points_in_range("m", Some(6), Some(7)).count(), 0);
        assert_eq!(db.points_in_range("m", Some(10), None).count(), 0);
        assert_eq!(db.points_in_range("m", None, None).count(), 8);
        assert_eq!(db.points_in_range("nosuch", None, None).count(), 0);
    }

    #[test]
    fn points_in_range_touches_only_overlapping_shards() {
        // spans of 10 over [0, 50): ranges land inside / across shards,
        // and exactly on shard edges — all equivalent to a linear filter
        let mut sharded = Db::with_shard_span(10);
        let mut single = Db::with_shard_span(i64::MAX / 4);
        for ts in 0..50 {
            let p = Point::new("m", ts).field("v", ts as f64);
            sharded.insert(p.clone());
            single.insert(p);
        }
        assert!(sharded.shards("m").len() > 1);
        assert_eq!(single.shards("m").len(), 1);
        for (a, b) in [(0, 49), (5, 25), (10, 19), (9, 10), (19, 20), (30, 30), (48, 200), (-5, 3)] {
            let s1: Vec<i64> = sharded
                .points_in_range("m", Some(a), Some(b))
                .map(|p| p.ts)
                .collect();
            let s2: Vec<i64> = single
                .points_in_range("m", Some(a), Some(b))
                .map(|p| p.ts)
                .collect();
            assert_eq!(s1, s2, "range [{a}, {b}]");
        }
    }

    #[test]
    fn tail_start_ts_counts_distinct_timestamps() {
        let mut db = Db::new();
        // two series reporting at each of 4 pipeline triggers
        for ts in [10, 20, 30, 40] {
            db.insert(Point::new("m", ts).tag("s", "a").field("v", 1.0));
            db.insert(Point::new("m", ts).tag("s", "b").field("v", 2.0));
        }
        assert_eq!(db.tail_start_ts("m", 1), Some(40));
        assert_eq!(db.tail_start_ts("m", 2), Some(30));
        assert_eq!(db.tail_start_ts("m", 4), Some(10));
        // fewer distinct timestamps than requested: earliest
        assert_eq!(db.tail_start_ts("m", 99), Some(10));
        assert_eq!(db.tail_start_ts("m", 0), None);
        assert_eq!(db.tail_start_ts("nosuch", 3), None);
    }

    #[test]
    fn tail_start_ts_crosses_shard_boundaries() {
        let mut db = Db::with_shard_span(10);
        for ts in [5, 15, 25] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        assert_eq!(db.shards("m").len(), 3);
        assert_eq!(db.tail_start_ts("m", 1), Some(25));
        assert_eq!(db.tail_start_ts("m", 2), Some(15));
        assert_eq!(db.tail_start_ts("m", 3), Some(5));
    }

    #[test]
    fn compaction_rolls_up_old_shards_and_keeps_raw_recent() {
        // span 10, points over [0, 35): shards [0,10) [10,20) [20,30)
        // [30,40). retain_raw 10 => watermark 24: shards 0 and 1 compact,
        // shard [20,30) contains ts 24..29 >= watermark side — max_ts 29
        // >= 24 so it stays raw, as does [30,40)
        let mut db = Db::with_shard_span(10);
        for ts in 0..35 {
            for s in ["a", "b"] {
                db.insert(
                    Point::new("m", ts)
                        .tag("s", s)
                        .field("v", ts as f64)
                        .field("w", 2.0 * ts as f64),
                );
            }
        }
        let before = db.len();
        let rep = db.compact(10);
        assert_eq!(rep.points_before, before);
        assert_eq!(rep.shards_compacted, 2);
        // each compacted shard: 2 series => 2 rollup points (was 20)
        assert_eq!(rep.points_after, before - 2 * 20 + 2 * 2);
        assert_eq!(db.len(), rep.points_after);
        let s0 = &db.shards("m")[0];
        assert!(s0.is_compacted());
        assert_eq!(s0.len(), 2);
        let p = &s0.points()[0];
        assert_eq!(p.tags[ROLLUP_TAG], "mean");
        assert_eq!(p.ts, 9, "rollup carries the series' last in-shard ts");
        assert_eq!(p.fields["v"], 4.5, "mean of 0..=9");
        assert_eq!(p.fields["rollup_n"], 10.0);
        // the retained raw window is untouched
        let recent: Vec<i64> = db
            .points_in_range("m", Some(25), Some(34))
            .map(|p| p.ts)
            .collect();
        assert_eq!(recent.len(), 20);
        assert!(db.shards("m")[2].points().iter().all(|p| !p.tags.contains_key(ROLLUP_TAG)));
        // idempotent: a second pass changes nothing
        let rep2 = db.compact(10);
        assert_eq!(rep2.shards_compacted, 0);
        assert_eq!(rep2.points_after, rep2.points_before);
    }

    #[test]
    fn compaction_survives_save_load_roundtrip() {
        let mut db = Db::with_shard_span(10);
        for ts in 0..30 {
            db.insert(Point::new("m", ts).tag("s", "a").field("v", ts as f64));
        }
        db.compact(5);
        let dump_before: Vec<String> = db.points_iter("m").map(|p| p.to_line()).collect();
        let path = std::env::temp_dir().join("cbench_tsdb_compact_roundtrip.lp");
        let _ = std::fs::remove_dir_all(&path);
        db.save(&path).unwrap();
        // the manifest records the store's own span: a plain load keeps it
        let mut back = Db::load(&path).unwrap();
        assert_eq!(back.shard_span(), 10);
        // the compacted flag comes from the manifest, before any body load
        assert!(back.shards("m")[0].is_compacted());
        assert!(!back.shards("m")[0].is_loaded());
        let dump_after: Vec<String> = back.points_iter("m").map(|p| p.to_line()).collect();
        assert_eq!(dump_before, dump_after);
        // reloaded rollup shards are recognized and not re-compacted
        let rep = back.compact(5);
        assert_eq!(rep.shards_compacted, 0);
        assert_eq!(rep.points_after, rep.points_before);
        assert!(back.shards("m")[0].is_compacted());
        std::fs::remove_dir_all(&path).ok();
    }

    #[test]
    fn late_insert_reopens_compacted_shard_and_recompaction_merges_weights() {
        // a raw point landing in rolled-up history must reopen the shard,
        // and the next pass must merge it into the existing rollup
        // weight-correctly (no mean-of-means, no reset raw count)
        let mut db = Db::with_shard_span(10);
        for ts in 0..30 {
            db.insert(Point::new("m", ts).tag("s", "a").field("v", 1.0));
        }
        db.compact(5); // shards [0,10) and [10,20) -> rollups of 10 points
        assert!(db.shards("m")[0].is_compacted());
        assert_eq!(db.shards("m")[0].points()[0].fields["rollup_n"], 10.0);

        // late import: one raw point with a different value into shard 0
        db.insert(Point::new("m", 5).tag("s", "a").field("v", 12.0));
        assert!(!db.shards("m")[0].is_compacted(), "raw insert reopens the shard");
        assert_eq!(db.shards("m")[0].len(), 2);

        let rep = db.compact(5);
        assert_eq!(rep.shards_compacted, 1, "only the reopened shard recompacts");
        let s0 = &db.shards("m")[0];
        assert!(s0.is_compacted());
        assert_eq!(s0.len(), 1, "rollup and late point merge into one series");
        let p = &s0.points()[0];
        assert_eq!(p.fields["rollup_n"], 11.0, "raw count accumulates, not resets");
        // weighted mean: (10 x 1.0 + 1 x 12.0) / 11
        assert!((p.fields["v"] - 2.0).abs() < 1e-12, "got {}", p.fields["v"]);
        assert_eq!(p.ts, 9, "rollup keeps the series' last in-shard ts");
    }

    #[test]
    fn compaction_on_empty_db_is_a_noop() {
        let mut db = Db::new();
        let rep = db.compact(100);
        assert_eq!(rep, CompactionReport::default());
    }

    #[test]
    fn save_load_roundtrip() {
        let mut db = Db::new();
        db.insert(sample());
        db.insert(Point::new("lbm", 7).tag("op", "srt").field("mlups", 900.0));
        let path = std::env::temp_dir().join("cbench_tsdb_test.lp");
        let _ = std::fs::remove_dir_all(&path);
        db.save(&path).unwrap();
        assert!(path.join(MANIFEST_FILE).is_file(), "manifest layout");
        let back = Db::load(&path).unwrap();
        // the index answers without materializing anything
        assert_eq!(back.len(), 2);
        assert_eq!(back.n_points("lbm"), 1);
        assert!(back.shards("lbm").iter().all(|s| !s.is_loaded()));
        assert_eq!(back.points_iter("fe2ti").next().unwrap(), &sample());
        std::fs::remove_dir_all(&path).ok();
    }

    /// Unique temp dir per test: tests run concurrently.
    fn tmp_store(name: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("cbench_tsdb_{name}"));
        let _ = std::fs::remove_dir_all(&p);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn deep_db(span: i64, n: i64) -> Db {
        let mut db = Db::with_shard_span(span);
        for ts in 0..n {
            for s in ["a", "b"] {
                db.insert(Point::new("m", ts).tag("s", s).field("v", ts as f64));
            }
        }
        db
    }

    #[test]
    fn manifest_load_is_lazy_and_queries_materialize_only_touched_shards() {
        let mut db = deep_db(10, 100); // 10 shards
        let path = tmp_store("lazy");
        db.save(&path).unwrap();
        let back = Db::load(&path).unwrap();
        assert_eq!(back.shards("m").len(), 10);
        assert!(back.shards("m").iter().all(|s| !s.is_loaded()), "load parses no bodies");
        // meta answers without materialization
        assert_eq!(back.len(), 200);
        assert_eq!(back.newest_ts(), Some(99));
        assert_eq!(back.shards("m")[3].min_ts(), Some(30));
        assert!(back.shards("m").iter().all(|s| !s.is_loaded()));
        // a mid-history range query touches exactly the overlapping shards
        let hits: Vec<i64> = back.points_in_range("m", Some(42), Some(57)).map(|p| p.ts).collect();
        assert_eq!(hits.len(), 2 * 16);
        let loaded: Vec<i64> = back
            .shards("m")
            .iter()
            .filter(|s| s.is_loaded())
            .map(|s| s.key())
            .collect();
        assert_eq!(loaded, vec![4, 5], "only the window's shards were parsed");
        // a tail walk parses from the newest shard backwards only
        assert_eq!(back.tail_start_ts("m", 3), Some(97));
        assert!(back.shards("m")[9].is_loaded());
        assert!(!back.shards("m")[0].is_loaded(), "cold history stays cold");
    }

    #[test]
    fn lru_eviction_caps_loaded_bodies_and_reloads_lazily() {
        let mut db = deep_db(10, 100); // 10 shards
        let path = tmp_store("lru");
        db.save(&path).unwrap();
        let mut back = Db::load(&path).unwrap();
        assert_eq!(back.loaded_bodies(), 0);

        // materialize every shard, oldest-to-newest touch order
        let n: usize = back.points_iter("m").count();
        assert_eq!(n, 200);
        assert_eq!(back.loaded_bodies(), 10);

        // explicit eviction keeps the most recently touched bodies
        let evicted = back.evict_cold_bodies(3);
        assert_eq!(evicted, 7);
        assert_eq!(back.loaded_bodies(), 3);
        let loaded: Vec<i64> = back
            .shards("m")
            .iter()
            .filter(|s| s.is_loaded())
            .map(|s| s.key())
            .collect();
        assert_eq!(loaded, vec![7, 8, 9], "LRU keeps the newest-touched shards");

        // evicted bodies re-materialize lazily, byte-identical
        let hits: Vec<i64> = back.points_in_range("m", Some(12), Some(13)).map(|p| p.ts).collect();
        assert_eq!(hits, vec![12, 12, 13, 13]);
        assert_eq!(back.loaded_bodies(), 4);
        assert!(back.shards("m")[1].evicted.load(Ordering::Relaxed));

        // with a cap set, the mutating path holds it automatically
        back.set_body_cap(Some(2));
        assert!(back.loaded_bodies() <= 2);
        for _ in back.points_in_range("m", Some(0), Some(49)) {} // warm 5 shards
        assert!(back.loaded_bodies() > 2, "read path does not evict");
        back.insert(Point::new("m", 99).tag("s", "x").field("v", 1.0));
        assert!(back.loaded_bodies() <= 3, "insert path enforces the cap");

        // dirty bodies are never evicted: the shard just inserted into
        // must survive an aggressive eviction pass
        let dirty_key = 99i64.div_euclid(10);
        back.evict_cold_bodies(0);
        let still: Vec<i64> = back
            .shards("m")
            .iter()
            .filter(|s| s.is_loaded())
            .map(|s| s.key())
            .collect();
        assert_eq!(still, vec![dirty_key], "only the dirty shard stays");
        // the store still saves correctly after evictions
        back.save(&path).unwrap();
        let again = Db::load(&path).unwrap();
        assert_eq!(again.len(), 201);
        std::fs::remove_dir_all(&path).ok();
    }

    #[test]
    fn incremental_save_rewrites_only_dirty_shards() {
        let mut db = deep_db(10, 50); // 5 shards
        let path = tmp_store("dirty");
        let rep = db.save_report(&path).unwrap();
        assert_eq!(rep, PersistReport { shards_written: 5, shards_kept: 0 });
        // a no-op save keeps every shard in place
        let rep = db.save_report(&path).unwrap();
        assert_eq!(rep, PersistReport { shards_written: 0, shards_kept: 5 });

        // prove the skip is real: delete a cold shard's backing file —
        // an incremental save must not need (or recreate) it
        let cold = db.shards("m")[1].file_name().unwrap().to_string();
        std::fs::remove_file(path.join(&cold)).unwrap();
        db.insert(Point::new("m", 49).tag("s", "late").field("v", 1.0)); // newest shard only
        let rep = db.save_report(&path).unwrap();
        assert_eq!(rep, PersistReport { shards_written: 1, shards_kept: 4 });
        assert!(!path.join(&cold).exists(), "cold shard was never rewritten");

        // the same save through a reloaded handle is also incremental
        std::fs::remove_dir_all(&path).ok();
        let mut db = deep_db(10, 50);
        db.save(&path).unwrap();
        let mut back = Db::load(&path).unwrap();
        back.insert(Point::new("m", 5).tag("s", "late").field("v", 2.0)); // reopen shard 0
        let rep = back.save_report(&path).unwrap();
        assert_eq!(rep, PersistReport { shards_written: 1, shards_kept: 4 });
        // saving a loaded store to a DIFFERENT directory copies everything
        let copy = tmp_store("dirty_copy");
        let rep = back.save_report(&copy).unwrap();
        assert_eq!(rep.shards_written, 5);
        // ...and rebinds: the copy is now the incremental home
        let rep = back.save_report(&copy).unwrap();
        assert_eq!(rep, PersistReport { shards_written: 0, shards_kept: 5 });
        std::fs::remove_dir_all(&path).ok();
        std::fs::remove_dir_all(&copy).ok();
    }

    #[test]
    fn legacy_single_file_migrates_on_first_save_and_roundtrips() {
        // write the pre-manifest format by hand
        let mut db = deep_db(10, 30);
        db.compact(4); // shards [0,10) and [10,20) roll up
        let legacy = tmp_store("legacy");
        db.export_lp(&legacy).unwrap();
        assert!(legacy.is_file());
        let legacy_bytes = std::fs::read_to_string(&legacy).unwrap();

        // loading the legacy file parses it whole and leaves it untouched
        let mut back = Db::load(&legacy).unwrap();
        assert!(legacy.is_file(), "old file untouched until first save");
        assert!(back.shards("m")[0].is_compacted(), "rollup marker re-detected");
        let dump: Vec<String> = back.points_iter("m").map(|p| p.to_line()).collect();

        // the first save migrates the layout in place: file -> directory
        back.save(&legacy).unwrap();
        assert!(legacy.is_dir());
        assert!(legacy.join(MANIFEST_FILE).is_file());
        let again = Db::load(&legacy).unwrap();
        assert!(again.shards("m")[0].is_compacted());
        let dump2: Vec<String> = again.points_iter("m").map(|p| p.to_line()).collect();
        assert_eq!(dump, dump2, "migration preserves contents byte-identically");
        // export brings back the exact legacy bytes (stable dump order)
        let exported = tmp_store("legacy_export");
        again.export_lp(&exported).unwrap();
        assert_eq!(std::fs::read_to_string(&exported).unwrap(), legacy_bytes);
        // idempotent: an unchanged reloaded store saves zero shards
        let mut again = again;
        let rep = again.save_report(&legacy).unwrap();
        assert_eq!(rep.shards_written, 0);
        std::fs::remove_dir_all(&legacy).ok();
        std::fs::remove_file(&exported).ok();
    }

    #[test]
    fn stray_tmp_files_are_ignored_and_cleaned_on_load() {
        let mut db = deep_db(10, 20);
        let path = tmp_store("straytmp");
        db.save(&path).unwrap();
        // a crash between renames leaves .tmp siblings behind
        std::fs::write(path.join("m-k0.lp.tmp"), "garbage that must not be parsed").unwrap();
        std::fs::write(path.join(format!("{MANIFEST_FILE}.tmp")), "{half a manifest").unwrap();
        let back = Db::load(&path).unwrap();
        assert_eq!(back.len(), 40, "load ignores stray .tmp files");
        assert!(!path.join("m-k0.lp.tmp").exists(), "stray shard tmp cleaned");
        assert!(!path.join(format!("{MANIFEST_FILE}.tmp")).exists(), "stray manifest tmp cleaned");
        // foreign .lp files in a bound store are dropped by the next save
        // (the manifest is authoritative)
        std::fs::write(path.join("orphan.lp"), "m v=1 1\n").unwrap();
        let mut back = back;
        back.insert(Point::new("m", 19).field("v", 9.0));
        back.save(&path).unwrap();
        assert!(!path.join("orphan.lp").exists());
        std::fs::remove_dir_all(&path).ok();
    }

    #[test]
    fn load_with_differing_span_repartitions() {
        let mut db = deep_db(10, 40);
        let path = tmp_store("respan");
        db.save(&path).unwrap();
        // matching span: lazy, same layout
        let lazy = Db::load_with_shard_span(&path, 10).unwrap();
        assert_eq!(lazy.shards("m").len(), 4);
        assert!(lazy.shards("m").iter().all(|s| !s.is_loaded()));
        // differing span: repartitioned (a full-copy operation)
        let wide = Db::load_with_shard_span(&path, 20).unwrap();
        assert_eq!(wide.shards("m").len(), 2);
        let a: Vec<String> = lazy.points_iter("m").map(|p| p.to_line()).collect();
        let b: Vec<String> = wide.points_iter("m").map(|p| p.to_line()).collect();
        assert_eq!(a, b, "re-sharding preserves contents and order");
        std::fs::remove_dir_all(&path).ok();
    }

    #[test]
    fn manifest_roundtrips_negative_shard_keys_and_odd_measurement_names() {
        let mut db = Db::with_shard_span(10);
        db.insert(Point::new("m x,y=z", -25).tag("t", "v").field("f", 1.5));
        db.insert(Point::new("m x,y=z", 7).field("f", 2.5));
        let path = tmp_store("oddnames");
        db.save(&path).unwrap();
        let back = Db::load(&path).unwrap();
        let keys: Vec<i64> = back.shards("m x,y=z").iter().map(|s| s.key()).collect();
        assert_eq!(keys, vec![-3, 0]);
        assert_eq!(back.shards("m x,y=z")[0].min_ts(), Some(-25));
        let pts: Vec<i64> = back.points_iter("m x,y=z").map(|p| p.ts).collect();
        assert_eq!(pts, vec![-25, 7]);
        std::fs::remove_dir_all(&path).ok();
    }

    #[test]
    fn load_rejects_missing_shard_file_and_bad_manifest() {
        let mut db = deep_db(10, 20);
        let path = tmp_store("missing");
        db.save(&path).unwrap();
        let victim = db.shards("m")[0].file_name().unwrap().to_string();
        std::fs::remove_file(path.join(&victim)).unwrap();
        assert!(Db::load(&path).is_err(), "missing shard file fails the load eagerly");
        std::fs::write(path.join(MANIFEST_FILE), "not json").unwrap();
        assert!(Db::load(&path).is_err());
        // a directory without a manifest is not silently treated as empty
        let empty = tmp_store("nomanifest");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(Db::load(&empty).is_err());
        std::fs::remove_dir_all(&path).ok();
        std::fs::remove_dir_all(&empty).ok();
    }

    #[test]
    fn rewritten_shards_get_fresh_names_so_the_old_manifest_stays_valid() {
        // crash-atomicity: an incremental rewrite must never overwrite a
        // file the committed manifest references — the new content goes
        // to a fresh name, and the superseded file is dropped only after
        // the manifest rename
        let mut db = deep_db(10, 30);
        let path = tmp_store("freshnames");
        db.save(&path).unwrap();
        let old_name = db.shards("m")[2].file_name().unwrap().to_string();
        db.insert(Point::new("m", 25).tag("s", "late").field("v", 1.0));
        let rep = db.save_report(&path).unwrap();
        assert_eq!(rep.shards_written, 1);
        let new_name = db.shards("m")[2].file_name().unwrap().to_string();
        assert_ne!(old_name, new_name, "rewrite must not reuse the live file name");
        assert!(!path.join(&old_name).exists(), "superseded file dropped post-commit");
        assert!(path.join(&new_name).is_file());
        // the reloaded store agrees with memory
        let back = Db::load(&path).unwrap();
        assert_eq!(back.n_points("m"), 61);
        std::fs::remove_dir_all(&path).ok();
    }

    #[test]
    fn crashed_legacy_migration_recovers_from_the_bak_sibling() {
        // simulate a crash after the legacy file was parked aside but
        // before the manifest committed: a half-built directory plus the
        // .legacy.bak sibling. Loads must fall back to the preserved file.
        let mut db = deep_db(10, 20);
        let legacy = tmp_store("migrecover");
        db.export_lp(&legacy).unwrap();
        let bak = {
            let mut os = legacy.as_os_str().to_os_string();
            os.push(".legacy.bak");
            std::path::PathBuf::from(os)
        };
        let _ = std::fs::remove_file(&bak);
        std::fs::rename(&legacy, &bak).unwrap();
        std::fs::create_dir_all(&legacy).unwrap(); // half-built, no manifest
        let mut back = Db::load(&legacy).unwrap();
        assert_eq!(back.len(), 40, "recovered from the .legacy.bak sibling");
        // a successful save completes the migration and clears the bak
        std::fs::remove_dir_all(&legacy).unwrap();
        back.save(&legacy).unwrap();
        assert!(legacy.join(MANIFEST_FILE).is_file());
        assert!(!bak.exists(), "bak removed once the migration committed");
        std::fs::remove_dir_all(&legacy).ok();
    }

    #[test]
    fn late_insert_into_cold_shard_materializes_and_dirties_only_it() {
        let mut db = deep_db(10, 50);
        let path = tmp_store("lateinsert");
        db.save(&path).unwrap();
        let mut back = Db::load(&path).unwrap();
        back.insert(Point::new("m", 12).tag("s", "late").field("v", 0.5));
        let loaded: Vec<i64> = back
            .shards("m")
            .iter()
            .filter(|s| s.is_loaded())
            .map(|s| s.key())
            .collect();
        assert_eq!(loaded, vec![1], "only the target shard materialized");
        let dirty: Vec<i64> = back
            .shards("m")
            .iter()
            .filter(|s| s.is_dirty())
            .map(|s| s.key())
            .collect();
        assert_eq!(dirty, vec![1]);
        assert_eq!(back.n_points("m"), 101);
        std::fs::remove_dir_all(&path).ok();
    }
}
