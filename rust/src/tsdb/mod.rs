//! InfluxDB stand-in: a time-series database with tags, fields and a
//! line-protocol wire format.
//!
//! The paper stores every benchmark result in InfluxDB (§4.3): *fields*
//! carry the runtime metrics (TTS, FLOP count, traffic), *tags* carry the
//! metadata (domain size, solver, compute node), and the pipeline trigger
//! time is the timestamp. Grafana then queries grouped-by-tag series.
//! This module implements that data model from scratch:
//!
//! * [`Point`] — measurement + tags + fields + nanosecond timestamp,
//! * line protocol encode/parse ([`Point::to_line`], [`Point::parse_line`]),
//! * [`Db`] — an in-memory engine with optional file persistence,
//! * [`Query`] — tag filters, time range, field selection, group-by-tags,
//!   and the aggregations the dashboards use (last/mean/min/max).

pub mod query;

pub use query::{Aggregate, GroupedSeries, Query};

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// One data point.
#[derive(Debug, Clone, PartialEq)]
pub struct Point {
    pub measurement: String,
    pub tags: BTreeMap<String, String>,
    pub fields: BTreeMap<String, f64>,
    /// Nanoseconds since campaign epoch.
    pub ts: i64,
}

impl Point {
    pub fn new(measurement: &str, ts: i64) -> Point {
        Point {
            measurement: measurement.to_string(),
            tags: BTreeMap::new(),
            fields: BTreeMap::new(),
            ts,
        }
    }
    pub fn tag(mut self, k: &str, v: &str) -> Point {
        self.tags.insert(k.to_string(), v.to_string());
        self
    }
    pub fn field(mut self, k: &str, v: f64) -> Point {
        self.fields.insert(k.to_string(), v);
        self
    }

    /// Influx line protocol: `measurement,tag=v,... field=v,... ts`.
    /// Spaces/commas in tag values are escaped with `\`.
    pub fn to_line(&self) -> String {
        let esc = |s: &str| s.replace('\\', "\\\\").replace(',', "\\,").replace(' ', "\\ ").replace('=', "\\=");
        let mut line = esc(&self.measurement);
        for (k, v) in &self.tags {
            line.push(',');
            line.push_str(&esc(k));
            line.push('=');
            line.push_str(&esc(v));
        }
        line.push(' ');
        let fields: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("{}={v}", esc(k)))
            .collect();
        line.push_str(&fields.join(","));
        line.push(' ');
        line.push_str(&self.ts.to_string());
        line
    }

    /// Parse one line-protocol line.
    pub fn parse_line(line: &str) -> Result<Point, String> {
        // split into 3 sections on unescaped spaces
        let mut sections: Vec<String> = Vec::new();
        let mut cur = String::new();
        let mut esc = false;
        for c in line.chars() {
            if esc {
                cur.push(c);
                esc = false;
            } else if c == '\\' {
                cur.push(c);
                esc = true;
            } else if c == ' ' && sections.len() < 2 {
                sections.push(std::mem::take(&mut cur));
            } else {
                cur.push(c);
            }
        }
        sections.push(cur);
        if sections.len() != 3 {
            return Err(format!("expected 3 sections, got {}", sections.len()));
        }
        let unesc = |s: &str| -> String {
            let mut out = String::new();
            let mut esc = false;
            for c in s.chars() {
                if esc {
                    out.push(c);
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else {
                    out.push(c);
                }
            }
            out
        };
        // measurement + tags: split on unescaped commas
        let split_unescaped = |s: &str, sep: char| -> Vec<String> {
            let mut parts = Vec::new();
            let mut cur = String::new();
            let mut esc = false;
            for c in s.chars() {
                if esc {
                    cur.push(c);
                    esc = false;
                } else if c == '\\' {
                    cur.push(c);
                    esc = true;
                } else if c == sep {
                    parts.push(std::mem::take(&mut cur));
                } else {
                    cur.push(c);
                }
            }
            parts.push(cur);
            parts
        };
        let head = split_unescaped(&sections[0], ',');
        let mut p = Point::new(&unesc(&head[0]), 0);
        for t in &head[1..] {
            let kv = split_unescaped(t, '=');
            if kv.len() != 2 {
                return Err(format!("bad tag `{t}`"));
            }
            p.tags.insert(unesc(&kv[0]), unesc(&kv[1]));
        }
        for f in split_unescaped(&sections[1], ',') {
            let kv = split_unescaped(&f, '=');
            if kv.len() != 2 {
                return Err(format!("bad field `{f}`"));
            }
            let v: f64 = kv[1].parse().map_err(|_| format!("bad field value `{}`", kv[1]))?;
            p.fields.insert(unesc(&kv[0]), v);
        }
        p.ts = sections[2]
            .trim()
            .parse()
            .map_err(|_| format!("bad timestamp `{}`", sections[2]))?;
        if p.fields.is_empty() {
            return Err("point has no fields".into());
        }
        Ok(p)
    }
}

/// The storage engine: points per measurement, kept time-ordered.
#[derive(Debug, Default)]
pub struct Db {
    measurements: BTreeMap<String, Vec<Point>>,
}

impl Db {
    pub fn new() -> Db {
        Db::default()
    }

    /// Insert one point (keeps the measurement time-sorted).
    pub fn insert(&mut self, p: Point) {
        let v = self.measurements.entry(p.measurement.clone()).or_default();
        // common case: appended in time order
        if v.last().map(|l| l.ts <= p.ts).unwrap_or(true) {
            v.push(p);
        } else {
            let idx = v.partition_point(|q| q.ts <= p.ts);
            v.insert(idx, p);
        }
    }

    /// Ingest a batch of line-protocol text (the pipeline's upload step).
    pub fn ingest_lines(&mut self, text: &str) -> Result<usize, String> {
        let mut n = 0;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            self.insert(Point::parse_line(line)?);
            n += 1;
        }
        Ok(n)
    }

    pub fn measurements(&self) -> impl Iterator<Item = &String> {
        self.measurements.keys()
    }

    pub fn len(&self) -> usize {
        self.measurements.values().map(|v| v.len()).sum()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn points(&self, measurement: &str) -> &[Point] {
        self.measurements
            .get(measurement)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Points of `measurement` within the inclusive `[t_min, t_max]`
    /// window, located by binary search on the time-sorted storage —
    /// the pushdown behind [`Query::range`], O(log n + hits) instead of
    /// a full scan.
    pub fn points_in_range(
        &self,
        measurement: &str,
        t_min: Option<i64>,
        t_max: Option<i64>,
    ) -> &[Point] {
        let pts = self.points(measurement);
        let lo = t_min.map(|t| pts.partition_point(|p| p.ts < t)).unwrap_or(0);
        let hi = t_max
            .map(|t| pts.partition_point(|p| p.ts <= t))
            .unwrap_or(pts.len());
        if lo >= hi {
            &[]
        } else {
            &pts[lo..hi]
        }
    }

    /// Timestamp at which the trailing `n` *distinct* timestamps of
    /// `measurement` begin — the pushdown bound behind [`Query::tail`].
    /// CB uploads one point per live series per pipeline trigger, so the
    /// walk from the end touches O(n × series) points regardless of how
    /// many years of history sit in front. Returns `None` for an empty
    /// measurement or `n == 0`; with fewer than `n` distinct timestamps
    /// it returns the earliest one.
    pub fn tail_start_ts(&self, measurement: &str, n: usize) -> Option<i64> {
        if n == 0 {
            return None;
        }
        let pts = self.points(measurement);
        let mut distinct = 0usize;
        let mut last: Option<i64> = None;
        for p in pts.iter().rev() {
            if last != Some(p.ts) {
                distinct += 1;
                last = Some(p.ts);
                if distinct == n {
                    return last;
                }
            }
        }
        last
    }

    /// All distinct values of `tag` within a measurement — powers the
    /// dashboard template-variable dropdowns (the "collision Setup menu").
    pub fn tag_values(&self, measurement: &str, tag: &str) -> Vec<String> {
        let mut vals: Vec<String> = self
            .points(measurement)
            .iter()
            .filter_map(|p| p.tags.get(tag).cloned())
            .collect();
        vals.sort();
        vals.dedup();
        vals
    }

    /// Persist as line protocol.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for pts in self.measurements.values() {
            for p in pts {
                writeln!(f, "{}", p.to_line())?;
            }
        }
        Ok(())
    }

    /// Load from a line-protocol file.
    pub fn load(path: &Path) -> std::io::Result<Db> {
        let text = std::fs::read_to_string(path)?;
        let mut db = Db::new();
        db.ingest_lines(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        Ok(db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Point {
        Point::new("fe2ti", 1_000_000_000)
            .tag("node", "icx36")
            .tag("solver", "ilu")
            .field("tts", 40.5)
            .field("gflops", 25.0)
    }

    #[test]
    fn line_protocol_roundtrip() {
        let p = sample();
        let line = p.to_line();
        assert!(line.starts_with("fe2ti,node=icx36,solver=ilu "));
        let q = Point::parse_line(&line).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_escapes_specials() {
        let p = Point::new("m x", 5)
            .tag("k,1", "v 2=3")
            .field("f", 1.0);
        let q = Point::parse_line(&p.to_line()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_negative_timestamp_roundtrip() {
        // timestamps are ns relative to the campaign epoch; pre-epoch
        // imports (e.g. backfilled history) are legitimately negative
        let p = Point::new("m", -1_500_000_000).field("v", 1.0);
        let line = p.to_line();
        assert!(line.ends_with(" -1500000000"));
        assert_eq!(Point::parse_line(&line).unwrap(), p);
        assert_eq!(Point::parse_line("m v=1 -1").unwrap().ts, -1);
    }

    #[test]
    fn line_protocol_escaped_commas_spaces_equals_everywhere() {
        // every syntactic position that the wire format delimits:
        // measurement, tag key, tag value, field key — with every special
        let p = Point::new("mea,su re=ment", 7)
            .tag("tag,key with=all", "va,l ue=x")
            .tag("plain", "v")
            .field("fie,ld key=f", -2.5)
            .field("g", 1e-7);
        let q = Point::parse_line(&p.to_line())
            .unwrap_or_else(|e| panic!("{e}: {}", p.to_line()));
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_backslash_tails_roundtrip() {
        // trailing and doubled backslashes must survive the escape layer
        let p = Point::new("m\\", 1)
            .tag("k\\\\", "v\\")
            .field("f\\", 3.0);
        let q = Point::parse_line(&p.to_line()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn line_protocol_extreme_field_values_roundtrip() {
        // Rust's f64 Display prints the shortest representation that
        // parses back exactly, so numeric round-trips must be lossless
        for v in [
            0.1,
            -0.30000000000000004,
            1.7976931348623157e308,
            5e-324,
            -1234567890.123456,
            0.0,
        ] {
            let p = Point::new("m", 9).field("v", v);
            let q = Point::parse_line(&p.to_line()).unwrap();
            assert_eq!(p, q, "value {v:e}");
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Point::parse_line("nofields 123").is_err());
        assert!(Point::parse_line("m f=1 notanumber").is_err());
        assert!(Point::parse_line("m f=x 1").is_err());
        assert!(Point::parse_line("m").is_err());
    }

    #[test]
    fn db_keeps_time_order() {
        let mut db = Db::new();
        for ts in [5, 1, 3, 2, 4] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let ts: Vec<i64> = db.points("m").iter().map(|p| p.ts).collect();
        assert_eq!(ts, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn ingest_and_tag_values() {
        let mut db = Db::new();
        let text = "\
# comment
lbm,node=icx36,op=srt mlups=1200 1
lbm,node=icx36,op=trt mlups=1100 2

lbm,node=rome1,op=srt mlups=400 3
";
        assert_eq!(db.ingest_lines(text).unwrap(), 3);
        assert_eq!(db.len(), 3);
        assert_eq!(db.tag_values("lbm", "op"), vec!["srt", "trt"]);
        assert_eq!(db.tag_values("lbm", "node"), vec!["icx36", "rome1"]);
        assert!(db.tag_values("lbm", "missing").is_empty());
    }

    #[test]
    fn points_in_range_binary_search_matches_scan() {
        let mut db = Db::new();
        for ts in [1, 2, 2, 3, 5, 8, 8, 9] {
            db.insert(Point::new("m", ts).field("v", ts as f64));
        }
        let slice = db.points_in_range("m", Some(2), Some(8));
        assert_eq!(slice.len(), 6);
        assert_eq!(slice.first().unwrap().ts, 2);
        assert_eq!(slice.last().unwrap().ts, 8);
        assert_eq!(db.points_in_range("m", None, Some(1)).len(), 1);
        assert_eq!(db.points_in_range("m", Some(9), None).len(), 1);
        assert!(db.points_in_range("m", Some(6), Some(7)).is_empty());
        assert!(db.points_in_range("m", Some(10), None).is_empty());
        assert_eq!(db.points_in_range("m", None, None).len(), 8);
        assert!(db.points_in_range("nosuch", None, None).is_empty());
    }

    #[test]
    fn tail_start_ts_counts_distinct_timestamps() {
        let mut db = Db::new();
        // two series reporting at each of 4 pipeline triggers
        for ts in [10, 20, 30, 40] {
            db.insert(Point::new("m", ts).tag("s", "a").field("v", 1.0));
            db.insert(Point::new("m", ts).tag("s", "b").field("v", 2.0));
        }
        assert_eq!(db.tail_start_ts("m", 1), Some(40));
        assert_eq!(db.tail_start_ts("m", 2), Some(30));
        assert_eq!(db.tail_start_ts("m", 4), Some(10));
        // fewer distinct timestamps than requested: earliest
        assert_eq!(db.tail_start_ts("m", 99), Some(10));
        assert_eq!(db.tail_start_ts("m", 0), None);
        assert_eq!(db.tail_start_ts("nosuch", 3), None);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut db = Db::new();
        db.insert(sample());
        db.insert(Point::new("lbm", 7).tag("op", "srt").field("mlups", 900.0));
        let path = std::env::temp_dir().join("cbench_tsdb_test.lp");
        db.save(&path).unwrap();
        let back = Db::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.points("fe2ti")[0], sample());
        std::fs::remove_file(&path).ok();
    }
}
